"""End-to-end driver: distributed GraphSAGE training with RapidGNN on the
Reddit-statistics benchmark graph, a few hundred steps (assignment
deliverable b; the paper's kind is training).

Runs the full pipeline -- deterministic schedule, hot-cache VectorPull,
threaded prefetcher, AdamW training, checkpointing -- and reports the
paper's headline metrics against the on-demand baseline.

  PYTHONPATH=src python examples/train_gnn_end_to_end.py [--steps 300]
"""
import argparse
import time

import jax
import numpy as np

from repro.graph import load_dataset, partition_graph, KHopSampler
from repro.core import (build_schedule, ShardedFeatureStore,
                        RapidGNNRunner, BaselineRunner, NetworkModel)
from repro.models import (GNNConfig, init_params, make_train_step,
                          batch_to_device)
from repro.train import AdamW, save_checkpoint

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--dataset", default="reddit_sim")
ap.add_argument("--batch-size", type=int, default=256)
ap.add_argument("--workers", type=int, default=4)
ap.add_argument("--hidden", type=int, default=256)
ap.add_argument("--ckpt", default="/tmp/rapidgnn_ckpt")
args = ap.parse_args()

g = load_dataset(args.dataset)
pg = partition_graph(g, args.workers, "metis")
sampler = KHopSampler(g, fanouts=[25, 10], batch_size=args.batch_size)

# enough epochs to cover the requested step count
train_nodes = pg.local_nodes[0][g.train_mask[pg.local_nodes[0]]]
steps_per_epoch = max(len(train_nodes) // args.batch_size, 1)
epochs = max(args.steps // steps_per_epoch, 1)
print(f"{args.dataset}: {g.num_nodes} nodes, {g.num_edges / 1e6:.1f}M "
      f"edges; {steps_per_epoch} steps/epoch x {epochs} epochs")

ws = build_schedule(sampler, pg, worker=0, s0=42, num_epochs=epochs,
                    n_hot=32768)

cfg = GNNConfig(kind="sage", in_dim=g.feat_dim, hidden_dim=args.hidden,
                num_classes=g.num_classes, num_layers=2)
params = init_params(cfg, jax.random.key(0))
n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
print(f"GraphSAGE params: {n_params / 1e6:.2f}M, hidden {args.hidden}")

opt = AdamW(lr=3e-3, weight_decay=1e-4)
state = {"p": params, "o": opt.init(params), "loss": [], "acc": []}
step = make_train_step(cfg, opt)


def train_fn(feats, cb):
    state["p"], state["o"], aux = step(state["p"], state["o"],
                                       batch_to_device(cb, feats))
    state["loss"].append(float(aux["loss"]))
    state["acc"].append(float(aux["acc"]))
    n = len(state["loss"])
    if n % 25 == 0:
        print(f"  step {n:4d}  loss {state['loss'][-1]:.3f}  "
              f"acc {state['acc'][-1]:.3f}")
    return state["loss"][-1]


print("\n== RapidGNN ==")
store = ShardedFeatureStore(pg, worker=0, net=NetworkModel(enabled=True))
t0 = time.time()
m = RapidGNNRunner(ws, store, batch_size=args.batch_size, Q=4,
                   train_fn=train_fn).run()
rapid_t = time.time() - t0
rt = m.totals()
save_checkpoint(args.ckpt, state["p"], step=len(state["loss"]))

print("\n== on-demand baseline (no train, fetch path only) ==")
store_b = ShardedFeatureStore(pg, worker=0, net=NetworkModel(enabled=True))
t0 = time.time()
b = BaselineRunner(ws, store_b, batch_size=args.batch_size).run()
base_t = time.time() - t0
bt = b.totals()

steps = len(state["loss"])
print(f"\ntrained {steps} steps in {rapid_t:.1f}s "
      f"({1e3 * rapid_t / steps:.0f} ms/step)")
print(f"loss {state['loss'][0]:.3f} -> {state['loss'][-1]:.3f};  "
      f"acc {state['acc'][0]:.3f} -> {state['acc'][-1]:.3f}")
print(f"cache hit rate {rt['hit_rate']:.1%}")
print(f"remote fetches: baseline {bt['rpc_count']:.0f} vs "
      f"rapidgnn {rt['rpc_count']:.0f} "
      f"({bt['rpc_count'] / max(rt['rpc_count'], 1):.1f}x fewer)")
print(f"critical-path fetch stall: baseline {bt['fetch_stall_s']:.2f}s vs "
      f"rapidgnn {rt['fetch_stall_s']:.2f}s")
print(f"checkpoint: {args.ckpt}")
assert state["loss"][-1] < state["loss"][0]
print("OK")
