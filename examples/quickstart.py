"""Quickstart: RapidGNN's full pipeline on a synthetic graph in ~30 s.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.graph import load_dataset, partition_graph, KHopSampler
from repro.core import (build_schedule, ShardedFeatureStore,
                        RapidGNNRunner, BaselineRunner, NetworkModel)
from repro.models import (GNNConfig, init_params, make_train_step,
                          batch_to_device)
from repro.train import AdamW

# 1. a partitioned graph (the paper's setting: 4 workers, edge-cut parts)
g = load_dataset("tiny")
pg = partition_graph(g, num_parts=4, method="metis")
print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges; "
      f"edge-cut {pg.edge_cut_fraction():.2f}")

# 2. deterministic schedule: every batch of every epoch enumerated OFFLINE
sampler = KHopSampler(g, fanouts=[25, 10], batch_size=64)
ws = build_schedule(sampler, pg, worker=0, s0=42, num_epochs=3,
                    n_hot=256)
es = ws.epoch(0)
print(f"epoch 0: {es.num_batches} batches, {es.remote_ids.size} unique "
      f"remote nodes, hot-set {es.cache_ids.size}")

# 3. a GraphSAGE model + optimizer (all from scratch, pure JAX)
cfg = GNNConfig(kind="sage", in_dim=g.feat_dim, hidden_dim=64,
                num_classes=g.num_classes, num_layers=2)
params = init_params(cfg, jax.random.key(0))
opt = AdamW(lr=3e-3)
state = {"p": params, "o": opt.init(params), "hist": []}
step = make_train_step(cfg, opt)


def train_fn(feats, cb):
    state["p"], state["o"], aux = step(state["p"], state["o"],
                                       batch_to_device(cb, feats))
    state["hist"].append(float(aux["acc"]))
    return float(aux["loss"])


# 4. run RapidGNN (cache + prefetch) and the DGL-style baseline
net = NetworkModel(enabled=True)        # modelled 10 GbE
store = ShardedFeatureStore(pg, worker=0, net=net)
r = RapidGNNRunner(ws, store, batch_size=64, Q=4, train_fn=train_fn).run()
rt = r.totals()

store_b = ShardedFeatureStore(pg, worker=0, net=NetworkModel(enabled=True))
b = BaselineRunner(ws, store_b, batch_size=64).run()
bt = b.totals()

print(f"\naccuracy:   {state['hist'][0]:.2f} -> {state['hist'][-1]:.2f}")
print(f"cache hit rate:        {rt['hit_rate']:.1%}")
print(f"remote fetch reduction: {bt['rpc_count'] / max(rt['rpc_count'], 1):.1f}x")
print(f"bytes moved: baseline {bt['remote_bytes']/1e6:.1f} MB vs "
      f"rapidgnn {(rt['remote_bytes'] + rt['vector_pull_bytes'])/1e6:.1f} MB")
assert state["hist"][-1] > state["hist"][0]
print("OK")
