"""Batched transformer-decode demo: greedy decode on any assigned
architecture's reduced config, exercising the KV-cache / ring-buffer /
recurrent decode paths. For the repo's GNN serving path (admission
queue, hot-cache assembly, degradation tiers) see
``python -m repro.launch.serve_gnn``.

  PYTHONPATH=src python examples/serve_batched.py --arch recurrentgemma-9b
"""
import subprocess
import sys

if __name__ == "__main__":
    args = sys.argv[1:] or ["--arch", "recurrentgemma-9b", "--batch", "4",
                            "--prompt-len", "8", "--gen", "24"]
    raise SystemExit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve_decode"] + args))
