"""Beyond-paper demo: RapidGNN's deterministic-schedule + hot-set cache
applied to a vocab-sharded transformer embedding table (DESIGN.md §4).

Shows the offline enumeration (Alg. 1 lines 1-3 on token ids), the
hot-set selection, and the resulting traffic reduction for a Zipf token
stream -- then validates the DEVICE path (a2a pull + cache_gather merge)
against a direct numpy gather.

  PYTHONPATH=src python examples/hot_embedding_cache.py
"""
import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import zipf_tokens, enumerate_token_accesses
from repro.graph.sampler import rng_from
from repro.models.transformer.embedding import HotEmbeddingSim

arch = "gemma2-2b"
cfg = get_arch(arch)
workers, batch, seq, steps = 8, 16, 256, 100

print(f"arch {arch}: vocab {cfg.vocab_size}, d_model {cfg.d_model}")
print("1) offline enumeration of the run's token accesses ...")
counts = enumerate_token_accesses(cfg, batch, seq, steps, s0=7)
nz = counts[counts > 0]
print(f"   {nz.size} unique tokens accessed; "
      f"{(nz == 1).mean():.1%} exactly once; max freq {nz.max()} "
      f"(the paper's Fig. 3 long tail, on text)")

print("2) hot-set caches per worker + traffic accounting ...")
for n_hot in (4096, 32768):
    sim = HotEmbeddingSim(vocab=cfg.vocab_size, d=cfg.d_model,
                          num_workers=workers, n_hot=n_hot, counts=counts)
    base = cach = 0
    for i in range(steps):
        toks = zipf_tokens(rng_from(7, 0, i), cfg.vocab_size, (batch, seq))
        b, c, _ = sim.batch_traffic(toks, worker=0)
        base += b
        cach += c
    cach += sim.cache_build_bytes()
    print(f"   n_hot {n_hot:6d}: baseline {base/1e6:7.1f} MB -> "
          f"cached {cach/1e6:7.1f} MB  ({base/max(cach,1):.2f}x less)")

print("3) device-path validation (4 emulated devices) ...")
import os
import subprocess
import sys
code = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.dist import make_mesh, build_pull_plan
from repro.models.transformer.embedding import device_embedding_lookup
P_, vper, d, m = 4, 64, 16, 24
rng = np.random.default_rng(0)
table = rng.normal(size=(P_*vper, d)).astype(np.float32)
owner = np.repeat(np.arange(P_), vper)
mesh = make_mesh((P_,), ("data",))
# per-worker token batch + (empty-cache) pull plan
toks, plans, want = [], [], []
for w in range(P_):
    t = rng.integers(0, P_*vper, size=m)
    toks.append(t)
    plans.append(build_pull_plan(t.astype(np.int32), np.arange(m, dtype=np.int32),
                                 owner, P_, m))
    want.append(table[t])
plan = {
  "send_ids": jnp.asarray(np.stack([p.send_ids for p in plans])),
  "send_pos": jnp.asarray(np.stack([p.send_pos for p in plans])),
  "send_mask": jnp.asarray(np.stack([p.send_mask for p in plans])),
  "offsets": jnp.asarray((np.arange(P_)*vper).astype(np.int32)),
}
cache_ids = jnp.full((P_, 4), 2**31 - 1, jnp.int32)
cache_feats = jnp.zeros((P_, 4, d), jnp.float32)
with mesh:
    out = device_embedding_lookup(mesh, jnp.asarray(table.reshape(P_, vper, d)),
                                  cache_ids, cache_feats,
                                  jnp.asarray(np.stack(toks), jnp.int32), plan, m)
np.testing.assert_allclose(np.asarray(out), np.stack(want), rtol=1e-6)
print("   device embedding lookup == direct gather OK")
"""
env = dict(os.environ)
env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
env.setdefault("PYTHONPATH", "src")
r = subprocess.run([sys.executable, "-c", code], env=env,
                   capture_output=True, text=True)
print(r.stdout.strip() or r.stderr[-500:])
assert r.returncode == 0
print("OK")
