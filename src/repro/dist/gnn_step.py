"""Scan-pipelined RapidGNN epoch on an SPMD ``("data",)`` mesh.

This is Alg. 1's prefetcher/trainer overlap expressed INSIDE the compiled
step program (DESIGN.md §6.3): a ``jax.lax.scan`` over the S steps of an
epoch whose body (a) issues the all_to_all residual-miss pull for step
i+1 and (b) trains on step i's already-pulled features. Both live in one
dataflow graph with no dependency between them, so the collective hides
behind the train step's compute -- the device analogue of the host-side
``core.prefetch.Prefetcher`` thread, with the bounded queue replaced by a
1-step software pipeline carried through the scan.

Host-side companions (all numpy, computed offline from the deterministic
schedule): ``DeviceView`` relabels the partitioned graph into contiguous
per-worker slot ranges so ownership is ``id // n_per``; ``epoch_k_max``
computes the exact static lane bound; ``collate_device_epoch`` packs a
whole epoch into (S, P, ...) arrays; ``stack_caches`` stacks the
per-worker hot sets C_s.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.schedule import EpochSchedule, collate
from repro.graph.partition import PartitionedGraph
from repro.kernels.cache_lookup.ops import cache_lookup, to_device_ids
from repro.models.gnn import GNNConfig, loss_fn
from repro.dist.feature_a2a import build_pull_plan, pull_shard

#: int64 cache padding; survives the int32 canonicalisation cast exactly
#: and matches the ``cache_lookup`` device sentinel.
CACHE_PAD = int(2 ** 31 - 1)


@dataclasses.dataclass
class DeviceCache:
    """One worker's hot set C_s in DEVICE id space, sorted for searchsorted."""
    ids: np.ndarray      # (k,) int64 device ids, sorted unique
    feats: np.ndarray    # (k, d) float32


@dataclasses.dataclass
class DeviceView:
    """Device relabeling of a PartitionedGraph.

    Partitions own arbitrary global-id sets; the device path needs
    ownership decidable by arithmetic (``owner = id // n_per``) so the
    pull can turn an id into (owner, slot) with no lookup table on
    device. ``build`` assigns worker p's nodes the dense device ids
    ``p * n_per + [0..|V_p|)`` with ``n_per = max_p |V_p|`` (tail slots
    of smaller partitions are zero rows, never referenced).
    """
    num_parts: int
    n_per: int
    table: np.ndarray      # (P, n_per, d) float32, partition-sharded rows
    offsets: np.ndarray    # (P, 1) int32   first device slot per worker
    g2d: np.ndarray        # (n,) int64     global id -> device id
    features: np.ndarray   # (n, d)         global table (host ref, not copied)

    @staticmethod
    def build(pg: PartitionedGraph) -> "DeviceView":
        g = pg.graph
        P_ = pg.num_parts
        n_per = int(max(ln.shape[0] for ln in pg.local_nodes))
        table = np.zeros((P_, n_per, g.feat_dim), np.float32)
        g2d = np.empty(g.num_nodes, np.int64)
        for p, loc in enumerate(pg.local_nodes):
            table[p, : loc.shape[0]] = g.features[loc]
            g2d[loc] = p * n_per + np.arange(loc.shape[0], dtype=np.int64)
        offsets = (np.arange(P_, dtype=np.int32) * n_per)[:, None]
        return DeviceView(num_parts=P_, n_per=n_per, table=table,
                          offsets=offsets, g2d=g2d, features=g.features)

    @property
    def owner_d(self) -> np.ndarray:
        """(P*n_per,) device-id -> owner, for build_pull_plan."""
        return np.repeat(np.arange(self.num_parts, dtype=np.int32),
                         self.n_per)

    def remap_cache(self, cache_ids_global: np.ndarray) -> DeviceCache:
        """Global hot-set ids (schedule output) -> sorted device cache."""
        dev = self.g2d[cache_ids_global]
        order = np.argsort(dev)
        return DeviceCache(
            ids=dev[order],
            feats=self.features[cache_ids_global[order]].astype(np.float32))


def _batch_miss(es_batch, cache: DeviceCache, dv: DeviceView, worker: int):
    """-> (dev_ids (m,), miss_mask (m,)) for one sampled batch."""
    dev = dv.g2d[es_batch.input_nodes]
    remote = (dev // dv.n_per) != worker
    miss = remote & ~np.isin(dev, cache.ids, assume_unique=False)
    return dev, miss


def epoch_k_max(es_list: Sequence[EpochSchedule],
                caches: Sequence[DeviceCache], dv: DeviceView) -> int:
    """Exact static per-owner lane bound over all (worker, step) pairs.

    Pad bounds (m_max / edge maxima) are NOT recomputed here -- callers
    precompute them once via ``WorkerSchedule.pad_bounds()`` (the
    multi-epoch runner maxes this over every epoch's caches so all
    epochs share one compiled program). Workers with fewer batches
    simply contribute fewer (worker, step) pairs."""
    k = 1
    for w, es in enumerate(es_list):
        for b in es.batches:
            dev, miss = _batch_miss(b, caches[w], dv, w)
            if miss.any():
                owners = dev[miss] // dv.n_per
                k = max(k, int(np.bincount(owners).max()))
    return k


def collate_device_epoch(es_list: Sequence[EpochSchedule],
                         caches: Sequence[DeviceCache], dv: DeviceView,
                         labels: np.ndarray, batch_size: int, m_max: int,
                         edge_max: Sequence[int], k_max: int,
                         num_steps: int) -> Dict[str, np.ndarray]:
    """Pack an epoch into the (S, P, ...) device layout.

    Per (step, worker): the padded collated batch (ids remapped to
    device space, -1 padded) plus the residual-miss PullPlan lanes.
    Layout matches launch/dryrun_gnn.specs exactly.

    ``m_max``/``edge_max``/``k_max``/``num_steps`` are precomputed
    bounds -- the multi-epoch runner passes GLOBAL (all-epoch, all-
    worker) values so every epoch collates to identical shapes and one
    XLA compilation. A worker with fewer than ``num_steps`` batches
    (uneven train-node partitions, possibly zero batches) gets fully
    masked empty steps for the tail: ids -1, all masks False, so it
    still participates in every collective but trains on nothing.
    Raises when a worker has MORE batches than ``num_steps`` (silent
    truncation would corrupt the fetch accounting).
    """
    P_ = len(es_list)
    S = num_steps
    L = len(edge_max)
    over = [w for w, es in enumerate(es_list) if len(es.batches) > S]
    if over:
        raise ValueError(
            f"workers {over} have more batches than num_steps={S}; "
            f"pass num_steps >= max worker batch count "
            f"(dropping steps would corrupt miss accounting)")
    out = {
        "input_nodes": np.full((S, P_, m_max), -1, np.int64),
        "labels": np.zeros((S, P_, batch_size), np.int32),
        "seed_mask": np.zeros((S, P_, batch_size), bool),
        "send_ids": np.zeros((S, P_, P_, k_max), np.int32),
        "send_pos": np.zeros((S, P_, P_, k_max), np.int32),
        "send_mask": np.zeros((S, P_, P_, k_max), bool),
        "edge_src": [np.zeros((S, P_, e), np.int32) for e in edge_max],
        "edge_dst": [np.zeros((S, P_, e), np.int32) for e in edge_max],
        "edge_mask": [np.zeros((S, P_, e), bool) for e in edge_max],
    }
    owner_d = dv.owner_d
    for w, es in enumerate(es_list):
        for i in range(len(es.batches)):
            b = es.batches[i]
            cb = collate(b, labels, batch_size, m_max, edge_max)
            dev, miss = _batch_miss(b, caches[w], dv, w)
            m = b.num_input_nodes
            out["input_nodes"][i, w, :m] = dev
            out["labels"][i, w] = cb.labels
            out["seed_mask"][i, w] = cb.seed_mask
            plan = build_pull_plan(dev[miss].astype(np.int32),
                                   np.flatnonzero(miss).astype(np.int32),
                                   owner_d, P_, k_max)
            out["send_ids"][i, w] = plan.send_ids
            out["send_pos"][i, w] = plan.send_pos
            out["send_mask"][i, w] = plan.send_mask
            for l in range(L):
                out["edge_src"][l][i, w] = cb.edge_src[l]
                out["edge_dst"][l][i, w] = cb.edge_dst[l]
                out["edge_mask"][l][i, w] = cb.edge_mask[l]
    return out


def stack_caches(caches: Sequence[DeviceCache], dv: DeviceView,
                 n_hot: int):
    """Stack per-worker hot sets into (P, n_hot) ids + (P, n_hot, d) rows.

    Ids stay sorted with CACHE_PAD tail padding (the device sentinel), so
    the binary-search ``cache_lookup`` works shard-locally unchanged.
    Raises when a cache exceeds ``n_hot``: the collation already routed
    those ids through C_s, so dropping them here would silently train on
    zero feature rows (same contract as build_pull_plan's overflow).
    """
    P_ = len(caches)
    d = dv.table.shape[-1]
    cids = np.full((P_, n_hot), CACHE_PAD, np.int64)
    cfeats = np.zeros((P_, n_hot, d), np.float32)
    for w, c in enumerate(caches):
        k = c.ids.shape[0]
        if k > n_hot:
            raise ValueError(
                f"worker {w} hot set has {k} ids > n_hot={n_hot}; "
                f"truncating would serve zero rows for ids the pull "
                f"plans treat as cache hits")
        cids[w, :k] = c.ids
        cfeats[w, :k] = c.feats
    return cids, cfeats


def _local_merge(tbl, base, q, fallback):
    """Overlay this worker's shard rows onto ``fallback`` where the
    queried device id is locally owned (slot in [0, n_per)); padding ids
    (-1) are never local. Shared by both epoch programs so the
    rapid-vs-baseline comparison assembles features identically."""
    n_per = tbl.shape[0]
    slot = q - base
    local = (slot >= 0) & (slot < n_per)
    rows = tbl[jnp.clip(slot, 0, n_per - 1)]
    return jnp.where(local[:, None], rows, fallback)


def _pmean_train_step(cfg: GNNConfig, opt, params, opt_state, feats, x):
    """Shared scan-body tail for both epoch programs: batch loss/grad,
    pmean over ``data`` (params stay replicated), optimizer update.
    -> (params, opt_state, loss, acc)."""

    def lf(p):
        return loss_fn(cfg, p, feats, x["edge_src"], x["edge_dst"],
                       x["edge_mask"], x["labels"], x["seed_mask"])

    (loss, acc), grads = jax.value_and_grad(lf, has_aux=True)(params)
    grads, loss, acc = jax.lax.pmean((grads, loss, acc), "data")
    p2, o2 = opt.update(grads, opt_state, params)
    return p2, o2, loss, acc


def make_pipelined_epoch(cfg: GNNConfig, opt, mesh, m_max: int):
    """-> epoch_fn(params, opt_state, table, offsets, cache_ids,
    cache_feats, batches) running S pipelined steps on the mesh.

    Per scan step (DESIGN.md §6.3): pull step i+1's residual misses
    (carried to the next iteration) while training on step i's features,
    assembled local-first -> cache C_s -> pulled residuals; grads are
    pmean'd over ``data`` so params stay replicated. Returns
    (params, opt_state, losses (S,), accs (S,)).
    """

    def epoch_fn(params, opt_state, table, offsets, cache_ids,
                 cache_feats, batches):

        def device_epoch(params, opt_state, tbl, offs, cids, cfeats, bt):
            tbl = tbl[0]                          # (n_per, d) my shard
            base = offs.reshape(-1)[0]
            cids32 = to_device_ids(cids[0])       # (n_hot,) sorted int32
            cfe = cfeats[0]
            bt = jax.tree.map(lambda a: a[:, 0], bt)   # drop worker dim

            def pull(send):
                return pull_shard(tbl, send["send_ids"], send["send_pos"],
                                  send["send_mask"], base, m_max)

            def assemble(pulled, ids):
                q = to_device_ids(ids)
                merged, _ = cache_lookup(cids32, cfe, q, pulled)
                return _local_merge(tbl, base, q, merged)

            send = {k: bt[k] for k in ("send_ids", "send_pos", "send_mask")}
            # prefetch stream: step i's body pulls step i+1's misses (the
            # final roll wraps to step 0 -- one wasted pull, discarded)
            xs = {
                "input_nodes": bt["input_nodes"],
                "labels": bt["labels"],
                "seed_mask": bt["seed_mask"],
                "edge_src": bt["edge_src"],
                "edge_dst": bt["edge_dst"],
                "edge_mask": bt["edge_mask"],
                "next_send": jax.tree.map(
                    lambda a: jnp.roll(a, -1, axis=0), send),
            }
            pulled0 = pull(jax.tree.map(lambda a: a[0], send))

            def step(carry, x):
                params, opt_state, pulled = carry
                nxt = pull(x["next_send"])        # overlap: no dep on train
                feats = assemble(pulled, x["input_nodes"])
                p2, o2, loss, acc = _pmean_train_step(
                    cfg, opt, params, opt_state, feats, x)
                return (p2, o2, nxt), (loss, acc)

            (params, opt_state, _), (losses, accs) = jax.lax.scan(
                step, (params, opt_state, pulled0), xs)
            return params, opt_state, losses, accs

        return shard_map(
            device_epoch, mesh=mesh,
            in_specs=(P(), P(), P("data"), P("data"), P("data"),
                      P("data"), P(None, "data")),
            out_specs=(P(), P(), P(), P()), check_rep=False,
        )(params, opt_state, table, offsets, cache_ids, cache_feats,
          batches)

    return epoch_fn


def make_ondemand_epoch(cfg: GNNConfig, opt, mesh, m_max: int):
    """-> epoch_fn(params, opt_state, table, offsets, batches): the
    DGL-style on-demand baseline as a NON-overlapped scan.

    Same mesh, same pull-plan wire format, same train step as
    ``make_pipelined_epoch`` -- but no cache C_s and no software
    pipeline: step i's all_to_all pull feeds step i's own features, so
    the collective sits on the trainer's critical path every step. This
    is the device analogue of ``core.runtime.BaselineRunner``, making
    device rapid-vs-baseline step time directly measurable
    (DESIGN.md §6.5). Collate its batches with EMPTY caches so every
    remote id rides the pull lanes.
    """

    def epoch_fn(params, opt_state, table, offsets, batches):

        def device_epoch(params, opt_state, tbl, offs, bt):
            tbl = tbl[0]                          # (n_per, d) my shard
            base = offs.reshape(-1)[0]
            bt = jax.tree.map(lambda a: a[:, 0], bt)   # drop worker dim

            def step(carry, x):
                params, opt_state = carry
                # pull THIS step's remote rows: the train step below
                # depends on it, so nothing overlaps (on-demand fetch)
                pulled = pull_shard(tbl, x["send_ids"], x["send_pos"],
                                    x["send_mask"], base, m_max)
                q = to_device_ids(x["input_nodes"])
                feats = _local_merge(tbl, base, q, pulled)
                p2, o2, loss, acc = _pmean_train_step(
                    cfg, opt, params, opt_state, feats, x)
                return (p2, o2), (loss, acc)

            xs = {k: bt[k] for k in
                  ("input_nodes", "labels", "seed_mask", "send_ids",
                   "send_pos", "send_mask", "edge_src", "edge_dst",
                   "edge_mask")}
            (params, opt_state), (losses, accs) = jax.lax.scan(
                step, (params, opt_state), xs)
            return params, opt_state, losses, accs

        return shard_map(
            device_epoch, mesh=mesh,
            in_specs=(P(), P(), P("data"), P("data"), P(None, "data")),
            out_specs=(P(), P(), P(), P()), check_rep=False,
        )(params, opt_state, table, offsets, batches)

    return epoch_fn


def empty_caches(num_parts: int, feat_dim: int) -> List[DeviceCache]:
    """Per-worker EMPTY hot sets: the no-cache (baseline) collation key.
    ``_batch_miss`` then routes every remote id through the pull lanes."""
    return [DeviceCache(ids=np.zeros(0, np.int64),
                        feats=np.zeros((0, feat_dim), np.float32))
            for _ in range(num_parts)]
