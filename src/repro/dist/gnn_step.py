"""Scan-pipelined RapidGNN epoch on an SPMD mesh -- flat ``("data",)``
or hierarchical ``("dcn", "data")`` (two-tier pulls, DESIGN.md §6.7).

This is Alg. 1's prefetcher/trainer overlap expressed INSIDE the compiled
step program (DESIGN.md §6.3): a ``jax.lax.scan`` over the S steps of an
epoch whose body (a) issues the all_to_all residual-miss pull for step
i+1 and (b) trains on step i's already-pulled features. Both live in one
dataflow graph with no dependency between them, so the collective hides
behind the train step's compute -- the device analogue of the host-side
``core.prefetch.Prefetcher`` thread, with the bounded queue replaced by a
1-step software pipeline carried through the scan.

Per-step feature assembly is the SINGLE-PASS fused path
(``kernels/assemble``): local-shard gather, C_s binary-search merge and
pulled-residual overlay resolved per row with one output materialization
(DESIGN.md §3), shared by both epoch programs so rapid-vs-baseline
comparisons assemble features identically. The legacy three-stage chain
(``cache_lookup`` then local overlay) survives as the ``"staged"``
backend / interpret-mode oracle.

Host-side companions (all numpy, computed offline from the deterministic
schedule): ``DeviceView`` relabels the partitioned graph into contiguous
per-worker slot ranges so ownership is ``id // n_per``; ``epoch_k_max``
computes the exact static lane bound; ``collate_device_epoch`` packs a
whole epoch into (S, P, ...) arrays in one VECTORIZED pass (single
``g2d`` gather over the schedule compiler's FlatEpoch streams, one
stamp-table membership pass per worker, batched lane packing,
boolean-mask slab fills for every ragged array -- DESIGN.md §6.6; the
per-(step,
worker) loop survives as ``collate_device_epoch_loop``, the
parity/bench reference); ``stack_caches`` stacks the per-worker hot
sets C_s.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.schedule import EpochSchedule, collate
from repro.graph.partition import PartitionedGraph
from repro.kernels.assemble.ops import assemble_features
from repro.kernels.cache_lookup.ops import to_device_ids
from repro.models.gnn import GNNConfig, loss_fn
from repro.dist.feature_a2a import (build_pull_plan, pack_pull_lanes,
                                    pack_pull_lanes_two_tier, pull_shard,
                                    pull_shard_two_tier)

#: pull-plan keys of the collated epoch dict, per topology tier layout
PULL_KEYS_FLAT = ("send_ids", "send_pos", "send_mask")
PULL_KEYS_HIER = ("intra_ids", "intra_pos", "intra_mask",
                  "inter_ids", "inter_pos", "inter_mask")

#: int64 cache padding; survives the int32 canonicalisation cast exactly
#: and matches the ``cache_lookup`` device sentinel.
CACHE_PAD = int(2 ** 31 - 1)


@dataclasses.dataclass
class DeviceCache:
    """One worker's hot set C_s in DEVICE id space, sorted for searchsorted."""
    ids: np.ndarray      # (k,) int64 device ids, sorted unique
    feats: np.ndarray    # (k, d) float32


@dataclasses.dataclass
class DeviceView:
    """Device relabeling of a PartitionedGraph.

    Partitions own arbitrary global-id sets; the device path needs
    ownership decidable by arithmetic (``owner = id // n_per``) so the
    pull can turn an id into (owner, slot) with no lookup table on
    device. ``build`` assigns worker p's nodes the dense device ids
    ``p * n_per + [0..|V_p|)`` with ``n_per = max_p |V_p|`` (tail slots
    of smaller partitions are zero rows, never referenced).
    """
    num_parts: int
    n_per: int
    table: np.ndarray      # (P, n_per, d) float32, partition-sharded rows
    offsets: np.ndarray    # (P, 1) int32   first device slot per worker
    g2d: np.ndarray        # (n,) int64     global id -> device id
    features: np.ndarray   # (n, d)         global table (host ref, not copied)

    @staticmethod
    def build(pg: PartitionedGraph) -> "DeviceView":
        g = pg.graph
        P_ = pg.num_parts
        n_per = int(max(ln.shape[0] for ln in pg.local_nodes))
        table = np.zeros((P_, n_per, g.feat_dim), np.float32)
        g2d = np.empty(g.num_nodes, np.int64)
        for p, loc in enumerate(pg.local_nodes):
            table[p, : loc.shape[0]] = g.features[loc]
            g2d[loc] = p * n_per + np.arange(loc.shape[0], dtype=np.int64)
        offsets = (np.arange(P_, dtype=np.int32) * n_per)[:, None]
        return DeviceView(num_parts=P_, n_per=n_per, table=table,
                          offsets=offsets, g2d=g2d, features=g.features)

    @property
    def owner_d(self) -> np.ndarray:
        """(P*n_per,) device-id -> owner, for build_pull_plan."""
        return np.repeat(np.arange(self.num_parts, dtype=np.int32),
                         self.n_per)

    def remap_cache(self, cache_ids_global: np.ndarray) -> DeviceCache:
        """Global hot-set ids (schedule output) -> sorted device cache."""
        dev = self.g2d[cache_ids_global]
        order = np.argsort(dev)
        return DeviceCache(
            ids=dev[order],
            feats=self.features[cache_ids_global[order]].astype(np.float32))


def _batch_miss(es_batch, cache: DeviceCache, dv: DeviceView, worker: int):
    """-> (dev_ids (m,), miss_mask (m,)) for one sampled batch."""
    dev = dv.g2d[es_batch.input_nodes]
    remote = (dev // dv.n_per) != worker
    miss = remote & ~np.isin(dev, cache.ids, assume_unique=False)
    return dev, miss


def _epoch_flat(es_list: Sequence[EpochSchedule], dv: DeviceView
                ) -> Optional[Dict[str, np.ndarray]]:
    """Splice the P workers' FlatEpoch payloads into one worker-major
    batch stream with ONE ``g2d`` gather (the vectorized staging spine,
    DESIGN.md §6.6). Since the schedule compiler already stores each
    worker-epoch flat (CSR offsets, no per-batch objects), this is P
    concatenations -- the per-(worker, batch) rec loop is gone.

    -> dict: the per-worker ``flats`` plus per-batch ``step``/``worker``
    /``m_counts``/``starts`` (element offsets) and the per-element
    ``dev`` device ids; None for an epoch with no batches at all.
    Per-element batch/column coordinates are NOT materialized here --
    ``_miss_coords`` derives them lazily for just the miss subset.
    """
    flats = [es.flat for es in es_list]
    nbs = np.fromiter((f.num_batches for f in flats), np.int64,
                      len(flats))
    n = int(nbs.sum())
    if n == 0:
        return None
    step = np.concatenate([np.arange(nb, dtype=np.int64) for nb in nbs])
    worker = np.repeat(np.arange(len(flats), dtype=np.int64), nbs)
    m_counts = np.concatenate([f.m_counts for f in flats])
    dev = dv.g2d[np.concatenate([f.input_nodes for f in flats])]
    starts = np.zeros(n + 1, np.int64)
    np.cumsum(m_counts, out=starts[1:])
    return {"flats": flats, "step": step, "worker": worker,
            "m_counts": m_counts, "dev": dev, "starts": starts}


def _miss_coords(flat: Dict[str, np.ndarray], miss: np.ndarray):
    """(batch ordinal, buffer row) of each missed element, derived from
    the element offsets -- a binary search over the (n_batches,) starts
    vector on just the miss subset instead of materializing full
    per-element repeat/arange coordinate arrays."""
    idx = np.flatnonzero(miss)
    eb = np.searchsorted(flat["starts"], idx, side="right") - 1
    return eb, idx - flat["starts"][eb]


#: device-id spaces up to this many slots use the O(1) stamp-table
#: membership test (int32 stamp array = 4 bytes/slot host scratch);
#: larger spaces fall back to per-worker binary search
STAMP_TABLE_MAX_SLOTS = 1 << 26


def _classify_misses(flat: Dict[str, np.ndarray],
                     caches: Sequence[DeviceCache], dv: DeviceView):
    """Residual-miss classification for a whole epoch in one vectorized
    pass per worker (replacing the S x P per-batch ``np.isin`` calls,
    each of which re-sorted the hot set).

    The flattened element stream is worker-major, so each worker's
    elements are one contiguous slice. Membership in that worker's hot
    set is an O(1) probe of a slot-indexed STAMP table (``stamp[id] ==
    w``; workers stamp in ascending order, so later overwrites never
    corrupt earlier queries and the table needs no clearing) -- for id
    spaces too large for the 4 B/slot scratch it degrades to one
    vectorized binary search per worker against its cache-resident
    (n_hot,) key vector. Remoteness is two compares against the
    worker's slot range, not a division.

    -> (miss mask aligned with ``flat['dev']``, owners of just the
    missed elements).
    """
    dev = flat["dev"]
    miss = np.zeros(dev.shape, bool)
    wk, mc = flat["worker"], flat["m_counts"]
    n_slots = dv.num_parts * dv.n_per
    stamp = (np.full(n_slots, -1, np.int32)
             if n_slots <= STAMP_TABLE_MAX_SLOTS else None)
    lo = 0
    for w, cache in enumerate(caches):
        span = int(mc[wk == w].sum())
        sl = slice(lo, lo + span)
        lo += span
        if span == 0:
            continue
        d = dev[sl]
        base = w * dv.n_per
        rem = (d < base) | (d >= base + dv.n_per)
        if cache.ids.shape[0] == 0 or not rem.any():
            miss[sl] = rem
            continue
        q = d[rem]
        m = rem.copy()
        if stamp is not None:
            stamp[cache.ids] = w
            m[rem] = stamp[q] != w
        else:
            pos = np.minimum(np.searchsorted(cache.ids, q),
                             cache.ids.shape[0] - 1)
            m[rem] = cache.ids[pos] != q
        miss[sl] = m
    return miss, dev[miss] // dv.n_per


def epoch_k_max(es_list: Sequence[EpochSchedule],
                caches: Sequence[DeviceCache], dv: DeviceView) -> int:
    """Exact static per-owner lane bound over all (worker, step) pairs,
    computed in one vectorized pass over the whole epoch (bincount over
    (batch, owner) group keys -- no per-batch loop).

    Pad bounds (m_max / edge maxima) are NOT recomputed here -- callers
    precompute them once via ``WorkerSchedule.pad_bounds()`` (the
    multi-epoch runner maxes this over every epoch's caches so all
    epochs share one compiled program). Workers with fewer batches
    simply contribute fewer (worker, step) pairs."""
    flat = _epoch_flat(es_list, dv)
    if flat is None:
        return 1
    miss, owner_miss = _classify_misses(flat, caches, dv)
    if owner_miss.size == 0:
        return 1
    P_ = len(es_list)
    eb, _ = _miss_coords(flat, miss)
    return max(1, int(np.bincount(eb * P_ + owner_miss).max()))


def epoch_k_max_split(es_list: Sequence[EpochSchedule],
                      caches: Sequence[DeviceCache], dv: DeviceView,
                      topo) -> tuple:
    """Exact static lane bounds for the TWO-TIER plan: ``(k_max_intra,
    k_max_inter)`` over all (worker, step) pairs of the epoch, split by
    whether the missed id's owner shares the requesting worker's host
    (same vectorized bincount pass as ``epoch_k_max``, one group key
    per tier). Both bounds floor at 1 so degenerate tiers (single-host
    epochs, all-local epochs) still compile static shapes."""
    flat = _epoch_flat(es_list, dv)
    if flat is None:
        return 1, 1
    miss, owner_miss = _classify_misses(flat, caches, dv)
    if owner_miss.size == 0:
        return 1, 1
    P_ = len(es_list)
    D = topo.devices_per_host
    eb, _ = _miss_coords(flat, miss)
    req = flat["worker"][eb]
    same = topo.same_host(owner_miss, req)
    k_i = k_x = 1
    if same.any():
        k_i = int(np.bincount(
            eb[same] * D + topo.local_of(owner_miss[same])).max())
    if (~same).any():
        k_x = int(np.bincount(eb[~same] * P_ + owner_miss[~same]).max())
    return max(1, k_i), max(1, k_x)


def _alloc_epoch(P_: int, S: int, batch_size: int, m_max: int,
                 edge_max: Sequence[int], k_max: int, topology=None,
                 k_max_inter: Optional[int] = None
                 ) -> Dict[str, np.ndarray]:
    """Empty (S, P, ...) device-layout epoch: every step fully masked.
    With a hierarchical ``topology`` the pull lanes split into the
    two-tier layout -- intra (S, P, D, k_max) + inter (S, P, P,
    k_max_inter) -- instead of the flat send_* (S, P, P, k_max)."""
    out = {
        "input_nodes": np.full((S, P_, m_max), -1, np.int64),
        "labels": np.zeros((S, P_, batch_size), np.int32),
        "seed_mask": np.zeros((S, P_, batch_size), bool),
        "edge_src": [np.zeros((S, P_, e), np.int32) for e in edge_max],
        "edge_dst": [np.zeros((S, P_, e), np.int32) for e in edge_max],
        "edge_mask": [np.zeros((S, P_, e), bool) for e in edge_max],
    }
    if topology is not None and topology.is_hierarchical:
        D = topology.devices_per_host
        k_x = k_max_inter if k_max_inter is not None else k_max
        out["intra_ids"] = np.zeros((S, P_, D, k_max), np.int32)
        out["intra_pos"] = np.zeros((S, P_, D, k_max), np.int32)
        out["intra_mask"] = np.zeros((S, P_, D, k_max), bool)
        out["inter_ids"] = np.zeros((S, P_, P_, k_x), np.int32)
        out["inter_pos"] = np.zeros((S, P_, P_, k_x), np.int32)
        out["inter_mask"] = np.zeros((S, P_, P_, k_x), bool)
    else:
        out["send_ids"] = np.zeros((S, P_, P_, k_max), np.int32)
        out["send_pos"] = np.zeros((S, P_, P_, k_max), np.int32)
        out["send_mask"] = np.zeros((S, P_, P_, k_max), bool)
    return out


def _check_num_steps(es_list: Sequence[EpochSchedule], S: int) -> None:
    over = [w for w, es in enumerate(es_list) if es.num_batches > S]
    if over:
        raise ValueError(
            f"workers {over} have more batches than num_steps={S}; "
            f"pass num_steps >= max worker batch count "
            f"(dropping steps would corrupt miss accounting)")


def collate_device_epoch(es_list: Sequence[EpochSchedule],
                         caches: Sequence[DeviceCache], dv: DeviceView,
                         labels: np.ndarray, batch_size: int, m_max: int,
                         edge_max: Sequence[int], k_max: int,
                         num_steps: int, topology=None,
                         k_max_inter: Optional[int] = None
                         ) -> Dict[str, np.ndarray]:
    """Pack an epoch into the (S, P, ...) device layout -- VECTORIZED.

    Per (step, worker): the padded collated batch (ids remapped to
    device space, -1 padded) plus the residual-miss PullPlan lanes.
    Layout matches launch/dryrun_gnn.specs exactly, batch-for-batch
    identical to ``collate_device_epoch_loop`` (the per-(step, worker)
    reference this path is parity-tested against).

    The per-element work stages in a handful of whole-epoch numpy ops
    instead of S x P small ones (DESIGN.md §6.6): one ``g2d`` gather
    over every input node, one label gather over every seed, one
    stamp-table membership pass per worker for miss classification
    (``_classify_misses``, replacing S x P ``np.isin`` re-sorts), one
    sort-based lane packing (``pack_pull_lanes``) replacing S x P
    ``build_pull_plan`` calls, and -- now that the schedule compiler
    stores each worker-epoch as a FlatEpoch -- ONE boolean-mask
    assignment per (worker, output array) for the ragged padded fills,
    streaming each worker's flat arrays into its padded slab in C
    order, replacing the last per-batch memcpy loop. This is what
    keeps the host's double-buffer staging ahead of the device at 256+
    workers.

    ``m_max``/``edge_max``/``k_max``/``num_steps`` are precomputed
    bounds -- the multi-epoch runner passes GLOBAL (all-epoch, all-
    worker) values so every epoch collates to identical shapes and one
    XLA compilation. A worker with fewer than ``num_steps`` batches
    (uneven train-node partitions, possibly zero batches) gets fully
    masked empty steps for the tail: ids -1, all masks False, so it
    still participates in every collective but trains on nothing.
    Raises when a worker has MORE batches than ``num_steps`` (silent
    truncation would corrupt the fetch accounting).

    With a hierarchical ``topology`` the pull lanes come out two-tier
    (``intra_*``/``inter_*`` via ``pack_pull_lanes_two_tier``, bounds
    ``k_max``/``k_max_inter``) instead of flat ``send_*`` -- everything
    else (batches, labels, edges) is layout-identical.
    """
    P_ = len(es_list)
    S = num_steps
    _check_num_steps(es_list, S)
    hier = topology is not None and topology.is_hierarchical
    out = _alloc_epoch(P_, S, batch_size, m_max, edge_max, k_max,
                       topology=topology, k_max_inter=k_max_inter)
    flat = _epoch_flat(es_list, dv)
    if flat is None:
        return out
    flats = flat["flats"]
    row = flat["step"] * P_ + flat["worker"]    # batch -> flat (step, w)
    dev, starts = flat["dev"], flat["starts"]

    # ragged padded fills: per worker slab, ONE boolean-mask assignment
    # per output array. The mask `arange(K) < counts[:, None]` iterates
    # the (S, K) slab in C order, which is exactly the worker's flat
    # stream order, so `slab[valid] = stream` is a single compiled
    # sequential copy -- no per-batch loop, no index arrays (an
    # int64-index scatter moves 3x the bytes and measured ~2x slower)
    def _pad_counts(cnts: np.ndarray) -> np.ndarray:
        full = np.zeros(S, np.int64)
        full[:cnts.shape[0]] = cnts
        return full

    lo = 0
    for w, f in enumerate(flats):
        if f.num_batches == 0:
            continue    # fully masked worker; may carry 0 layer info
        span = int(f.input_starts[-1])
        valid = np.arange(m_max) < _pad_counts(f.m_counts)[:, None]
        out["input_nodes"][:, w][valid] = dev[lo:lo + span]
        lo += span
        svalid = np.arange(batch_size) < \
            _pad_counts(np.diff(f.seed_starts))[:, None]
        out["labels"][:, w][svalid] = labels[f.seeds]
        out["seed_mask"][:, w][svalid] = True
        for l in range(len(edge_max)):
            evalid = np.arange(edge_max[l]) < \
                _pad_counts(np.diff(f.edge_starts[l]))[:, None]
            out["edge_src"][l][:, w][evalid] = f.edge_src[l]
            out["edge_dst"][l][:, w][evalid] = f.edge_dst[l]
            out["edge_mask"][l][:, w][evalid] = f.edge_mask[l]

    # residual-miss pull lanes: one classification + one batched packing
    miss, owner_miss = _classify_misses(flat, caches, dv)
    eb, col = _miss_coords(flat, miss)
    # assume_unique: the sampler dedupes input_nodes per batch, so no
    # (group, id, pos) duplicates can exist
    if hier:
        D = topology.devices_per_host
        k_x = k_max_inter if k_max_inter is not None else k_max
        intra, inter = pack_pull_lanes_two_tier(
            dev[miss], col, row[eb], owner_miss, flat["worker"][eb],
            S * P_, topology, k_max, k_x, assume_unique=True)
        out["intra_ids"] = intra[0].reshape(S, P_, D, k_max)
        out["intra_pos"] = intra[1].reshape(S, P_, D, k_max)
        out["intra_mask"] = intra[2].reshape(S, P_, D, k_max)
        out["inter_ids"] = inter[0].reshape(S, P_, P_, k_x)
        out["inter_pos"] = inter[1].reshape(S, P_, P_, k_x)
        out["inter_mask"] = inter[2].reshape(S, P_, P_, k_x)
        return out
    sids, spos, smask, _ = pack_pull_lanes(
        dev[miss], col, row[eb], owner_miss, S * P_, P_, k_max,
        assume_unique=True)
    out["send_ids"] = sids.reshape(S, P_, P_, k_max)
    out["send_pos"] = spos.reshape(S, P_, P_, k_max)
    out["send_mask"] = smask.reshape(S, P_, P_, k_max)
    return out


def collate_device_epoch_loop(es_list: Sequence[EpochSchedule],
                              caches: Sequence[DeviceCache],
                              dv: DeviceView, labels: np.ndarray,
                              batch_size: int, m_max: int,
                              edge_max: Sequence[int], k_max: int,
                              num_steps: int) -> Dict[str, np.ndarray]:
    """Per-(step, worker) reference collation: one ``collate`` +
    ``build_pull_plan`` call per batch. Kept as the oracle the
    vectorized ``collate_device_epoch`` is parity-tested and benchmarked
    against (``benchmarks/assemble.py``)."""
    P_ = len(es_list)
    S = num_steps
    L = len(edge_max)
    _check_num_steps(es_list, S)
    out = _alloc_epoch(P_, S, batch_size, m_max, edge_max, k_max)
    owner_d = dv.owner_d
    for w, es in enumerate(es_list):
        for i in range(len(es.batches)):
            b = es.batches[i]
            cb = collate(b, labels, batch_size, m_max, edge_max)
            dev, miss = _batch_miss(b, caches[w], dv, w)
            m = b.num_input_nodes
            out["input_nodes"][i, w, :m] = dev
            out["labels"][i, w] = cb.labels
            out["seed_mask"][i, w] = cb.seed_mask
            plan = build_pull_plan(dev[miss].astype(np.int32),
                                   np.flatnonzero(miss).astype(np.int32),
                                   owner_d, P_, k_max)
            out["send_ids"][i, w] = plan.send_ids
            out["send_pos"][i, w] = plan.send_pos
            out["send_mask"][i, w] = plan.send_mask
            for l in range(L):
                out["edge_src"][l][i, w] = cb.edge_src[l]
                out["edge_dst"][l][i, w] = cb.edge_dst[l]
                out["edge_mask"][l][i, w] = cb.edge_mask[l]
    return out


def stack_caches(caches: Sequence[DeviceCache], dv: DeviceView,
                 n_hot: int):
    """Stack per-worker hot sets into (P, n_hot) ids + (P, n_hot, d) rows.

    Ids stay sorted with CACHE_PAD tail padding (the device sentinel), so
    the binary-search ``cache_lookup`` works shard-locally unchanged.
    Raises when a cache exceeds ``n_hot``: the collation already routed
    those ids through C_s, so dropping them here would silently train on
    zero feature rows (same contract as build_pull_plan's overflow).
    """
    P_ = len(caches)
    d = dv.table.shape[-1]
    cids = np.full((P_, n_hot), CACHE_PAD, np.int64)
    cfeats = np.zeros((P_, n_hot, d), np.float32)
    for w, c in enumerate(caches):
        k = c.ids.shape[0]
        if k > n_hot:
            raise ValueError(
                f"worker {w} hot set has {k} ids > n_hot={n_hot}; "
                f"truncating would serve zero rows for ids the pull "
                f"plans treat as cache hits")
        cids[w, :k] = c.ids
        cfeats[w, :k] = c.feats
    return cids, cfeats


def prefetch_stream(send: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Roll the per-step pull plans one step forward (step i's scan body
    pulls step i+1's misses) and fully MASK the final element: the roll
    wraps step 0's plan to the last scan step, whose pull is discarded,
    so shipping its real lanes would be a wasted fetch. The masked
    element keeps the collective shape-static (the all_to_all still
    runs) but requests only zero lanes -- fetch accounting is unchanged
    because lane counts come from the un-rolled host arrays.

    send: dict of (S, ...) arrays -- the flat ``send_*`` triplet or the
    two-tier ``intra_*``/``inter_*`` sextet; keys ending in ``mask``
    are AND-masked, the rest zeroed on the dead final element.
    """
    S = next(iter(send.values())).shape[0]
    out = {}
    for key, a in send.items():
        rolled = jnp.roll(a, -1, axis=0)
        live = (jnp.arange(S) < S - 1).reshape((S,) + (1,) * (a.ndim - 1))
        out[key] = (rolled & live if key.endswith("mask")
                    else jnp.where(live, rolled, 0))
    return out


def _pmean_train_step(cfg: GNNConfig, opt, params, opt_state, feats, x,
                      axis="data"):
    """Shared scan-body tail for both epoch programs: batch loss/grad,
    pmean over the full worker ``axis`` (``"data"`` flat, ``("dcn",
    "data")`` hierarchical -- the same all-group AllReduce, so params
    stay replicated and curves stay bit-comparable), optimizer update.
    -> (params, opt_state, loss, acc)."""

    def lf(p):
        return loss_fn(cfg, p, feats, x["edge_src"], x["edge_dst"],
                       x["edge_mask"], x["labels"], x["seed_mask"])

    (loss, acc), grads = jax.value_and_grad(lf, has_aux=True)(params)
    grads, loss, acc = jax.lax.pmean((grads, loss, acc), axis)
    p2, o2 = opt.update(grads, opt_state, params)
    return p2, o2, loss, acc


def make_pipelined_epoch(cfg: GNNConfig, opt, mesh, m_max: int,
                         assemble_backend: str = "auto",
                         assemble_interpret: bool = False,
                         topology=None):
    """-> epoch_fn(params, opt_state, table, offsets, cache_ids,
    cache_feats, batches) running S pipelined steps on the mesh.

    Per scan step (DESIGN.md §6.3): pull step i+1's residual misses
    (carried to the next iteration) while training on step i's features,
    assembled by the fused single-pass kernel (local shard > cache C_s >
    pulled residuals resolved per row, one output materialization --
    ``kernels/assemble``, backend selected by ``assemble_backend``);
    grads are pmean'd over the full worker axis so params stay
    replicated. Returns (params, opt_state, losses (S,), accs (S,)).

    A hierarchical ``topology`` switches the pull to the TWO-TIER
    exchange (``pull_shard_two_tier``: intra-host lanes over the ici
    axis, cross-host lanes over the flattened (dcn, data) pair) and the
    worker axis to ``("dcn", "data")`` -- bit-equal curves, cheaper
    same-host wires (DESIGN.md §6.7).
    """
    hier = topology is not None and topology.is_hierarchical
    ax = topology.worker_axes if topology is not None else "data"
    pull_keys = PULL_KEYS_HIER if hier else PULL_KEYS_FLAT

    def epoch_fn(params, opt_state, table, offsets, cache_ids,
                 cache_feats, batches):

        def device_epoch(params, opt_state, tbl, offs, cids, cfeats, bt):
            tbl = tbl[0]                          # (n_per, d) my shard
            base = offs.reshape(-1)[0]
            cids32 = to_device_ids(cids[0])       # (n_hot,) sorted int32
            cfe = cfeats[0]
            bt = jax.tree.map(lambda a: a[:, 0], bt)   # drop worker dim

            def pull(send):
                if hier:
                    return pull_shard_two_tier(tbl, send, base, m_max,
                                               world_axes=ax)
                return pull_shard(tbl, send["send_ids"], send["send_pos"],
                                  send["send_mask"], base, m_max)

            def assemble(pulled, ids):
                return assemble_features(
                    tbl, base, cids32, cfe, to_device_ids(ids), pulled,
                    backend=assemble_backend,
                    interpret=assemble_interpret)

            send = {k: bt[k] for k in pull_keys}
            # prefetch stream: step i's body pulls step i+1's misses; the
            # wrapped final element is fully masked (its pull would be
            # discarded), so no real lanes ride the wasted wrap fetch
            xs = {
                "input_nodes": bt["input_nodes"],
                "labels": bt["labels"],
                "seed_mask": bt["seed_mask"],
                "edge_src": bt["edge_src"],
                "edge_dst": bt["edge_dst"],
                "edge_mask": bt["edge_mask"],
                "next_send": prefetch_stream(send),
            }
            pulled0 = pull(jax.tree.map(lambda a: a[0], send))

            def step(carry, x):
                params, opt_state, pulled = carry
                nxt = pull(x["next_send"])        # overlap: no dep on train
                feats = assemble(pulled, x["input_nodes"])
                p2, o2, loss, acc = _pmean_train_step(
                    cfg, opt, params, opt_state, feats, x, axis=ax)
                return (p2, o2, nxt), (loss, acc)

            (params, opt_state, _), (losses, accs) = jax.lax.scan(
                step, (params, opt_state, pulled0), xs)
            return params, opt_state, losses, accs

        return shard_map(
            device_epoch, mesh=mesh,
            in_specs=(P(), P(), P(ax), P(ax), P(ax),
                      P(ax), P(None, ax)),
            out_specs=(P(), P(), P(), P()), check_rep=False,
        )(params, opt_state, table, offsets, cache_ids, cache_feats,
          batches)

    return epoch_fn


def make_ondemand_epoch(cfg: GNNConfig, opt, mesh, m_max: int,
                        assemble_backend: str = "auto",
                        assemble_interpret: bool = False,
                        topology=None):
    """-> epoch_fn(params, opt_state, table, offsets, batches): the
    DGL-style on-demand baseline as a NON-overlapped scan.

    Same mesh, same pull-plan wire format, same train step and the SAME
    fused assembly path as ``make_pipelined_epoch`` (cache-less:
    ``assemble_features`` with no C_s, so local shard > pulled) -- the
    rapid-vs-baseline comparison assembles features identically. But no
    software pipeline: step i's all_to_all pull feeds step i's own
    features, so the collective sits on the trainer's critical path
    every step. This is the device analogue of
    ``core.runtime.BaselineRunner``, making device rapid-vs-baseline
    step time directly measurable (DESIGN.md §6.5). Collate its batches
    with EMPTY caches so every remote id rides the pull lanes. A
    hierarchical ``topology`` switches pulls to the two-tier exchange,
    same as ``make_pipelined_epoch``.
    """
    hier = topology is not None and topology.is_hierarchical
    ax = topology.worker_axes if topology is not None else "data"
    pull_keys = PULL_KEYS_HIER if hier else PULL_KEYS_FLAT

    def epoch_fn(params, opt_state, table, offsets, batches):

        def device_epoch(params, opt_state, tbl, offs, bt):
            tbl = tbl[0]                          # (n_per, d) my shard
            base = offs.reshape(-1)[0]
            bt = jax.tree.map(lambda a: a[:, 0], bt)   # drop worker dim

            def step(carry, x):
                params, opt_state = carry
                # pull THIS step's remote rows: the train step below
                # depends on it, so nothing overlaps (on-demand fetch)
                if hier:
                    pulled = pull_shard_two_tier(tbl, x, base, m_max,
                                                 world_axes=ax)
                else:
                    pulled = pull_shard(tbl, x["send_ids"], x["send_pos"],
                                        x["send_mask"], base, m_max)
                feats = assemble_features(
                    tbl, base, None, None,
                    to_device_ids(x["input_nodes"]), pulled,
                    backend=assemble_backend,
                    interpret=assemble_interpret)
                p2, o2, loss, acc = _pmean_train_step(
                    cfg, opt, params, opt_state, feats, x, axis=ax)
                return (p2, o2), (loss, acc)

            xs = {k: bt[k] for k in
                  ("input_nodes", "labels", "seed_mask", "edge_src",
                   "edge_dst", "edge_mask") + pull_keys}
            (params, opt_state), (losses, accs) = jax.lax.scan(
                step, (params, opt_state), xs)
            return params, opt_state, losses, accs

        return shard_map(
            device_epoch, mesh=mesh,
            in_specs=(P(), P(), P(ax), P(ax), P(None, ax)),
            out_specs=(P(), P(), P(), P()), check_rep=False,
        )(params, opt_state, table, offsets, batches)

    return epoch_fn


def empty_caches(num_parts: int, feat_dim: int) -> List[DeviceCache]:
    """Per-worker EMPTY hot sets: the no-cache (baseline) collation key.
    ``_batch_miss`` then routes every remote id through the pull lanes."""
    return [DeviceCache(ids=np.zeros(0, np.int64),
                        feats=np.zeros((0, feat_dim), np.float32))
            for _ in range(num_parts)]
