"""Multi-epoch device runners: Alg. 1's epoch loop on the SPMD mesh.

``DeviceRapidGNNRunner`` drives N epochs through ``make_pipelined_epoch``
with the paper's double-buffer protocol (DESIGN.md §6.5): while epoch e
trains on device against C_s, a BACKGROUND staging thread builds epoch
e+1 -- the next epoch's schedule itself when the ``WorkerSchedule`` is
lazy/device-resident (the train-overlapped next-epoch build, DESIGN.md
§2.2), then its C_sec (``remap_cache`` + ``stack_caches``) and pull
plans through the VECTORIZED ``collate_device_epoch`` (DESIGN.md §6.6;
whole-epoch numpy, no per-(step, worker) loop, so staging keeps up with
the device at 256+ workers). The main thread blocks only on the device
epoch; whatever staging wall is left AFTER training completes is the
EXPOSED staging wall (``exposed_stage_s``, near zero when training
dominates), and the staged buffers swap in at the epoch boundary
(Alg. 1 l.18) -- the device analogue of
``core.prefetch.SecondaryCacheBuilder``.

Every epoch is collated to GLOBAL static bounds: ``WorkerSchedule.
pad_bounds()`` merged across workers, one ``k_max`` maxed over every
epoch's caches, and ``num_steps`` = the max worker batch count (short
workers get fully masked empty steps). All N epochs therefore run ONE
compiled program -- ``trace_count`` stays 1.

``DeviceBaselineRunner`` is the same loop over ``make_ondemand_epoch``
with EMPTY caches: no C_s, no software pipeline, every remote id pulled
on the critical path -- the DGL-style on-demand path, so device
rapid-vs-baseline step time is measurable on the same mesh.

``assert_host_parity`` checks the device runner's per-epoch residual-miss
lane counts against the host-sim ``RapidGNNRunner``'s ``cache_misses``
batch-exact on the identical schedule (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.schedule import WorkerSchedule, merge_pad_bounds
from repro.fault.inject import TransientFault, fault_point
from repro.models.gnn import GNNConfig, init_params
from repro.dist.gnn_step import (DeviceCache, DeviceView,
                                 collate_device_epoch, empty_caches,
                                 epoch_k_max, epoch_k_max_split,
                                 make_ondemand_epoch,
                                 make_pipelined_epoch, stack_caches)
from repro.dist.topology import Topology
from repro.train.checkpoint import save_run_state


class StagingError(RuntimeError):
    """Epoch staging failed persistently (retry budget exhausted or a
    non-transient error); the original failure rides as ``__cause__``."""


@dataclasses.dataclass
class DeviceEpochReport:
    """Per-epoch accounting from one device runner epoch."""
    epoch: int
    steps: int                  # scan length (global, padded)
    miss_lanes: np.ndarray      # (P,) residual-miss pull lanes per worker
    wire_rows: int              # padded rows the a2a actually moves
    losses: np.ndarray          # (S,) pmean'd per step
    accs: np.ndarray            # (S,)
    wall_time_s: float
    #: host wall of staging the NEXT epoch (schedule build if lazy +
    #: collation + C_sec), overlapped with this epoch's training ...
    stage_s: float = 0.0
    #: ... and the slice of it left exposed after training finished
    #: (what a synchronous stage would add to the critical path is
    #: ``stage_s``; the overlap hides ``stage_s - exposed_stage_s``).
    exposed_stage_s: float = 0.0
    #: 1 when this epoch ran in a degraded mode (e.g. staged cache lost
    #: -> uncached baseline-style epoch), with the reason alongside
    degraded: int = 0
    degrade_reason: str = ""
    #: staging retries spent producing THIS epoch's buffers
    stage_retries: int = 0
    #: two-tier split of ``miss_lanes`` on a hierarchical topology:
    #: same-host lanes (cheap ici wire) vs cross-host lanes (DCN wire);
    #: ``intra + inter == miss_lanes`` elementwise (flat: intra =
    #: miss_lanes, inter = 0 -- every peer counts as same-host)
    intra_lanes: Optional[np.ndarray] = None    # (P,)
    inter_lanes: Optional[np.ndarray] = None    # (P,)
    #: padded-row split of ``wire_rows`` by tier (flat: all intra)
    intra_wire_rows: int = 0
    inter_wire_rows: int = 0

    @property
    def total_miss_lanes(self) -> int:
        return int(self.miss_lanes.sum())

    def payload_bytes(self, feat_dim: int, itemsize: int = 4) -> int:
        """True feature bytes requested (== host-sim remote_bytes)."""
        return self.total_miss_lanes * feat_dim * itemsize

    def request_bytes(self, itemsize: int = 4) -> int:
        """Id bytes shipped on the a2a REQUEST legs (the padded int32 id
        matrices of every pull this epoch) -- the previously
        unaccounted half of the wire (DESIGN.md §6.7)."""
        return int(self.wire_rows) * itemsize

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready export: ``repro.eval.cells.device_cell_result``
        stores these per-epoch records on the campaign ``CellResult``
        (the ``epoch_metrics`` field of ``BENCH_paper.json``)."""
        intra = (self.miss_lanes if self.intra_lanes is None
                 else self.intra_lanes)
        inter = (np.zeros_like(self.miss_lanes)
                 if self.inter_lanes is None else self.inter_lanes)
        return {"epoch": self.epoch, "steps": self.steps,
                "miss_lanes": [int(x) for x in self.miss_lanes],
                "wire_rows": int(self.wire_rows),
                "intra_lanes": [int(x) for x in intra],
                "inter_lanes": [int(x) for x in inter],
                "intra_wire_rows": int(self.intra_wire_rows),
                "inter_wire_rows": int(self.inter_wire_rows),
                "losses": [float(x) for x in self.losses],
                "accs": [float(x) for x in self.accs],
                "wall_time_s": float(self.wall_time_s),
                "stage_s": float(self.stage_s),
                "exposed_stage_s": float(self.exposed_stage_s),
                "degraded": int(self.degraded),
                "degrade_reason": self.degrade_reason,
                "stage_retries": int(self.stage_retries)}


class _DeviceRunnerBase:
    """Shared epoch-loop machinery; subclasses pick program + caches."""

    uses_cache = True
    pulls_beyond_steps = 0      # a2a pulls per epoch in excess of S steps

    def __init__(self, schedules: Sequence[WorkerSchedule], dv: DeviceView,
                 cfg: GNNConfig, opt, mesh, batch_size: int,
                 labels: np.ndarray, seed: int = 0,
                 assemble_backend: str = "auto", *,
                 stage_deadline_s: Optional[float] = None,
                 max_stage_retries: int = 2,
                 stage_retry_base_s: float = 0.01,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 1,
                 topology: Optional[Topology] = None):
        self.assemble_backend = assemble_backend
        # supervision knobs (DESIGN.md §10): a deadline on the overlapped
        # stage future, a bounded retry budget for transient stage
        # failures, and optional periodic atomic run-state checkpoints
        self.stage_deadline_s = stage_deadline_s
        self.max_stage_retries = max_stage_retries
        self.stage_retry_base_s = stage_retry_base_s
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.stage_retries = 0
        self.degraded_epochs = 0
        self.deadline_overruns = 0
        self.recovery_wall_s = 0.0
        self.schedules = list(schedules)
        self.P = len(self.schedules)
        if mesh.devices.size != self.P:
            raise ValueError(f"{self.P} schedules for a "
                             f"{mesh.devices.size}-device mesh")
        self.topo = topology if topology is not None \
            else Topology.flat(self.P)
        if self.topo.num_workers != self.P:
            raise ValueError(
                f"topology {self.topo.describe()} describes "
                f"{self.topo.num_workers} workers, runner has {self.P}")
        if self.topo.is_hierarchical and tuple(mesh.axis_names) != \
                ("dcn", "data"):
            raise ValueError(
                f"hierarchical topology needs a ('dcn', 'data') mesh, "
                f"got axes {tuple(mesh.axis_names)}")
        n_epochs = {len(ws.epochs) for ws in self.schedules}
        if len(n_epochs) != 1:
            raise ValueError(f"workers disagree on epoch count: {n_epochs}")
        self.num_epochs = n_epochs.pop()
        self.dv = dv
        self.cfg = cfg
        self.opt = opt
        self.mesh = mesh
        self.batch_size = batch_size
        self.labels = labels
        self.seed = seed

        # global static bounds: pad_bounds merged across workers, steps /
        # lane bound maxed over every (worker, epoch) -- the
        # one-compilation key (per-epoch bounds would retrigger tracing).
        # One pass loads each (worker, epoch) once (spilled schedules
        # load here and once more when the epoch is staged). Only the
        # bound SCALARS are retained: cache feature rows are rebuilt per
        # staged epoch so at most two epochs' C_s/C_sec are live at once
        # (the paper's 2*n_hot*d memory bound, not E*n_hot*d).
        self.m_max, self.edge_max = merge_pad_bounds(self.schedules)
        self.n_hot = max(1, max(ws.n_hot for ws in self.schedules))
        # hierarchical: k_max bounds the INTRA tier, k_max_inter the
        # cross-host DCN tier; flat: k_max is the single-tier bound and
        # k_max_inter stays 1 (unused)
        self.num_steps, self.k_max, self.k_max_inter = 0, 1, 1
        for e in range(self.num_epochs):
            es_list = [ws.epoch(e) for ws in self.schedules]
            # ids-only cache view: the lane bound never touches feats
            ids_only = self._caches_for(es_list, ids_only=True)
            self.num_steps = max(self.num_steps,
                                 max(es.num_batches for es in es_list))
            if self.topo.is_hierarchical:
                k_i, k_x = epoch_k_max_split(es_list, ids_only, self.dv,
                                             self.topo)
                self.k_max = max(self.k_max, k_i)
                self.k_max_inter = max(self.k_max_inter, k_x)
            else:
                self.k_max = max(self.k_max,
                                 epoch_k_max(es_list, ids_only, self.dv))

        self.trace_count = 0
        self._fn = jax.jit(self._counted(self._make_epoch_fn()))
        self.params: Optional[Any] = None
        self.opt_state: Optional[Any] = None
        self.stage_time_s = 0.0     # host-side staging wall (cumulative)
        self.exposed_stage_s = 0.0  # slice of it NOT hidden by training

    def _caches_for(self, es_list, ids_only: bool = False
                    ) -> List[DeviceCache]:
        d = self.dv.table.shape[-1]
        if not self.uses_cache:
            return empty_caches(self.P, d)
        if ids_only:
            return [DeviceCache(ids=np.sort(self.dv.g2d[es.cache_ids]),
                                feats=np.zeros((0, d), np.float32))
                    for es in es_list]
        return [self.dv.remap_cache(es.cache_ids) for es in es_list]

    def _counted(self, fn):
        def wrapped(*args):
            self.trace_count += 1   # fires once per XLA trace, not per call
            return fn(*args)
        return wrapped

    # -- per-epoch staging (the host half of the double buffer) ---------

    def _stage(self, e: int, attempt: int = 0) -> Dict[str, Any]:
        fault_point("stage", attempt=attempt, epoch=e)
        t0 = time.perf_counter()
        out = self._stage_inner(e)
        dt = time.perf_counter() - t0
        self.stage_time_s += dt
        out["stage_s"] = dt
        return out

    def _collate_and_account(self, es_list, caches, k_max: int,
                             k_max_inter: int) -> Dict[str, Any]:
        """Collate one epoch and derive its per-tier lane/wire
        accounting: true per-requesting-worker lane counts from the
        masks, padded wire rows from the static shapes. On a flat
        topology the whole exchange counts as the intra tier (every
        peer is same-host); hierarchical splits by tier, and the tiers
        sum to exactly what the flat plan would count -- the byte-sum
        identity ``verify`` pins (DESIGN.md §6.7)."""
        batches = collate_device_epoch(
            es_list, caches, self.dv, self.labels, self.batch_size,
            self.m_max, self.edge_max, k_max, self.num_steps,
            topology=self.topo, k_max_inter=k_max_inter)
        # padded rows the program's all_to_alls move: the pipelined epoch
        # issues one extra pull (the pre-scan pulled0; its final wrap pull
        # is part of the S in-scan pulls), the on-demand epoch exactly S
        pulls = self.num_steps + self.pulls_beyond_steps
        if self.topo.is_hierarchical:
            intra = batches["intra_mask"].sum(axis=(0, 2, 3)) \
                .astype(np.int64)
            inter = batches["inter_mask"].sum(axis=(0, 2, 3)) \
                .astype(np.int64)
            _, P_, D, k_i = batches["intra_mask"].shape
            k_x = batches["inter_mask"].shape[-1]
            wire_intra = pulls * P_ * D * k_i
            wire_inter = pulls * P_ * P_ * k_x
        else:
            intra = batches["send_mask"].sum(axis=(0, 2, 3)) \
                .astype(np.int64)
            inter = np.zeros_like(intra)
            _, P_, _, k = batches["send_mask"].shape
            wire_intra = pulls * P_ * P_ * k
            wire_inter = 0
        return {
            "batches": jax.tree.map(jnp.asarray, batches),
            "lanes": intra + inter,
            "intra_lanes": intra,
            "inter_lanes": inter,
            "wire_rows": wire_intra + wire_inter,
            "intra_wire_rows": wire_intra,
            "inter_wire_rows": wire_inter,
        }

    def _stage_inner(self, e: int) -> Dict[str, Any]:
        es_list = [ws.epoch(e) for ws in self.schedules]
        caches = self._caches_for(es_list)
        staged = self._collate_and_account(es_list, caches, self.k_max,
                                           self.k_max_inter)
        if self.uses_cache:
            # the staged C_s can be LOST (fault plane): the epoch then
            # degrades to an uncached rebuild instead of failing the run
            if fault_point("stage_cache", epoch=e):
                staged["cache_lost"] = True
            else:
                cids, cfeats = stack_caches(caches, self.dv, self.n_hot)
                staged["cids"] = jnp.asarray(cids)
                staged["cfeats"] = jnp.asarray(cfeats)
        return staged

    def _stage_supervised(self, e: int, start_attempt: int = 0
                          ) -> Tuple[Dict[str, Any], int]:
        """Stage epoch ``e`` with a bounded transient-retry budget.

        Returns ``(staged, retries_used)``. Staging is deterministic
        given ``(schedule, e)``, so a retried or eagerly-rebuilt stage is
        bit-identical to the one the background thread would have built.
        """
        err: Optional[BaseException] = None
        for i in range(self.max_stage_retries + 1):
            if i:
                time.sleep(self.stage_retry_base_s * 2 ** (i - 1))
                self.stage_retries += 1
            try:
                return self._stage(e, attempt=start_attempt + i), i
            except TransientFault as exc:
                err = exc
        raise StagingError(f"staging epoch {e} failed after "
                           f"{self.max_stage_retries} retries") from err

    def _await_stage(self, fut, e: int) -> Tuple[Dict[str, Any], int]:
        """Collect the overlapped stage of epoch ``e``; on deadline
        overrun or a dead staging thread, rebuild EAGERLY on the critical
        path (counted in ``recovery_wall_s``) -- graceful degradation,
        never a different schedule."""
        try:
            return fut.result(timeout=self.stage_deadline_s), 0
        except FuturesTimeout:
            self.deadline_overruns += 1
        except Exception:
            pass    # dead stage thread: the eager rebuild retries fresh
        t0 = time.perf_counter()
        # start_attempt=1: the background attempt 0 already fired, so a
        # transient fault keyed to attempt 0 clears here deterministically
        staged, retries = self._stage_supervised(e, start_attempt=1)
        self.recovery_wall_s += time.perf_counter() - t0
        self.stage_retries += 1
        return staged, retries + 1

    def _degrade_uncached(self, e: int) -> Dict[str, Any]:
        """Rebuild epoch ``e`` with EMPTY caches after the staged C_s was
        lost: every remote id goes through the pull pipeline for this one
        epoch (baseline-style, counted as degraded). The lane bound may
        grow past the cached ``k_max``, which costs at most ONE extra XLA
        trace for the degraded epoch; feature values are unchanged, so
        the loss curve still matches the clean run bit-for-bit."""
        es_list = [ws.epoch(e) for ws in self.schedules]
        d = self.dv.table.shape[-1]
        caches = empty_caches(self.P, d)
        if self.topo.is_hierarchical:
            k_i, k_x = epoch_k_max_split(es_list, caches, self.dv,
                                         self.topo)
            k = max(self.k_max, k_i)
            kx = max(self.k_max_inter, k_x)
        else:
            k = max(self.k_max, epoch_k_max(es_list, caches, self.dv))
            kx = self.k_max_inter
        staged = self._collate_and_account(es_list, caches, k, kx)
        cids, cfeats = stack_caches(caches, self.dv, self.n_hot)
        staged["cids"] = jnp.asarray(cids)
        staged["cfeats"] = jnp.asarray(cfeats)
        staged["stage_s"] = 0.0
        return staged

    # -- the epoch loop --------------------------------------------------

    def run(self, params=None, opt_state=None, start_epoch: int = 0,
            stop_epoch: Optional[int] = None) -> List[DeviceEpochReport]:
        """Drive epochs ``[start_epoch, stop_epoch)`` (defaults: all).

        The window exists for checkpoint resume: run ``[0, k)``, save
        ``self.params``/``self.opt_state``, then a FRESH runner restored
        from the checkpoint runs ``[k, N)`` -- static bounds are global,
        so both windows share one compiled program and the concatenated
        loss curve matches an uninterrupted run bit-for-bit."""
        if stop_epoch is None:
            stop_epoch = self.num_epochs
        if not 0 <= start_epoch < stop_epoch <= self.num_epochs:
            raise ValueError(f"bad epoch window [{start_epoch}, "
                             f"{stop_epoch}) for {self.num_epochs} epochs")
        if params is None:
            params = init_params(self.cfg, jax.random.key(self.seed))
        if opt_state is None:
            opt_state = self.opt.init(params)
        table = jnp.asarray(self.dv.table)
        offsets = jnp.asarray(self.dv.offsets)
        reports: List[DeviceEpochReport] = []
        # bootstrap C_s (Alg. 1 l.4), supervised: transient stage faults
        # retry in place instead of killing the run
        staged, pending_retries = self._stage_supervised(start_epoch)
        with self.mesh, ThreadPoolExecutor(max_workers=1) as pool:
            for e in range(start_epoch, stop_epoch):
                t0 = time.perf_counter()
                degraded, reason = 0, ""
                if self.uses_cache and staged.get("cache_lost"):
                    # staged cache lost: run e UNCACHED (one degraded
                    # epoch, Alg. 1 degenerating to the baseline path)
                    t_rec = time.perf_counter()
                    staged = self._degrade_uncached(e)
                    self.recovery_wall_s += time.perf_counter() - t_rec
                    self.degraded_epochs += 1
                    degraded, reason = 1, "cache_lost"
                params, opt_state, losses, accs = self._run_epoch(
                    params, opt_state, table, offsets, staged)
                # dispatch is async: a background thread stages epoch
                # e+1 (lazy schedule build + C_sec + plans) WHILE the
                # device trains epoch e. numpy/XLA release the GIL, so
                # the two genuinely overlap even single-host ...
                fut = (pool.submit(self._stage, e + 1, 0)
                       if e + 1 < stop_epoch else None)
                losses = np.asarray(losses)     # block on the device epoch
                accs = np.asarray(accs)
                t_done = time.perf_counter()
                nxt, nxt_retries = ((None, 0) if fut is None
                                    else self._await_stage(fut, e + 1))
                exposed = (time.perf_counter() - t_done
                           if fut is not None else 0.0)
                self.exposed_stage_s += exposed
                reports.append(DeviceEpochReport(
                    epoch=e, steps=self.num_steps,
                    miss_lanes=staged["lanes"],
                    wire_rows=staged["wire_rows"],
                    intra_lanes=staged.get("intra_lanes"),
                    inter_lanes=staged.get("inter_lanes"),
                    intra_wire_rows=staged.get("intra_wire_rows", 0),
                    inter_wire_rows=staged.get("inter_wire_rows", 0),
                    losses=losses, accs=accs,
                    wall_time_s=time.perf_counter() - t0,
                    stage_s=(nxt["stage_s"] if nxt is not None else 0.0),
                    exposed_stage_s=exposed,
                    degraded=degraded, degrade_reason=reason,
                    stage_retries=pending_retries))
                self.params, self.opt_state = params, opt_state
                if (self.checkpoint_dir is not None
                        and (e + 1) % self.checkpoint_every == 0):
                    # atomic run-state commit; the crash probe AFTER it
                    # models dying between epochs -- resume picks up from
                    # LATEST and the stitched loss curve is bit-equal
                    save_run_state(self.checkpoint_dir,
                                   {"params": params, "opt": opt_state},
                                   step=e + 1)
                    fault_point("run_crash", epoch=e + 1)
                staged, pending_retries = nxt, nxt_retries
        self.params, self.opt_state = params, opt_state
        return reports

    # subclass hooks ------------------------------------------------------

    def _make_epoch_fn(self):
        raise NotImplementedError

    def _run_epoch(self, params, opt_state, table, offsets, staged):
        raise NotImplementedError


class DeviceRapidGNNRunner(_DeviceRunnerBase):
    """Paper Alg. 1 on the mesh: C_s/C_sec double buffer + pipelined pull."""

    uses_cache = True
    pulls_beyond_steps = 1      # the pre-scan pulled0 priming the pipeline

    def _make_epoch_fn(self):
        return make_pipelined_epoch(self.cfg, self.opt, self.mesh,
                                    self.m_max,
                                    assemble_backend=self.assemble_backend,
                                    topology=self.topo)

    def _run_epoch(self, params, opt_state, table, offsets, staged):
        return self._fn(params, opt_state, table, offsets, staged["cids"],
                        staged["cfeats"], staged["batches"])


class DeviceBaselineRunner(_DeviceRunnerBase):
    """DGL-style on-demand path: no cache, pull on the critical path."""

    uses_cache = False

    def _make_epoch_fn(self):
        return make_ondemand_epoch(self.cfg, self.opt, self.mesh,
                                   self.m_max,
                                   assemble_backend=self.assemble_backend,
                                   topology=self.topo)

    def _run_epoch(self, params, opt_state, table, offsets, staged):
        return self._fn(params, opt_state, table, offsets,
                        staged["batches"])


def host_miss_matrix(schedules: Sequence[WorkerSchedule], pg,
                     batch_size: int) -> np.ndarray:
    """(E, P) host-sim ``cache_misses`` per (epoch, worker): every worker
    run through ``core.runtime.RapidGNNRunner`` on the same schedule."""
    from repro.core.fetch import ShardedFeatureStore
    from repro.core.metrics import NetworkModel
    from repro.core.runtime import RapidGNNRunner

    E = len(schedules[0].epochs)
    out = np.zeros((E, len(schedules)), np.int64)
    for w, ws in enumerate(schedules):
        store = ShardedFeatureStore(pg, worker=w,
                                    net=NetworkModel(enabled=False))
        m = RapidGNNRunner(ws, store, batch_size=batch_size).run()
        out[:, w] = [em.cache_misses for em in m.epochs]
    return out


def assert_host_parity(schedules: Sequence[WorkerSchedule], pg,
                       batch_size: int,
                       reports: Sequence[DeviceEpochReport]) -> np.ndarray:
    """Device residual-miss lanes == host-sim cache_misses, per (epoch,
    worker). The two paths count the SAME miss sets from independent code
    (numpy searchsorted vs pull-plan lanes), so equality pins the device
    fetch accounting to the paper's (DESIGN.md §7). Returns the matrix."""
    host = host_miss_matrix(schedules, pg, batch_size)
    dev = np.stack([r.miss_lanes for r in reports])
    np.testing.assert_array_equal(
        dev, host,
        err_msg="device pull-lane counts diverge from host cache_misses")
    return host
