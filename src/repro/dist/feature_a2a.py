"""SPMD cache-first feature exchange: the device realisation of the
paper's VectorPull / SyncPull over a flat ``("data",)`` or hierarchical
``("dcn", "data")`` mesh (DESIGN.md §6; topology layer §6.7).

Host-sim counterpart: ``repro.core.fetch.ShardedFeatureStore``. Here the
"distributed KV store" is a partition-sharded feature table resident in
device memory -- ``table[(P, n_per, d)]`` sharded on its leading dim over
``data`` -- and a remote fetch is one ``all_to_all`` round trip:

  1. every worker sends each owner the (deduped, offline-enumerated) slot
     requests it needs from that owner   -- ids up the wire,
  2. each owner gathers the rows from its local shard,
  3. a second ``all_to_all`` returns the rows, which the requester
     scatters into its padded (m_max, d) batch buffer by ``send_pos``.

The request matrix is the PULL-PLAN WIRE FORMAT (DESIGN.md §6.2), built
OFFLINE by ``build_pull_plan`` from the deterministic schedule -- this is
what makes the exchange a static-shape collective XLA can overlap with
compute, instead of a dynamic RPC storm.

On a hierarchical mesh (``repro.dist.topology.Topology``) the plan is
TWO-TIER: ``pack_pull_lanes_two_tier`` splits each worker's misses by
whether the owner shares its host -- same-host lanes ride a cheap
intra-host ``all_to_all`` over the ici ``data`` axis (owner addressed
by LOCAL device index), cross-host lanes a separate batched exchange
over the flattened ``("dcn", "data")`` axis pair. The union of the two
tiers is bit-equal to the flat plan (the parity property pins it), and
``pull_shard_two_tier`` scatter-adds both tiers' disjoint contributions
into one buffer, bit-equal to the flat pull.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.kernels.cache_lookup.ops import cache_lookup


@dataclasses.dataclass(frozen=True)
class PullPlan:
    """One worker's residual-miss requests for one batch.

    Wire format (DESIGN.md §6.2): row ``p`` of each array is this
    worker's request lane to owner ``p``; lanes are padded to the
    epoch-level ``k_max`` so every step reuses one compiled program.
    ``send_pos`` is the destination row in the requester's padded
    (m_max, d) feature buffer -- the owner never needs it, it rides
    along host-side only.
    """
    send_ids: np.ndarray    # (P, k_max) int32  requested ids (0 padded)
    send_pos: np.ndarray    # (P, k_max) int32  dst row in the batch buffer
    send_mask: np.ndarray   # (P, k_max) bool   lane validity
    counts: np.ndarray      # (P,) int32        true request count per owner

    @property
    def k_max(self) -> int:
        return int(self.send_ids.shape[1])

    def payload_bytes(self, row_bytes: int) -> int:
        """Feature bytes actually requested (un-padded)."""
        return int(self.counts.sum()) * row_bytes

    def wire_bytes(self, row_bytes: int) -> int:
        """Feature bytes moved by the padded all_to_all return leg."""
        return int(self.send_ids.size) * row_bytes

    def request_bytes(self) -> int:
        """Id bytes moved by the padded all_to_all REQUEST leg (the
        first collective in ``pull_shard`` ships the full (P, k_max)
        int32 id matrix) -- previously unaccounted, so the return leg's
        ``wire_bytes`` understated the true wire total by P*k_max*4."""
        return int(self.send_ids.size) * int(self.send_ids.itemsize)


def build_pull_plan(ids: np.ndarray, pos: np.ndarray, owner: np.ndarray,
                    num_parts: int, k_max: int) -> PullPlan:
    """Pack (id -> buffer position) requests into per-owner lanes.

    ids (m,) requested node ids (negative = padding, dropped); pos (m,)
    destination rows, same length; owner (N,) id -> owning worker. Exact
    duplicate (id, pos) pairs are deduped to one lane slot; the same id
    at *distinct* positions keeps one slot per position (each output row
    must receive its feature -- ids are already unique per batch in the
    GNN path, where the sampler dedupes ``input_nodes``).

    Raises ValueError when any owner's request count exceeds ``k_max``
    (silent truncation would drop features and corrupt training).
    """
    ids = np.asarray(ids)
    pos = np.asarray(pos)
    if ids.shape != pos.shape:
        raise ValueError(f"ids/pos length mismatch: {ids.shape} vs {pos.shape}")
    valid = ids >= 0
    ids, pos = ids[valid].astype(np.int64), pos[valid].astype(np.int64)
    if ids.size:
        pairs = np.unique(np.stack([ids, pos], axis=1), axis=0)
        ids, pos = pairs[:, 0], pairs[:, 1]
    dest = np.asarray(owner)[ids].astype(np.int64)
    # validate BEFORE bincount: a negative owner would crash it with an
    # opaque "negative values" error, and the historical post-hoc
    # ``counts.size > num_parts`` check only caught the too-HIGH side
    if ids.size and (int(dest.min()) < 0 or int(dest.max()) >= num_parts):
        raise ValueError(f"owner id out of range: [{dest.min()}, "
                         f"{dest.max()}] not in [0, {num_parts})")
    counts = np.bincount(dest, minlength=num_parts).astype(np.int32)
    if ids.size and int(counts.max()) > k_max:
        over = np.flatnonzero(counts > k_max)
        raise ValueError(
            f"pull plan overflow: owners {over.tolist()} requested "
            f"{counts[over].tolist()} rows > k_max={k_max}; raise k_max "
            f"(epoch_k_max gives the exact bound)")

    send_ids = np.zeros((num_parts, k_max), np.int32)
    send_pos = np.zeros((num_parts, k_max), np.int32)
    send_mask = np.zeros((num_parts, k_max), bool)
    order = np.argsort(dest, kind="stable")
    start = np.zeros(num_parts + 1, np.int64)
    np.cumsum(counts, out=start[1:])
    lane = np.arange(ids.size) - start[dest[order]]
    send_ids[dest[order], lane] = ids[order].astype(np.int32)
    send_pos[dest[order], lane] = pos[order].astype(np.int32)
    send_mask[dest[order], lane] = True
    return PullPlan(send_ids=send_ids, send_pos=send_pos,
                    send_mask=send_mask, counts=counts)


def _fast_key_fits(num_groups: int, num_parts: int, span_i: int,
                   span_p: int) -> bool:
    """True when the rebased composite (group, id, pos) key fits int64
    headroom (< 2**62), i.e. the single-sort fast path is safe. Spans
    are REBASED extents (``max - min + 1``), not absolute maxima --
    exposed for the boundary regression tests."""
    return num_groups * num_parts * span_i * span_p < 2 ** 62


def pack_pull_lanes(ids: np.ndarray, pos: np.ndarray, group: np.ndarray,
                    owner: np.ndarray, num_groups: int, num_parts: int,
                    k_max: int, assume_unique: bool = False):
    """Batched ``build_pull_plan``: pack MANY batches' requests into
    per-(group, owner) lanes in one vectorized pass (DESIGN.md §6.6).

    ids/pos/group/owner are aligned (n,) arrays -- one element per
    requested (id -> buffer position), ``group`` the flat batch ordinal
    (e.g. ``step * P + worker``) and ``owner`` the owning worker of each
    id. Negative ids (padding) are dropped; exact (group, id, pos)
    duplicates collapse to one lane slot; lanes within a (group, owner)
    pair are ordered by ascending (id, pos) -- all three semantics
    identical to calling ``build_pull_plan`` once per group, which the
    collation parity tests pin. ``assume_unique=True`` skips the dedupe
    pass -- valid when ids are unique within each group, the sampler's
    ``input_nodes`` invariant.

    -> (send_ids, send_pos, send_mask) of shape (num_groups, num_parts,
    k_max) plus counts (num_groups, num_parts). Raises on lane overflow
    (silent truncation would corrupt training) and out-of-range owners.
    """
    ids = np.asarray(ids, dtype=np.int64)       # no copy when already i64
    pos = np.asarray(pos, dtype=np.int64)
    group = np.asarray(group, dtype=np.int64)
    owner = np.asarray(owner, dtype=np.int64)
    valid = ids >= 0
    if not valid.all():
        ids, pos, group, owner = (a[valid] for a in (ids, pos, group,
                                                     owner))
    if ids.size and (owner.min() < 0 or owner.max() >= num_parts):
        raise ValueError(f"owner id out of range: [{owner.min()}, "
                         f"{owner.max()}] not in [0, {num_parts})")
    shape = (num_groups, num_parts, k_max)
    send_ids = np.zeros(shape, np.int32)
    send_pos = np.zeros(shape, np.int32)
    send_mask = np.zeros(shape, bool)
    counts = np.zeros((num_groups, num_parts), np.int32)
    if not ids.size:
        return send_ids, send_pos, send_mask, counts
    gidx = group * num_parts + owner
    # (group, id, pos) ordering via ONE composite int64 key when the
    # value ranges allow it -- a single introsort beats the 3-key
    # lexsort ~3x at epoch scale. Stability is irrelevant: the key is
    # unique per lane except for EXACT duplicates, which dedupe anyway.
    # Keys are REBASED to the observed min so only the id/pos SPANS
    # spend key bits: a large device-id base (big P*n_per meshes put
    # every id near P*n_per) must not push an epoch whose actual id
    # range is tiny onto the slow lexsort fallback.
    imin, pmin = int(ids.min()), int(pos.min())
    span_i = int(ids.max()) - imin + 1
    span_p = int(pos.max()) - pmin + 1
    if _fast_key_fits(num_groups, num_parts, span_i, span_p):
        key = (gidx * span_i + (ids - imin)) * span_p + (pos - pmin)
        order = np.argsort(key)
        if not assume_unique:
            k_s = key[order]
            keep = np.ones(k_s.size, bool)  # drop exact duplicate lanes
            keep[1:] = k_s[1:] != k_s[:-1]
            order = order[keep]
    else:                                   # huge spans: lexsort fallback
        order = np.lexsort((pos, ids, gidx))
        if not assume_unique:
            g0, i0, p0 = gidx[order], ids[order], pos[order]
            keep = np.ones(g0.size, bool)
            keep[1:] = ((g0[1:] != g0[:-1]) | (i0[1:] != i0[:-1])
                        | (p0[1:] != p0[:-1]))
            order = order[keep]
    g_s, i_s, p_s = gidx[order], ids[order], pos[order]
    cnt = np.bincount(g_s, minlength=num_groups * num_parts)
    if int(cnt.max()) > k_max:
        over = np.flatnonzero(cnt > k_max)
        raise ValueError(
            f"pull plan overflow: (group, owner) pairs "
            f"{[divmod(int(o), num_parts) for o in over[:8].tolist()]} "
            f"requested {cnt[over[:8]].tolist()} rows > k_max={k_max}; "
            f"raise k_max (epoch_k_max gives the exact bound)")
    start = np.zeros(cnt.size + 1, np.int64)
    np.cumsum(cnt, out=start[1:])
    lane = np.arange(g_s.size) - start[g_s]
    flat = g_s * k_max + lane
    send_ids.reshape(-1)[flat] = i_s.astype(np.int32)
    send_pos.reshape(-1)[flat] = p_s.astype(np.int32)
    send_mask.reshape(-1)[flat] = True
    counts[:] = cnt.reshape(num_groups, num_parts)
    return send_ids, send_pos, send_mask, counts


def pack_pull_lanes_two_tier(ids: np.ndarray, pos: np.ndarray,
                             group: np.ndarray, owner: np.ndarray,
                             requester: np.ndarray, num_groups: int,
                             topo, k_max_intra: int, k_max_inter: int,
                             assume_unique: bool = False):
    """Topology-aware ``pack_pull_lanes``: split each request by whether
    its owner shares the requester's host (DESIGN.md §6.7).

    ``requester`` is the flat worker ordinal issuing each request,
    aligned with ids/pos/group/owner; ``topo`` a
    ``repro.dist.topology.Topology``. Same-host requests pack into
    ``(num_groups, D, k_max_intra)`` lanes addressed by the owner's
    LOCAL device index (the intra-host ``all_to_all`` over the ici axis
    only spans D peers); cross-host requests pack into ``(num_groups,
    P, k_max_inter)`` lanes addressed by the owner's flat ordinal (the
    DCN-tier exchange over the flattened axis pair spans all P). Ids
    stay GLOBAL in both tiers -- the serving side's slot arithmetic is
    base-relative regardless of which wire the request rode.

    -> (intra, inter): two ``pack_pull_lanes``-shaped 4-tuples
    (send_ids, send_pos, send_mask, counts). Their union is bit-equal
    to the flat-mesh ``pack_pull_lanes`` output (each lane appears in
    exactly one tier, same per-(group, owner) ascending (id, pos)
    order), which the two-tier parity property pins.
    """
    ids = np.asarray(ids, dtype=np.int64)
    pos = np.asarray(pos, dtype=np.int64)
    group = np.asarray(group, dtype=np.int64)
    owner = np.asarray(owner, dtype=np.int64)
    requester = np.asarray(requester, dtype=np.int64)
    valid = ids >= 0
    if not valid.all():
        ids, pos, group, owner, requester = (
            a[valid] for a in (ids, pos, group, owner, requester))
    P_ = topo.num_workers
    if ids.size and (owner.min() < 0 or owner.max() >= P_):
        raise ValueError(f"owner id out of range: [{owner.min()}, "
                         f"{owner.max()}] not in [0, {P_})")
    same = topo.same_host(owner, requester)
    intra = pack_pull_lanes(
        ids[same], pos[same], group[same], topo.local_of(owner[same]),
        num_groups, topo.devices_per_host, k_max_intra,
        assume_unique=assume_unique)
    inter = pack_pull_lanes(
        ids[~same], pos[~same], group[~same], owner[~same],
        num_groups, P_, k_max_inter, assume_unique=assume_unique)
    return intra, inter


def pull_shard(table: jnp.ndarray, send_ids: jnp.ndarray,
               send_pos: jnp.ndarray, send_mask: jnp.ndarray,
               base, m_max: int, axis="data") -> jnp.ndarray:
    """Per-device exchange body; call inside shard_map over ``axis``
    (the flat worker axis ``"data"``, or a mesh-axis tuple like
    ``("dcn", "data")`` whose row-major flattening is the worker order).

    table (n_per, d) this worker's shard; send_* (G, k) its request
    lanes, one row per member of the ``axis`` group; base this worker's
    first global slot. -> (m_max, d) buffer with requested rows
    scattered to ``send_pos`` (other rows zero). Padding lanes may
    request owner-slot 0; the requester's send_mask zeroes them at
    scatter, so the mask never has to cross the wire.
    """
    n_per, d = table.shape
    req = jax.lax.all_to_all(send_ids, axis, 0, 0)        # (G, k) asks TO me
    slot = jnp.clip(req - base, 0, n_per - 1)
    rows = table[slot]                                    # (G, k, d) serve
    got = jax.lax.all_to_all(rows, axis, 0, 0)            # (G, k, d) mine
    out = jnp.zeros((m_max, d), table.dtype)
    pos = jnp.where(send_mask, send_pos, 0).reshape(-1)
    contrib = jnp.where(send_mask.reshape(-1, 1), got.reshape(-1, d), 0)
    return out.at[pos].add(contrib)


def pull_shard_two_tier(table: jnp.ndarray, send: dict, base, m_max: int,
                        ici_axis="data",
                        world_axes=("dcn", "data")) -> jnp.ndarray:
    """Two-tier exchange body for a hierarchical mesh (DESIGN.md §6.7).

    ``send`` holds the two-tier lanes from ``pack_pull_lanes_two_tier``:
    ``intra_*`` (D, k_i) same-host requests exchanged over the cheap ici
    ``ici_axis`` (owner = LOCAL device index, ids remain global -- slot
    arithmetic on the serving side is base-relative either way), and
    ``inter_*`` (P, k_x) cross-host requests over the flattened
    ``world_axes`` pair. The two tiers' request sets are DISJOINT (a
    miss is same-host xor cross-host) and every real position receives
    exactly one nonzero contribution, so scatter-adding both tiers into
    one zero buffer is bit-equal to the flat single-tier pull.
    """
    n_per, d = table.shape
    out = jnp.zeros((m_max, d), table.dtype)
    for pre, axis in (("intra", ici_axis), ("inter", world_axes)):
        sid, spo, sma = (send[f"{pre}_ids"], send[f"{pre}_pos"],
                         send[f"{pre}_mask"])
        req = jax.lax.all_to_all(sid, axis, 0, 0)
        rows = table[jnp.clip(req - base, 0, n_per - 1)]
        got = jax.lax.all_to_all(rows, axis, 0, 0)
        pos = jnp.where(sma, spo, 0).reshape(-1)
        contrib = jnp.where(sma.reshape(-1, 1), got.reshape(-1, d), 0)
        out = out.at[pos].add(contrib)
    return out


def pull_features(mesh, table: jnp.ndarray, send_ids: jnp.ndarray,
                  send_pos: jnp.ndarray, send_mask: jnp.ndarray,
                  offsets: jnp.ndarray, m_max: int) -> jnp.ndarray:
    """All-worker a2a feature pull against the partition-sharded table.

    table (P, n_per, d) sharded over ``data``; send_* (P, P, k_max) --
    dim 0 the requesting worker (sharded), dim 1 the owner lane;
    offsets (P,) int32 first global slot of each partition.
    -> (P, m_max, d) per-worker scattered feature buffers.
    """
    def body(tbl, sid, spo, sma, off):
        return pull_shard(tbl[0], sid[0], spo[0], sma[0],
                          off.reshape(-1)[0], m_max)[None]

    return shard_map(
        body, mesh=mesh,
        in_specs=(P("data"), P("data"), P("data"), P("data"), P("data")),
        out_specs=P("data"), check_rep=False,
    )(table, send_ids, send_pos, send_mask, offsets)


def cache_gather(cache_ids: jnp.ndarray, cache_feats: jnp.ndarray,
                 query: jnp.ndarray, base: jnp.ndarray):
    """Hot-set C_s merge: overlay cache hits onto a pre-filled buffer.

    cache_ids (n_hot,) SORTED int32 (INT32_MAX padded); cache_feats
    (n_hot, d); query (m,) ids (-1 = padding, never hits); base (m, d)
    buffer already holding pulled/local rows. -> (merged, hit_mask).
    On TPU this is the fused Pallas ``cache_lookup`` kernel; the jnp
    oracle runs everywhere else.
    """
    return cache_lookup(cache_ids, cache_feats, query, base)
