"""NamedSharding factories for the production dry-runs (DESIGN.md §6.4).

Consumed by ``launch/specs.py``: every (arch x shape) combo jits with
explicit in/out shardings built here. The rules are deliberately simple
and divisibility-guarded -- ``fit_spec`` drops any mesh axis whose extent
does not divide the dimension, so one rule set covers all ten archs on
both the 16x16 single-pod and 2x16x16 multi-pod meshes:

  * params: column-parallel default -- widest trailing dim divisible by
    ``model`` is sharded over it; stacked-layer leading dims (R) and
    vocab rows stay unsharded.
  * optimizer state: moments mirror the param shardings; scalars
    replicate.
  * batches: leading batch dim over the data-parallel axes (pod, data);
    M-RoPE position streams (3, B, S) shard dim 1.
  * decode state: batch dim over (pod, data) -- dim 1 for the stacked
    scan caches (R, B, ...), dim 0 for tail caches (B, ...).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.mesh import dp_axes


def _extent(mesh, entry) -> int:
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def fit_spec(mesh, spec, shape) -> P:
    """Drop spec entries whose mesh extent does not divide the dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        size = _extent(mesh, entry)
        out.append(entry if (size > 1 and dim % size == 0) else None)
    return P(*out)


def param_shardings(cfg, mesh, params):
    """Column-parallel default over ``model`` for every weight leaf."""
    tp = mesh.shape.get("model", 1)

    def leaf(x):
        spec = [None] * x.ndim
        if tp > 1:
            for i in range(x.ndim - 1, 0, -1):   # never the leading dim:
                if x.shape[i] % tp == 0 and x.shape[i] >= 2 * tp:
                    spec[i] = "model"            # (R-stacks / vocab rows)
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, params)


def opt_shardings(params_sh, opt_s):
    """Optimizer-state shardings from the param shardings.

    Fields whose pytree structure mirrors the params (AdamW mu/nu, SGD
    momentum) inherit the param shardings; everything else (step
    counters) replicates.
    """
    mesh = jax.tree.leaves(params_sh)[0].mesh
    repl = NamedSharding(mesh, P())
    p_struct = jax.tree.structure(params_sh)
    fields = {}
    for f in opt_s._fields:
        sub = getattr(opt_s, f)
        fields[f] = (params_sh if jax.tree.structure(sub) == p_struct
                     else jax.tree.map(lambda _: repl, sub))
    return type(opt_s)(**fields)


def batch_shardings(cfg, mesh, batch: Dict[str, Any]):
    """Input batches: batch dim over (pod, data), divisibility-guarded."""
    dp = dp_axes(mesh)
    out = {}
    for k, v in batch.items():
        if k == "mrope_positions":               # (3, B, S)
            spec = P(None, dp, None)
        else:                                    # (B, ...)
            spec = P(dp, *([None] * (v.ndim - 1)))
        out[k] = NamedSharding(mesh, fit_spec(mesh, spec, v.shape))
    return out


def decode_state_shardings(cfg, mesh, state):
    """Decode caches: batch dim over (pod, data).

    ``scan`` leaves are stacked per pattern position (R, B, ...); tail
    leaves are unstacked (B, ...). Sequence-dim sharding over ``model``
    is applied inside ``serve.attention.sharded_decode_attention`` via
    shard_map, not here.
    """
    dp = dp_axes(mesh)

    def shard(x, batch_dim):
        spec = [None] * x.ndim
        if x.ndim > batch_dim:
            spec[batch_dim] = dp
        return NamedSharding(mesh, fit_spec(mesh, P(*spec), x.shape))

    return {
        "scan": jax.tree.map(lambda x: shard(x, 1 if x.ndim > 1 else 0),
                             state["scan"]),
        "tail": jax.tree.map(lambda x: shard(x, 0), state["tail"]),
    }
