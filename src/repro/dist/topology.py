"""Hierarchical multi-host topology for the RapidGNN device path.

The flat ``("data",)`` mesh treats every worker pair as equidistant, but
the paper's communication win matters most when workers sit across slow
inter-node links. ``Topology`` describes the machine praxis-style --
``ici_mesh_shape`` (fast intra-host interconnect), ``dcn_mesh_shape``
(slow cross-host data-center network) and ``mesh_axis_names`` -- and
builds the hierarchical mesh plus the worker/host arithmetic every
two-tier collective in ``feature_a2a`` / ``gnn_step`` addresses
(DESIGN.md §6.7).

Axis layout: the DCN axis is OUTER, so the flat worker ordinal of device
``(h, i)`` is ``h * devices_per_host + i`` -- exactly the row-major
flattening ``jax.lax.all_to_all`` applies to a tuple axis name, which is
what keeps the two-tier exchange bit-compatible with the flat one. A
flat topology (``hosts == 1``) degenerates to the ``("data",)`` mesh the
rest of the repo has always run.

``owner_bias`` feeds the weighted ``select_hot_set`` path: hot-set cache
admission can up-weight features whose owners sit across the DCN
boundary, trading cheap intra-host misses for fewer expensive cross-host
ones (the GreenGNN-style topology shaping; OPT-IN -- the default
schedule stays bit-identical to the unbiased one).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Sequence, Tuple, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    """Praxis-style hierarchical mesh description.

    ``ici_mesh_shape[i]`` and ``dcn_mesh_shape[i]`` give axis ``i`` of
    the physical mesh its intra-host (ICI) and cross-host (DCN) extents;
    the realised mesh axis extent is their product. The RapidGNN worker
    axes are ``data`` (ICI) and ``dcn`` (the DCN factor of the same
    logical axis, kept as a separate OUTER mesh axis so collectives can
    address either tier).
    """
    ici_mesh_shape: Tuple[int, ...]
    dcn_mesh_shape: Tuple[int, ...]
    mesh_axis_names: Tuple[str, ...]

    def __post_init__(self):
        if not (len(self.ici_mesh_shape) == len(self.dcn_mesh_shape)
                == len(self.mesh_axis_names)):
            raise ValueError(
                f"mesh shape/name rank mismatch: ici "
                f"{self.ici_mesh_shape}, dcn {self.dcn_mesh_shape}, "
                f"names {self.mesh_axis_names}")
        if len(self.mesh_axis_names) != 1 or \
                self.mesh_axis_names[0] != "data":
            raise ValueError(
                f"only the single RapidGNN worker axis ('data',) is "
                f"supported, got {self.mesh_axis_names}")
        if min(self.ici_mesh_shape) < 1 or min(self.dcn_mesh_shape) < 1:
            raise ValueError(
                f"mesh extents must be >= 1: ici {self.ici_mesh_shape}, "
                f"dcn {self.dcn_mesh_shape}")

    # -- construction -----------------------------------------------------

    @staticmethod
    def flat(num_workers: int) -> "Topology":
        """Single-host topology: the classic ``("data",)`` mesh."""
        return Topology(ici_mesh_shape=(num_workers,),
                        dcn_mesh_shape=(1,), mesh_axis_names=("data",))

    @staticmethod
    def hierarchical(hosts: int, devices_per_host: int) -> "Topology":
        """``hosts`` emulated hosts x ``devices_per_host`` devices."""
        return Topology(ici_mesh_shape=(devices_per_host,),
                        dcn_mesh_shape=(hosts,), mesh_axis_names=("data",))

    @staticmethod
    def parse(s: str, num_workers: int) -> "Topology":
        """CellSpec string -> Topology: ``"flat"`` or ``"HxD"`` (e.g.
        ``"2x4"``), validated against the cell's worker count."""
        if s == "flat":
            return Topology.flat(num_workers)
        m = re.fullmatch(r"(\d+)x(\d+)", s)
        if m is None:
            raise ValueError(f"bad topology {s!r}: expected 'flat' or "
                             f"'<hosts>x<devices_per_host>'")
        hosts, dph = int(m.group(1)), int(m.group(2))
        if hosts * dph != num_workers:
            raise ValueError(f"topology {s!r} describes {hosts * dph} "
                             f"workers but the cell has {num_workers}")
        return Topology.hierarchical(hosts, dph)

    # -- derived geometry -------------------------------------------------

    @property
    def hosts(self) -> int:
        return int(math.prod(self.dcn_mesh_shape))

    @property
    def devices_per_host(self) -> int:
        return int(math.prod(self.ici_mesh_shape))

    @property
    def num_workers(self) -> int:
        return self.hosts * self.devices_per_host

    @property
    def is_hierarchical(self) -> bool:
        return self.hosts > 1

    @property
    def worker_axes(self) -> Union[str, Tuple[str, ...]]:
        """PartitionSpec entry sharding a leading dim by flat worker id:
        ``"data"`` flat, ``("dcn", "data")`` hierarchical (dcn outer =
        row-major flat ordinal ``h * devices_per_host + i``)."""
        return ("dcn", "data") if self.is_hierarchical else "data"

    def make_mesh(self):
        """Realise the jax mesh: ``(P,)/("data",)`` flat, ``(H, D)`` over
        ``("dcn", "data")`` hierarchical."""
        from repro.dist.mesh import make_mesh
        if self.is_hierarchical:
            return make_mesh((self.hosts, self.devices_per_host),
                             ("dcn", "data"))
        return make_mesh((self.num_workers,), ("data",))

    # -- worker/host arithmetic -------------------------------------------

    def host_of(self, worker: Union[int, np.ndarray]):
        """Flat worker ordinal(s) -> host ordinal(s)."""
        return worker // self.devices_per_host

    def local_of(self, worker: Union[int, np.ndarray]):
        """Flat worker ordinal(s) -> intra-host device index."""
        return worker % self.devices_per_host

    def same_host(self, a, b):
        """Elementwise: do workers ``a`` and ``b`` share a host?"""
        return self.host_of(a) == self.host_of(b)

    def owner_bias(self, worker: int, dcn_bias: float) -> np.ndarray:
        """(P,) ``select_hot_set`` frequency multiplier for ``worker``:
        ``dcn_bias`` on owners across the DCN boundary, 1.0 on same-host
        owners -- cache admission then prefers saving the expensive
        cross-host fetches. ``dcn_bias=1.0`` is the unbiased schedule."""
        if dcn_bias <= 0:
            raise ValueError(f"dcn_bias must be positive, got {dcn_bias}")
        owners = np.arange(self.num_workers)
        return np.where(self.same_host(owners, worker), 1.0,
                        float(dcn_bias))

    def describe(self) -> str:
        if self.is_hierarchical:
            return f"{self.hosts}x{self.devices_per_host}"
        return "flat"
