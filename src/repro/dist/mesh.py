"""Mesh construction + axis conventions for the device-distributed path.

Axis vocabulary (DESIGN.md §6): ``data`` is the RapidGNN worker axis --
one mesh slot per paper "worker", holding that worker's feature-table
partition, steady cache C_s, and batch stream. On a hierarchical
multi-host topology (``repro.dist.topology.Topology``, DESIGN.md §6.7)
``data`` becomes the INTRA-host ici axis and a ``dcn`` axis sits OUTER,
so the flat worker ordinal is the row-major ``("dcn", "data")``
flattening. ``model`` (tensor/expert parallel) and ``pod`` (multi-pod
data parallel) are the transformer substrate's axes. Everything here is
a FUNCTION of an explicit shape so importing this module never touches
jax device state (device count locks at first backend init; the
dry-runs set XLA_FLAGS before importing jax).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> jax.sharding.Mesh:
    """Build a device mesh, e.g. ``make_mesh((4,), ("data",))``."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def dp_axes(mesh) -> Optional[Union[str, Tuple[str, ...]]]:
    """The data-parallel axes of ``mesh`` as a PartitionSpec entry.

    Returns a tuple of the present batch-sharding axes (``pod``
    outermost, then ``dcn``, then ``data``) or None when the mesh has
    none of them -- usable directly as one entry of a
    ``PartitionSpec``.
    """
    axes = tuple(a for a in ("pod", "dcn", "data") if a in mesh.axis_names)
    return axes if axes else None
