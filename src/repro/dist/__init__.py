"""Device-distributed RapidGNN subsystem (DESIGN.md §6).

SPMD realisation of the paper's data path over a flat ``("data",)`` or
hierarchical ``("dcn", "data")`` mesh (``Topology``, DESIGN.md §6.7):
partition-sharded feature table, offline-built pull plans (two-tier on
hierarchical meshes: cheap intra-host lanes + a separate cross-host DCN
exchange), all_to_all cache-first feature exchange, and the
scan-pipelined epoch that overlaps step i+1's pull with step i's
training. Host-emulated devices run the same code as TPU pods (tests
pin ``--xla_force_host_platform_device_count``).

Importing this package never touches jax device state -- meshes are built
by ``make_mesh`` on demand, so launchers can set XLA_FLAGS first.
"""
from repro.dist.mesh import make_mesh, dp_axes
from repro.dist.topology import Topology
from repro.dist.feature_a2a import (PullPlan, build_pull_plan,
                                    pack_pull_lanes,
                                    pack_pull_lanes_two_tier, pull_shard,
                                    pull_shard_two_tier,
                                    pull_features, cache_gather)
from repro.dist.gnn_step import (CACHE_PAD, DeviceCache, DeviceView,
                                 epoch_k_max, epoch_k_max_split,
                                 collate_device_epoch,
                                 collate_device_epoch_loop, stack_caches,
                                 make_pipelined_epoch, make_ondemand_epoch,
                                 empty_caches, prefetch_stream)
from repro.dist.runner import (DeviceEpochReport, DeviceRapidGNNRunner,
                               DeviceBaselineRunner, host_miss_matrix,
                               assert_host_parity)
from repro.dist.shardings import (fit_spec, param_shardings, opt_shardings,
                                  batch_shardings, decode_state_shardings)

__all__ = [
    "make_mesh", "dp_axes", "Topology",
    "PullPlan", "build_pull_plan", "pack_pull_lanes",
    "pack_pull_lanes_two_tier", "pull_shard", "pull_shard_two_tier",
    "pull_features", "cache_gather",
    "CACHE_PAD", "DeviceCache", "DeviceView", "epoch_k_max",
    "epoch_k_max_split",
    "collate_device_epoch", "collate_device_epoch_loop", "stack_caches",
    "make_pipelined_epoch", "make_ondemand_epoch", "empty_caches",
    "prefetch_stream",
    "DeviceEpochReport", "DeviceRapidGNNRunner", "DeviceBaselineRunner",
    "host_miss_matrix", "assert_host_parity",
    "fit_spec", "param_shardings", "opt_shardings", "batch_shardings",
    "decode_state_shardings",
]
