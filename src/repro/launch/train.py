"""Training launcher.

Two modes:
  * GNN (the paper's workload): ``--workload gnn`` runs the full RapidGNN
    pipeline (schedule -> cache -> prefetch -> train) or the DGL-style
    baseline on a synthetic benchmark graph.
  * LM  (assigned archs):      ``--workload lm --arch <id>`` runs the
    reduced variant of an assigned architecture on synthetic token data
    (CPU-sized end-to-end driver; the full configs are dry-run only).

Examples:
  PYTHONPATH=src python -m repro.launch.train --workload gnn \
      --dataset reddit_sim --system rapidgnn --epochs 5
  PYTHONPATH=src python -m repro.launch.train --workload lm \
      --arch smollm-360m --steps 50
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def run_gnn(args) -> None:
    import jax
    from repro.graph import load_dataset, partition_graph, KHopSampler
    from repro.core import (build_schedule, ShardedFeatureStore,
                            RapidGNNRunner, BaselineRunner, NetworkModel)
    from repro.models import (GNNConfig, init_params, make_train_step,
                              batch_to_device)
    from repro.train import AdamW, save_checkpoint

    g = load_dataset(args.dataset)
    pg = partition_graph(g, args.workers, args.partition)
    sampler = KHopSampler(g, fanouts=[25, 10], batch_size=args.batch_size)
    ws = build_schedule(sampler, pg, worker=0, s0=args.seed,
                        num_epochs=args.epochs, n_hot=args.n_hot)

    cfg = GNNConfig(kind=args.model, in_dim=g.feat_dim, hidden_dim=256,
                    num_classes=g.num_classes, num_layers=2)
    params = init_params(cfg, jax.random.key(args.seed))
    opt = AdamW(lr=3e-3)
    opt_state = opt.init(params)
    step = make_train_step(cfg, opt)
    state = {"params": params, "opt": opt_state, "hist": []}

    def train_fn(feats, cb):
        batch = batch_to_device(cb, feats)
        state["params"], state["opt"], aux = step(state["params"],
                                                  state["opt"], batch)
        state["hist"].append((float(aux["loss"]), float(aux["acc"])))
        return float(aux["loss"])

    net = NetworkModel(enabled=args.network_model)
    store = ShardedFeatureStore(pg, worker=0, net=net)
    runner_cls = (RapidGNNRunner if args.system == "rapidgnn"
                  else BaselineRunner)
    kw = {"Q": args.Q} if args.system == "rapidgnn" else {}
    runner = runner_cls(ws, store, batch_size=args.batch_size,
                        train_fn=train_fn, **kw)
    t0 = time.time()
    metrics = runner.run()
    wall = time.time() - t0
    tot = metrics.totals()
    print(f"\n== {args.system} on {args.dataset} "
          f"({args.workers}w, batch {args.batch_size}) ==")
    print(f"wall {wall:.1f}s  epochs {args.epochs}  "
          f"final loss {state['hist'][-1][0]:.3f}  "
          f"acc {state['hist'][-1][1]:.3f}")
    for k in ("rpc_count", "remote_bytes", "vector_pull_bytes",
              "hit_rate", "fetch_stall_s", "modeled_net_time_s"):
        v = tot[k]
        print(f"  {k}: {v:.4g}")
    if args.ckpt:
        save_checkpoint(args.ckpt, state["params"],
                        step=len(state["hist"]))
        print("checkpoint saved to", args.ckpt)


def run_lm(args) -> None:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.data.pipeline import synthetic_lm_batches
    from repro.models.transformer import init_params, lm_loss
    from repro.train import AdamW, save_checkpoint
    from functools import partial

    cfg = get_reduced(args.arch)
    params = init_params(cfg, jax.random.key(args.seed))
    opt = AdamW(lr=3e-4, weight_decay=0.01, max_grad_norm=1.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(p, o, b):
        (loss, _), g = jax.value_and_grad(
            lambda pp: lm_loss(cfg, pp, b), has_aux=True)(p)
        p2, o2 = opt.update(g, o, p)
        return p2, o2, loss

    t0 = time.time()
    losses = []
    for i, batch in enumerate(synthetic_lm_batches(
            cfg, batch=args.batch_size, seq=args.seq, steps=args.steps,
            s0=args.seed)):
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
        if i % 10 == 0:
            print(f"step {i:4d}  loss {losses[-1]:.4f}")
    print(f"\n== lm {args.arch} (reduced) == {args.steps} steps "
          f"in {time.time()-t0:.1f}s; loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training must reduce loss"
    if args.ckpt:
        save_checkpoint(args.ckpt, params, step=args.steps)
        print("checkpoint saved to", args.ckpt)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=["gnn", "lm"], default="gnn")
    # gnn
    ap.add_argument("--dataset", default="ogbn_products_sim")
    ap.add_argument("--system", choices=["rapidgnn", "baseline"],
                    default="rapidgnn")
    ap.add_argument("--model", choices=["sage", "gcn"], default="sage")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--partition", default="metis")
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--n-hot", type=int, default=4096)
    ap.add_argument("--Q", type=int, default=4)
    ap.add_argument("--network-model", action="store_true",
                    help="charge modelled 10GbE time on critical-path fetches")
    # lm
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    # common
    ap.add_argument("--batch-size", type=int, default=1000)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()
    if args.workload == "gnn":
        run_gnn(args)
    else:
        if args.batch_size == 1000:
            args.batch_size = 8
        run_lm(args)


if __name__ == "__main__":
    main()
