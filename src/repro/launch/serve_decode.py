"""Transformer-decode demo launcher: batched decode on a reduced arch
config.

Runs greedy decoding with the KV-cache ``serve_step`` over a batch of
synthetic prompts (CPU-sized; full configs are exercised by the
dry-run). This is the LLM DEMO path only -- the production serving
entry point for this repo's GNN workload is ``repro.launch.serve_gnn``
(the ``repro.serve.gnn`` online inference service, DESIGN.md §11).

  PYTHONPATH=src python -m repro.launch.serve_decode --arch gemma2-2b \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data.pipeline import zipf_tokens
from repro.graph.sampler import rng_from
from repro.models.transformer import (init_params, init_decode_state,
                                      serve_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = init_params(cfg, jax.random.key(args.seed))
    B = args.batch
    max_len = args.prompt_len + args.gen
    src_len = 8 if cfg.kind == "encdec" else 0
    states = init_decode_state(cfg, B, max_len=max_len, src_len=src_len)

    rng = rng_from(args.seed)   # RNG-CONTRACT: keyed Philox stream
    prompts = zipf_tokens(rng, cfg.vocab_size, (B, args.prompt_len))

    @jax.jit
    def step(params, states, tok, pos):
        mp = (jnp.broadcast_to(pos[None, :, None], (3, B, 1))
              if cfg.mrope_sections else None)
        return serve_step(cfg, params, states, tok, pos,
                          mrope_positions=mp)

    # prefill via sequential decode (cache-filling); real prefill on TPU
    # lowers the chunked forward (launch/specs.py "prefill")
    t0 = time.time()
    tok = jnp.asarray(prompts[:, :1])
    out_tokens = [np.asarray(tok)]
    for t in range(max_len - 1):
        pos = jnp.full((B,), t, jnp.int32)
        logits, states = step(params, states, tok, pos)
        if t + 1 < args.prompt_len:
            tok = jnp.asarray(prompts[:, t + 1:t + 2])
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.concatenate(out_tokens, axis=1)
    steps = max_len - 1
    print(f"== serve {args.arch} (reduced) ==")
    print(f"batch {B}  prompt {args.prompt_len}  gen {args.gen}")
    print(f"{steps} decode steps in {dt:.2f}s "
          f"({1e3 * dt / steps:.1f} ms/step, "
          f"{B * steps / dt:.0f} tok/s aggregate)")
    print("sample token ids:", gen[0, args.prompt_len:
                                   args.prompt_len + 10].tolist())
    assert np.isfinite(gen).all()


if __name__ == "__main__":
    main()
