"""GNN production-mesh dry-run: the paper's own workload at pod scale.

Lowers + compiles the device-distributed RapidGNN pipelined epoch
(cache-first a2a feature pull + GraphSAGE train step, 1-step prefetch
overlap) for P = 256 (single pod) or 512 (multi-pod) workers using
ShapeDtypeStruct stand-ins -- no allocation, same contract as the
transformer dry-run.

  PYTHONPATH=src python -m repro.launch.dryrun_gnn [--multi-pod]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn import GNNConfig
from repro.train.optim import AdamW
from repro.dist.gnn_step import make_ondemand_epoch, make_pipelined_epoch
from repro.launch.dryrun import collective_bytes


def specs(P_, S, m_max, edge_max, B, n_per, d, n_hot, k_max, n_classes):
    f32, i32, i64 = jnp.float32, jnp.int32, jnp.int64

    def sds(shape, dt):
        return jax.ShapeDtypeStruct(shape, dt)

    batches = {
        "input_nodes": sds((S, P_, m_max), i64),
        "labels": sds((S, P_, B), i32),
        "seed_mask": sds((S, P_, B), jnp.bool_),
        "send_ids": sds((S, P_, P_, k_max), i32),
        "send_pos": sds((S, P_, P_, k_max), i32),
        "send_mask": sds((S, P_, P_, k_max), jnp.bool_),
        "edge_src": [sds((S, P_, e), i32) for e in edge_max],
        "edge_dst": [sds((S, P_, e), i32) for e in edge_max],
        "edge_mask": [sds((S, P_, e), jnp.bool_) for e in edge_max],
    }
    table = sds((P_, n_per, d), f32)
    offsets = sds((P_, 1), i32)
    cache_ids = sds((P_, n_hot), i64)
    cache_feats = sds((P_, n_hot, d), f32)
    return table, offsets, cache_ids, cache_feats, batches


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--baseline", action="store_true",
                    help="lower the on-demand (no cache, non-overlapped) "
                         "baseline epoch instead of the pipelined one")
    ap.add_argument("--assemble-backend", default="auto",
                    choices=("auto", "fused", "ref", "staged"),
                    help="feature-assembly path: fused single-pass "
                         "Pallas kernel, jnp fused reference, or the "
                         "legacy staged chain (auto: fused on TPU, "
                         "ref elsewhere)")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    P_ = 512 if args.multi_pod else 256
    mesh = jax.make_mesh((P_,), ("data",))

    # paper-scale per-worker shapes: OGBN-Papers100M-like partition
    d, B, n_hot, k_max, m_max = 128, 1000, 32768, 4096, 60_000
    n_per, S = 220_000, 8              # nodes/worker, steps (scan dim)
    edge_max = [m_max * 2, B * 25]
    cfg = GNNConfig(kind="sage", in_dim=d, hidden_dim=256, num_classes=172,
                    num_layers=2)
    opt = AdamW(lr=3e-3)

    params_s = jax.eval_shape(
        lambda k: __import__("repro.models.gnn", fromlist=["init_params"]
                             ).init_params(cfg, k), jax.random.key(0))
    opt_s = jax.eval_shape(opt.init, params_s)

    table, offsets, cids, cfeats, batches = specs(
        P_, S, m_max, edge_max, B, n_per, d, n_hot, k_max, 172)

    t0 = time.time()
    with mesh:
        if args.baseline:
            epoch_fn = make_ondemand_epoch(
                cfg, opt, mesh, m_max,
                assemble_backend=args.assemble_backend)
            lowered = jax.jit(epoch_fn).lower(params_s, opt_s, table,
                                              offsets, batches)
        else:
            epoch_fn = make_pipelined_epoch(
                cfg, opt, mesh, m_max,
                assemble_backend=args.assemble_backend)
            lowered = jax.jit(epoch_fn).lower(params_s, opt_s, table,
                                              offsets, cids, cfeats,
                                              batches)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cl = collective_bytes(compiled.as_text())
    rec = {
        "workload": ("rapidgnn-sage-ondemand" if args.baseline
                     else "rapidgnn-sage"), "workers": P_,
        "mesh": f"{P_} (data)",
        "assemble_backend": args.assemble_backend,
        "compile_s": round(time.time() - t0, 1),
        "memory": {
            "argument_size_bytes": mem.argument_size_in_bytes,
            "temp_size_bytes": mem.temp_size_in_bytes,
        },
        "collectives": cl,
        "per_worker": {"n_per": n_per, "feat_dim": d, "n_hot": n_hot,
                       "k_max": k_max, "m_max": m_max, "batch": B,
                       "steps": S},
    }
    os.makedirs(args.out, exist_ok=True)
    tag = f"rapidgnn_gnn__pod{2 if args.multi_pod else 1}"
    if args.baseline:
        tag += "__ondemand"
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec, indent=1))
    print("GNN production-mesh dry-run OK")


if __name__ == "__main__":
    main()
