"""Deprecation shim: ``repro.launch.serve`` moved.

The transformer-decode demo now lives at ``repro.launch.serve_decode``;
the GNN inference service launcher is ``repro.launch.serve_gnn``. This
shim keeps ``python -m repro.launch.serve`` working for the decode demo
one rename cycle, with a pointer on stderr.
"""
from __future__ import annotations

import sys

from repro.launch.serve_decode import main

if __name__ == "__main__":
    print("[deprecated] repro.launch.serve is now repro.launch."
          "serve_decode (GNN serving: repro.launch.serve_gnn)",
          file=sys.stderr)
    main()
