"""Multi-pod dry-run: lower + compile every (arch x input-shape) combo on
the production meshes and extract roofline inputs (assignment MULTI-POD
DRY-RUN + ROOFLINE ANALYSIS).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
      --shape train_4k [--multi-pod] [--out artifacts/dryrun]

Outputs one JSON per combo with: memory_analysis, cost_analysis (FLOPs /
bytes), per-collective byte volumes parsed from the post-SPMD HLO, and
compile wall-time. Default sweeps the full 10 x 4 matrix.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import (device count locks on first init).

import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ARCH_NAMES, INPUT_SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import make_dryrun_spec

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic by op kind, parsed from post-SPMD HLO.

    Accounting model (documented in EXPERIMENTS.md §Roofline): for each
    collective instruction we count the RESULT shard bytes, except
    all-reduce (2x: ring reduce-scatter + all-gather) and reduce-scatter
    (input shard bytes = result x group, approximated by the first
    operand's shape).
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)", s)
        if not m:
            continue
        body = m.group(1)
        op = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", body):
                op = k
                break
        if op is None:
            continue
        if re.search(rf"\b{op}-done\(", body):
            continue                       # avoid double count of async pairs
        shapes = _SHAPE_RE.findall(body)
        if not shapes:
            continue
        result = _shape_bytes(*shapes[0])
        if op == "all-reduce":
            vol = 2 * result
        elif op == "reduce-scatter":
            vol = _shape_bytes(*shapes[1]) if len(shapes) > 1 else result
        else:
            vol = result
        out[op] += vol
        counts[op] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


def run_one(arch: str, shape: str, multi_pod: bool, cfg=None,
            S=None, B=None, opt: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    if opt:
        import dataclasses
        from repro.configs import get_arch
        cfg = cfg or get_arch(arch)
        changes = {}
        if "seqshard" in opt:
            changes["seq_shard_attn"] = True
        if "resident" in opt:
            changes["moe_resident_experts"] = True
        cfg = dataclasses.replace(cfg, **changes)
    spec = make_dryrun_spec(arch, shape, mesh, cfg=cfg, S=S, B=B)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "kind": spec.meta["kind"],
           "S": spec.meta["seq"], "B": spec.meta["batch"],
           "attn_variant": spec.meta.get("attn_variant", "full")}
    t0 = time.time()
    with mesh:
        jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                         out_shardings=spec.out_shardings)
        lowered = jitted.lower(*spec.args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        }
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and (
                           k in ("flops", "bytes accessed")
                           or k.startswith("bytes accessed"))}
        rec["collectives"] = collective_bytes(compiled.as_text())

    cfg = spec.meta["cfg"]
    pc = cfg.param_counts()
    rec["params_total"] = pc["total"]
    rec["params_active"] = pc["active"]
    rec["tokens"] = spec.meta["batch"] * (spec.meta["seq"]
                                          if spec.meta["kind"] != "decode"
                                          else 1)
    return rec


#: cost-variant grid (roofline): r repeats x small S (+ B split for decode)
CV_GRID = {
    "train": [("train_4k", r, S, 16) for r in (1, 2)
              for S in (512, 1024, 2048)],
    "prefill": [("prefill_32k", r, S, 16) for r in (1, 2)
                for S in (512, 1024, 2048)],
    "decode": ([("decode_32k", r, S, 16) for r in (1, 2)
                for S in (1024, 2048, 4096)]
               + [("decode_32k", r, 1024, 32) for r in (1, 2)]),
}


def run_cost_variants(archs, out_dir: str) -> None:
    from repro.configs import get_arch
    from repro.launch.specs import cost_variant_cfg
    for a in archs:
        for kind, grid in CV_GRID.items():
            for shape, r, S, B in grid:
                tag = f"{a}__cv_{kind}_r{r}_S{S}_B{B}"
                path = os.path.join(out_dir, tag + ".json")
                if os.path.exists(path):
                    continue
                cfg = cost_variant_cfg(get_arch(a), r, S)
                print(f"[cv] {tag} ...", flush=True)
                try:
                    rec = run_one(a, shape, False, cfg=cfg, S=S, B=B)
                    rec["cv"] = {"kind": kind, "r": r, "S": S, "B": B}
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"  ok {rec['compile_s']}s "
                          f"flops {rec['cost'].get('flops', 0):.3e}")
                except Exception as e:
                    print(f"  FAIL: {e}")
                    traceback.print_exc()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--cost-variants", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma list: seqshard,resident (EXPERIMENTS §Perf)")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    archs = ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(args.out, exist_ok=True)
    if args.cost_variants:
        run_cost_variants(archs, args.out)
        return

    failures = []
    for a in archs:
        for s in shapes:
            tag = f"{a}__{s}__{'pod2' if args.multi_pod else 'pod1'}"
            if args.opt:
                tag += "__opt-" + args.opt.replace(",", "-")
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (exists)")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                rec = run_one(a, s, args.multi_pod, opt=args.opt)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"  ok: compile {rec['compile_s']}s  "
                      f"flops {rec['cost'].get('flops', 0):.3e}  "
                      f"coll {rec['collectives']['total']:.3e}B")
            except Exception as e:
                failures.append((tag, str(e)))
                print(f"  FAIL: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e.splitlines()[0] if e else "")
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
