"""GNN inference serving launcher: the online tier end to end.

Builds a partitioned graph, starts the ``repro.serve.gnn`` service
(dispatcher + cache warmer threads), fires a Philox-keyed Poisson
request stream at it, and prints the health snapshot plus a latency
summary -- the serving analogue of ``launch/train.py``.

  PYTHONPATH=src python -m repro.launch.serve_gnn --dataset tiny \
      --requests 64 --rate 200 --fault-profile serve-pull-flaky
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.fault.inject import active_plan
from repro.fault.plan import PROFILES, plan_from_profile
from repro.graph import KHopSampler, load_dataset, partition_graph
from repro.graph.sampler import rng_from
from repro.models import GNNConfig, init_params
from repro.serve.gnn import GNNInferenceService, Overloaded


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="tiny")
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--worker", type=int, default=0)
    ap.add_argument("--fanouts", type=int, nargs="+", default=[5, 5])
    ap.add_argument("--batch-size", type=int, default=8,
                    help="max seeds per request (static collation bound)")
    ap.add_argument("--max-batch-requests", type=int, default=4)
    ap.add_argument("--n-hot", type=int, default=256)
    ap.add_argument("--high-water", type=int, default=64)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--timeout-s", type=float, default=1.0,
                    help="per-request deadline")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fault-profile", default=None,
                    choices=sorted(PROFILES),
                    help="run the stream under a named fault plan")
    args = ap.parse_args()

    g = load_dataset(args.dataset, seed=args.seed)
    pg = partition_graph(g, args.parts, "greedy")
    sampler = KHopSampler(g, fanouts=args.fanouts,
                          batch_size=args.batch_size)
    cfg = GNNConfig(kind="sage", in_dim=g.feat_dim, hidden_dim=64,
                    num_classes=g.num_classes, num_layers=len(args.fanouts))
    params = init_params(cfg, jax.random.key(args.seed))
    svc = GNNInferenceService(
        pg, sampler, cfg, params, s0=args.seed, worker=args.worker,
        n_hot=args.n_hot, max_batch_requests=args.max_batch_requests,
        high_water=args.high_water,
        default_timeout_s=args.timeout_s).start()

    rng = rng_from(args.seed, 0x5345)       # "SE": the arrival stream
    gaps = rng.exponential(1.0 / args.rate, size=args.requests)
    sizes = rng.integers(1, args.batch_size + 1, size=args.requests)
    plan = (plan_from_profile(args.fault_profile, seed=args.seed)
            if args.fault_profile else None)

    pendings, shed = [], 0
    t0 = time.perf_counter()
    with active_plan(plan):
        for i in range(args.requests):
            time.sleep(float(gaps[i]))
            seeds = rng.integers(0, g.num_nodes, size=int(sizes[i]))
            try:
                pendings.append(svc.submit(seeds))
            except Overloaded:
                shed += 1
        lat, errors = [], 0
        for p in pendings:
            try:
                lat.append(p.result(timeout=10.0).latency_s)
            except Exception:
                errors += 1
    wall = time.perf_counter() - t0
    svc.close()

    health = svc.health()
    print(f"== serve_gnn {args.dataset} P={args.parts} "
          f"worker={args.worker} ==")
    print(f"{args.requests} requests in {wall:.2f}s "
          f"({len(lat)} served, {shed} shed, {errors} errors)")
    if lat:
        print(f"latency p50 {1e3 * float(np.percentile(lat, 50)):.2f} ms  "
              f"p99 {1e3 * float(np.percentile(lat, 99)):.2f} ms")
    print(json.dumps(health, indent=2, default=str))


if __name__ == "__main__":
    main()
