"""input_specs: ShapeDtypeStruct stand-ins for every (arch x shape) combo.

Nothing here allocates device memory: parameters/optimizer/caches come
from ``jax.eval_shape`` and batches are ShapeDtypeStructs. The dry-run
lowers + compiles against these (assignment MULTI-POD DRY-RUN step 2).

Frontend stubs: [audio] provides ``enc_embeds`` (B, S_src, d) frame
embeddings; [vlm] provides ``embeds`` (B, S, d) patch embeddings plus
M-RoPE position streams (3, B, S).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_NAMES, INPUT_SHAPES, SUBQUADRATIC,
                           get_arch)
from repro.models.transformer import (init_params, init_decode_state,
                                      lm_loss, serve_step, forward, encode)
from repro.models.transformer.common import ArchConfig
from repro.train.optim import AdamW
from repro.dist.shardings import (param_shardings, opt_shardings,
                                  batch_shardings, decode_state_shardings)

#: window for the sliding-window long_500k variant on full-attention archs
LONG_WINDOW = 8_192
#: encoder/cross source length for enc-dec decode shapes
SRC_LEN = 4_096


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


@dataclasses.dataclass
class DryRunSpec:
    arch: str
    shape: str
    fn: Callable                    # python callable to jit
    args: Tuple[Any, ...]           # ShapeDtypeStruct trees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    meta: Dict[str, Any]


def _eval_params(cfg: ArchConfig):
    return jax.eval_shape(partial(init_params, cfg), jax.random.key(0))


def train_batch_specs(cfg: ArchConfig, B: int, S: int):
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
    }
    if cfg.mrope_sections:
        batch["mrope_positions"] = jax.ShapeDtypeStruct((3, B, S),
                                                        jnp.int32)
    if cfg.frontend == "vision":
        batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.bfloat16)
    if cfg.kind == "encdec":
        batch["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.bfloat16)
    return batch


def cost_variant_cfg(cfg: ArchConfig, r: int, S: int) -> ArchConfig:
    """Small UNROLLED variant for roofline cost measurement: r repeats of
    the pattern, single-chunk attention (no scan bodies anywhere XLA's
    cost analysis would count only once)."""
    changes = dict(num_layers=len(cfg.pattern) * r, unroll_layers=True,
                   attn_q_chunk=S, attn_kv_chunk=S)
    if cfg.kind == "encdec":
        changes["num_enc_layers"] = r
    return dataclasses.replace(cfg, **changes)


def make_dryrun_spec(arch: str, shape: str, mesh,
                     optimizer: Optional[AdamW] = None,
                     cfg: Optional[ArchConfig] = None,
                     S: Optional[int] = None,
                     B: Optional[int] = None) -> DryRunSpec:
    cfg = cfg or get_arch(arch)
    S_d, B_d, kind = INPUT_SHAPES[shape]
    S = S or S_d
    B = B or B_d
    optimizer = optimizer or AdamW(lr=1e-4, weight_decay=0.01,
                                   max_grad_norm=1.0)
    params_s = _eval_params(cfg)
    params_sh = param_shardings(cfg, mesh, params_s)
    meta: Dict[str, Any] = {"cfg": cfg, "seq": S, "batch": B, "kind": kind}

    if kind == "train":
        opt_s = jax.eval_shape(optimizer.init, params_s)
        opt_sh = opt_shardings(params_sh, opt_s)
        batch_s = train_batch_specs(cfg, B, S)
        batch_sh = batch_shardings(cfg, mesh, batch_s)

        def train_step(params, opt_state, batch):
            (loss, aux), grads = jax.value_and_grad(
                partial(lm_loss, cfg, mesh=mesh), has_aux=True)(params,
                                                                batch)
            p2, o2 = optimizer.update(grads, opt_state, params)
            return p2, o2, loss

        return DryRunSpec(arch, shape, train_step,
                          (params_s, opt_s, batch_s),
                          (params_sh, opt_sh, batch_sh),
                          (params_sh, opt_sh, None), meta)

    if kind == "prefill":
        batch_s = train_batch_specs(cfg, B, S)
        batch_s.pop("labels")
        batch_s.pop("loss_mask")
        batch_sh = batch_shardings(cfg, mesh, batch_s)

        def prefill_step(params, batch):
            enc_out = (encode(cfg, params, batch["enc_embeds"])
                       if cfg.kind == "encdec" else None)
            logits = forward(cfg, params, batch["tokens"],
                             mrope_positions=batch.get("mrope_positions"),
                             embeds=batch.get("embeds"), enc_out=enc_out,
                             mesh=mesh)
            return logits[:, -1]          # next-token logits

        return DryRunSpec(arch, shape, prefill_step, (params_s, batch_s),
                          (params_sh, batch_sh), None, meta)

    # ---- decode ----
    window_override = 0
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        window_override = LONG_WINDOW
        meta["attn_variant"] = "sliding_window"
    src_len = SRC_LEN if cfg.kind == "encdec" else 0
    state_s = jax.eval_shape(
        partial(init_decode_state, cfg, B, S,
                window_override=window_override, src_len=src_len))
    state_sh = decode_state_shardings(cfg, mesh, state_s)
    tok_s = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_s = jax.ShapeDtypeStruct((B,), jnp.int32)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.mesh import dp_axes
    from repro.dist.shardings import fit_spec
    dp = dp_axes(mesh)
    tok_sh = NamedSharding(mesh, fit_spec(mesh, P(dp, None), (B, 1)))
    pos_sh = NamedSharding(mesh, fit_spec(mesh, P(dp), (B,)))
    mrope = None
    if cfg.mrope_sections:
        mrope = jax.ShapeDtypeStruct((3, B, 1), jnp.int32)

    def decode_step(params, states, tokens, pos, mrope_positions=None):
        return serve_step(cfg, params, states, tokens, pos,
                          mrope_positions=mrope_positions, mesh=mesh,
                          window_override=window_override)

    args = (params_s, state_s, tok_s, pos_s)
    in_sh = (params_sh, state_sh, tok_sh, pos_sh)
    if mrope is not None:
        args = args + (mrope,)
        in_sh = in_sh + (NamedSharding(
            mesh, fit_spec(mesh, P(None, dp, None), (3, B, 1))),)
    return DryRunSpec(arch, shape, decode_step, args, in_sh,
                      (None, state_sh), meta)
