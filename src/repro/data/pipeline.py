"""Token data pipeline with RapidGNN-style deterministic scheduling.

The same H(s0, e, i) seed derivation as the graph sampler drives batch
composition, so the full token-access pattern of a run is enumerable
offline -- which is what the hot-token embedding cache (embedding.py)
consumes. Token ids follow a Zipf distribution (natural-language-like
long tail, the transformer analogue of the paper's Fig. 3).
"""
from __future__ import annotations

from typing import Dict, Iterator, List

import jax.numpy as jnp
import numpy as np

from repro.graph.sampler import rng_from
from repro.models.transformer.common import ArchConfig


def zipf_tokens(rng: np.random.Generator, vocab: int, shape,
                a: float = 1.1) -> np.ndarray:
    """Zipf-distributed token ids over [0, vocab)."""
    ranks = rng.zipf(a, size=shape).astype(np.int64)
    return ((ranks - 1) % vocab).astype(np.int32)


def make_batch(cfg: ArchConfig, rng: np.random.Generator, batch: int,
               seq: int) -> Dict[str, jnp.ndarray]:
    toks = zipf_tokens(rng, cfg.vocab_size, (batch, seq))
    out = {"tokens": jnp.asarray(toks),
           "labels": jnp.asarray(np.roll(toks, -1, axis=1)),
           "loss_mask": jnp.ones((batch, seq), jnp.float32)}
    if cfg.mrope_sections:
        out["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(seq)[None, None], (3, batch, seq))
    if cfg.frontend == "vision":
        out["embeds"] = jnp.asarray(
            0.02 * rng.standard_normal((batch, seq, cfg.d_model)),
            jnp.float32)
    if cfg.kind == "encdec":
        out["enc_embeds"] = jnp.asarray(
            0.02 * rng.standard_normal((batch, seq, cfg.d_model)),
            jnp.float32)
    return out


def synthetic_lm_batches(cfg: ArchConfig, batch: int, seq: int, steps: int,
                         s0: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
    for i in range(steps):
        yield make_batch(cfg, rng_from(s0, 0, i), batch, seq)


def enumerate_token_accesses(cfg: ArchConfig, batch: int, seq: int,
                             steps: int, s0: int = 0) -> np.ndarray:
    """Offline enumeration of the token-id access counts for a whole run
    (paper Alg. 1 lines 1-3 applied to the embedding table)."""
    counts = np.zeros(cfg.vocab_size, np.int64)
    for i in range(steps):
        toks = zipf_tokens(rng_from(s0, 0, i), cfg.vocab_size,
                           (batch, seq))
        counts += np.bincount(toks.reshape(-1), minlength=cfg.vocab_size)
    return counts
