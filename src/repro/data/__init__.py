from repro.data.pipeline import (synthetic_lm_batches, make_batch,
                                 zipf_tokens, enumerate_token_accesses)

__all__ = ["synthetic_lm_batches", "make_batch", "zipf_tokens",
           "enumerate_token_accesses"]
