"""GraphSAGE + GCN in pure JAX over padded MFG blocks (paper §2.3 models).

The forward consumes the static-shape ``CollatedBatch`` layout: a padded
input-node feature matrix ``h`` of shape (m_max, d) whose *dst prefix*
property (dst nodes of every layer are a prefix of its src nodes, and the
final seeds are ``h[:batch_size]``) lets all layers update the same
buffer.

Aggregation dispatches per ``GNNConfig.agg_backend``: the default
``"segment"`` is masked ``segment_sum`` over the padded edge lists (the
oracle and CPU path); ``"pallas"`` / ``"pallas_interpret"`` run the fused
``kernels/gather_agg`` Pallas kernel, which exploits the deterministic
sampler's dst-major fan-out-regular edge layout (every dst owns exactly
``fanout`` contiguous edges, so the padded tail starts on a row boundary
and aggregates to zero) -- ``cfg.fanouts`` must then carry the per-layer
fan-outs. The kernel path has a custom VJP, so ``loss_fn`` grads work on
every backend.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import CollatedBatch
from repro.kernels.gather_agg.ops import gather_agg


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    kind: str                 # "sage" | "gcn"
    in_dim: int
    hidden_dim: int
    num_classes: int
    num_layers: int
    dropout: float = 0.0      # (dry-run/CPU benches run deterministic)
    #: per-layer sampler fan-outs (input->output); required by the
    #: pallas aggregation backends (dst-major regular layout contract)
    fanouts: Optional[Tuple[int, ...]] = None
    #: "segment" (jnp segment_sum oracle) | "pallas" (fused gather_agg
    #: kernel) | "pallas_interpret" (kernel body interpreted on CPU)
    agg_backend: str = "segment"

    def __post_init__(self):
        if self.agg_backend not in ("segment", "pallas",
                                    "pallas_interpret"):
            raise ValueError(f"unknown agg_backend {self.agg_backend!r}")
        if self.agg_backend != "segment":
            if self.fanouts is None:
                raise ValueError(
                    "pallas aggregation needs cfg.fanouts (the dst-major "
                    "fan-out-regular layout contract)")
            if len(self.fanouts) < self.num_layers:
                raise ValueError(
                    f"cfg.fanouts has {len(self.fanouts)} entries for "
                    f"{self.num_layers} layers")


def init_params(cfg: GNNConfig, key: jax.Array) -> Dict[str, Any]:
    dims = ([cfg.in_dim] + [cfg.hidden_dim] * (cfg.num_layers - 1)
            + [cfg.num_classes])
    params: Dict[str, Any] = {"layers": []}
    for l in range(cfg.num_layers):
        key, k1, k2 = jax.random.split(key, 3)
        d_in, d_out = dims[l], dims[l + 1]
        scale = 1.0 / np.sqrt(d_in)
        if cfg.kind == "sage":
            layer = {
                "w_self": jax.random.uniform(k1, (d_in, d_out), jnp.float32,
                                             -scale, scale),
                "w_neigh": jax.random.uniform(k2, (d_in, d_out), jnp.float32,
                                              -scale, scale),
                "b": jnp.zeros((d_out,), jnp.float32),
            }
        elif cfg.kind == "gcn":
            layer = {
                "w": jax.random.uniform(k1, (d_in, d_out), jnp.float32,
                                        -scale, scale),
                "b": jnp.zeros((d_out,), jnp.float32),
            }
        else:
            raise ValueError(cfg.kind)
        params["layers"].append(layer)
    return params


def aggregate_mean(h: jnp.ndarray, edge_src: jnp.ndarray,
                   edge_dst: jnp.ndarray, edge_mask: jnp.ndarray,
                   num_segments: int) -> jnp.ndarray:
    """Masked mean of src features into dst slots (the paper's AGG).
    The jnp oracle; ``_aggregate`` may dispatch to the fused Pallas
    kernel instead."""
    msg = h[edge_src] * edge_mask[:, None].astype(h.dtype)
    summed = jax.ops.segment_sum(msg, edge_dst, num_segments=num_segments)
    cnt = jax.ops.segment_sum(edge_mask.astype(h.dtype), edge_dst,
                              num_segments=num_segments)
    return summed / jnp.maximum(cnt, 1.0)[:, None]


def _aggregate(cfg: GNNConfig, layer: int, h: jnp.ndarray,
               edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
               edge_mask: jnp.ndarray, m: int) -> jnp.ndarray:
    """Backend switch for the AGG: fused ``gather_agg`` when the config
    opts in AND the padded edge list honours the fan-out-regular
    contract (edge count divisible by the layer fan-out; the sampler's
    dst-major layout with replacement guarantees it), else the
    ``segment_sum`` oracle. Kernel output covers the dst prefix rows
    only -- the tail up to ``m`` is zero on both paths (padded dst rows
    are fully masked)."""
    fo = cfg.fanouts[layer] if cfg.fanouts else 0
    E = edge_src.shape[0]
    if cfg.agg_backend != "segment" and fo > 0 and E % fo == 0:
        nd = E // fo
        agg = gather_agg(h, edge_src, edge_mask, nd=nd, fanout=fo,
                         use_kernel=True,
                         interpret=cfg.agg_backend == "pallas_interpret")
        if nd < m:
            agg = jnp.concatenate(
                [agg, jnp.zeros((m - nd, h.shape[1]), agg.dtype)])
        return agg[:m]
    return aggregate_mean(h, edge_src, edge_dst, edge_mask, m)


def forward(cfg: GNNConfig, params: Dict[str, Any],
            features: jnp.ndarray,
            edge_src: Sequence[jnp.ndarray], edge_dst: Sequence[jnp.ndarray],
            edge_mask: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """-> logits for the whole padded node array; seeds are the prefix."""
    h = features
    m = features.shape[0]
    for l, layer in enumerate(params["layers"]):
        agg = _aggregate(cfg, l, h, edge_src[l], edge_dst[l],
                         edge_mask[l], m)
        if cfg.kind == "sage":
            h_new = h @ layer["w_self"] + agg @ layer["w_neigh"] + layer["b"]
        else:  # gcn: mean over {self} U neighbors (renormalisation trick)
            h_new = 0.5 * (h + agg) @ layer["w"] + layer["b"]
        if l < cfg.num_layers - 1:
            h_new = jax.nn.relu(h_new)
        h = h_new
    return h


def loss_fn(cfg: GNNConfig, params, features, edge_src, edge_dst, edge_mask,
            labels, seed_mask):
    logits = forward(cfg, params, features, edge_src, edge_dst, edge_mask)
    B = labels.shape[0]
    lg = logits[:B]
    logp = jax.nn.log_softmax(lg, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    w = seed_mask.astype(jnp.float32)
    loss = jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
    acc = jnp.sum((jnp.argmax(lg, -1) == labels) * w) / jnp.maximum(
        jnp.sum(w), 1.0)
    return loss, acc


def make_train_step(cfg: GNNConfig, optimizer):
    """-> jit'd (params, opt_state, batch_dict) -> (params, opt_state, aux)."""

    @jax.jit
    def step(params, opt_state, batch):
        def lf(p):
            return loss_fn(cfg, p, batch["features"], batch["edge_src"],
                           batch["edge_dst"], batch["edge_mask"],
                           batch["labels"], batch["seed_mask"])
        (loss, acc), grads = jax.value_and_grad(lf, has_aux=True)(params)
        params2, opt_state2 = optimizer.update(grads, opt_state, params)
        return params2, opt_state2, {"loss": loss, "acc": acc}

    return step


def batch_to_device(cb: CollatedBatch, features: np.ndarray) -> Dict[str, Any]:
    return {
        "features": jnp.asarray(features),
        "edge_src": [jnp.asarray(e) for e in cb.edge_src],
        "edge_dst": [jnp.asarray(e) for e in cb.edge_dst],
        "edge_mask": [jnp.asarray(e) for e in cb.edge_mask],
        "labels": jnp.asarray(cb.labels),
        "seed_mask": jnp.asarray(cb.seed_mask),
    }
