"""Model assembly: decoder-only LM + enc-dec, train/serve steps, shardings.

Layer stacks are scanned over the repeat dimension R (pattern positions
applied sequentially inside each scan body, ``jax.checkpoint``-remat'ed),
so 80-layer configs compile one body per pattern position regardless of
depth -- essential for the 512-device dry-runs on one CPU core.

Frontend stubs ([audio]/[vlm]): per the assignment carve-out, the model
consumes precomputed frame/patch embeddings of the right shape from
``input_specs`` -- the transformer backbone is the real implementation.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer.common import ArchConfig, rms_norm, softcap, dense_init
from repro.models.transformer.blocks import (init_block_params, block_apply,
                                             block_decode)


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ------------------------------------------------------------- init ------

def init_params(cfg: ArchConfig, key: jax.Array) -> Dict[str, Any]:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": dense_init(keys[0], (cfg.padded_vocab, cfg.d_model), 1,
                             dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1],
                                       (cfg.d_model, cfg.padded_vocab), 0,
                                       dt)

    R = cfg.num_repeats
    with_cross = cfg.kind == "encdec"

    def stack_blocks(kind, base_key, n, cross):
        ks = jax.random.split(base_key, n)
        ps = [init_block_params(cfg, kind, k, dt, with_cross=cross)
              for k in ks]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)

    params["blocks"] = [stack_blocks(kind, jax.random.fold_in(keys[2], i),
                                     R, with_cross)
                        for i, kind in enumerate(cfg.pattern)]
    params["tail_blocks"] = [
        init_block_params(cfg, kind, jax.random.fold_in(keys[4], i), dt,
                          with_cross=with_cross)
        for i, kind in enumerate(cfg.tail)]
    if cfg.kind == "encdec":
        params["enc_blocks"] = [stack_blocks("attn",
                                             jax.random.fold_in(keys[3], 0),
                                             cfg.num_enc_layers, False)]
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dt)
    return params


# ---------------------------------------------------------- forward ------

def _scan_blocks(cfg: ArchConfig, blocks, x, apply_fn):
    """Scan the stacked pattern blocks: blocks[i] has leaves (R, ...).
    ``cfg.unroll_layers`` switches to a python loop (roofline cost
    variants -- see launch/dryrun.py)."""

    @jax.checkpoint
    def body(h, layer_params):
        for i, kind in enumerate(cfg.pattern):
            h = apply_fn(kind, layer_params[i], h)
        return h, None

    if cfg.unroll_layers:
        R = jax.tree.leaves(blocks)[0].shape[0]
        for r in range(R):
            x, _ = body(x, jax.tree.map(lambda a: a[r], blocks))
        return x
    x, _ = jax.lax.scan(body, x, blocks)
    return x


def encode(cfg: ArchConfig, params, enc_embeds: jnp.ndarray) -> jnp.ndarray:
    """Encoder over stub frontend embeddings (B, S_src, d)."""
    pos = jnp.arange(enc_embeds.shape[1])[None, :]

    def apply_fn(kind, p, h):
        return block_apply(cfg, "attn", p, h, positions=pos, causal=False)

    x = _scan_blocks(
        dataclasses.replace(cfg, pattern=("attn",)), params["enc_blocks"],
        enc_embeds.astype(_dtype(cfg)), apply_fn)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(cfg: ArchConfig, params, tokens: jnp.ndarray, *,
            positions: Optional[jnp.ndarray] = None,
            mrope_positions: Optional[jnp.ndarray] = None,
            embeds: Optional[jnp.ndarray] = None,
            enc_out: Optional[jnp.ndarray] = None,
            mesh=None) -> jnp.ndarray:
    """tokens (B,S) -> logits (B,S,V). ``embeds`` (frontend stub output)
    is added onto the token embeddings when given."""
    dt = _dtype(cfg)
    x = params["embed"][tokens].astype(dt)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    if embeds is not None:
        x = x + embeds.astype(dt)
    if positions is None:
        positions = jnp.arange(tokens.shape[1])[None, :]

    def apply_fn(kind, p, h):
        return block_apply(cfg, kind, p, h, positions=positions,
                           mrope_positions=mrope_positions, enc_out=enc_out,
                           mesh=mesh)

    x = _scan_blocks(cfg, params["blocks"], x, apply_fn)
    for i, kind in enumerate(cfg.tail):
        x = apply_fn(kind, params["tail_blocks"][i], x)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(dt)
    logits = (x @ head)[..., :cfg.vocab_size]
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)


def lm_loss(cfg: ArchConfig, params, batch: Dict[str, jnp.ndarray],
            mesh=None) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits = forward(cfg, params, batch["tokens"],
                     mrope_positions=batch.get("mrope_positions"),
                     embeds=batch.get("embeds"),
                     enc_out=(encode(cfg, params, batch["enc_embeds"])
                              if cfg.kind == "encdec" else None),
                     mesh=mesh)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(nll))
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss}


def make_train_step(cfg: ArchConfig, optimizer, mesh=None):
    def step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(
            partial(lm_loss, cfg, mesh=mesh), has_aux=True)(params, batch)
        params2, opt2 = optimizer.update(grads, opt_state, params)
        return params2, opt2, aux
    return step


# ----------------------------------------------------------- decode ------

def init_decode_state(cfg: ArchConfig, batch: int, max_len: int,
                      window_override: int = 0,
                      src_len: int = 0) -> list:
    """Per-pattern-position stacked caches, leaves (R, B, ...)."""
    dt = _dtype(cfg)

    def one(kind, R):
        if kind in ("attn", "local"):
            S = cfg.window if kind == "local" else (
                window_override if window_override > 0 else max_len)
            S = min(S, max_len)
            st = {"k": jnp.zeros((R, batch, S, cfg.num_kv_heads,
                                  cfg.head_dim), dt),
                  "v": jnp.zeros((R, batch, S, cfg.num_kv_heads,
                                  cfg.head_dim), dt)}
        elif kind == "ssm":
            st = {"conv": jnp.zeros((R, batch, cfg.ssm_conv - 1,
                                     cfg.d_inner + 2 * cfg.ssm_state), dt),
                  "ssm": jnp.zeros((R, batch, cfg.ssm_heads,
                                    cfg.ssm_head_dim, cfg.ssm_state),
                                   jnp.float32)}
        elif kind == "rglru":
            w = cfg.lru_width or cfg.d_model
            st = {"conv": jnp.zeros((R, batch, cfg.ssm_conv - 1, w), dt),
                  "h": jnp.zeros((R, batch, w), jnp.float32)}
        else:
            raise ValueError(kind)
        if cfg.kind == "encdec":
            st["xk"] = jnp.zeros((R, batch, src_len, cfg.num_kv_heads,
                                  cfg.head_dim), dt)
            st["xv"] = jnp.zeros((R, batch, src_len, cfg.num_kv_heads,
                                  cfg.head_dim), dt)
            st["x_len"] = jnp.zeros((R, batch), jnp.int32)
        return st

    states = [one(kind, cfg.num_repeats) for kind in cfg.pattern]
    tail_states = [jax.tree.map(lambda x: x[0], one(kind, 1))
                   for kind in cfg.tail]
    return {"scan": states, "tail": tail_states}


def serve_step(cfg: ArchConfig, params, states, tokens: jnp.ndarray,
               pos: jnp.ndarray, *,
               mrope_positions: Optional[jnp.ndarray] = None,
               mesh=None, window_override: int = 0):
    """One decode step. tokens (B, 1); pos (B,) absolute positions.
    -> (logits (B, 1, V), new states)."""
    dt = _dtype(cfg)
    x = params["embed"][tokens].astype(dt)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    positions = pos[:, None]

    # scan over the repeat dim with the SAME interleaving as training:
    # within each scan body, pattern positions apply in order.
    def body(h, xs):
        layer_ps, layer_ss = xs
        new_ss = []
        for i, kind in enumerate(cfg.pattern):
            h, ns = block_decode(
                cfg, kind, layer_ps[i], h, layer_ss[i], pos=pos,
                positions=positions, mrope_positions=mrope_positions,
                mesh=mesh, window_override=window_override)
            new_ss.append(ns)
        return h, tuple(new_ss)

    if cfg.unroll_layers:
        R = jax.tree.leaves(params["blocks"])[0].shape[0]
        outs = []
        for r in range(R):
            xs = jax.tree.map(lambda a: a[r],
                              (tuple(params["blocks"]),
                               tuple(states["scan"])))
            x, ns = body(x, xs)
            outs.append(ns)
        new_scan = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    else:
        x, new_scan = jax.lax.scan(
            body, x, (tuple(params["blocks"]), tuple(states["scan"])))
    new_tail = []
    for i, kind in enumerate(cfg.tail):
        x, ns = block_decode(
            cfg, kind, params["tail_blocks"][i], x, states["tail"][i],
            pos=pos, positions=positions, mrope_positions=mrope_positions,
            mesh=mesh, window_override=window_override)
        new_tail.append(ns)
    new_states = {"scan": list(new_scan), "tail": new_tail}

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(dt)
    logits = softcap((x @ head)[..., :cfg.vocab_size].astype(jnp.float32),
                     cfg.final_softcap)
    return logits, new_states
