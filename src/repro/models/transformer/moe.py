"""Mixture-of-Experts: top-k router + sort-free capacity dispatch,
expert-parallel over the `model` mesh axis.

RapidGNN tie-in (DESIGN.md §4): MoE dispatch is the transformer's
"remote feature fetch" -- data-dependent sparse access to sharded state.
The deterministic schedule makes per-expert loads enumerable offline, so
capacity C is a *static* bound (the analogue of k_max in the a2a pull)
rather than a runtime reallocation.

Parallel layout: tokens stay sharded over (pod, data); experts are sharded
over `model` (E_local = E / tp per shard). Each model shard routes the
full token set (router weights replicated -- FLOPs are negligible),
dispatches only tokens choosing ITS experts into an (E_local, C, d)
buffer, applies its expert FFNs, and psums partial outputs over `model`.
This trades the classic a2a for one psum of the activations -- the same
volume as a TP FFN -- and is the paper-faithful "cache-local first" shape.
An a2a variant is evaluated in the perf hillclimb (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer.common import ArchConfig, dense_init


def init_moe_params(cfg: ArchConfig, key: jax.Array,
                    dtype=jnp.float32) -> Dict[str, jax.Array]:
    E, d, ff = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (d, E), 0, dtype),
        "w1": dense_init(k2, (E, d, ff), 1, dtype),   # gate proj
        "w3": dense_init(k3, (E, d, ff), 1, dtype),   # up proj
        "w2": dense_init(k4, (E, ff, d), 1, dtype),   # down proj
    }


def capacity(cfg: ArchConfig, tokens: int) -> int:
    import math
    c = math.ceil(cfg.top_k * tokens * cfg.capacity_factor
                  / cfg.num_experts)
    return max(c, 4)


def moe_local(params: Dict[str, jax.Array], x: jnp.ndarray,
              cfg: ArchConfig, e_offset: jnp.ndarray | int,
              n_local: int, cap: Optional[int] = None) -> jnp.ndarray:
    """Partial MoE output from experts [e_offset, e_offset+n_local).

    x (T, d) local tokens; expert weights already sliced to n_local.
    Caller psums partials over the expert-parallel axis.
    """
    T, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    C = cap if cap is not None else capacity(cfg, T)
    act = cfg.activation()

    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    top_p, top_e = jax.lax.top_k(probs, k)                   # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    e_flat = top_e.reshape(-1)                               # (T*k,)
    p_flat = top_p.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(T), k)

    e_loc = e_flat - e_offset
    mine = (e_loc >= 0) & (e_loc < n_local)
    key = jnp.where(mine, e_loc, n_local)                    # bucket E_l = drop

    # position of each token within its expert queue (dispatch order)
    onehot = jax.nn.one_hot(key, n_local + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot                # exclusive
    pos = jnp.take_along_axis(pos, key[:, None], axis=1)[:, 0]
    keep = mine & (pos < C)

    # scatter tokens into the (E_local, C, d) buffer (dropped -> row E_l)
    be = jnp.where(keep, key, n_local)
    bp = jnp.where(keep, pos, 0)
    buf = jnp.zeros((n_local + 1, C, d), x.dtype)
    buf = buf.at[be, bp].add(x[t_flat])
    buf = buf[:n_local]

    h = jnp.einsum("ecd,edf->ecf", buf, params["w1"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w3"].astype(x.dtype))
    y_e = jnp.einsum("ecf,efd->ecd", act(h) * u,
                     params["w2"].astype(x.dtype))           # (E_l, C, d)

    # combine back to tokens
    y_tok = y_e[jnp.where(keep, key, 0), bp]                 # (T*k, d)
    w = (p_flat * keep).astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[t_flat].add(y_tok * w[:, None])
    return out


def moe_apply(params: Dict[str, jax.Array], x: jnp.ndarray,
              cfg: ArchConfig, mesh=None, dp_spec=None,
              cap: Optional[int] = None) -> jnp.ndarray:
    """x (B, S, d) -> (B, S, d). With a mesh, experts shard over `model`
    via a fully-manual shard_map; without, all experts run locally."""
    B, S, d = x.shape
    x2 = x.reshape(B * S, d)
    if mesh is None or mesh.shape.get("model", 1) == 1:
        out = moe_local(params, x2, cfg, 0, cfg.num_experts, cap=cap)
        return out.reshape(B, S, d)

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    tp = mesh.shape["model"]
    n_local = cfg.num_experts // tp
    dp = dp_spec if dp_spec is not None else tuple(
        a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh.shape[a]
    if (B * S) % dp_size != 0:       # e.g. decode with global_batch=1
        dp = None

    if cfg.moe_resident_experts:
        # weight-stationary: experts over `model`, FF over dp; tokens are
        # replicated into the block (the allgather GSPMD inserts is tiny
        # at decode) and FF partials psum over dp. Weights never move.
        def body_ws(router, w1, w2, w3, xl):
            p = {"router": router, "w1": w1[0], "w2": w2[0],
                 "w3": w3[0]}
            off = jax.lax.axis_index("model") * n_local
            out = moe_local(p, xl, cfg, off, n_local, cap=cap)
            axes = ("model",) + ((dp if isinstance(dp, tuple) else (dp,))
                                 if dp else ())
            return jax.lax.psum(out, axes)

        wspec1 = P("model", None, None, dp)    # (tp, E_l, d, ff/dp)
        wspec2 = P("model", None, dp, None)
        out = shard_map(
            body_ws, mesh=mesh,
            in_specs=(P(), wspec1, wspec2, wspec1, P()),
            out_specs=P(),
        )(params["router"],
          params["w1"].reshape(tp, n_local, *params["w1"].shape[1:]),
          params["w2"].reshape(tp, n_local, *params["w2"].shape[1:]),
          params["w3"].reshape(tp, n_local, *params["w3"].shape[1:]),
          x2)
        return out.reshape(B, S, d)

    def body(router, w1, w2, w3, xl):
        p = {"router": router, "w1": w1[0], "w2": w2[0], "w3": w3[0]}
        off = jax.lax.axis_index("model") * n_local
        out = moe_local(p, xl, cfg, off, n_local, cap=cap)
        return jax.lax.psum(out, "model")

    espec = P("model")
    out = shard_map(
        body, mesh=mesh,
        in_specs=(P(), espec, espec, espec, P(dp, None)),
        out_specs=P(dp, None),
    )(params["router"],
      params["w1"].reshape(tp, n_local, *params["w1"].shape[1:]),
      params["w2"].reshape(tp, n_local, *params["w2"].shape[1:]),
      params["w3"].reshape(tp, n_local, *params["w3"].shape[1:]),
      x2)
    return out.reshape(B, S, d)
