"""Mamba2 SSD (state-space duality) mixer [arXiv:2405.21060].

Chunked SSD algorithm (the paper's "minimal discrete" form) in pure JAX:
quadratic attention-like compute INSIDE chunks of length Q, linear
recurrent state passing BETWEEN chunks (lax.scan). TPU adaptation: the
intra-chunk einsums are MXU-shaped (Q x Q x head_dim), the inter-chunk
scan carries only (h, p, n) state -- no sequence-length quadratic memory,
which is what qualifies mamba2 for long_500k.

Decode is O(1): a single recurrent state update per token.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer.common import ArchConfig, dense_init, rms_norm


def init_ssm_params(cfg: ArchConfig, key: jax.Array,
                    dtype=jnp.float32) -> Dict[str, jax.Array]:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * n
    ks = jax.random.split(key, 4)
    return {
        # in_proj packs [z (di), xBC (di+2n), dt (h)]
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * n + h), 0, dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_dim), 0, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[2], (di, d), 0, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                 ) -> jnp.ndarray:
    """Depthwise causal conv1d: x (B,S,C), w (K,C) -> (B,S,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a (..., L) -> (..., L, L): segsum[i, j] = sum_{t=j+1..i} a_t for
    i >= j (0 on the diagonal), -inf above the diagonal."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_scan(x: jnp.ndarray, dA: jnp.ndarray, B: jnp.ndarray,
             C: jnp.ndarray, chunk: int,
             init_state: jnp.ndarray | None = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD. x (b,S,h,p); dA (b,S,h); B,C (b,S,n) (single group).
    -> (y (b,S,h,p), final_state (b,h,p,n))."""
    b, S, h, p = x.shape
    n = B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    c = S // Q

    xc = x.reshape(b, c, Q, h, p)
    dAc = dA.reshape(b, c, Q, h)
    Bc = B.reshape(b, c, Q, n)
    Cc = C.reshape(b, c, Q, n)

    A_cs = jnp.cumsum(dAc, axis=2)                      # (b,c,Q,h)
    L = jnp.exp(_segsum(jnp.moveaxis(dAc, 3, 2)))       # (b,c,h,Q,Q)

    # intra-chunk (diagonal blocks); exp(-inf) = 0 masks the upper triangle
    scores = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)      # (b,c,Q,Q)
    y_diag = jnp.einsum("bcqs,bchqs,bcshp->bcqhp", scores, L, xc)

    # per-chunk end states
    decay_to_end = jnp.exp(A_cs[:, :, -1:, :] - A_cs)   # (b,c,Q,h)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bc, decay_to_end, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(A_cs[:, :, -1, :])            # (b,c,h)

    def step(carry, inp):
        st, dcy = inp                                   # (b,h,p,n),(b,h)
        prev = carry
        new = prev * dcy[..., None, None] + st
        return new, prev

    init = (init_state if init_state is not None
            else jnp.zeros((b, h, p, n), x.dtype))
    final, prevs = jax.lax.scan(step,
                                init,
                                (jnp.moveaxis(states, 1, 0),
                                 jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prevs, 0, 1)             # (b,c,h,p,n)

    # contribution of the incoming state to each position
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, prev_states,
                       jnp.exp(A_cs))
    y = (y_diag + y_off).reshape(b, S, h, p)
    return y, final


def ssm_forward(params: Dict[str, jax.Array], x: jnp.ndarray,
                cfg: ArchConfig) -> jnp.ndarray:
    """Full mamba2 mixer: x (B,S,d) -> (B,S,d)."""
    Bsz, S, d = x.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"].astype(x.dtype),
                                   params["conv_b"].astype(x.dtype)))
    xs, B_, C_ = jnp.split(xBC, [di, di + n], axis=-1)
    xs = xs.reshape(Bsz, S, h, p)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"])                        # (h,)
    dA = dt * A                                          # (B,S,h)

    y, _ = ssd_scan(xs.astype(jnp.float32) * dt[..., None],
                    dA, B_.astype(jnp.float32), C_.astype(jnp.float32),
                    cfg.ssm_chunk)
    y = y + params["D"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(Bsz, S, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)),
                 params["norm_scale"], cfg.norm_eps)
    return (y @ params["out_proj"].astype(y.dtype)).astype(x.dtype)


def ssm_decode_step(params: Dict[str, jax.Array], x: jnp.ndarray,
                    conv_state: jnp.ndarray, ssm_state: jnp.ndarray,
                    cfg: ArchConfig):
    """One-token decode. x (B,1,d); conv_state (B,K-1,conv_dim);
    ssm_state (B,h,p,n) -> (y (B,1,d), new conv/ssm states)."""
    Bsz, _, d = x.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    K = cfg.ssm_conv

    zxbcdt = x[:, 0] @ params["in_proj"].astype(x.dtype)
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)

    conv_in = jnp.concatenate([conv_state, xBC[:, None]], axis=1)  # (B,K,·)
    w = params["conv_w"].astype(x.dtype)
    xBC = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_in, w)
                      + params["conv_b"].astype(x.dtype))
    new_conv = conv_in[:, 1:]

    xs, B_, C_ = jnp.split(xBC, [di, di + n], axis=-1)
    xs = xs.reshape(Bsz, h, p).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,h)
    A = -jnp.exp(params["a_log"])
    da = jnp.exp(dt * A)                                 # (B,h)

    # h_new = h * exp(dtA) + (dt*x) outer B
    upd = jnp.einsum("bhp,bn->bhpn", xs * dt[..., None],
                     B_.astype(jnp.float32))
    new_ssm = ssm_state * da[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_ssm, C_.astype(jnp.float32))
    y = y + params["D"][:, None] * xs
    y = y.reshape(Bsz, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)),
                 params["norm_scale"], cfg.norm_eps)
    out = (y @ params["out_proj"].astype(y.dtype)).astype(x.dtype)
    return out[:, None], new_conv, new_ssm
