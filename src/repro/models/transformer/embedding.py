"""Hot-token embedding cache: RapidGNN's technique on the vocab table.

DESIGN.md §4: a vocab-sharded embedding table is the transformer's
"distributed KV store" -- every token id is a remote feature fetch unless
its row lives locally. Token ids are Zipf-distributed (long tail), and
the deterministic data schedule (data/pipeline.py) makes the access
counts of a whole run enumerable OFFLINE, exactly like the paper's
Alg. 1 lines 1-3. So each worker:

  1. enumerates its run's token-access counts (offline),
  2. VectorPulls the top-n_hot non-local rows into a device cache,
  3. serves batches cache-first; only residual misses hit the a2a pull.

The device data path reuses the SAME machinery as the GNN core:
``repro.dist.feature_a2a.pull_features`` for the pull and the
``cache_lookup`` Pallas kernel for the hit path. ``HotEmbeddingSim``
provides host-side accounting for the benchmarks (bytes/RPC reduction --
paper Fig. 4/5 on the embedding workload).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class HotEmbeddingSim:
    vocab: int
    d: int
    num_workers: int
    n_hot: int
    counts: np.ndarray          # (vocab,) offline access counts

    def __post_init__(self):
        per = (self.vocab + self.num_workers - 1) // self.num_workers
        self.owner = np.minimum(np.arange(self.vocab) // per,
                                self.num_workers - 1)
        # per-worker hot set: most-accessed REMOTE ids (paper N_cache)
        self.cache = []
        for w in range(self.num_workers):
            remote = np.flatnonzero(self.owner != w)
            order = remote[np.argsort(-self.counts[remote],
                                      kind="stable")]
            self.cache.append(np.sort(order[: self.n_hot]))

    def batch_traffic(self, tokens: np.ndarray, worker: int
                      ) -> Tuple[int, int, int]:
        """-> (baseline_bytes, cached_bytes, hits) for one batch on one
        worker. Baseline = every unique remote id fetched (DGL-style,
        already deduped -- favourable to the baseline)."""
        uniq = np.unique(tokens)
        remote = uniq[self.owner[uniq] != worker]
        hits = np.isin(remote, self.cache[worker],
                       assume_unique=True).sum()
        row = self.d * 4
        return remote.size * row, int((remote.size - hits) * row), int(hits)

    def cache_build_bytes(self) -> int:
        return self.n_hot * self.d * 4


def device_embedding_lookup(mesh, table, cache_ids, cache_feats, tokens,
                            plan, m_max):
    """Device path: cache-first gather + a2a residual pull.

    Thin composition of the GNN-core primitives (see module docstring);
    used by the TPU data path and exercised in tests via the host mesh.
    table (P, V/P, d) vocab-sharded over `data`; plan is a PullPlan for
    the residual misses (built offline from the deterministic schedule).
    """
    from repro.dist.feature_a2a import pull_features, cache_gather
    import jax.numpy as jnp
    pulled = pull_features(mesh, table, plan["send_ids"], plan["send_pos"],
                           plan["send_mask"], plan["offsets"], m_max)
    import jax
    def merge(cid, cfe, tok, base):
        out, _ = cache_gather(cid, cfe, tok, base)
        return out
    return jax.vmap(merge)(cache_ids, cache_feats, tokens, pulled)
