"""Block-level init/apply for every layer kind: attn/local, ssm, rglru.

Each block = mixer + (FFN | MoE | nothing-for-ssm), pre-norm residual
(+ optional gemma2 sandwich post-norms). Parameters for one *pattern
position* are stacked over the repeat dimension R and scanned in
model.py.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer.common import (ArchConfig, apply_mrope,
                                             apply_rope, dense_init,
                                             rms_norm, softcap)
from repro.models.transformer.attention import attention, decode_attention
from repro.models.transformer.moe import init_moe_params, moe_apply
from repro.models.transformer.ssm import (init_ssm_params, ssm_forward,
                                          ssm_decode_step)
from repro.models.transformer.rglru import (init_rglru_params,
                                            rglru_forward,
                                            rglru_decode_step)


# --------------------------------------------------------------- init ----

def init_attn_params(cfg: ArchConfig, key: jax.Array, dtype,
                     cross: bool = False) -> Dict[str, Any]:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.q_dim), 0, dtype),
        "wk": dense_init(ks[1], (d, cfg.kv_dim), 0, dtype),
        "wv": dense_init(ks[2], (d, cfg.kv_dim), 0, dtype),
        "wo": dense_init(ks[3], (cfg.q_dim, d), 0, dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), dtype)
    return p


def init_ffn_params(cfg: ArchConfig, key: jax.Array, dtype,
                    d_ff: Optional[int] = None) -> Dict[str, Any]:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w1": dense_init(k1, (d, ff), 0, dtype),
            "w3": dense_init(k2, (d, ff), 0, dtype),
            "w2": dense_init(k3, (ff, d), 0, dtype)}


def init_block_params(cfg: ArchConfig, kind: str, key: jax.Array, dtype,
                      with_cross: bool = False) -> Dict[str, Any]:
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": jnp.zeros((d,), dtype)}
    if kind in ("attn", "local"):
        p["attn"] = init_attn_params(cfg, ks[0], dtype)
    elif kind == "ssm":
        p["ssm"] = init_ssm_params(cfg, ks[0], dtype)
    elif kind == "rglru":
        p["rglru"] = init_rglru_params(cfg, ks[0], dtype)
    else:
        raise ValueError(kind)
    if cfg.post_norms:
        p["ln1_post"] = jnp.zeros((d,), dtype)
    if with_cross:
        p["ln_x"] = jnp.zeros((d,), dtype)
        p["xattn"] = init_attn_params(cfg, ks[1], dtype, cross=True)
    if kind != "ssm":
        p["ln2"] = jnp.zeros((d,), dtype)
        if cfg.moe:
            p["moe"] = init_moe_params(cfg, ks[2], dtype)
            if cfg.dense_residual:
                p["ffn"] = init_ffn_params(cfg, ks[3], dtype)
        else:
            p["ffn"] = init_ffn_params(cfg, ks[3], dtype)
        if cfg.post_norms:
            p["ln2_post"] = jnp.zeros((d,), dtype)
    return p


# -------------------------------------------------------------- apply ----

def _project_qkv(cfg: ArchConfig, p, h, positions, mrope_positions):
    B, S, _ = h.shape
    q = h @ p["wq"].astype(h.dtype)
    k = h @ p["wk"].astype(h.dtype)
    v = h @ p["wv"].astype(h.dtype)
    if "bq" in p:
        q, k, v = (q + p["bq"].astype(h.dtype), k + p["bk"].astype(h.dtype),
                   v + p["bv"].astype(h.dtype))
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope_sections and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.rope_theta,
                        cfg.mrope_sections)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta,
                        cfg.mrope_sections)
    elif positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def ffn_apply(cfg: ArchConfig, p, h):
    act = cfg.activation()
    return (act(h @ p["w1"].astype(h.dtype)) * (h @ p["w3"].astype(h.dtype))
            ) @ p["w2"].astype(h.dtype)


def mixer_ffn(cfg: ArchConfig, p, x, mesh):
    """The FFN/MoE half of a block (shared by train & decode paths)."""
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe:
        out = moe_apply(p["moe"], h2, cfg, mesh=mesh)
        if cfg.dense_residual:
            out = out + ffn_apply(cfg, p["ffn"], h2)
    else:
        out = ffn_apply(cfg, p["ffn"], h2)
    if cfg.post_norms:
        out = rms_norm(out, p["ln2_post"], cfg.norm_eps)
    return x + out


def block_apply(cfg: ArchConfig, kind: str, p, x, *, positions=None,
                mrope_positions=None, enc_out=None, mesh=None,
                causal: bool = True):
    """Training/prefill forward for one block. x (B,S,d)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("attn", "local"):
        q, k, v = _project_qkv(cfg, p["attn"], h, positions,
                               mrope_positions)
        window = cfg.window if kind == "local" else 0
        if cfg.seq_shard_attn and mesh is not None and \
                mesh.shape.get("model", 1) > 1:
            # sequence-parallel attention: q rows sharded over `model`,
            # k/v replicated (GSPMD inserts the allgather). Per-device
            # score work becomes S/tp x S regardless of head count.
            from jax.sharding import PartitionSpec as SP
            from repro.dist.mesh import dp_axes
            dp = dp_axes(mesh)
            q = jax.lax.with_sharding_constraint(
                q, jax.sharding.NamedSharding(
                    mesh, SP(dp, "model", None, None)))
            k = jax.lax.with_sharding_constraint(
                k, jax.sharding.NamedSharding(mesh, SP(dp, None, None,
                                                       None)))
            v = jax.lax.with_sharding_constraint(
                v, jax.sharding.NamedSharding(mesh, SP(dp, None, None,
                                                       None)))
        o = attention(q, k, v, causal=causal, window=window,
                      attn_softcap=cfg.attn_softcap,
                      q_chunk=cfg.attn_q_chunk,
                      kv_chunk=cfg.attn_kv_chunk)
        o = o.reshape(*x.shape[:2], cfg.q_dim) @ p["attn"]["wo"].astype(
            x.dtype)
    elif kind == "ssm":
        o = ssm_forward(p["ssm"], h, cfg)
    elif kind == "rglru":
        o = rglru_forward(p["rglru"], h, cfg)
    else:
        raise ValueError(kind)
    if cfg.post_norms:
        o = rms_norm(o, p["ln1_post"], cfg.norm_eps)
    x = x + o

    if enc_out is not None and "xattn" in p:
        hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
        px = p["xattn"]
        B, S, _ = hx.shape
        q = (hx @ px["wq"].astype(hx.dtype)).reshape(B, S, cfg.num_heads,
                                                     cfg.head_dim)
        k = (enc_out @ px["wk"].astype(hx.dtype)).reshape(
            B, -1, cfg.num_kv_heads, cfg.head_dim)
        v = (enc_out @ px["wv"].astype(hx.dtype)).reshape(
            B, -1, cfg.num_kv_heads, cfg.head_dim)
        o = attention(q, k, v, causal=False)
        x = x + o.reshape(B, S, cfg.q_dim) @ px["wo"].astype(hx.dtype)

    if kind != "ssm":
        x = mixer_ffn(cfg, p, x, mesh)
    return x


# -------------------------------------------------------- decode apply ----

def block_decode(cfg: ArchConfig, kind: str, p, x, state: Dict[str, Any],
                 *, pos, positions=None, mrope_positions=None,
                 enc_out=None, mesh=None, window_override: int = 0):
    """One-token decode. x (B,1,d); state holds this block's caches.
    pos (B,) absolute position of the new token."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    new_state = dict(state)
    if kind in ("attn", "local"):
        q, k, v = _project_qkv(cfg, p["attn"], h, positions,
                               mrope_positions)
        S_cache = state["k"].shape[1]
        # ring-buffer write: when S_cache covers all positions this is the
        # identity; for window caches (S_cache == window) it wraps. RoPE
        # is applied at write time, so slot order is irrelevant to
        # attention (permutation-invariant over the valid set).
        slot = pos % S_cache
        bidx = jnp.arange(x.shape[0])
        k_cache = state["k"].at[bidx, slot].set(k[:, 0].astype(
            state["k"].dtype))
        v_cache = state["v"].at[bidx, slot].set(v[:, 0].astype(
            state["v"].dtype))
        length = jnp.minimum(pos + 1, S_cache)
        if mesh is not None and mesh.shape.get("model", 1) > 1:
            from repro.serve.attention import sharded_decode_attention
            o = sharded_decode_attention(mesh, q, k_cache, v_cache, length,
                                         attn_softcap=cfg.attn_softcap)
        else:
            o = decode_attention(q, k_cache, v_cache, length,
                                 attn_softcap=cfg.attn_softcap)
        o = o.reshape(x.shape[0], 1, cfg.q_dim) @ p["attn"]["wo"].astype(
            x.dtype)
        new_state["k"], new_state["v"] = k_cache, v_cache
    elif kind == "ssm":
        o, new_conv, new_ssm = ssm_decode_step(p["ssm"], h,
                                               state["conv"], state["ssm"],
                                               cfg)
        new_state["conv"], new_state["ssm"] = new_conv, new_ssm
    elif kind == "rglru":
        o, new_conv, new_h = rglru_decode_step(p["rglru"], h,
                                               state["conv"], state["h"],
                                               cfg)
        new_state["conv"], new_state["h"] = new_conv, new_h
    else:
        raise ValueError(kind)
    if cfg.post_norms:
        o = rms_norm(o, p["ln1_post"], cfg.norm_eps)
    x = x + o

    if "xattn" in p and "xk" in state:
        hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
        px = p["xattn"]
        B = hx.shape[0]
        q = (hx @ px["wq"].astype(hx.dtype)).reshape(B, 1, cfg.num_heads,
                                                     cfg.head_dim)
        # cross K/V were precomputed at prefill time
        o = decode_attention(q, state["xk"], state["xv"],
                             state["x_len"])
        x = x + o.reshape(B, 1, cfg.q_dim) @ px["wo"].astype(hx.dtype)

    if kind != "ssm":
        x = mixer_ffn(cfg, p, x, mesh)
    return x, new_state
