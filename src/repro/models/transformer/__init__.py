from repro.models.transformer.common import ArchConfig
from repro.models.transformer.model import (init_params, forward, encode,
                                            lm_loss, make_train_step,
                                            init_decode_state, serve_step)

__all__ = ["ArchConfig", "init_params", "forward", "encode", "lm_loss",
           "make_train_step", "init_decode_state", "serve_step"]
