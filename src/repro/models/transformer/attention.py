"""Training/prefill attention: chunked online-softmax (flash-style) in jnp.

Memory is O(S * q_chunk) instead of O(S^2), which is what lets the
train_4k / prefill_32k dry-runs fit HBM (the (B,H,S,S) score tensor of a
naive implementation would be TBs at 32k). Sliding-window ("local")
layers attend over a dynamically-sliced KV *band* so the compiled FLOPs
reflect the sub-quadratic cost (roofline honesty), not just a mask.

GQA is computed in grouped form -- q is reshaped to (B, S, kvH, G, dh) and
k/v are never repeated to H heads.

NOTE on HLO FLOPs: full-causal attention computes the full (S x S)
rectangle and masks; compiled FLOPs are ~2x the causal triangle. The
roofline analysis corrects for this via the MODEL_FLOPS ratio
(EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.transformer.common import softcap as _softcap

NEG_INF = -1e30


def _online_update(carry, s, v_chunk, valid):
    m, l, acc = carry
    s = jnp.where(valid, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(valid, p, 0.0)
    l_new = l * alpha + p.sum(axis=-1)
    acc_new = (acc * alpha[..., None] +
               jnp.einsum("bhgqk,bkhd->bhgqd", p, v_chunk))
    return m_new, l_new, acc_new


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: int = 0,
              attn_softcap: float = 0.0, q_chunk: int = 512,
              kv_chunk: int = 1024, scale: Optional[float] = None,
              q_offset: int = 0) -> jnp.ndarray:
    """q (B,Sq,H,dh); k/v (B,Skv,kvH,dh) -> (B,Sq,H,dh).

    ``q_offset`` is the absolute position of q[0] (cross-chunk prefill).
    ``window > 0`` restricts attention to the last `window` positions
    (inclusive of self) and switches to banded compute.
    """
    B, Sq, H, dh = q.shape
    _, Skv, kvH, _ = k.shape
    G = H // kvH
    scale = scale if scale is not None else dh ** -0.5
    qg = (q * scale).reshape(B, Sq, kvH, G, dh)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0

    if window > 0:
        return _banded(qg, k, v, window=window, attn_softcap=attn_softcap,
                       q_chunk=q_chunk, q_offset=q_offset).reshape(
                           B, Sq, H, dh)

    nq, nk = Sq // q_chunk, Skv // kv_chunk

    def q_block(i):
        qb = jax.lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, 1)
        qb = jnp.moveaxis(qb, 1, 3)            # (B,kvH,G,Tq,dh)
        qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, j):
            kb = jax.lax.dynamic_slice_in_dim(k, j * kv_chunk, kv_chunk, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, j * kv_chunk, kv_chunk, 1)
            s = jnp.einsum("bhgqd,bkhd->bhgqk", qb.astype(jnp.float32),
                           kb.astype(jnp.float32))
            s = _softcap(s, attn_softcap)
            kpos = j * kv_chunk + jnp.arange(kv_chunk)
            valid = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                valid = kpos[None, :] <= qpos[:, None]
            return _online_update(carry, s, vb.astype(jnp.float32),
                                  valid[None, None, None]), None

        init = (jnp.full((B, kvH, G, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((B, kvH, G, q_chunk), jnp.float32),
                jnp.zeros((B, kvH, G, q_chunk, dh), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)          # (B,Tq,kvH,G,dh)

    blocks = jax.lax.map(q_block, jnp.arange(nq))   # (nq,B,Tq,kvH,G,dh)
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, Sq, kvH, G, dh)
    return out.reshape(B, Sq, H, dh).astype(q.dtype)


def _banded(qg, k, v, *, window, attn_softcap, q_chunk, q_offset):
    """Sliding-window attention over a dynamically sliced KV band."""
    B, Sq, kvH, G, dh = qg.shape
    Skv = k.shape[1]
    band = window + q_chunk            # covers all positions a chunk needs
    band = min(band, Skv)
    nq = Sq // q_chunk

    def q_block(i):
        qb = jnp.moveaxis(
            jax.lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, 1),
            1, 3)                                   # (B,kvH,G,Tq,dh)
        qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)
        # kv band start (absolute index into the kv array)
        start = jnp.clip(q_offset + i * q_chunk + q_chunk - band, 0,
                         Skv - band)
        kb = jax.lax.dynamic_slice_in_dim(k, start, band, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, band, 1)
        s = jnp.einsum("bhgqd,bkhd->bhgqk", qb.astype(jnp.float32),
                       kb.astype(jnp.float32))
        s = _softcap(s, attn_softcap)
        kpos = start + jnp.arange(band)
        valid = ((kpos[None, :] <= qpos[:, None]) &
                 (kpos[None, :] > qpos[:, None] - window))
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        p = jnp.where(valid[None, None, None], p, 0.0)
        out = jnp.einsum("bhgqk,bkhd->bhgqd", p,
                         vb.astype(jnp.float32))
        out = out / jnp.maximum(p.sum(-1), 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)              # (B,Tq,kvH,G,dh)

    blocks = jax.lax.map(q_block, jnp.arange(nq))
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, Sq, kvH, G, dh)
    return out.astype(k.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, length: jnp.ndarray, *,
                     window: int = 0, attn_softcap: float = 0.0,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """One-token decode: q (B,1,H,dh); caches (B,S,kvH,dh); length (B,).

    jnp path (CPU/oracle). The TPU path with a sequence-sharded cache is
    repro.serve.attention.sharded_decode_attention, built on the
    flash_decode Pallas kernel.
    """
    from repro.kernels.flash_decode.ops import flash_decode
    from repro.kernels.flash_decode.ref import finalize
    B, _, H, dh = q.shape

    def one(qi, ki, vi, ln):
        start = (jnp.maximum(ln - window, 0) if window > 0
                 else jnp.zeros((), jnp.int32))
        acc, m, l = flash_decode(qi, ki, vi, ln, start.astype(jnp.int32),
                                 scale=scale, softcap=attn_softcap)
        return finalize(acc, l)

    out = jax.vmap(one)(q[:, 0], k_cache, v_cache, length)
    return out[:, None].astype(q.dtype)
