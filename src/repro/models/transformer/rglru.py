"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_r u_t + b_r)           (recurrence gate)
    i_t = sigmoid(W_i u_t + b_i)           (input gate)
    log a_t = -c * softplus(Lambda) * r_t  (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

TPU adaptation: the linear recurrence runs as a jax.lax.associative_scan
over (a, b) pairs -- log-depth tree matching the paper's hardware-
efficient formulation -- rather than a sequential loop. Decode is an O(1)
state update. The full Griffin recurrent block wraps the RG-LRU with a
temporal conv and a GeLU gate branch.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer.common import ArchConfig, dense_init

_C = 8.0


def init_rglru_params(cfg: ArchConfig, key: jax.Array,
                      dtype=jnp.float32) -> Dict[str, jax.Array]:
    d = cfg.d_model
    w = cfg.lru_width or d
    nb = max(cfg.num_heads, 1)         # gate blocks (Griffin §2.4)
    assert w % nb == 0, (w, nb)
    wb = w // nb
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], (d, w), 0, dtype),        # recurrent branch
        "w_gate": dense_init(ks[1], (d, w), 0, dtype),     # gelu gate branch
        "w_out": dense_init(ks[2], (w, d), 0, dtype),
        "conv_w": dense_init(ks[3], (cfg.ssm_conv, w), 0, dtype),
        "conv_b": jnp.zeros((w,), dtype),
        # BLOCK-DIAGONAL recurrence/input gates (the Griffin paper's
        # "block-diagonal weights"): head-local => shardable over `model`
        # with zero collective traffic (EXPERIMENTS.md §Perf iter. 4)
        "w_r": dense_init(ks[4], (nb, wb, wb), 1, dtype),
        "b_r": jnp.zeros((w,), jnp.float32),
        "w_i": dense_init(ks[5], (nb, wb, wb), 1, dtype),
        "b_i": jnp.zeros((w,), jnp.float32),
        # Lambda init so that a^c ~ U[0.9, 0.999] at r=1 (paper init)
        "lam": jnp.log(jnp.expm1(-jnp.log(
            jnp.linspace(0.9, 0.999, w)) / _C)).astype(jnp.float32),
    }


def _block_mm(u, wblk):
    """u (..., w) x block-diagonal (nb, wb, wb) -> (..., w), head-local."""
    nb, wb, _ = wblk.shape
    ub = u.reshape(*u.shape[:-1], nb, wb)
    out = jnp.einsum("...hw,hwv->...hv", ub, wblk.astype(u.dtype))
    return out.reshape(*u.shape)


def _gates(params, u):
    r = jax.nn.sigmoid(_block_mm(u, params["w_r"])
                       + params["b_r"].astype(u.dtype))
    i = jax.nn.sigmoid(_block_mm(u, params["w_i"])
                       + params["b_i"].astype(u.dtype))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-9, 1.0)) * (i * u)
    return a, b


def rglru_scan(params: Dict[str, jax.Array], u: jnp.ndarray,
               h0: jnp.ndarray | None = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """u (B,S,w) fp32 -> (h (B,S,w), final state (B,w))."""
    a, b = _gates(params, u)
    if h0 is not None:
        # fold the carried state into the first step's offset
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def _causal_conv(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K)) + b


def rglru_forward(params: Dict[str, jax.Array], x: jnp.ndarray,
                  cfg: ArchConfig) -> jnp.ndarray:
    """Griffin recurrent block: x (B,S,d) -> (B,S,d)."""
    u = x @ params["w_x"].astype(x.dtype)
    u = _causal_conv(u, params["conv_w"].astype(x.dtype),
                     params["conv_b"].astype(x.dtype))
    h, _ = rglru_scan(params, u.astype(jnp.float32))
    g = jax.nn.gelu(x @ params["w_gate"].astype(x.dtype))
    out = (h.astype(x.dtype) * g) @ params["w_out"].astype(x.dtype)
    return out


def rglru_decode_step(params: Dict[str, jax.Array], x: jnp.ndarray,
                      conv_state: jnp.ndarray, h_state: jnp.ndarray,
                      cfg: ArchConfig):
    """x (B,1,d); conv_state (B,K-1,w); h_state (B,w) -> (y, states)."""
    u = x[:, 0] @ params["w_x"].astype(x.dtype)            # (B,w)
    conv_in = jnp.concatenate([conv_state, u[:, None]], axis=1)
    w = params["conv_w"].astype(x.dtype)
    u = jnp.einsum("bkc,kc->bc", conv_in, w) + params["conv_b"].astype(x.dtype)
    new_conv = conv_in[:, 1:]

    a, b = _gates(params, u.astype(jnp.float32))
    h_new = a * h_state + b
    g = jax.nn.gelu(x[:, 0] @ params["w_gate"].astype(x.dtype))
    out = (h_new.astype(x.dtype) * g) @ params["w_out"].astype(x.dtype)
    return out[:, None], new_conv, h_new
