"""Shared transformer substrate: unified arch config, norms, RoPE/M-RoPE.

One ``ArchConfig`` describes every assigned architecture (dense GQA, MoE,
SSM, hybrid RG-LRU, enc-dec, VLM/audio backbones). Layer stacks are
expressed as a repeating ``pattern`` of block kinds scanned with
``jax.lax.scan`` over the repeat dimension (compile-once-per-kind).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    kind: str = "decoder"              # "decoder" | "encdec"
    num_layers: int = 12               # decoder layers
    num_enc_layers: int = 0            # encoder layers (encdec only)
    d_model: int = 1024
    num_heads: int = 16
    num_kv_heads: int = 16
    head_dim: int = 64
    d_ff: int = 4096
    vocab_size: int = 32000
    # block pattern, cycled over num_layers: entries in
    # {"attn", "local", "ssm", "rglru"}
    pattern: Tuple[str, ...] = ("attn",)
    # attention
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    attn_softcap: float = 0.0          # gemma2 attention logit softcap
    final_softcap: float = 0.0         # gemma2 final logit softcap
    window: int = 0                    # sliding window for "local" blocks
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w)
    # MoE
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False       # arctic: dense FFN in parallel
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # RG-LRU (recurrentgemma)
    lru_width: int = 0
    # misc
    act: str = "silu"                  # "silu" | "gelu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    frontend: str = ""                 # "" | "audio" | "vision" (stubbed)
    dtype: str = "bfloat16"
    qk_norm: bool = False              # per-head q/k RMSNorm (qwen3)
    post_norms: bool = False           # sandwich norms (gemma2)
    embed_scale: bool = False          # scale embeddings by sqrt(d) (gemma)
    # cost-model controls (dry-run roofline): XLA cost_analysis counts a
    # scan body ONCE, so the roofline pipeline compiles small UNROLLED
    # variants and extrapolates (launch/dryrun.py)
    unroll_layers: bool = False
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    # ---- beyond-paper perf options (EXPERIMENTS.md §Perf) ----
    # sequence-parallel training attention: shard the q/scores sequence
    # dim over `model` (k/v allgathered). Fixes head-count/TP mismatches
    # (e.g. smollm's 15 heads on TP=16, which GSPMD otherwise replicates).
    seq_shard_attn: bool = False
    # keep MoE expert weights resident per model-shard (no FSDP dim) --
    # removes the per-layer expert allgather; decode-friendly.
    moe_resident_experts: bool = False

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a multiple of 256 so the vocab dim
        shards over any TP axis (Megatron-style); logits are sliced back
        to ``vocab_size``, semantics unchanged."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def num_repeats(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def tail(self) -> Tuple[str, ...]:
        """Pattern positions of the trailing partial repeat (e.g.
        recurrentgemma-9b: 38 layers = 12 x (rglru,rglru,local) + 2)."""
        return self.pattern[: self.num_layers % len(self.pattern)]

    @property
    def d_inner(self) -> int:          # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def activation(self):
        return jax.nn.silu if self.act == "silu" else jax.nn.gelu

    def param_counts(self) -> dict:
        """Analytic parameter counts (N for the 6ND roofline term)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = {}
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        per_layer["attn"] = attn + 2 * d
        per_layer["local"] = per_layer["attn"]
        per_layer["ffn"] = 3 * d * ff + d
        if self.moe:
            per_layer["moe"] = (self.num_experts * 3 * d * self.moe_d_ff
                                + d * self.num_experts + d)
            per_layer["moe_active"] = (self.top_k * 3 * d * self.moe_d_ff
                                       + d * self.num_experts + d)
        if "ssm" in self.pattern:
            di, n, h = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer["ssm"] = (d * (2 * di + 2 * n + h) + di * d
                                + self.ssm_conv * (di + 2 * n) + 3 * h + d)
        if "rglru" in self.pattern:
            w = self.lru_width or d
            per_layer["rglru"] = (2 * d * w + w * d + 2 * w * w // 1
                                  + self.ssm_conv * w + 2 * d)
        total = emb
        active = emb
        for i in range(self.num_layers):
            kindl = self.pattern[i % len(self.pattern)]
            blk = per_layer.get(kindl, per_layer.get("attn"))
            total += blk
            active += blk
            if kindl != "ssm":          # every non-SSM block has FFN/MoE
                if self.moe:
                    total += per_layer["moe"]
                    active += per_layer["moe_active"]
                    if self.dense_residual:
                        total += per_layer["ffn"]
                        active += per_layer["ffn"]
                else:
                    total += per_layer["ffn"]
                    active += per_layer["ffn"]
        if self.kind == "encdec":
            enc = self.num_enc_layers * (per_layer["attn"] + per_layer["ffn"])
            xattn = self.num_layers * per_layer["attn"]
            total += enc + xattn
            active += enc + xattn
        return {"total": int(total), "active": int(active)}


# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x (..., S, H, dh); positions (..., S) -> rotated x."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Sequence[int]) -> jnp.ndarray:
    """Multimodal RoPE (qwen2-vl): positions (3, ..., S); the dh/2
    frequency bands are split into (t, h, w) sections, each rotated by its
    own position stream."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (dh/2,)
    sec = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                           for i, s in enumerate(sections)])
    assert sec.shape[0] == dh // 2, (sections, dh)
    # pick the position stream per frequency band
    arr = jnp.moveaxis(positions, 0, -1)[..., None, :]   # (..., S, 1, 3)
    idx = sec.astype(jnp.int32).reshape(
        (1,) * (arr.ndim - 2) + (sec.shape[0], 1))       # (...,1,dh/2,1)
    pos = jnp.take_along_axis(arr, idx, axis=-1)[..., 0]  # (..., S, dh/2)
    ang = pos.astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return jnp.tanh(x / cap) * cap if cap > 0.0 else x


def dense_init(key, shape, in_axis=0, dtype=jnp.float32):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else \
        int(jnp.prod(jnp.array([shape[a] for a in in_axis])))
    std = fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)
