from repro.models.gnn import (GNNConfig, init_params, forward, loss_fn,
                              make_train_step, batch_to_device)

__all__ = ["GNNConfig", "init_params", "forward", "loss_fn",
           "make_train_step", "batch_to_device"]
