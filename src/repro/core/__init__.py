"""RapidGNN core: deterministic schedule, hot-set cache, prefetch pipeline."""
from repro.core.schedule import (build_schedule, WorkerSchedule,
                                 EpochSchedule, CollatedBatch, collate,
                                 epoch_edge_maxima, merge_pad_bounds,
                                 select_hot_set)
from repro.core.cache import FeatureCache, DoubleBufferCache
from repro.core.fetch import ShardedFeatureStore
from repro.core.prefetch import Prefetcher, SecondaryCacheBuilder, assemble_features
from repro.core.runtime import RapidGNNRunner, BaselineRunner, global_pad_bounds
from repro.core.metrics import (EpochMetrics, RunMetrics, NetworkModel,
                                modelled_energy, POWER)

__all__ = [
    "build_schedule", "WorkerSchedule", "EpochSchedule", "CollatedBatch",
    "collate", "epoch_edge_maxima", "merge_pad_bounds", "select_hot_set",
    "FeatureCache",
    "DoubleBufferCache",
    "ShardedFeatureStore", "Prefetcher", "SecondaryCacheBuilder",
    "assemble_features", "RapidGNNRunner", "BaselineRunner",
    "global_pad_bounds", "EpochMetrics", "RunMetrics", "NetworkModel",
    "modelled_energy", "POWER",
]
