"""Offline enumeration + cache-candidate selection (paper §3, Alg. 1 l.1-4).

Precomputes, per worker, the full deterministic training schedule:
  * every epoch's batch metadata  {B_e}  (ids / offsets / locality only),
  * the access union  N = U_e U_i N_i^e  and  N_remote = N \\ N_local,
  * per-epoch remote access frequencies  freq(.)  over {B_e},
  * the hot set  N_cache = top-n_hot of N_remote by freq  (per epoch, so
    the double buffer C_sec for e+1 can differ from C_s for e),
  * padding bounds  m_max  and per-layer edge maxima (XLA static shapes).

Like the paper's SSD streaming, epochs can be spilled to disk
(``spill_dir``) so precompute memory stays bounded on huge runs.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.graph.partition import PartitionedGraph
from repro.graph.sampler import KHopSampler, SampledBatch


@dataclasses.dataclass
class EpochSchedule:
    epoch: int
    batches: List[SampledBatch]
    remote_ids: np.ndarray        # unique remote node ids accessed in epoch
    remote_freq: np.ndarray       # access counts aligned with remote_ids
    cache_ids: np.ndarray         # top-n_hot remote ids, SORTED (lookup key)
    m_max: int                    # max |N_i^e| over the epoch

    @property
    def num_batches(self) -> int:
        return len(self.batches)


@dataclasses.dataclass
class WorkerSchedule:
    worker: int
    s0: int
    n_hot: int
    epochs: List[Optional[EpochSchedule]]
    spill_dir: Optional[str] = None
    #: per-epoch (m_max, edge_maxima) pad metadata, captured at build time
    #: so pad-bound queries never re-unpickle spilled epochs from disk.
    epoch_meta: Optional[List[Tuple[int, List[int]]]] = None

    def epoch(self, e: int) -> EpochSchedule:
        if self.epochs[e] is None:                      # spilled
            path = os.path.join(self.spill_dir,
                                f"w{self.worker}_e{e}.pkl")
            with open(path, "rb") as f:
                return pickle.load(f)
        return self.epochs[e]

    def _meta(self) -> List[Tuple[int, List[int]]]:
        if self.epoch_meta is None:     # schedules built before the cache
            self.epoch_meta = []        # existed: one-time backfill
            for e in range(len(self.epochs)):
                es = self.epoch(e)
                self.epoch_meta.append((es.m_max, epoch_edge_maxima(es)))
        return self.epoch_meta

    @property
    def m_max(self) -> int:
        return max(m for m, _ in self._meta())

    def pad_bounds(self) -> Tuple[int, List[int]]:
        """Static (m_max, edge_maxima) across ALL epochs -> one XLA
        compilation; served from cached metadata, never from spill_dir.
        Empty epochs (all-zero or empty edge maxima) don't shrink the
        merged bound."""
        metas = self._meta()
        m_max = max(m for m, _ in metas)
        edge_max: List[int] = []
        for _, em in metas:
            edge_max = _merge_edge_maxima(edge_max, em)
        return m_max, edge_max


def _merge_edge_maxima(acc: List[int], em: Sequence[int]) -> List[int]:
    """Elementwise max-merge of per-layer edge maxima; an empty list
    (epoch/worker with no batches) never shrinks the accumulator."""
    if not em:
        return acc
    if not acc:
        return list(em)
    return [max(a, b) for a, b in zip(acc, em)]


def merge_pad_bounds(
        schedules: Sequence["WorkerSchedule"]) -> Tuple[int, List[int]]:
    """Global static (m_max, edge_maxima) across WORKERS: max-merge each
    schedule's all-epoch ``pad_bounds()``, skipping all-empty workers'
    empty edge lists -- the one-compilation bound the multi-epoch device
    runner collates every epoch to."""
    m_max, edge_max = 0, []
    for ws in schedules:
        m, em = ws.pad_bounds()
        m_max = max(m_max, m)
        edge_max = _merge_edge_maxima(edge_max, em)
    return m_max, edge_max


def _build_epoch(sampler: KHopSampler, pg: PartitionedGraph, worker: int,
                 s0: int, e: int, train_nodes: np.ndarray,
                 n_hot: int) -> EpochSchedule:
    batches = sampler.sample_epoch(s0, worker, e, train_nodes)
    # frequency over the epoch: one count per batch containing the node
    # (N_i^e is a set -- matches the paper's freq(.) over {B_e})
    all_remote: List[np.ndarray] = []
    m_max = 0
    for b in batches:
        m_max = max(m_max, b.num_input_nodes)
        remote = b.input_nodes[pg.owner[b.input_nodes] != worker]
        all_remote.append(remote)
    if all_remote:
        cat = np.concatenate(all_remote)
        remote_ids, remote_freq = np.unique(cat, return_counts=True)
    else:
        remote_ids = np.zeros(0, np.int64)
        remote_freq = np.zeros(0, np.int64)
    k = min(n_hot, remote_ids.shape[0])
    if k > 0:
        hot = remote_ids[np.argpartition(-remote_freq, k - 1)[:k]]
        cache_ids = np.sort(hot)
    else:
        cache_ids = np.zeros(0, np.int64)
    return EpochSchedule(epoch=e, batches=batches, remote_ids=remote_ids,
                         remote_freq=remote_freq, cache_ids=cache_ids,
                         m_max=m_max)


def build_schedule(sampler: KHopSampler, pg: PartitionedGraph, worker: int,
                   s0: int, num_epochs: int, n_hot: int,
                   spill_dir: Optional[str] = None) -> WorkerSchedule:
    """Paper Alg. 1 lines 1-3, for one worker."""
    local = pg.local_nodes[worker]
    tm = pg.graph.train_mask
    train_nodes = local[tm[local]] if tm is not None else local
    epochs: List[Optional[EpochSchedule]] = []
    epoch_meta: List[Tuple[int, List[int]]] = []
    for e in range(num_epochs):
        es = _build_epoch(sampler, pg, worker, s0, e, train_nodes, n_hot)
        epoch_meta.append((es.m_max,
                           epoch_edge_maxima(es,
                                             num_layers=len(sampler.fanouts))))
        if spill_dir is not None:
            os.makedirs(spill_dir, exist_ok=True)
            with open(os.path.join(spill_dir, f"w{worker}_e{e}.pkl"),
                      "wb") as f:
                pickle.dump(es, f)
            epochs.append(None)
        else:
            epochs.append(es)
    return WorkerSchedule(worker=worker, s0=s0, n_hot=n_hot, epochs=epochs,
                          spill_dir=spill_dir, epoch_meta=epoch_meta)


# ---------------------------------------------------------------------------
# Padded device-ready collation (XLA static shapes; DESIGN.md §2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CollatedBatch:
    """Static-shape batch: every array padded to epoch-level maxima.
    Padded input-node slots carry id -1 and are masked everywhere."""
    seeds: np.ndarray          # (B,) int32, -1 padded
    seed_mask: np.ndarray      # (B,) bool
    labels: np.ndarray         # (B,) int32
    input_nodes: np.ndarray    # (m_max,) int64, -1 padded
    input_mask: np.ndarray     # (m_max,) bool
    num_inputs: int
    # per layer: (E_max,) arrays
    edge_src: List[np.ndarray]
    edge_dst: List[np.ndarray]
    edge_mask: List[np.ndarray]
    num_dst: List[int]         # true dst count per layer (static per batch)


def collate(batch: SampledBatch, labels: np.ndarray, batch_size: int,
            m_max: int, edge_max: Sequence[int]) -> CollatedBatch:
    b = batch
    m = b.num_input_nodes
    inp = np.full(m_max, -1, dtype=np.int64)
    inp[:m] = b.input_nodes
    imask = np.zeros(m_max, dtype=bool)
    imask[:m] = True

    B = b.seeds.shape[0]
    seeds = np.full(batch_size, -1, dtype=np.int64)
    seeds[:B] = b.seeds
    smask = np.zeros(batch_size, dtype=bool)
    smask[:B] = True
    lab = np.zeros(batch_size, dtype=np.int32)
    lab[:B] = labels[b.seeds]

    es, ed, em, ndst = [], [], [], []
    for l, blk in enumerate(b.blocks):
        E = blk.edge_src.shape[0]
        pe = np.zeros(edge_max[l], dtype=np.int32)
        pd = np.zeros(edge_max[l], dtype=np.int32)
        pm = np.zeros(edge_max[l], dtype=bool)
        pe[:E] = blk.edge_src
        pd[:E] = blk.edge_dst
        pm[:E] = blk.edge_mask
        es.append(pe)
        ed.append(pd)
        em.append(pm)
        ndst.append(blk.num_dst)
    return CollatedBatch(seeds=seeds, seed_mask=smask, labels=lab,
                         input_nodes=inp, input_mask=imask, num_inputs=m,
                         edge_src=es, edge_dst=ed, edge_mask=em,
                         num_dst=ndst)


def epoch_edge_maxima(es: EpochSchedule,
                      num_layers: Optional[int] = None) -> List[int]:
    """Per-layer max padded edge count over the epoch's batches.

    An epoch with no batches (a worker whose partition holds no train
    nodes) has no blocks to take the layer count from: with
    ``num_layers`` given it contributes all-zero maxima, otherwise an
    empty list -- ``pad_bounds`` skips both when merging."""
    if not es.batches:
        return [0] * (num_layers or 0)
    L = len(es.batches[0].blocks)
    return [max(b.blocks[l].edge_src.shape[0] for b in es.batches)
            for l in range(L)]
