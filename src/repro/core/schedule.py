"""Offline enumeration + cache-candidate selection (paper §3, Alg. 1 l.1-4).

Precomputes, per worker, the full deterministic training schedule:
  * every epoch's batch metadata  {B_e}  (ids / offsets / locality only),
    compiled whole-epoch by ``KHopSampler.sample_epoch_batched`` into a
    packed ``FlatEpoch`` (DESIGN.md §2.1; the per-batch ``sample_epoch``
    loop survives as the parity oracle, ``compiler="loop"``, and
    ``compiler="device"`` runs the sort-bound middle on the accelerator,
    DESIGN.md §2.2 -- all three bit-identical),
  * the access union  N = U_e U_i N_i^e  and  N_remote = N \\ N_local,
  * per-epoch remote access frequencies  freq(.)  over {B_e},
  * the hot set  N_cache = top-n_hot of N_remote by (freq desc, id asc)
    -- the DETERMINISTIC tie-break Prop 3.1 needs -- (per epoch, so the
    double buffer C_sec for e+1 can differ from C_s for e),
  * padding bounds  m_max  and per-layer edge maxima (XLA static shapes).

Like the paper's SSD streaming, epochs can be spilled to disk
(``spill_dir``): the FlatEpoch arrays go straight into one ``np.savez``
file per (worker, epoch) -- flat ndarray blocks, no pickled object
graph -- so spills are smaller and reload without per-batch
reconstruction. The writes themselves run on a background
``SpillWriter`` thread, off the build loop's critical path. A schedule
can instead stay DEVICE-RESIDENT (``lazy=True``): no payload retention,
no spill -- ``epoch(e)`` re-runs the deterministic compiler on demand,
which the device runner's staging thread overlaps with training.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
import zipfile
import zlib
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.fault.inject import fault_point
from repro.graph.partition import PartitionedGraph
from repro.graph.sampler import FlatEpoch, KHopSampler, SampledBatch


class SpillCorruptError(RuntimeError):
    """A spilled epoch failed integrity at load: unreadable archive,
    missing entries, or a per-array crc32 mismatch. ``WorkerSchedule.
    epoch`` heals it by rebuilding from the deterministic compiler."""

    def __init__(self, msg: str, path: Optional[str] = None):
        super().__init__(msg)
        self.path = path


class EpochSchedule:
    """One worker-epoch of the schedule: packed batches + hot-set
    metadata.

    The canonical batch payload is ``flat`` (a ``FlatEpoch``: CSR-style
    whole-epoch arrays, DESIGN.md §2.1); ``batches`` materializes the
    legacy ``List[SampledBatch]`` form lazily as zero-copy views for
    the per-batch oracle/compat paths (host-sim runners, loop
    collation). Constructing from ``batches=`` packs them into a
    FlatEpoch, so synthetic-schedule builders keep working unchanged.
    """

    def __init__(self, epoch: int, flat: Optional[FlatEpoch] = None,
                 batches: Optional[List[SampledBatch]] = None,
                 remote_ids: Optional[np.ndarray] = None,
                 remote_freq: Optional[np.ndarray] = None,
                 cache_ids: Optional[np.ndarray] = None,
                 m_max: int = 0):
        if flat is None:
            if batches is None:
                raise ValueError("EpochSchedule needs flat= or batches=")
            worker = batches[0].worker if batches else 0
            flat = FlatEpoch.from_batches(batches, epoch=epoch,
                                          worker=worker)
            self._batches: Optional[List[SampledBatch]] = list(batches)
        else:
            self._batches = None
        self.epoch = epoch
        self.flat = flat
        z = np.zeros(0, np.int64)
        self.remote_ids = remote_ids if remote_ids is not None else z
        self.remote_freq = remote_freq if remote_freq is not None \
            else z.copy()
        self.cache_ids = cache_ids if cache_ids is not None else z.copy()
        self.m_max = m_max

    @property
    def batches(self) -> List[SampledBatch]:
        if self._batches is None:
            self._batches = self.flat.to_batches()
        return self._batches

    @property
    def num_batches(self) -> int:
        return self.flat.num_batches


# ---------------------------------------------------------------------------
# npz spill format (flat arrays only -- no pickled objects)
# ---------------------------------------------------------------------------

def spill_path(spill_dir: str, worker: int, e: int) -> str:
    return os.path.join(spill_dir, f"w{worker}_e{e}.npz")


def save_epoch_npz(path: str, es: EpochSchedule) -> None:
    """Spill one epoch: every FlatEpoch array plus the hot-set metadata
    as plain ndarray entries (``allow_pickle`` stays off on reload).

    Integrity (DESIGN.md §10): each array gets a ``crc32_<name>``
    companion entry so bit-rot/tearing is detected at load (and healed
    by rebuild); the write is atomic (tmp + fsync + rename) so a crash
    mid-spill can never leave a half-written file under the final name."""
    flat = es.flat
    arrs = {
        "meta": np.array([es.epoch, flat.worker, es.m_max,
                          flat.num_layers], np.int64),
        "seeds": flat.seeds, "seed_starts": flat.seed_starts,
        "input_nodes": flat.input_nodes,
        "input_starts": flat.input_starts, "num_dst": flat.num_dst,
        "remote_ids": es.remote_ids, "remote_freq": es.remote_freq,
        "cache_ids": es.cache_ids,
    }
    for l in range(flat.num_layers):
        arrs[f"edge_src_{l}"] = flat.edge_src[l]
        arrs[f"edge_dst_{l}"] = flat.edge_dst[l]
        arrs[f"edge_mask_{l}"] = flat.edge_mask[l]
        arrs[f"edge_starts_{l}"] = flat.edge_starts[l]
    for k in list(arrs):
        arrs[f"crc32_{k}"] = np.uint32(_array_crc(arrs[k]))
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrs)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _array_crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


class SpillWriter:
    """Background npz spill writer: ``save_epoch_npz`` runs on a worker
    thread so disk writes come OFF the build loop's critical path (the
    write of epoch ``e`` overlaps the build of epoch ``e+1``).
    ``flush()`` joins the queue at epoch boundaries -- at most one spill
    is ever in flight, bounding live payload memory at two epochs -- and
    re-raises any writer-thread failure on the submitting thread."""

    def __init__(self):
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._err_lock = threading.Lock()
        self._closed = False
        self._t = threading.Thread(target=self._run, daemon=True,
                                   name="spill-writer")
        self._t.start()

    def _run(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                path, es = item
                save_epoch_npz(path, es)
                # spill-damage probe (corrupt/truncate/drop the file
                # just written): detection happens at LOAD via the crc
                # entries, recovery via the builder rebuild
                fault_point("spill_write", path=path, epoch=es.epoch,
                            worker=es.flat.worker)
            except BaseException as exc:      # surfaced at next flush()
                with self._err_lock:
                    self._err = exc
            finally:
                self._q.task_done()

    def submit(self, path: str, es: EpochSchedule) -> None:
        if self._closed:
            raise RuntimeError("SpillWriter.submit() after close()")
        self._raise_pending()
        self._q.put((path, es))

    def flush(self) -> None:
        self._q.join()
        self._raise_pending()

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Idempotent teardown, safe on exception paths: the sentinel is
        posted and the worker joined (bounded) even if flush() raises a
        pending writer error. A writer that outlives the deadline raises
        a loud ``TimeoutError`` naming the thread (never a silent leak)."""
        if self._closed:
            return
        self._closed = True
        try:
            self.flush()
        finally:
            self._q.put(None)
            self._t.join(timeout=timeout)
            if self._t.is_alive():
                raise TimeoutError(
                    f"spill writer thread {self._t.name} still alive "
                    f"after {timeout}s join deadline")

    def _raise_pending(self) -> None:
        with self._err_lock:
            err, self._err = self._err, None
        if err is not None:
            raise RuntimeError("background spill write failed") from err


def _verify_spill(z, path: str) -> None:
    """Per-array crc check. Files spilled before the crc entries existed
    stay loadable (no companion entry -> no check)."""
    for k in z.files:
        if k.startswith("crc32_"):
            continue
        want = f"crc32_{k}"
        if want not in z.files:
            continue
        if _array_crc(z[k]) != int(z[want]):
            raise SpillCorruptError(
                f"crc mismatch for array {k!r} in spill {path}",
                path=path)


def load_epoch_npz(path: str) -> EpochSchedule:
    """Load one spilled epoch, raising ``SpillCorruptError`` on ANY
    integrity failure -- missing/truncated/unreadable archive, missing
    entries, or crc mismatch -- instead of leaking raw numpy/zipfile
    errors (the caller's heal path keys on the typed error)."""
    try:
        with np.load(path) as z:
            _verify_spill(z, path)
            e, worker, m_max, L = (int(x) for x in z["meta"])
            flat = FlatEpoch(
                epoch=e, worker=worker, seeds=z["seeds"],
                seed_starts=z["seed_starts"],
                input_nodes=z["input_nodes"],
                input_starts=z["input_starts"], num_dst=z["num_dst"],
                edge_src=[z[f"edge_src_{l}"] for l in range(L)],
                edge_dst=[z[f"edge_dst_{l}"] for l in range(L)],
                edge_mask=[z[f"edge_mask_{l}"] for l in range(L)],
                edge_starts=[z[f"edge_starts_{l}"] for l in range(L)])
            return EpochSchedule(epoch=e, flat=flat,
                                 remote_ids=z["remote_ids"],
                                 remote_freq=z["remote_freq"],
                                 cache_ids=z["cache_ids"], m_max=m_max)
    except SpillCorruptError:
        raise
    except (OSError, ValueError, KeyError, EOFError,
            zipfile.BadZipFile) as exc:
        raise SpillCorruptError(f"unreadable spill {path}: {exc!r}",
                                path=path) from exc


@dataclasses.dataclass
class WorkerSchedule:
    worker: int
    s0: int
    n_hot: int
    epochs: List[Optional[EpochSchedule]]
    spill_dir: Optional[str] = None
    #: per-epoch (m_max, edge_maxima) pad metadata, captured at build time
    #: so pad-bound queries never re-load spilled epochs from disk.
    epoch_meta: Optional[List[Tuple[int, List[int]]]] = None
    #: on-demand epoch recompiler (bit-identical by Prop 3.1). In lazy /
    #: device-resident mode it IS the payload source (``epoch(e)``
    #: re-runs it every call); for spilled schedules it is the HEAL path:
    #: a spill that fails integrity at load is rebuilt and re-spilled.
    builder: Optional[Callable[[int], EpochSchedule]] = None
    #: spilled epochs healed by rebuild (fault plane, DESIGN.md §10)
    spill_rebuilds: int = 0

    def epoch(self, e: int) -> EpochSchedule:
        if self.epochs[e] is not None:
            return self.epochs[e]
        if self.spill_dir is not None:                  # spilled
            path = spill_path(self.spill_dir, self.worker, e)
            try:
                return load_epoch_npz(path)
            except SpillCorruptError:
                if self.builder is None:
                    raise
                # heal: the deterministic compiler IS the backup copy --
                # rebuild bit-identically and re-spill for the next read
                self.spill_rebuilds += 1
                es = self.builder(e)
                save_epoch_npz(path, es)
                return es
        if self.builder is not None:                    # device-resident
            return self.builder(e)
        raise RuntimeError(
            f"epoch {e} has no payload, spill_dir, or builder")

    def _meta(self) -> List[Tuple[int, List[int]]]:
        if self.epoch_meta is None:     # schedules built before the cache
            self.epoch_meta = []        # existed: one-time backfill
            for e in range(len(self.epochs)):
                es = self.epoch(e)
                self.epoch_meta.append((es.m_max, epoch_edge_maxima(es)))
        return self.epoch_meta

    @property
    def m_max(self) -> int:
        return max(m for m, _ in self._meta())

    def pad_bounds(self) -> Tuple[int, List[int]]:
        """Static (m_max, edge_maxima) across ALL epochs -> one XLA
        compilation; served from cached metadata, never from spill_dir.
        Empty epochs (all-zero or empty edge maxima) don't shrink the
        merged bound."""
        metas = self._meta()
        m_max = max(m for m, _ in metas)
        edge_max: List[int] = []
        for _, em in metas:
            edge_max = _merge_edge_maxima(edge_max, em)
        return m_max, edge_max


def _merge_edge_maxima(acc: List[int], em: Sequence[int]) -> List[int]:
    """Elementwise max-merge of per-layer edge maxima; an empty list
    (epoch/worker with no batches) never shrinks the accumulator."""
    if not em:
        return acc
    if not acc:
        return list(em)
    return [max(a, b) for a, b in zip(acc, em)]


def merge_pad_bounds(
        schedules: Sequence["WorkerSchedule"]) -> Tuple[int, List[int]]:
    """Global static (m_max, edge_maxima) across WORKERS: max-merge each
    schedule's all-epoch ``pad_bounds()``, skipping all-empty workers'
    empty edge lists -- the one-compilation bound the multi-epoch device
    runner collates every epoch to."""
    m_max, edge_max = 0, []
    for ws in schedules:
        m, em = ws.pad_bounds()
        m_max = max(m_max, m)
        edge_max = _merge_edge_maxima(edge_max, em)
    return m_max, edge_max


def select_hot_set(remote_ids: np.ndarray, remote_freq: np.ndarray,
                   n_hot: int,
                   weight: Optional[np.ndarray] = None) -> np.ndarray:
    """Top-``n_hot`` remote ids by (freq desc, id asc), returned SORTED.

    The lexicographic tie-break is load-bearing: ``argpartition`` (the
    historical selection) breaks frequency ties arbitrarily across numpy
    versions/platforms, and a schedule whose C_s depends on partition
    internals is not the paper's deterministic schedule (Prop 3.1).
    ``remote_ids`` arrives ascending (``np.unique`` output), so a STABLE
    sort on descending frequency realises (-freq, id) order exactly.

    ``weight`` (aligned with ``remote_ids``) multiplies the frequency
    before ranking -- the topology-aware admission bias (DESIGN.md
    §6.7): cross-DCN owners get ``weight > 1`` so the cache preferably
    saves the expensive fetches. ``weight=None`` (and any all-equal
    weight) leaves the selection bit-identical to the unbiased path.
    """
    k = min(n_hot, remote_ids.shape[0])
    if k <= 0:
        return np.zeros(0, np.int64)
    eff = remote_freq if weight is None \
        else remote_freq.astype(np.float64) * weight
    order = np.argsort(-eff, kind="stable")
    return np.sort(remote_ids[order[:k]])


def _build_epoch(sampler: KHopSampler, pg: PartitionedGraph, worker: int,
                 s0: int, e: int, train_nodes: np.ndarray, n_hot: int,
                 compiler: str = "batched",
                 owner_bias: Optional[np.ndarray] = None) -> EpochSchedule:
    if compiler == "batched":
        flat = sampler.sample_epoch_batched(s0, worker, e, train_nodes)
    elif compiler == "device":
        from repro.graph.device_sampler import sample_epoch_batched_device
        flat = sample_epoch_batched_device(sampler, s0, worker, e,
                                           train_nodes)
    elif compiler == "loop":
        flat = FlatEpoch.from_batches(
            sampler.sample_epoch(s0, worker, e, train_nodes), epoch=e,
            worker=worker, num_layers=len(sampler.fanouts))
    else:
        raise ValueError(f"unknown schedule compiler {compiler!r} "
                         f"(expected 'batched', 'device' or 'loop')")
    m_counts = flat.m_counts
    m_max = int(m_counts.max()) if m_counts.size else 0
    # frequency over the epoch: one count per batch containing the node
    # (N_i^e is a set; input_nodes are unique per batch, so one bincount
    # over the flat stream IS the per-batch indicator sum)
    remote = flat.input_nodes[pg.owner[flat.input_nodes] != worker]
    if compiler == "device" and owner_bias is None:
        from repro.graph.device_sampler import (device_remote_freq,
                                                device_select_hot_set)
        remote_ids, remote_freq = device_remote_freq(
            remote, int(pg.graph.num_nodes))
        cache_ids = device_select_hot_set(remote_ids, remote_freq, n_hot)
    else:
        # owner_bias (topology-aware admission, DESIGN.md §6.7) routes
        # through the numpy selector on every compiler: the weighted
        # ranking has no device port, and schedule determinism only
        # needs the selection itself to be platform-independent
        if remote.size:
            remote_ids, remote_freq = np.unique(remote,
                                                return_counts=True)
        else:
            remote_ids = np.zeros(0, np.int64)
            remote_freq = np.zeros(0, np.int64)
        weight = (None if owner_bias is None
                  else np.asarray(owner_bias,
                                  np.float64)[pg.owner[remote_ids]])
        cache_ids = select_hot_set(remote_ids, remote_freq, n_hot,
                                   weight=weight)
    return EpochSchedule(epoch=e, flat=flat, remote_ids=remote_ids,
                         remote_freq=remote_freq, cache_ids=cache_ids,
                         m_max=m_max)


def build_schedule(sampler: KHopSampler, pg: PartitionedGraph, worker: int,
                   s0: int, num_epochs: int, n_hot: int,
                   spill_dir: Optional[str] = None,
                   compiler: str = "batched",
                   lazy: bool = False,
                   owner_bias: Optional[np.ndarray] = None
                   ) -> WorkerSchedule:
    """Paper Alg. 1 lines 1-3, for one worker.

    ``compiler`` picks the epoch sampler: ``"batched"`` (default) is the
    vectorized whole-epoch compiler, ``"device"`` its accelerator port
    (DESIGN.md §2.2), ``"loop"`` the per-batch oracle -- all three
    produce bit-identical schedules (the parity suites pin it).

    ``lazy=True`` is the device-resident mode: one metadata prepass
    captures pad bounds + per-epoch maxima, then epoch PAYLOADS are
    dropped and ``epoch(e)`` re-runs the deterministic compiler on
    demand -- at most two epochs ever live in memory, and disk spill is
    skipped entirely (the schedule re-materializes from (s0, w, e)
    faster than an npz read-back on device). Spilled (non-lazy) builds
    write their npz files on a background ``SpillWriter`` thread, so
    epoch ``e``'s write overlaps epoch ``e+1``'s build.

    ``owner_bias`` ((P,) float, e.g. ``Topology.owner_bias``) weights
    the hot-set frequency per owning worker -- the topology-aware cache
    admission (DESIGN.md §6.7). None keeps the unbiased paper schedule
    bit-identical."""
    local = pg.local_nodes[worker]
    tm = pg.graph.train_mask
    train_nodes = local[tm[local]] if tm is not None else local
    if lazy:
        spill_dir = None        # device-resident: no disk spill at all
    epochs: List[Optional[EpochSchedule]] = []
    epoch_meta: List[Tuple[int, List[int]]] = []
    writer: Optional[SpillWriter] = None
    if spill_dir is not None:
        os.makedirs(spill_dir, exist_ok=True)
        writer = SpillWriter()
    try:
        for e in range(num_epochs):
            es = _build_epoch(sampler, pg, worker, s0, e, train_nodes,
                              n_hot, compiler=compiler,
                              owner_bias=owner_bias)
            epoch_meta.append(
                (es.m_max,
                 epoch_edge_maxima(es, num_layers=len(sampler.fanouts))))
            if lazy:
                epochs.append(None)     # payload rebuilt on demand
            elif writer is not None:
                writer.flush()          # epoch boundary: e-1's write done
                writer.submit(spill_path(spill_dir, worker, e), es)
                epochs.append(None)
            else:
                epochs.append(es)
    finally:
        if writer is not None:
            writer.close()

    # the builder closure is ALWAYS attached: it is the payload source in
    # lazy mode and the spill heal path otherwise (a corrupt/missing npz
    # rebuilds bit-identically from (s0, worker, e) -- Prop 3.1)
    def builder(e: int) -> EpochSchedule:
        return _build_epoch(sampler, pg, worker, s0, e, train_nodes,
                            n_hot, compiler=compiler,
                            owner_bias=owner_bias)
    return WorkerSchedule(worker=worker, s0=s0, n_hot=n_hot, epochs=epochs,
                          spill_dir=spill_dir, epoch_meta=epoch_meta,
                          builder=builder)


# ---------------------------------------------------------------------------
# Padded device-ready collation (XLA static shapes; DESIGN.md §2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CollatedBatch:
    """Static-shape batch: every array padded to epoch-level maxima.
    Padded input-node slots carry id -1 and are masked everywhere."""
    seeds: np.ndarray          # (B,) int32, -1 padded
    seed_mask: np.ndarray      # (B,) bool
    labels: np.ndarray         # (B,) int32
    input_nodes: np.ndarray    # (m_max,) int64, -1 padded
    input_mask: np.ndarray     # (m_max,) bool
    num_inputs: int
    # per layer: (E_max,) arrays
    edge_src: List[np.ndarray]
    edge_dst: List[np.ndarray]
    edge_mask: List[np.ndarray]
    num_dst: List[int]         # true dst count per layer (static per batch)


def collate(batch: SampledBatch, labels: np.ndarray, batch_size: int,
            m_max: int, edge_max: Sequence[int]) -> CollatedBatch:
    b = batch
    m = b.num_input_nodes
    inp = np.full(m_max, -1, dtype=np.int64)
    inp[:m] = b.input_nodes
    imask = np.zeros(m_max, dtype=bool)
    imask[:m] = True

    B = b.seeds.shape[0]
    seeds = np.full(batch_size, -1, dtype=np.int64)
    seeds[:B] = b.seeds
    smask = np.zeros(batch_size, dtype=bool)
    smask[:B] = True
    lab = np.zeros(batch_size, dtype=np.int32)
    lab[:B] = labels[b.seeds]

    es, ed, em, ndst = [], [], [], []
    for l, blk in enumerate(b.blocks):
        E = blk.edge_src.shape[0]
        pe = np.zeros(edge_max[l], dtype=np.int32)
        pd = np.zeros(edge_max[l], dtype=np.int32)
        pm = np.zeros(edge_max[l], dtype=bool)
        pe[:E] = blk.edge_src
        pd[:E] = blk.edge_dst
        pm[:E] = blk.edge_mask
        es.append(pe)
        ed.append(pd)
        em.append(pm)
        ndst.append(blk.num_dst)
    return CollatedBatch(seeds=seeds, seed_mask=smask, labels=lab,
                         input_nodes=inp, input_mask=imask, num_inputs=m,
                         edge_src=es, edge_dst=ed, edge_mask=em,
                         num_dst=ndst)


def epoch_edge_maxima(es: EpochSchedule,
                      num_layers: Optional[int] = None) -> List[int]:
    """Per-layer max padded edge count over the epoch's batches, read
    straight off the FlatEpoch segment offsets (one ``diff().max()`` per
    layer, no batch loop).

    An epoch with no batches (a worker whose partition holds no train
    nodes) contributes all-zero maxima (layer count from ``num_layers``
    or the flat layout itself) -- ``pad_bounds`` skips those when
    merging."""
    flat = es.flat
    if flat.num_batches == 0:
        return [0] * (num_layers if num_layers is not None
                      else flat.num_layers)
    return [int(np.diff(s).max()) for s in flat.edge_starts]
