"""Double-buffered steady feature cache C_s / C_sec (paper §4 components 5-6).

The cache stores features of the top-``n_hot`` most frequently accessed
remote nodes for the current epoch, keyed by SORTED node id so lookup is a
binary search (``np.searchsorted`` host-side; the Pallas ``cache_lookup``
kernel device-side). Buffer 1 (C_sec) for epoch e+1 is built concurrently
with training on epoch e and atomically swapped at the epoch boundary
(paper Alg. 1 line 18).

Memory bound (paper §3): 2 * n_hot * d floats for the two buffers.
"""
from __future__ import annotations

import threading
from typing import Optional, Tuple

import numpy as np


class FeatureCache:
    """One buffer: sorted ids + aligned features."""

    def __init__(self, ids: np.ndarray, feats: np.ndarray):
        assert ids.ndim == 1 and feats.shape[0] == ids.shape[0]
        assert np.all(np.diff(ids) > 0), "cache ids must be sorted unique"
        self.ids = ids
        self.feats = feats

    @property
    def nbytes(self) -> int:
        return int(self.ids.nbytes + self.feats.nbytes)

    def lookup(self, query: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """-> (positions, hit_mask); positions valid only where hit."""
        query = np.asarray(query)
        if self.ids.shape[0] == 0:      # indexing an empty table would raise
            return (np.zeros(query.shape, np.intp),
                    np.zeros(query.shape, bool))
        pos = np.searchsorted(self.ids, query)
        pos_c = np.minimum(pos, self.ids.shape[0] - 1)
        hit = self.ids[pos_c] == query
        return pos_c, hit

    def gather(self, query: np.ndarray, out: np.ndarray,
               hit: Optional[np.ndarray] = None) -> np.ndarray:
        pos, h = self.lookup(query)
        if hit is None:
            hit = h
        out[hit] = self.feats[pos[hit]]
        return hit


EMPTY = FeatureCache(np.zeros(0, np.int64), np.zeros((0, 1), np.float32))


class DoubleBufferCache:
    """C_s (buffer 0) serving lookups + C_sec (buffer 1) under construction."""

    def __init__(self, feat_dim: int):
        self.feat_dim = feat_dim
        self._steady: FeatureCache = EMPTY
        self._secondary: Optional[FeatureCache] = None
        self._lock = threading.Lock()

    @property
    def steady(self) -> FeatureCache:
        return self._steady

    def install_steady(self, cache: FeatureCache) -> None:
        with self._lock:
            self._steady = cache

    def stage_secondary(self, cache: FeatureCache) -> None:
        with self._lock:
            self._secondary = cache

    def swap(self) -> bool:
        """Atomic C_sec -> C_s at the epoch boundary. True if swapped."""
        with self._lock:
            if self._secondary is None:
                return False
            self._steady = self._secondary
            self._secondary = None
            return True

    @property
    def device_bytes(self) -> int:
        b = self._steady.nbytes
        if self._secondary is not None:
            b += self._secondary.nbytes
        return b
