"""Distributed KV-store feature fetching: VectorPull / SyncPull.

Host-simulation path (this module): the sharded feature store is the
paper's per-worker KV store; every cross-partition read is accounted (and
optionally time-charged through the NetworkModel). The device-collective
path for TPU meshes lives in ``repro.dist.feature_a2a`` (all_to_all over
the `data` axis) and is exercised by the dry-run.

Paper mapping:
  VectorPull(ids)  -- one bulk vectorized request building the cache C_s
  SyncPull(ids)    -- residual-miss fetch; issued by the *prefetcher*, so
                      it is off the trainer's critical path unless the
                      trainer outruns the queue.
"""
from __future__ import annotations

import threading
from typing import Optional

import numpy as np

from repro.core.metrics import EpochMetrics, NetworkModel
from repro.fault.inject import fault_point, retry_call
from repro.graph.partition import PartitionedGraph


class ShardedFeatureStore:
    """Paper's Distributed KV store: features owned per partition."""

    #: bounded retry budget for transient pull failures (fault plane,
    #: DESIGN.md §10): a SyncPull RPC that fails transiently is retried
    #: with exponential backoff; a persistent failure propagates typed.
    pull_retries = 2
    retry_base_s = 1e-3

    def __init__(self, pg: PartitionedGraph, worker: int,
                 net: Optional[NetworkModel] = None):
        self.pg = pg
        self.worker = worker
        self.net = net or NetworkModel(enabled=False)
        self.feat = pg.graph.features     # authoritative global table
        self.d = pg.graph.feat_dim
        self.itemsize = self.feat.itemsize
        # metrics accumulation is lock-guarded: the serving path issues
        # concurrent sync_pulls against ONE store, and `m.x += v` on a
        # dataclass attribute is a read-modify-write race that would
        # break the `bytes == sum(n_remote) * row` differential
        # identity. Callers sharing one EpochMetrics across *stores*
        # must still coordinate externally (the runners never do).
        self._m_lock = threading.Lock()

    def _remote_mask(self, ids: np.ndarray) -> np.ndarray:
        return self.pg.owner[ids] != self.worker

    # -- bulk cache build (one vectorized RPC; paper Alg. 1 line 4) --------
    def vector_pull(self, ids: np.ndarray, m: EpochMetrics) -> np.ndarray:
        nbytes = int(ids.shape[0]) * self.d * self.itemsize
        # ONE batched request: the per-node marshalling tax is paid once
        t = self.net.transfer_time(nbytes, n_rpc=1, n_nodes=1)
        with self._m_lock:
            m.vector_pull_bytes += nbytes
            m.modeled_net_time_s += t
        # bulk pull is off the critical path (built concurrently) -> no sleep
        return self.feat[ids].copy()

    # -- residual miss fetch (paper Alg. 1 line 14) -------------------------
    def sync_pull(self, ids: np.ndarray, m: EpochMetrics,
                  critical_path: bool = False) -> np.ndarray:
        # transient-failure probe BEFORE any accounting: a retried pull
        # must not inflate rpc_count/remote_bytes (the bytes_identity
        # differential check counts successful transfers only)
        def _on_retry(_a: int) -> None:
            with self._m_lock:
                m.pull_retries += 1
        retry_call(lambda a: fault_point("pull", attempt=a,
                                         epoch=m.epoch,
                                         worker=self.worker),
                   self.pull_retries, self.retry_base_s,
                   on_retry=_on_retry)
        remote = self._remote_mask(ids)
        n_remote = int(remote.sum())
        nbytes = n_remote * self.d * self.itemsize
        # one RPC per remote partition touched (DistDGL KV-store
        # fan-out); a fully-LOCAL batch touches no partition, so it
        # charges zero RPCs and zero modelled time (the historical
        # ``max(len(owners), 1)`` floor modelled a phantom RPC there)
        owners = np.unique(self.pg.owner[ids[remote]]) if n_remote else []
        n_rpc = len(owners)
        # the critical-path charge SLEEPS for t_net -- keep it outside
        # the metrics lock or one slow pull serializes every other caller
        t = (self.net.charge(nbytes, n_rpc=n_rpc, n_nodes=n_remote)
             if critical_path
             else self.net.transfer_time(nbytes, n_rpc=n_rpc,
                                         n_nodes=n_remote))
        with self._m_lock:
            m.rpc_count += n_remote      # paper's rpc_e += |M_i|
            m.sync_pull_calls += 1
            m.remote_bytes += nbytes
            m.modeled_net_time_s += t
            m.sync_net_time_s += t
        return self.feat[ids].copy()

    # -- local reads are free -----------------------------------------------
    def local_read(self, ids: np.ndarray) -> np.ndarray:
        return self.feat[ids].copy()
