"""Instrumentation: RPC/byte/hit counters, network-time model, energy model.

The paper measures on a 4-machine Chameleon testbed (10 Gbps Ethernet,
2x Xeon E5-2670v3, 2x P100) with NVML/psutil. We have no cluster, so:

  * communication is ACCOUNTED exactly (every pulled feature byte is
    counted at its source, padding charged to RapidGNN),
  * network TIME is modelled as  t = rtt * n_rpc + bytes / bandwidth
    with the testbed's 10 Gbps and a configurable RTT,
  * ENERGY is modelled as  E = P_mean * duration  per component, with
    P_mean taken from the paper's Table 3 measurements (CPU 36.73 W
    RapidGNN / 42.70 W baseline; GPU 30.84 / 29.45 W) -- durations are
    ours, power envelopes are the paper's. Reported as *modelled*.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List


@dataclasses.dataclass
class NetworkModel:
    """10 GbE + RPC-stack cost model (paper testbed, Table 1).

    t = rtt * n_rpc + bytes/BW + per_node_us * n_nodes

    The per-node term models (de)serialization + marshalling of feature
    RPCs -- the paper (§2.3) and P3 [13] attribute "up to 80 % of training
    time to communication AND SERIALIZATION"; a vectorized bulk pull
    (VectorPull) pays it only on its single batched request, which is
    exactly the asymmetry RapidGNN exploits."""
    bandwidth_gbps: float = 10.0
    rtt_ms: float = 0.5
    per_node_us: float = 2.0
    enabled: bool = True            # if True, fetches sleep for t_net

    def transfer_time(self, nbytes: int, n_rpc: int = 1,
                      n_nodes: int = 0) -> float:
        if n_rpc == 0 and nbytes == 0:
            return 0.0
        return (self.rtt_ms * 1e-3 * max(n_rpc, 1) +
                nbytes * 8.0 / (self.bandwidth_gbps * 1e9) +
                self.per_node_us * 1e-6 * n_nodes)

    def charge(self, nbytes: int, n_rpc: int = 1,
               n_nodes: int = 0) -> float:
        t = self.transfer_time(nbytes, n_rpc, n_nodes)
        if self.enabled and t > 0:
            time.sleep(t)
        return t


@dataclasses.dataclass
class EpochMetrics:
    """Per-epoch counters. Every field is a plain int/float so the whole
    record serializes losslessly through ``to_dict``/``from_dict`` (the
    campaign's ``CellResult`` export, repro/eval)."""
    epoch: int = 0
    rpc_count: int = 0               # paper's rpc_e: SyncPull calls' ids
    sync_pull_calls: int = 0
    remote_bytes: int = 0            # bytes pulled off-worker this epoch
    vector_pull_bytes: int = 0       # bulk cache-build bytes (off critical path)
    cache_hits: int = 0
    cache_misses: int = 0
    prefetch_hits: int = 0           # batches served from the prefetch queue
    default_path: int = 0            # trainer outran prefetcher (race)
    remote_requests: int = 0         # remote ids requested (pre-cache)
    wall_time_s: float = 0.0
    compute_time_s: float = 0.0
    fetch_stall_s: float = 0.0       # critical-path fetch time
    modeled_net_time_s: float = 0.0
    sync_net_time_s: float = 0.0     # SyncPull-only (per-step network time)
    # -- fault plane (DESIGN.md §10): recovery accounting ------------------
    pull_retries: int = 0            # transient sync_pull failures retried
    prefetch_retries: int = 0        # prefetch batches rebuilt after fault
    csec_degraded: int = 0           # C_sec build lost -> stale C_s kept

    @property
    def hit_rate(self) -> float:
        t = self.cache_hits + self.cache_misses
        return self.cache_hits / t if t else 0.0

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, float]) -> "EpochMetrics":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


@dataclasses.dataclass
class RunMetrics:
    epochs: List[EpochMetrics] = dataclasses.field(default_factory=list)

    def to_dict(self) -> Dict[str, object]:
        """JSON-ready view: the per-epoch records plus the aggregate
        ``totals()`` (already derived, so consumers never re-sum)."""
        return {"epochs": [e.to_dict() for e in self.epochs],
                "totals": self.totals()}

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "RunMetrics":
        return cls(epochs=[EpochMetrics.from_dict(e)
                           for e in d["epochs"]])

    def totals(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for f in dataclasses.fields(EpochMetrics):
            if f.name == "epoch":
                continue
            out[f.name] = sum(getattr(e, f.name) for e in self.epochs)
        n = max(len(self.epochs), 1)
        out["mean_epoch_time_s"] = out["wall_time_s"] / n
        tot_hit = out["cache_hits"] + out["cache_misses"]
        out["hit_rate"] = out["cache_hits"] / tot_hit if tot_hit else 0.0
        return out


# ---- energy model ----------------------------------------------------------

#: component power envelopes (W). Calibrated to paper Table 3.
POWER = {
    "rapidgnn": {"cpu": 36.73, "gpu": 30.84},
    "baseline": {"cpu": 42.70, "gpu": 29.45},
}


def modelled_energy(duration_s: float, system: str) -> Dict[str, float]:
    p = POWER[system]
    return {"cpu_J": p["cpu"] * duration_s,
            "gpu_J": p["gpu"] * duration_s,
            "total_J": (p["cpu"] + p["gpu"]) * duration_s}
