"""End-to-end runners: RapidGNN (Alg. 1) vs on-demand baseline (DGL-style).

Both runners consume the SAME deterministic schedule, collation, and
train_fn, so every measured difference is attributable to the paper's
technique (cache + prefetch pipeline) and not to incidental implementation
drift. The baseline fetches every remote feature of every batch
synchronously on the critical path with no cache and no overlap -- the
DGL on-the-fly KV-pull data path the paper compares against.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional

import numpy as np

from repro.core.cache import DoubleBufferCache, FeatureCache
from repro.core.fetch import ShardedFeatureStore
from repro.core.metrics import EpochMetrics, NetworkModel, RunMetrics
from repro.core.prefetch import (Prefetcher, PrefetchStall,
                                 PrefetchWorkerError,
                                 SecondaryCacheBuilder,
                                 SecondaryCacheError, StagedBatch,
                                 assemble_features, local_fill)
from repro.core.schedule import WorkerSchedule, collate

TrainFn = Callable[[np.ndarray, "CollatedBatch"], float]  # noqa: F821


def global_pad_bounds(ws: WorkerSchedule):
    """Static shapes across ALL epochs -> one XLA compilation.

    Served from the schedule's build-time (m_max, edge_maxima) metadata
    cache, so spilled epochs are never re-loaded for pad bounds."""
    return ws.pad_bounds()


class RapidGNNRunner:
    """Alg. 1 consumer with supervision (DESIGN.md §10):

    * ``stall_timeout_s`` bounds each queue wait; on expiry the trainer
      rebuilds the batch on the critical path (``default_path`` counts
      it) from the SAME deterministic schedule, so a late/hung producer
      costs wall time, never changes the loss curve. ``None`` (default)
      keeps the historical blocking behavior.
    * a failed C_sec build degrades: the stale steady cache is kept for
      the next epoch (``csec_degraded`` counts it) -- lossless, since
      the cache only redirects fetches, never alters feature values.
    * producer joins are deadline-bounded (``join_timeout_s``); a hung
      thread raises a loud ``TimeoutError`` naming it.
    """

    def __init__(self, ws: WorkerSchedule, store: ShardedFeatureStore,
                 batch_size: int, Q: int = 4,
                 train_fn: Optional[TrainFn] = None,
                 stall_timeout_s: Optional[float] = None,
                 join_timeout_s: float = 30.0):
        self.ws = ws
        self.store = store
        self.batch_size = batch_size
        self.Q = Q
        self.train_fn = train_fn or (lambda feats, cb: 0.0)
        self.stall_timeout_s = stall_timeout_s
        self.join_timeout_s = join_timeout_s
        self.dbc = DoubleBufferCache(store.d)
        self.m_max, self.edge_max = global_pad_bounds(ws)
        self.metrics = RunMetrics()

    def _build_batch(self, es, i: int, labels, m: EpochMetrics
                     ) -> StagedBatch:
        """Critical-path fallback: rebuild batch ``i`` exactly as the
        prefetcher would have (same schedule, same cache, same pull set)
        when the trainer outruns or outlives the producer."""
        b = es.batches[i]
        cb = collate(b, labels, self.batch_size, self.m_max,
                     self.edge_max)
        feats = assemble_features(cb, self.store, self.dbc.steady, m,
                                  critical_path=True)
        return StagedBatch(i, cb, feats, 0.0)

    def run(self) -> RunMetrics:
        labels = self.store.pg.graph.labels
        n_epochs = len(self.ws.epochs)

        # initial steady cache: ONE VectorPull before epoch 0 (Alg.1 l.4)
        es0 = self.ws.epoch(0)
        boot = EpochMetrics(epoch=-1)
        feats0 = self.store.vector_pull(es0.cache_ids, boot)
        self.dbc.install_steady(FeatureCache(es0.cache_ids, feats0))

        for e in range(n_epochs):
            es = self.ws.epoch(e)
            m = EpochMetrics(epoch=e)
            if e == 0:   # charge the bootstrap pull to epoch 0
                m.vector_pull_bytes += boot.vector_pull_bytes
                m.modeled_net_time_s += boot.modeled_net_time_s
            t_epoch = time.perf_counter()

            builder = None
            if e + 1 < n_epochs:        # build C_sec for e+1 in parallel
                builder = SecondaryCacheBuilder(self.ws.epoch(e + 1),
                                                self.store, self.dbc,
                                                m).start()
            pf = Prefetcher(es, self.store, self.dbc, labels,
                            self.batch_size, self.m_max, self.edge_max,
                            self.Q, m).start()
            try:
                expect, n_batches = 0, es.num_batches
                while expect < n_batches:
                    t0 = time.perf_counter()
                    try:
                        staged = pf.get(timeout=self.stall_timeout_s)
                    except PrefetchStall:
                        # producer late/hung: rebuild batch `expect` on
                        # the critical path -- deterministic, so the
                        # loss curve is unchanged (DESIGN.md §10)
                        m.fetch_stall_s += time.perf_counter() - t0
                        staged = self._build_batch(es, expect, labels, m)
                        m.default_path += 1
                    else:
                        m.fetch_stall_s += time.perf_counter() - t0
                        if staged is None:
                            raise PrefetchWorkerError(
                                f"prefetcher ended early at batch "
                                f"{expect}/{n_batches}")
                        if staged.index < expect:
                            continue    # duplicate of a fallback batch
                        m.prefetch_hits += 1
                    t1 = time.perf_counter()
                    self.train_fn(staged.features, staged.collated)
                    m.compute_time_s += time.perf_counter() - t1
                    expect += 1
                # drain to the sentinel: a producer that fell behind the
                # fallback path may still deliver tail batches (a stall
                # HERE means it is hung -> bounded get raises typed)
                while pf.get(timeout=self.join_timeout_s) is not None:
                    pass
                pf.join(timeout=self.join_timeout_s)
                if builder is not None:
                    try:
                        builder.join(timeout=self.join_timeout_s)
                    except SecondaryCacheError:
                        # degraded mode: keep the stale steady cache for
                        # e+1 (swap() no-ops without a staged C_sec);
                        # lossless -- only the miss accounting shifts
                        m.csec_degraded += 1
            except BaseException:
                # unblock + bound both producers before propagating, so a
                # train_fn failure can't leak a thread wedged on a full
                # queue or an un-reaped C_sec pull
                pf.close()
                if builder is not None:
                    builder.close()
                raise
            self.dbc.swap()             # C_sec -> C_s (Alg.1 l.18)
            m.wall_time_s = time.perf_counter() - t_epoch
            self.metrics.epochs.append(m)
        return self.metrics

    @property
    def device_cache_bytes(self) -> int:
        return self.dbc.device_bytes


def occurrence_remote_ids(batch, owner: np.ndarray,
                          worker: int) -> np.ndarray:
    """Every remote node reference in a SampledBatch, one entry per
    unmasked edge-level occurrence (a node sampled k times appears k
    times). Every non-seed input node enters the batch through at least
    one unmasked edge, so this is always a multiset superset of the
    batch's unique remote set."""
    refs = [batch.input_nodes[blk.edge_src[blk.edge_mask]]
            for blk in batch.blocks]
    cat = (np.concatenate(refs) if refs
           else np.zeros(0, batch.input_nodes.dtype))
    return cat[owner[cat] != worker]


class BaselineRunner:
    """DGL-style on-demand path: synchronous un-cached remote fetch.

    ``dedupe=False`` additionally models per-request redundancy ("frequent
    and redundant RPC calls", paper §2.3) by charging each remote id once
    per occurrence rather than once per batch -- we keep dedupe=True by
    default, which is FAVOURABLE to the baseline.
    """

    def __init__(self, ws: WorkerSchedule, store: ShardedFeatureStore,
                 batch_size: int, train_fn: Optional[TrainFn] = None,
                 dedupe: bool = True):
        self.ws = ws
        self.store = store
        self.batch_size = batch_size
        self.train_fn = train_fn or (lambda feats, cb: 0.0)
        self.dedupe = dedupe
        self.m_max, self.edge_max = global_pad_bounds(ws)
        self.metrics = RunMetrics()

    def _assemble_per_occurrence(self, b, cb, m: EpochMetrics) -> np.ndarray:
        """dedupe=False fetch: charge bytes/RPCs for every edge-level
        occurrence of a remote node (redundant-RPC regime), then fill the
        buffer once per unique slot. The charged occurrence multiset is a
        superset of the unique remote set, so the filled rows' bytes are
        fully accounted."""
        store = self.store
        out, rem_idx = local_fill(cb, store)
        occ = occurrence_remote_ids(b, store.pg.owner, store.worker)
        m.remote_requests += int(occ.shape[0])
        m.cache_misses += int(occ.shape[0])
        if occ.shape[0]:
            store.sync_pull(occ, m, critical_path=True)
        if rem_idx.shape[0]:
            out[rem_idx] = store.feat[cb.input_nodes[rem_idx]]
        return out

    def run(self) -> RunMetrics:
        labels = self.store.pg.graph.labels
        for e in range(len(self.ws.epochs)):
            es = self.ws.epoch(e)
            m = EpochMetrics(epoch=e)
            t_epoch = time.perf_counter()
            for b in es.batches:
                t0 = time.perf_counter()
                cb = collate(b, labels, self.batch_size, self.m_max,
                             self.edge_max)
                if self.dedupe:
                    feats = assemble_features(cb, self.store, cache=None,
                                              m=m, critical_path=True)
                else:
                    feats = self._assemble_per_occurrence(b, cb, m)
                m.fetch_stall_s += time.perf_counter() - t0
                t1 = time.perf_counter()
                self.train_fn(feats, cb)
                m.compute_time_s += time.perf_counter() - t1
            m.wall_time_s = time.perf_counter() - t_epoch
            self.metrics.epochs.append(m)
        return self.metrics
