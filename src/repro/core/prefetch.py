"""Rolling prefetcher + secondary-cache builder (paper §4 components 4,6,7).

The prefetcher is a real producer thread staging device-ready batches
(collated metadata + assembled feature tensor) into a bounded queue of
depth Q -- the paper's MPMC ring. It is *cache-first*: features are served
from C_s, and only the residual miss set M_i goes through SyncPull. The
queue blocks when full (prefetcher ahead) and the trainer stalls when it
outruns the queue (the Prefetcher-Trainer race the paper describes); stall
time is metered separately as critical-path fetch time.

On TPU the same structure is realised as a software pipeline inside the
step program (``repro/dist/gnn_step.py::make_pipelined_epoch``, driven
across epochs by ``repro/dist/runner.py``); this host-thread version is
the faithful reproduction of the paper's runtime and what the CPU
benchmarks measure.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import List, Optional

import numpy as np

from repro.core.cache import DoubleBufferCache, FeatureCache
from repro.core.fetch import ShardedFeatureStore
from repro.core.metrics import EpochMetrics
from repro.core.schedule import (CollatedBatch, EpochSchedule, collate,
                                 epoch_edge_maxima)
from repro.fault.inject import fault_point, retry_call


class PrefetchWorkerError(RuntimeError):
    """The prefetch thread died (non-retryable failure or retry budget
    exhausted); the original exception rides along as ``__cause__``."""


class SecondaryCacheError(RuntimeError):
    """The C_sec builder thread died; the consumer may degrade (keep the
    stale steady cache -- lossless, counted) instead of failing the run."""


class PrefetchStall(TimeoutError):
    """``Prefetcher.get(timeout=)`` expired: the producer is late or
    hung. The consumer can fall back to a critical-path batch rebuild
    (``RapidGNNRunner`` does) -- determinism is unaffected either way."""


class StagedBatch:
    __slots__ = ("index", "collated", "features", "fetch_time")

    def __init__(self, index: int, collated: CollatedBatch,
                 features: np.ndarray, fetch_time: float):
        self.index = index
        self.collated = collated
        self.features = features
        self.fetch_time = fetch_time


def local_fill(cb: CollatedBatch, store: ShardedFeatureStore):
    """Zeroed (m_max, d) buffer with this worker's LOCAL rows filled.

    -> (out, rem_idx): rem_idx indexes the valid REMOTE slots still to be
    served (padded -1 slots are neither local nor remote). Shared by the
    cache-first assembly below and the baseline's per-occurrence path so
    both fill local rows identically."""
    ids = cb.input_nodes
    valid = cb.input_mask
    out = np.zeros((ids.shape[0], store.d), dtype=store.feat.dtype)
    safe_ids = np.where(valid, ids, 0)
    is_local = (store.pg.owner[safe_ids] == store.worker) & valid
    if is_local.any():
        out[is_local] = store.local_read(safe_ids[is_local])
    return out, np.flatnonzero(valid & ~is_local)


def assemble_features(cb: CollatedBatch, store: ShardedFeatureStore,
                      cache: Optional[FeatureCache], m: EpochMetrics,
                      critical_path: bool) -> np.ndarray:
    """Cache-first feature materialization for one batch (Alg.1 l.12-15)."""
    ids = cb.input_nodes
    out, rem_idx = local_fill(cb, store)
    n_remote = int(rem_idx.shape[0])
    m.remote_requests += n_remote
    if n_remote == 0:
        return out

    rem_ids = ids[rem_idx]
    if cache is not None and cache.ids.shape[0] > 0:
        pos, hit = cache.lookup(rem_ids)
        out[rem_idx[hit]] = cache.feats[pos[hit]]
        m.cache_hits += int(hit.sum())
        miss_idx = rem_idx[~hit]
    else:
        miss_idx = rem_idx
    m.cache_misses += int(miss_idx.shape[0])
    if miss_idx.shape[0]:
        out[miss_idx] = store.sync_pull(ids[miss_idx], m,
                                        critical_path=critical_path)
    return out


class Prefetcher:
    """Producer thread staging the next Q batches (paper Alg. 1 line 10).

    Supervision (DESIGN.md §10): a transiently-failing batch build is
    retried in place with exponential backoff (``max_retries``, counted
    in ``metrics.prefetch_retries``); a persistent/fatal failure lands
    in ``_err`` and surfaces TYPED (``PrefetchWorkerError``) at the
    sentinel or join. ``join`` is deadline-bounded and names the stuck
    thread, so a hung producer can never deadlock runner teardown."""

    #: bounded retry budget for transient per-batch build failures
    max_retries = 2
    retry_base_s = 1e-3

    def __init__(self, es: EpochSchedule, store: ShardedFeatureStore,
                 dbc: DoubleBufferCache, labels: np.ndarray,
                 batch_size: int, m_max: int, edge_max: List[int],
                 Q: int, metrics: EpochMetrics):
        self.es = es
        self.store = store
        self.dbc = dbc
        self.labels = labels
        self.batch_size = batch_size
        self.m_max = m_max
        self.edge_max = edge_max
        self.q: "queue.Queue[Optional[StagedBatch]]" = queue.Queue(maxsize=Q)
        self.metrics = metrics
        self._err: Optional[BaseException] = None
        self._err_lock = threading.Lock()
        self._stop = threading.Event()
        self._closed = False
        self._thread = threading.Thread(
            target=self._run, daemon=True,
            name=f"prefetch-w{store.worker}-e{es.epoch}")

    def start(self) -> "Prefetcher":
        self._thread.start()
        return self

    def _build(self, i: int, b, attempt: int) -> StagedBatch:
        # the fault probe sits BEFORE assembly so a retried attempt
        # never double-counts hit/miss/byte metrics
        fault_point("prefetch", attempt=attempt, epoch=self.es.epoch,
                    worker=self.store.worker, index=i)
        t0 = time.perf_counter()
        cb = collate(b, self.labels, self.batch_size, self.m_max,
                     self.edge_max)
        feats = assemble_features(cb, self.store, self.dbc.steady,
                                  self.metrics, critical_path=False)
        return StagedBatch(i, cb, feats, time.perf_counter() - t0)

    def _count_retry(self, _attempt: int) -> None:
        self.metrics.prefetch_retries += 1

    def _run(self) -> None:
        try:
            for i, b in enumerate(self.es.batches):
                if self._stop.is_set():
                    return
                staged = retry_call(
                    lambda a, _i=i, _b=b: self._build(_i, _b, a),
                    self.max_retries, self.retry_base_s,
                    on_retry=self._count_retry)
                self._put(staged)
        except BaseException as exc:          # re-raised in get()/join()
            with self._err_lock:
                self._err = exc
        finally:
            self._put(None)                   # epoch sentinel / unblock

    def _put(self, item: Optional[StagedBatch]) -> None:
        # bounded put that yields to close(): never deadlocks on a full
        # queue after the consumer has gone away
        while not self._stop.is_set():
            try:
                self.q.put(item, timeout=0.1)
                return
            except queue.Full:
                continue

    def get(self, timeout: Optional[float] = None) -> Optional[StagedBatch]:
        try:
            item = self.q.get(timeout=timeout)
        except queue.Empty:
            raise PrefetchStall(
                f"prefetch thread {self._thread.name} produced nothing "
                f"within {timeout}s") from None
        if item is None:
            self._raise_pending()
        return item

    def join(self, timeout: Optional[float] = 30.0) -> None:
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            raise TimeoutError(
                f"prefetch thread {self._thread.name} still alive after "
                f"{timeout}s join deadline")
        self._raise_pending()

    def close(self, timeout: float = 5.0) -> None:
        """Idempotent exception-path teardown: drains the bounded queue so
        a blocked producer exits, then joins it with a deadline."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        if self._thread.ident is not None:
            self._thread.join(timeout=timeout)

    def _raise_pending(self) -> None:
        with self._err_lock:
            err, self._err = self._err, None
        if err is not None:
            raise PrefetchWorkerError("prefetch thread failed") from err


class SecondaryCacheBuilder:
    """Builds C_sec for epoch e+1 concurrently (paper Alg. 1 lines 7-9).

    A failed build surfaces as ``SecondaryCacheError`` at join; the
    consumer may degrade by keeping the stale steady cache (``swap()``
    no-ops without a staged secondary -- lossless, since the cache only
    redirects fetches). A HUNG build is NOT degradable: the bounded
    join raises a loud ``TimeoutError`` naming the thread."""

    def __init__(self, next_es: EpochSchedule, store: ShardedFeatureStore,
                 dbc: DoubleBufferCache, metrics: EpochMetrics):
        self.next_es = next_es
        self.store = store
        self.dbc = dbc
        self.metrics = metrics
        self._err: Optional[BaseException] = None
        self._err_lock = threading.Lock()
        self._closed = False
        self._thread = threading.Thread(
            target=self._run, daemon=True,
            name=f"csec-w{store.worker}-e{metrics.epoch}")

    def start(self) -> "SecondaryCacheBuilder":
        self._thread.start()
        return self

    def _run(self) -> None:
        try:
            fault_point("csec", epoch=self.metrics.epoch,
                        worker=self.store.worker)
            ids = self.next_es.cache_ids
            feats = self.store.vector_pull(ids, self.metrics)
            self.dbc.stage_secondary(FeatureCache(ids, feats))
        except BaseException as exc:          # re-raised in join()
            with self._err_lock:
                self._err = exc

    def join(self, timeout: Optional[float] = 30.0) -> None:
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            raise TimeoutError(
                f"secondary-cache thread {self._thread.name} still alive "
                f"after {timeout}s join deadline")
        self._raise_pending()

    def close(self, timeout: float = 5.0) -> None:
        """Idempotent exception-path join (does not re-raise)."""
        if self._closed:
            return
        self._closed = True
        if self._thread.ident is not None:
            self._thread.join(timeout=timeout)

    def _raise_pending(self) -> None:
        with self._err_lock:
            err, self._err = self._err, None
        if err is not None:
            raise SecondaryCacheError(
                "secondary cache build failed") from err
