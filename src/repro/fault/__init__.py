"""Deterministic fault-injection plane (DESIGN.md §10).

``FaultPlan`` schedules faults bit-exactly via the §2.2 RNG contract;
``fault_point`` is the probe the runtime calls at each named site;
``repro.fault.chaos`` sweeps seeded plans and asserts every run is
either loss-bit-equal to the fault-free oracle or a TYPED error.
"""
from repro.fault.plan import (FAULT_SALT, PROFILES, SITES, FatalFault,
                              FaultPlan, FaultRule, InjectedCrash,
                              InjectedFault, TransientFault,
                              plan_from_profile, random_plan)
from repro.fault.inject import (activate, active_plan, current,
                                deactivate, fault_point, retry_call)

__all__ = [
    "FAULT_SALT", "PROFILES", "SITES", "FaultPlan", "FaultRule",
    "InjectedFault", "TransientFault", "FatalFault", "InjectedCrash",
    "plan_from_profile", "random_plan",
    "activate", "deactivate", "current", "active_plan", "fault_point",
    "retry_call",
]
