"""Chaos harness: sweep seeded fault plans through the host-sim runner.

``python -m repro.fault.chaos --seed N`` runs the tiny-graph RapidGNN
scenario (worker 0 of a 4-way greedy partition, 3 epochs, disk spill ON
so the spill heal path is exercised) once CLEAN to get the oracle loss
curve, then once per named host profile and per ``random_plan`` drawn
from the chaos pool. The robustness contract (DESIGN.md §10) is binary
per run:

  * the run COMPLETES -> its loss curve must be BIT-equal to the oracle
    (every tolerated fault recovers losslessly), or
  * the run raises one of the TYPED fault-plane errors -- never a raw
    numpy/OS error, never a hang, never a silent divergence.

A final checkpoint-atomicity drill crashes ``save_run_state`` between
the arrays commit and the manifest commit and proves ``LATEST`` still
resolves to the previous, bit-intact checkpoint.

Any violation prints a ``recovery FAILED`` line (CI greps for it) and
the CLI exits non-zero. Fault plans are Philox-keyed from the CLI seed
(§2.2 RNG contract), so every sweep replays bit-exactly.
"""
from __future__ import annotations

import argparse
import sys
import tempfile
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.fault.inject import active_plan
from repro.fault.plan import (FaultPlan, InjectedCrash, InjectedFault,
                              plan_from_profile, random_plan)

#: named host-side profiles the sweep always covers (chaos adds random
#: plans on top). ``ckpt-crash``/``run-crash`` are exercised by the
#: checkpoint drill / device suite, not the host epoch loop.
HOST_SWEEP = ("pull-flaky", "pull-dead", "prefetch-flaky",
              "prefetch-fatal", "prefetch-hang", "csec-loss",
              "spill-rot", "spill-trunc", "spill-gone")

#: the ONLY exceptions a faulted run may surface: the fault-plane's own
#: errors plus the typed detection/supervision errors of each site.
#: PrefetchStall subclasses TimeoutError; TransientFault/FatalFault/
#: InjectedCrash subclass InjectedFault.
def _allowed_errors() -> tuple:
    from repro.core.prefetch import (PrefetchWorkerError,
                                     SecondaryCacheError)
    from repro.core.schedule import SpillCorruptError
    from repro.train.checkpoint import CheckpointCorruptError
    return (InjectedFault, PrefetchWorkerError, SecondaryCacheError,
            SpillCorruptError, CheckpointCorruptError, TimeoutError)


class _Chaos:
    """One shared scenario (graph, partition, jitted train step) reused
    by every plan in the sweep; each run rebuilds its schedule in a
    fresh spill dir so file damage never leaks across runs."""

    def __init__(self):
        from repro.graph import KHopSampler, load_dataset, partition_graph
        from repro.models import (GNNConfig, batch_to_device, init_params,
                                  make_train_step)
        from repro.train import AdamW

        self.g = load_dataset("tiny")
        self.pg = partition_graph(self.g, 4, "greedy")
        self.sampler = KHopSampler(self.g, fanouts=[5, 5], batch_size=16)
        self.cfg = GNNConfig(kind="sage", in_dim=self.g.feat_dim,
                             hidden_dim=32,
                             num_classes=self.g.num_classes,
                             num_layers=2)
        self.opt = AdamW(lr=3e-3)
        self.step = make_train_step(self.cfg, self.opt)
        self._init_params = init_params
        self._to_device = batch_to_device

    def run(self, plan: Optional[FaultPlan],
            stall_timeout_s: float = 0.5) -> np.ndarray:
        import jax

        from repro.core import (NetworkModel, RapidGNNRunner,
                                ShardedFeatureStore, build_schedule)

        losses: List[float] = []
        params = self._init_params(self.cfg, jax.random.key(42))
        box = {"p": params, "o": self.opt.init(params)}

        def train_fn(feats, cb):
            batch = self._to_device(cb, feats)
            box["p"], box["o"], aux = self.step(box["p"], box["o"], batch)
            losses.append(float(aux["loss"]))
            return losses[-1]

        with tempfile.TemporaryDirectory() as td, active_plan(plan):
            # schedule build is INSIDE the plan scope: spill_write
            # damage lands at build time, detection+heal at epoch load
            ws = build_schedule(self.sampler, self.pg, worker=0, s0=42,
                                num_epochs=3, n_hot=64, spill_dir=td)
            store = ShardedFeatureStore(self.pg, worker=0,
                                        net=NetworkModel(enabled=False))
            RapidGNNRunner(ws, store, batch_size=16, train_fn=train_fn,
                           stall_timeout_s=stall_timeout_s).run()
        return np.asarray(losses, np.float64)


def _checkpoint_drill(log: Callable[[str], None]) -> bool:
    """Crash ``save_run_state`` between arrays and manifest commits:
    ``LATEST`` must keep naming the previous step, which must load back
    bit-equal."""
    from repro.train import latest_step, load_run_state, save_run_state

    tree1 = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
             "b": np.zeros(3, np.float32)}
    tree2 = {"w": tree1["w"] + 1.0, "b": tree1["b"] + 1.0}
    with tempfile.TemporaryDirectory() as td:
        save_run_state(td, tree1, step=1)
        crashed = False
        try:
            with active_plan(plan_from_profile("ckpt-crash")):
                save_run_state(td, tree2, step=2)
        except InjectedCrash:
            crashed = True
        ok = crashed and latest_step(td) == 1
        if ok:
            like = {"w": np.zeros((2, 3), np.float32),
                    "b": np.zeros(3, np.float32)}
            tree, step = load_run_state(td, like)
            ok = (step == 1
                  and np.array_equal(np.asarray(tree["w"]), tree1["w"])
                  and np.array_equal(np.asarray(tree["b"]), tree1["b"]))
    if not ok:
        log("recovery FAILED: checkpoint atomicity drill -- a crash "
            "mid-commit must leave LATEST on the previous bit-intact "
            "checkpoint")
    return ok


def run_chaos(seed: int = 0, fast: bool = False,
              n_random: Optional[int] = None,
              log: Callable[[str], None] = print) -> Dict:
    """Run the full sweep; returns a JSON-ready summary with
    ``ok=True`` iff every run either recovered bit-exactly or raised a
    typed error, and the checkpoint drill passed."""
    ch = _Chaos()
    oracle = ch.run(None)
    log(f"[chaos] oracle: {oracle.shape[0]} steps, "
        f"final loss {oracle[-1]:.6f}")

    plans = [plan_from_profile(p, seed=seed) for p in HOST_SWEEP]
    if n_random is None:
        n_random = 2 if fast else 8
    plans += [random_plan(seed, i) for i in range(n_random)]
    allowed = _allowed_errors()

    runs: List[Dict] = []
    bad: List[str] = []
    for plan in plans:
        try:
            losses = ch.run(plan)
        except allowed as exc:
            outcome = f"typed:{type(exc).__name__}"
        except BaseException as exc:   # untyped leak == contract breach
            outcome = f"untyped:{type(exc).__name__}"
            bad.append(plan.name)
            log(f"recovery FAILED: plan {plan.name} leaked an untyped "
                f"error {exc!r}")
        else:
            if (losses.shape == oracle.shape
                    and np.array_equal(losses, oracle)):
                outcome = "bit-equal"
            else:
                outcome = "diverged"
                bad.append(plan.name)
                log(f"recovery FAILED: plan {plan.name} completed with "
                    f"a loss curve diverging from the oracle")
        fires = plan.total_fires()
        runs.append({"plan": plan.name, "fires": fires,
                     "outcome": outcome,
                     "snapshot": plan.snapshot()})
        log(f"[chaos] {plan.name:18s} fires={fires:2d} {outcome}")

    ckpt_ok = _checkpoint_drill(log)
    ok = not bad and ckpt_ok
    log(f"[chaos] {len(runs)} plans, {len(bad)} failures, "
        f"checkpoint drill {'OK' if ckpt_ok else 'FAILED'}")
    return {"seed": seed, "oracle_steps": int(oracle.shape[0]),
            "runs": runs, "checkpoint_drill": ckpt_ok,
            "failed_plans": bad, "ok": ok}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="RapidGNN fault-injection chaos sweep")
    ap.add_argument("--seed", type=int, default=0,
                    help="Philox seed keying every fault plan")
    ap.add_argument("--fast", action="store_true",
                    help="2 random plans instead of 8")
    ap.add_argument("--plans", type=int, default=None,
                    help="override the random-plan count")
    args = ap.parse_args(argv)
    out = run_chaos(seed=args.seed, fast=args.fast, n_random=args.plans)
    return 0 if out["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
