"""Chaos harness: sweep seeded fault plans through the host-sim runner
AND the online serving tier.

``python -m repro.fault.chaos --seed N`` runs the tiny-graph RapidGNN
scenario (worker 0 of a 4-way greedy partition, 3 epochs, disk spill ON
so the spill heal path is exercised) once CLEAN to get the oracle loss
curve, then once per named host profile and per ``random_plan`` drawn
from the chaos pool. The robustness contract (DESIGN.md §10) is binary
per run:

  * the run COMPLETES -> its loss curve must be BIT-equal to the oracle
    (every tolerated fault recovers losslessly), or
  * the run raises one of the TYPED fault-plane errors -- never a raw
    numpy/OS error, never a hang, never a silent divergence.

A final checkpoint-atomicity drill crashes ``save_run_state`` between
the arrays commit and the manifest commit and proves ``LATEST`` still
resolves to the previous, bit-intact checkpoint.

The SERVE sweep (DESIGN.md §11) then drills ``repro.serve.gnn``: a
fixed request stream is replayed through a fresh service per plan
(``SERVE_SWEEP`` profiles + ``random_serve_plan`` draws), all sharing
ONE jitted program. Per request the contract is ternary: the response
is bit-equal to the clean single-request oracle (stale responses must
ALSO bit-match their snapshot rows against the authoritative table), or
the request was shed/failed with a TYPED serving error. Anything else
-- divergence, an untyped leak, a snapshot that lies -- fails the run,
and ``trace_count`` must stay 1 across the whole sweep.

Any violation prints a ``recovery FAILED`` line (CI greps for it) and
the CLI exits non-zero. Fault plans are Philox-keyed from the CLI seed
(§2.2 RNG contract), so every sweep replays bit-exactly.
"""
from __future__ import annotations

import argparse
import sys
import tempfile
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.fault.inject import active_plan
from repro.fault.plan import (FaultPlan, InjectedCrash, InjectedFault,
                              plan_from_profile, random_plan,
                              random_serve_plan)

#: named host-side profiles the sweep always covers (chaos adds random
#: plans on top). ``ckpt-crash``/``run-crash`` are exercised by the
#: checkpoint drill / device suite, not the host epoch loop.
HOST_SWEEP = ("pull-flaky", "pull-dead", "prefetch-flaky",
              "prefetch-fatal", "prefetch-hang", "csec-loss",
              "spill-rot", "spill-trunc", "spill-gone")

#: named serving profiles the serve sweep always covers.
SERVE_SWEEP = ("serve-pull-flaky", "serve-pull-dead", "serve-warm-flaky",
               "serve-warm-dead", "serve-warm-hang", "serve-warm-stale",
               "serve-queue-shed")

#: the ONLY exceptions a faulted run may surface: the fault-plane's own
#: errors plus the typed detection/supervision errors of each site.
#: PrefetchStall subclasses TimeoutError; TransientFault/FatalFault/
#: InjectedCrash subclass InjectedFault.
def _allowed_errors() -> tuple:
    from repro.core.prefetch import (PrefetchWorkerError,
                                     SecondaryCacheError)
    from repro.core.schedule import SpillCorruptError
    from repro.train.checkpoint import CheckpointCorruptError
    return (InjectedFault, PrefetchWorkerError, SecondaryCacheError,
            SpillCorruptError, CheckpointCorruptError, TimeoutError)


class _Chaos:
    """One shared scenario (graph, partition, jitted train step) reused
    by every plan in the sweep; each run rebuilds its schedule in a
    fresh spill dir so file damage never leaks across runs."""

    def __init__(self):
        from repro.graph import KHopSampler, load_dataset, partition_graph
        from repro.models import (GNNConfig, batch_to_device, init_params,
                                  make_train_step)
        from repro.train import AdamW

        self.g = load_dataset("tiny")
        self.pg = partition_graph(self.g, 4, "greedy")
        self.sampler = KHopSampler(self.g, fanouts=[5, 5], batch_size=16)
        self.cfg = GNNConfig(kind="sage", in_dim=self.g.feat_dim,
                             hidden_dim=32,
                             num_classes=self.g.num_classes,
                             num_layers=2)
        self.opt = AdamW(lr=3e-3)
        self.step = make_train_step(self.cfg, self.opt)
        self._init_params = init_params
        self._to_device = batch_to_device

    def run(self, plan: Optional[FaultPlan],
            stall_timeout_s: float = 0.5) -> np.ndarray:
        import jax

        from repro.core import (NetworkModel, RapidGNNRunner,
                                ShardedFeatureStore, build_schedule)

        losses: List[float] = []
        params = self._init_params(self.cfg, jax.random.key(42))
        box = {"p": params, "o": self.opt.init(params)}

        def train_fn(feats, cb):
            batch = self._to_device(cb, feats)
            box["p"], box["o"], aux = self.step(box["p"], box["o"], batch)
            losses.append(float(aux["loss"]))
            return losses[-1]

        with tempfile.TemporaryDirectory() as td, active_plan(plan):
            # schedule build is INSIDE the plan scope: spill_write
            # damage lands at build time, detection+heal at epoch load
            ws = build_schedule(self.sampler, self.pg, worker=0, s0=42,
                                num_epochs=3, n_hot=64, spill_dir=td)
            store = ShardedFeatureStore(self.pg, worker=0,
                                        net=NetworkModel(enabled=False))
            RapidGNNRunner(ws, store, batch_size=16, train_fn=train_fn,
                           stall_timeout_s=stall_timeout_s).run()
        return np.asarray(losses, np.float64)


def _allowed_serve_errors() -> tuple:
    from repro.serve.gnn import (Overloaded, ServeClosed, ServePullError,
                                 WarmerError)
    return (InjectedFault, Overloaded, ServePullError, WarmerError,
            ServeClosed, TimeoutError)


class _ServeChaos:
    """One shared serving scenario: a fixed Philox-keyed request stream
    replayed through a FRESH service per plan (fresh queue -> rid j ==
    stream index j, so oracles key by stream position), with one shared
    jitted ``ServeProgram`` across every run.

    Shape: three 4-request phases with a synchronous warm cycle between
    them, so each plan exercises the full tier ladder -- phase A runs
    uncached, B runs against generation 1, C against generation 2 (or
    degraded stale/uncached when a warm was killed)."""

    N_PHASES = 3
    PHASE_REQS = 4

    def __init__(self):
        from repro.graph import KHopSampler, load_dataset, partition_graph
        from repro.graph.sampler import rng_from
        from repro.models import GNNConfig, init_params
        import jax

        self.g = load_dataset("tiny")
        self.pg = partition_graph(self.g, 4, "greedy")
        self.sampler = KHopSampler(self.g, fanouts=[5, 5], batch_size=8)
        self.cfg = GNNConfig(kind="sage", in_dim=self.g.feat_dim,
                             hidden_dim=32,
                             num_classes=self.g.num_classes,
                             num_layers=2)
        self.params = init_params(self.cfg, jax.random.key(42))
        n = self.N_PHASES * self.PHASE_REQS
        self.streams = [
            rng_from(4242, j).integers(0, self.g.num_nodes,
                                       size=1 + j % 8).astype(np.int64)
            for j in range(n)]
        self.program = None       # built by the first service

    def _make_service(self):
        from repro.serve.gnn import GNNInferenceService
        svc = GNNInferenceService(
            self.pg, self.sampler, self.cfg, self.params, s0=42,
            worker=0, n_hot=64,
            max_batch_requests=self.PHASE_REQS,
            high_water=self.PHASE_REQS,
            default_timeout_s=30.0, program=self.program)
        self.program = svc.program
        return svc

    def oracles(self) -> List[np.ndarray]:
        svc = self._make_service()
        try:
            return [svc.oracle(s, rid=j)
                    for j, s in enumerate(self.streams)]
        finally:
            svc.close()

    def run(self, plan: FaultPlan, oracles: List[np.ndarray]) -> Dict:
        """Replay the stream under ``plan``; -> per-run summary with
        ``failures`` naming every contract breach."""
        from repro.serve.gnn import WarmerError
        allowed = _allowed_serve_errors()
        svc = self._make_service()
        counts = {"ok": 0, "shed": 0, "typed": 0, "stale": 0}
        failures: List[str] = []
        try:
            with active_plan(plan):
                for phase in range(self.N_PHASES):
                    lo = phase * self.PHASE_REQS
                    pending = {}
                    for j in range(lo, lo + self.PHASE_REQS):
                        try:
                            pending[j] = svc.submit(self.streams[j])
                        except allowed:
                            counts["shed"] += 1
                    try:
                        served = 0
                        while served < len(pending):
                            served += svc.step(timeout=0.1)
                    except allowed:
                        pass      # per-request errors re-checked below
                    for j, p in pending.items():
                        try:
                            resp = p.result(timeout=1.0)
                        except allowed:
                            counts["typed"] += 1
                            continue
                        except BaseException as exc:
                            failures.append(
                                f"req {j}: untyped "
                                f"{type(exc).__name__}")
                            continue
                        err = self._verify(j, resp, oracles)
                        if err:
                            failures.append(err)
                        else:
                            counts["ok"] += 1
                            counts["stale"] += int(resp.stale)
                    if phase < self.N_PHASES - 1:
                        try:
                            svc.warmer.warm_now()
                        except WarmerError:
                            pass  # degrade: stale/uncached tier next
        except BaseException as exc:
            failures.append(f"sweep leaked {type(exc).__name__}: {exc}")
        finally:
            svc.close()
        counts["health"] = svc.health()
        counts["failures"] = failures
        return counts

    def _verify(self, j: int, resp, oracles) -> Optional[str]:
        if not np.array_equal(resp.logits, oracles[j]):
            return (f"req {j}: tier={resp.tier} logits diverge from the "
                    f"clean oracle")
        if resp.stale:
            c = resp.served_cache
            if c is None:
                return f"req {j}: stale response without a snapshot"
            if not np.array_equal(c.feats, self.g.features[c.ids]):
                return (f"req {j}: stale snapshot rows diverge from the "
                        f"authoritative table")
        return None


def _serve_sweep(seed: int, fast: bool, log: Callable[[str], None],
                 n_random: Optional[int] = None) -> Dict:
    sc = _ServeChaos()
    oracles = sc.oracles()
    log(f"[chaos] serve oracle: {len(oracles)} requests")
    if n_random is None:
        n_random = 2 if fast else 6
    plans = [plan_from_profile(p, seed=seed) for p in SERVE_SWEEP]
    plans += [random_serve_plan(seed, i) for i in range(n_random)]
    runs: List[Dict] = []
    bad: List[str] = []
    for plan in plans:
        out = sc.run(plan, oracles)
        for f in out["failures"]:
            log(f"recovery FAILED: serve plan {plan.name}: {f}")
        if out["failures"]:
            bad.append(plan.name)
        if plan.name == "serve-warm-stale" and out["stale"] == 0:
            bad.append(plan.name)
            log("recovery FAILED: serve plan serve-warm-stale never "
                "exercised the stale tier")
        fires = plan.total_fires()
        runs.append({"plan": plan.name, "fires": fires,
                     "ok": out["ok"], "shed": out["shed"],
                     "typed": out["typed"], "stale": out["stale"],
                     "snapshot": plan.snapshot()})
        log(f"[chaos] {plan.name:18s} fires={fires:2d} "
            f"ok={out['ok']:2d} shed={out['shed']} typed={out['typed']} "
            f"stale={out['stale']}")
    traces = sc.program.trace_count if sc.program else 0
    if traces != 1:
        bad.append("trace-count")
        log(f"recovery FAILED: serve sweep compiled {traces} XLA traces "
            f"(static-shape collation guarantees exactly 1)")
    return {"runs": runs, "failed_plans": bad, "trace_count": traces,
            "ok": not bad}


def _checkpoint_drill(log: Callable[[str], None]) -> bool:
    """Crash ``save_run_state`` between arrays and manifest commits:
    ``LATEST`` must keep naming the previous step, which must load back
    bit-equal."""
    from repro.train import latest_step, load_run_state, save_run_state

    tree1 = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
             "b": np.zeros(3, np.float32)}
    tree2 = {"w": tree1["w"] + 1.0, "b": tree1["b"] + 1.0}
    with tempfile.TemporaryDirectory() as td:
        save_run_state(td, tree1, step=1)
        crashed = False
        try:
            with active_plan(plan_from_profile("ckpt-crash")):
                save_run_state(td, tree2, step=2)
        except InjectedCrash:
            crashed = True
        ok = crashed and latest_step(td) == 1
        if ok:
            like = {"w": np.zeros((2, 3), np.float32),
                    "b": np.zeros(3, np.float32)}
            tree, step = load_run_state(td, like)
            ok = (step == 1
                  and np.array_equal(np.asarray(tree["w"]), tree1["w"])
                  and np.array_equal(np.asarray(tree["b"]), tree1["b"]))
    if not ok:
        log("recovery FAILED: checkpoint atomicity drill -- a crash "
            "mid-commit must leave LATEST on the previous bit-intact "
            "checkpoint")
    return ok


def run_chaos(seed: int = 0, fast: bool = False,
              n_random: Optional[int] = None,
              log: Callable[[str], None] = print,
              serve_only: bool = False) -> Dict:
    """Run the full sweep; returns a JSON-ready summary with
    ``ok=True`` iff every run either recovered bit-exactly or raised a
    typed error, and the checkpoint drill passed. ``serve_only`` runs
    just the serving sweep (the CI fast-lane serve chaos step)."""
    if serve_only:
        serve = _serve_sweep(seed, fast, log, n_random=n_random)
        log(f"[chaos] {len(serve['runs'])} serve plans, "
            f"{len(serve['failed_plans'])} failures")
        return {"seed": seed, "runs": [], "checkpoint_drill": None,
                "failed_plans": serve["failed_plans"], "serve": serve,
                "ok": serve["ok"]}
    ch = _Chaos()
    oracle = ch.run(None)
    log(f"[chaos] oracle: {oracle.shape[0]} steps, "
        f"final loss {oracle[-1]:.6f}")

    plans = [plan_from_profile(p, seed=seed) for p in HOST_SWEEP]
    if n_random is None:
        n_random = 2 if fast else 8
    plans += [random_plan(seed, i) for i in range(n_random)]
    allowed = _allowed_errors()

    runs: List[Dict] = []
    bad: List[str] = []
    for plan in plans:
        try:
            losses = ch.run(plan)
        except allowed as exc:
            outcome = f"typed:{type(exc).__name__}"
        except BaseException as exc:   # untyped leak == contract breach
            outcome = f"untyped:{type(exc).__name__}"
            bad.append(plan.name)
            log(f"recovery FAILED: plan {plan.name} leaked an untyped "
                f"error {exc!r}")
        else:
            if (losses.shape == oracle.shape
                    and np.array_equal(losses, oracle)):
                outcome = "bit-equal"
            else:
                outcome = "diverged"
                bad.append(plan.name)
                log(f"recovery FAILED: plan {plan.name} completed with "
                    f"a loss curve diverging from the oracle")
        fires = plan.total_fires()
        runs.append({"plan": plan.name, "fires": fires,
                     "outcome": outcome,
                     "snapshot": plan.snapshot()})
        log(f"[chaos] {plan.name:18s} fires={fires:2d} {outcome}")

    ckpt_ok = _checkpoint_drill(log)
    serve = _serve_sweep(seed, fast, log)
    ok = not bad and ckpt_ok and serve["ok"]
    log(f"[chaos] {len(runs)} train plans ({len(bad)} failures), "
        f"{len(serve['runs'])} serve plans "
        f"({len(serve['failed_plans'])} failures), "
        f"checkpoint drill {'OK' if ckpt_ok else 'FAILED'}")
    return {"seed": seed, "oracle_steps": int(oracle.shape[0]),
            "runs": runs, "checkpoint_drill": ckpt_ok,
            "failed_plans": bad, "serve": serve, "ok": ok}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="RapidGNN fault-injection chaos sweep")
    ap.add_argument("--seed", type=int, default=0,
                    help="Philox seed keying every fault plan")
    ap.add_argument("--fast", action="store_true",
                    help="2 random plans instead of 8")
    ap.add_argument("--plans", type=int, default=None,
                    help="override the random-plan count")
    ap.add_argument("--serve-only", action="store_true",
                    help="run only the serving sweep (CI fast lane)")
    args = ap.parse_args(argv)
    out = run_chaos(seed=args.seed, fast=args.fast, n_random=args.plans,
                    serve_only=args.serve_only)
    return 0 if out["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
