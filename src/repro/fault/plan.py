"""Seeded deterministic fault plans (DESIGN.md §10).

A ``FaultPlan`` is a seed plus an ordered tuple of ``FaultRule``s.
Every injection decision is drawn through the §2.2 RNG contract --
``rng_from(seed, FAULT_SALT, site, kind, rule_index, attempt, epoch,
worker, index)`` -- so a decision depends only on WHERE the probe sits
(site + context + attempt number), never on when a thread happens to
reach it: a fault schedule replays bit-exactly across runs and across
arbitrary thread interleavings. The ``attempt`` field is load-bearing --
without it a "transient" fault would re-fire identically on every
retry and never clear.

Sites are string names; ``derive_seed`` takes int64 fields only, so
names enter the key as their crc32 (stable across processes, unlike
``hash``).
"""
from __future__ import annotations

import dataclasses
import threading
import zlib
from typing import Dict, Optional, Sequence, Tuple

from repro.graph.sampler import rng_from

#: domain-separation constant: fault draws can never collide with
#: sampler draws keyed from the same base seed
FAULT_SALT = 0x666C7464  # "fltd"

#: every named injection probe in the runtime (site -> where it lives)
SITES = {
    "stage": "dist/runner.py background epoch staging",
    "stage_cache": "dist/runner.py staged C_s/C_sec device buffers",
    "prefetch": "core/prefetch.py Prefetcher batch assembly",
    "csec": "core/prefetch.py SecondaryCacheBuilder",
    "spill_write": "core/schedule.py SpillWriter npz output",
    "pull": "core/fetch.py sync_pull",
    "checkpoint": "train/checkpoint.py save commit point",
    "run_crash": "dist/runner.py epoch boundary after checkpoint",
    # -- online serving sites (repro.serve.gnn, DESIGN.md §11) -------------
    "serve_pull": "serve/gnn/service.py residual sync-pull per micro-batch",
    "serve_warm": "serve/gnn/warmer.py hot-cache warm cycle",
    "serve_queue": "serve/gnn/admission.py request admission",
}

#: kinds that damage a file operand instead of raising
FILE_KINDS = ("corrupt", "truncate", "drop")
KINDS = ("error", "fatal", "hang", "crash") + FILE_KINDS


class InjectedFault(RuntimeError):
    """Base of every injected failure."""


class TransientFault(InjectedFault):
    """Retryable failure: clears on a later attempt (rule.max_attempt)."""


class FatalFault(InjectedFault):
    """Non-retryable worker failure."""


class InjectedCrash(InjectedFault):
    """Simulated process death (the kill -9 analogue): supervision must
    NOT absorb it -- it propagates so crash-resume paths get exercised."""


def _tag(name: str) -> int:
    return zlib.crc32(name.encode())


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One injection rule: fire ``kind`` at ``site`` with probability
    ``p`` whenever the context predicates match. ``max_attempt`` bounds
    transience: the rule only fires while ``attempt <= max_attempt``,
    so retry loops clear it (a large value models a persistent fault).
    ``delay_s`` is the hang duration for ``kind="hang"``."""
    site: str
    kind: str
    p: float = 1.0
    epochs: Optional[Tuple[int, ...]] = None
    workers: Optional[Tuple[int, ...]] = None
    indices: Optional[Tuple[int, ...]] = None
    max_attempt: int = 0
    delay_s: float = 0.05

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r} "
                             f"(have {sorted(SITES)})")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(have {KINDS})")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"p={self.p} outside [0, 1]")
        for f in ("epochs", "workers", "indices"):
            v = getattr(self, f)
            if v is not None:
                object.__setattr__(self, f, tuple(int(x) for x in v))

    def matches(self, attempt: int, epoch: int, worker: int,
                index: int) -> bool:
        if attempt > self.max_attempt:
            return False
        if self.epochs is not None and epoch not in self.epochs:
            return False
        if self.workers is not None and worker not in self.workers:
            return False
        if self.indices is not None and index not in self.indices:
            return False
        return True


class FaultPlan:
    """Deterministic fault schedule + thread-safe fire counters."""

    def __init__(self, seed: int, rules: Sequence[FaultRule],
                 name: str = "custom"):
        self.seed = int(seed)
        self.rules = tuple(rules)
        self.name = name
        self._lock = threading.Lock()
        self._fired: Dict[Tuple[str, str], int] = {}

    def decide(self, site: str, attempt: int = 0, epoch: int = -1,
               worker: int = -1, index: int = -1) -> Optional[FaultRule]:
        """First matching rule that fires for this context, else None.
        The Bernoulli draw is keyed by the full (site, kind, rule,
        attempt, ctx) tuple -- pure function of the context, independent
        of call order."""
        for i, rule in enumerate(self.rules):
            if rule.site != site:
                continue
            if not rule.matches(attempt, epoch, worker, index):
                continue
            if rule.p < 1.0:
                u = rng_from(self.seed, FAULT_SALT, _tag(site),
                             _tag(rule.kind), i, attempt, epoch, worker,
                             index).random()
                if u >= rule.p:
                    continue
            with self._lock:
                k = (site, rule.kind)
                self._fired[k] = self._fired.get(k, 0) + 1
            return rule
        return None

    def fires(self, site: Optional[str] = None,
              kind: Optional[str] = None) -> int:
        with self._lock:
            return sum(n for (s, k), n in self._fired.items()
                       if (site is None or s == site)
                       and (kind is None or k == kind))

    def total_fires(self) -> int:
        return self.fires()

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {f"{s}:{k}": n for (s, k), n in
                    sorted(self._fired.items())}


# ---------------------------------------------------------------------------
# named profiles (the fault campaign / chaos axes)
# ---------------------------------------------------------------------------

#: one rule-set per named failure mode; keep device-backend and
#: host-backend profile names DISJOINT (apart from "none") so a fault
#: campaign never cross-pairs two differently-faulted backends.
PROFILES: Dict[str, Tuple[FaultRule, ...]] = {
    "none": (),
    # -- device runner sites ------------------------------------------------
    "stage-flaky": (FaultRule("stage", "error", epochs=(1,)),),
    "stage-dead": (FaultRule("stage", "error", epochs=(1,),
                             max_attempt=99),),
    "stage-deadline": (FaultRule("stage", "hang", epochs=(1,),
                                 delay_s=0.4),),
    "cache-loss": (FaultRule("stage_cache", "drop", epochs=(1,)),),
    "ckpt-crash": (FaultRule("checkpoint", "crash", epochs=(2,)),),
    "run-crash": (FaultRule("run_crash", "crash", epochs=(2,)),),
    # -- host-sim sites -----------------------------------------------------
    "pull-flaky": (FaultRule("pull", "error", epochs=(1,)),),
    "pull-dead": (FaultRule("pull", "error", max_attempt=99),),
    "prefetch-flaky": (FaultRule("prefetch", "error", epochs=(1,),
                                 indices=(0,)),),
    "prefetch-fatal": (FaultRule("prefetch", "fatal", epochs=(1,),
                                 indices=(0,)),),
    "prefetch-hang": (FaultRule("prefetch", "hang", epochs=(1,),
                                indices=(0,), delay_s=0.3),),
    "csec-loss": (FaultRule("csec", "error", epochs=(0,)),),
    "spill-rot": (FaultRule("spill_write", "corrupt", epochs=(1,)),),
    "spill-trunc": (FaultRule("spill_write", "truncate", epochs=(1,)),),
    "spill-gone": (FaultRule("spill_write", "drop", epochs=(1,)),),
    # -- online serving sites (repro.serve.gnn) -----------------------------
    # serve probes carry the request id in ``index`` and the warm
    # generation in ``epoch``, so rules can target specific requests /
    # warm cycles. Transient pull faults clear under the service's
    # retry budget; "dead" variants exhaust it (typed ServePullError /
    # stale-tier degradation).
    "serve-pull-flaky": (FaultRule("serve_pull", "error"),),
    "serve-pull-dead": (FaultRule("serve_pull", "error", indices=(1,),
                                  max_attempt=99),),
    "serve-warm-flaky": (FaultRule("serve_warm", "error"),),
    "serve-warm-dead": (FaultRule("serve_warm", "error", max_attempt=99),),
    "serve-warm-hang": (FaultRule("serve_warm", "hang", delay_s=0.05),),
    # persistent failure of warm GENERATION 2 only: generation 1
    # succeeds, so the service holds a last-good snapshot and must
    # degrade to the STALE tier (flagged responses) rather than fail
    "serve-warm-stale": (FaultRule("serve_warm", "error", epochs=(2,),
                                   max_attempt=99),),
    "serve-queue-shed": (FaultRule("serve_queue", "error", p=0.5),),
}


def plan_from_profile(name: str, seed: int = 0) -> FaultPlan:
    if name not in PROFILES:
        raise ValueError(f"unknown fault profile {name!r} "
                         f"(have {sorted(PROFILES)})")
    return FaultPlan(seed, PROFILES[name], name=name)


#: (site, kind) pool the chaos harness samples host-side plans from --
#: every entry is a fault the host runtime claims to tolerate (recover
#: bit-exactly) or to surface as a TYPED error.
CHAOS_POOL: Tuple[Tuple[str, str], ...] = (
    ("pull", "error"),
    ("prefetch", "error"),
    ("prefetch", "fatal"),
    ("prefetch", "hang"),
    ("csec", "error"),
    ("spill_write", "corrupt"),
    ("spill_write", "truncate"),
    ("spill_write", "drop"),
)


def random_plan(seed: int, i: int, num_epochs: int = 3) -> FaultPlan:
    """Chaos plan #i for ``seed``: 1-3 rules drawn from ``CHAOS_POOL``
    via the keyed stream, so plan #i is identical on every machine."""
    rng = rng_from(seed, FAULT_SALT, _tag("chaos-plan"), i)
    rules = []
    for _ in range(int(rng.integers(1, 4))):
        site, kind = CHAOS_POOL[int(rng.integers(0, len(CHAOS_POOL)))]
        rules.append(FaultRule(
            site, kind,
            p=(0.5, 1.0)[int(rng.integers(0, 2))],
            epochs=(int(rng.integers(0, num_epochs)),),
            indices=(0,) if site == "prefetch" else None,
            max_attempt=int(rng.integers(0, 2)),
            delay_s=0.15))
    return FaultPlan(seed, rules, name=f"chaos-{i}")


#: (site, kind) pool for the SERVING chaos sweep. Kept SEPARATE from
#: the training ``CHAOS_POOL`` on purpose: mixing serve sites into the
#: training pool would dilute both sweeps' fault density, and a
#: training run never reaches a serve site (nor vice versa), so a
#: mixed plan wastes half its rules. "hang" doubles as the
#: deadline-pressure generator.
SERVE_CHAOS_POOL: Tuple[Tuple[str, str], ...] = (
    ("serve_pull", "error"),
    ("serve_warm", "error"),
    ("serve_warm", "hang"),
    ("serve_queue", "error"),
)


def random_serve_plan(seed: int, i: int) -> FaultPlan:
    """Serving chaos plan #i: 1-3 rules from ``SERVE_CHAOS_POOL`` on an
    independent keyed stream (tag differs from ``random_plan``, so the
    two sweeps never correlate). Probability and transience vary; no
    epoch predicate -- serve probes carry the warm generation there,
    which the drawn plan should hit regardless of its value."""
    rng = rng_from(seed, FAULT_SALT, _tag("serve-chaos-plan"), i)
    rules = []
    for _ in range(int(rng.integers(1, 4))):
        site, kind = SERVE_CHAOS_POOL[
            int(rng.integers(0, len(SERVE_CHAOS_POOL)))]
        rules.append(FaultRule(
            site, kind,
            p=(0.5, 1.0)[int(rng.integers(0, 2))],
            max_attempt=int(rng.integers(0, 2)),
            delay_s=0.02))
    return FaultPlan(seed, rules, name=f"serve-chaos-{i}")
