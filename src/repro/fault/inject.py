"""Injection runtime: the process-wide active plan + ``fault_point``.

``fault_point(site, ...)`` is the probe the runtime calls at each named
fault site. With no plan active it is a no-op returning ``None`` (the
production path: one dict read under a lock). With a plan active, the
plan's keyed Bernoulli decides -- deterministically in the site context,
never in wall-clock or thread order -- whether to raise a typed fault,
sleep (hang), damage the file operand, or report an advisory loss.

File damage goes through plain ``open``/``os`` byte surgery on purpose:
npz-level IO is sanctioned only inside ``repro/core/schedule.py``
(SPILL-SAFETY), and a corruptor that understood the format would be
weaker than one that flips raw bytes anyway.
"""
from __future__ import annotations

import contextlib
import os
import threading
import time
from typing import Callable, Iterator, Optional, Tuple

from repro.fault.plan import (FILE_KINDS, FAULT_SALT, FatalFault,
                              FaultPlan, InjectedCrash, TransientFault,
                              _tag)
from repro.graph.sampler import rng_from

_lock = threading.Lock()
_active: Optional[FaultPlan] = None


def activate(plan: FaultPlan) -> None:
    global _active
    with _lock:
        _active = plan


def deactivate() -> None:
    global _active
    with _lock:
        _active = None


def current() -> Optional[FaultPlan]:
    with _lock:
        return _active


@contextlib.contextmanager
def active_plan(plan: Optional[FaultPlan]) -> Iterator[Optional[FaultPlan]]:
    """Scope a plan over a run; always deactivates, even on the typed
    errors the plan itself throws."""
    if plan is not None:
        activate(plan)
    try:
        yield plan
    finally:
        if plan is not None:
            deactivate()


def fault_point(site: str, path: Optional[str] = None, attempt: int = 0,
                epoch: int = -1, worker: int = -1,
                index: int = -1) -> Optional[str]:
    """The probe. Returns the fired kind for advisory/file faults, None
    when nothing fires; raises for error/fatal/crash kinds."""
    plan = current()
    if plan is None:
        return None
    rule = plan.decide(site, attempt=attempt, epoch=epoch, worker=worker,
                       index=index)
    if rule is None:
        return None
    ctx = (f"site={site} epoch={epoch} worker={worker} index={index} "
           f"attempt={attempt}")
    if rule.kind == "hang":
        time.sleep(rule.delay_s)
        return "hang"
    if rule.kind == "error":
        raise TransientFault(f"injected transient fault: {ctx}")
    if rule.kind == "fatal":
        raise FatalFault(f"injected fatal fault: {ctx}")
    if rule.kind == "crash":
        raise InjectedCrash(f"injected crash: {ctx}")
    # file kinds: damage the operand when there is one, else advisory
    # (e.g. the stage_cache site "drops" in-memory buffers by signalling
    # the owner, which rebuilds without them)
    if path is not None:
        _damage_file(path, rule.kind, plan.seed, epoch=epoch,
                     worker=worker)
    return rule.kind


def retry_call(fn: Callable[[int], object], retries: int,
               base_delay_s: float = 1e-3,
               retry_on: Tuple[type, ...] = (TransientFault,),
               on_retry: Optional[Callable[[int], None]] = None):
    """Bounded retry with exponential backoff: ``fn(attempt)`` is called
    with attempts 0..retries; the last failure propagates. ``on_retry``
    runs before each re-attempt (counter hooks)."""
    for a in range(retries + 1):
        try:
            return fn(a)
        except retry_on:
            if a >= retries:
                raise
            if on_retry is not None:
                on_retry(a)
            time.sleep(base_delay_s * (2 ** a))


def _damage_file(path: str, kind: str, seed: int, epoch: int = -1,
                 worker: int = -1) -> None:
    """Raw-byte spill damage: drop, halve, or flip one keyed byte."""
    assert kind in FILE_KINDS, kind
    if kind == "drop":
        if os.path.exists(path):
            os.remove(path)
        return
    size = os.path.getsize(path)
    if kind == "truncate":
        os.truncate(path, max(size // 2, 1))
        return
    # corrupt: flip one byte at a deterministic keyed offset, past the
    # zip local-file header so the archive still opens and the damage
    # lands in payload (caught by the per-array crc32, not the opener)
    lo = min(64, size - 1)
    off = int(rng_from(seed, FAULT_SALT, _tag("corrupt-offset"), epoch,
                       worker).integers(lo, size))
    with open(path, "r+b") as f:
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([b[0] ^ 0xFF]))
