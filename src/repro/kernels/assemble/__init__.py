from repro.kernels.assemble.ops import (assemble_features, local_merge,
                                        resolve_backend, BACKENDS)

__all__ = ["assemble_features", "local_merge", "resolve_backend",
           "BACKENDS"]
