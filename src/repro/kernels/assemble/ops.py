"""jit'd public wrapper for the fused feature-assembly kernel.

Three interchangeable backends, all bit-identical on the same inputs
(every output row is a copy of exactly one source row):

  * ``"fused"``  -- the Pallas single-pass kernel (TPU; ``interpret=True``
    runs it on CPU for validation).
  * ``"ref"``    -- the pure-jnp fused oracle (CPU default; one traced
    where-chain, no kernel).
  * ``"staged"`` -- the legacy three-stage chain (``cache_lookup`` then
    local-shard overlay), kept as the interpret-mode oracle the parity
    suite pins the fused kernel to.

``backend="auto"`` resolves to ``"fused"`` on TPU and ``"ref"``
elsewhere, so the epoch programs pick the right path per platform with
no caller changes.  ``cache_ids=None`` assembles cache-less (the
on-demand baseline): local shard over pulled residuals only.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.assemble.assemble import assemble as _kernel
from repro.kernels.assemble.ref import assemble_ref
from repro.kernels.cache_lookup.ops import cache_lookup

BACKENDS = ("auto", "fused", "ref", "staged")


def resolve_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(f"assemble backend {backend!r} not in {BACKENDS}")
    if backend == "auto":
        return "fused" if jax.default_backend() == "tpu" else "ref"
    return backend


def local_merge(table: jnp.ndarray, base, query: jnp.ndarray,
                fallback: jnp.ndarray) -> jnp.ndarray:
    """Overlay this worker's shard rows onto ``fallback`` where the
    queried device id is locally owned (slot in [0, n_per)); padding ids
    (-1) are never local. The final stage of the legacy chain."""
    n_per = table.shape[0]
    slot = query - base
    local = (slot >= 0) & (slot < n_per)
    rows = table[jnp.clip(slot, 0, n_per - 1)]
    return jnp.where(local[:, None], rows.astype(fallback.dtype), fallback)


def _staged(table, base, cache_ids, cache_feats, query, pulled,
            use_kernel, interpret):
    """The legacy three-stage chain: pulled -> C_s merge -> local
    overlay. Three (m, d) materializations; retained as the oracle."""
    if cache_ids is None:
        return local_merge(table, base, query, pulled)
    merged, _ = cache_lookup(cache_ids, cache_feats, query, pulled,
                             use_kernel=use_kernel, interpret=interpret)
    return local_merge(table, base, query, merged)


@partial(jax.jit, static_argnames=("backend", "interpret"))
def assemble_features(table: jax.Array, base, cache_ids: Optional[jax.Array],
                      cache_feats: Optional[jax.Array], query: jax.Array,
                      pulled: jax.Array, *, backend: str = "auto",
                      interpret: bool = False) -> jax.Array:
    """Single-pass per-step feature assembly (DESIGN.md §3, §6.3).

    table (n_per, d) this worker's shard; base scalar first device slot;
    cache_ids (n_hot,) sorted int32 / None; cache_feats (n_hot, d) /
    None; query (m,) int32 device ids (-1 padded); pulled (m, d) a2a
    residual buffer -> (m, d) assembled rows, priority local > C_s >
    pulled.
    """
    backend = resolve_backend(backend)
    if backend == "staged":
        return _staged(table, base, cache_ids, cache_feats, query, pulled,
                       use_kernel=interpret, interpret=interpret)
    if cache_ids is None:
        cache_ids = jnp.zeros((0,), jnp.int32)
        cache_feats = jnp.zeros((0,) + pulled.shape[1:], pulled.dtype)
    if backend == "fused":
        return _kernel(table, base, cache_ids.astype(jnp.int32),
                       cache_feats, query.astype(jnp.int32), pulled,
                       interpret=interpret)
    return assemble_ref(table, base, cache_ids, cache_feats, query, pulled)
