"""Pallas TPU kernel: fused single-pass feature assembly (DESIGN.md §3).

Replaces the legacy three-stage assembly chain of the device epoch
(``pull_shard`` scatter -> ``cache_lookup.search`` -> ``merge_gather`` ->
jnp local-shard overlay) with ONE kernel pass per ``(m, d)`` tile.

Two phases, one output materialization:

  1. *classify* (metadata, (m,)-shaped): the tiled VPU mask-sum binary
     search over the sorted hot-set ids (``cache_lookup.search``, shared
     -- it is already dense vector work) plus the arithmetic ownership
     test ``base <= q < base + n_per``, folded into three scalar-prefetch
     vectors: per-row source selector (pulled / cache / local) and the
     two gather indices (cache row, shard slot).
  2. *select* -- a single ``pl.pallas_call`` over grid ``(m, d/dt)``
     whose BlockSpec index maps gather the cache row, the local-shard
     row and the pulled row for each query, and whose body writes the
     winning row ONCE.  The legacy chain materialized three full
     ``(m, d)`` buffers (merge_gather output, the local-shard gather,
     the final where); this path writes exactly one.

Feature dims not divisible by the tile pad internally (zeros, sliced off
the output) -- arbitrary ``m`` / ``n_hot`` / ``d`` are accepted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.cache_lookup.cache_lookup import SENTINEL, pad_to, search

#: per-row source selector values (scalar-prefetched into the kernel)
SRC_PULLED, SRC_CACHE, SRC_LOCAL = 0, 1, 2

DEFAULT_D_TILE = 128


def classify(cache_ids: jax.Array, query: jax.Array, base, n_per: int,
             interpret: bool = False):
    """-> (src (m,) int32 selector, cpos (m,) cache row, lslot (m,) shard
    slot); gather indices are clamped in-range so padding rows stay
    addressable (their selector never picks the clamped source)."""
    n_hot = cache_ids.shape[0]
    pos, hit = search(cache_ids, query, interpret=interpret)
    slot = query - base
    local = (slot >= 0) & (slot < n_per)
    src = jnp.where(local, SRC_LOCAL,
                    jnp.where(hit, SRC_CACHE, SRC_PULLED)).astype(jnp.int32)
    cpos = jnp.minimum(pos, max(n_hot - 1, 0)).astype(jnp.int32)
    lslot = jnp.clip(slot, 0, n_per - 1).astype(jnp.int32)
    return src, cpos, lslot


def _select_kernel(src, cpos, lslot, cache_ref, table_ref, pulled_ref,
                   o_ref):
    i = pl.program_id(0)
    s = src[i]
    row = jnp.where(
        s == SRC_LOCAL, table_ref[...].astype(o_ref.dtype),
        jnp.where(s == SRC_CACHE, cache_ref[...].astype(o_ref.dtype),
                  pulled_ref[...]))
    o_ref[...] = row


def assemble(table: jax.Array, base, cache_ids: jax.Array,
             cache_feats: jax.Array, query: jax.Array, pulled: jax.Array,
             d_tile: int = DEFAULT_D_TILE,
             interpret: bool = False) -> jax.Array:
    """Fused assembly: table (n_per, d); base scalar; cache_ids (n_hot,)
    sorted int32; cache_feats (n_hot, d); query (m,) int32; pulled (m, d)
    -> (m, d)."""
    n_per = table.shape[0]
    m, d0 = pulled.shape
    if cache_feats.shape[0] == 0:
        # sentinel row: the selector can never pick it (no hits), but the
        # BlockSpec index map needs an addressable row 0
        cache_ids = jnp.full((1,), SENTINEL, jnp.int32)
        cache_feats = jnp.zeros((1, d0), cache_feats.dtype)
    src, cpos, lslot = classify(cache_ids, query, base, n_per,
                                interpret=interpret)

    dt = min(d0, d_tile)
    if d0 % dt:
        cache_feats = pad_to(cache_feats, dt, 1, 0)
        table = pad_to(table, dt, 1, 0)
        pulled = pad_to(pulled, dt, 1, 0)
    d = pulled.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,          # src, cpos, lslot
        grid=(m, d // dt),
        in_specs=[
            pl.BlockSpec((1, dt), lambda i, k, s, p, l: (p[i], k)),
            pl.BlockSpec((1, dt), lambda i, k, s, p, l: (l[i], k)),
            pl.BlockSpec((1, dt), lambda i, k, s, p, l: (i, k)),
        ],
        out_specs=pl.BlockSpec((1, dt), lambda i, k, s, p, l: (i, k)),
    )
    out = pl.pallas_call(
        _select_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, d), pulled.dtype),
        interpret=interpret,
    )(src, cpos, lslot, cache_feats, table, pulled)
    return out[:, :d0]
