"""Pure-jnp oracle for the fused single-pass feature assembly.

Semantics of one assembled row (priority order, identical to the legacy
three-stage chain ``pull_shard -> cache_lookup -> local merge``):

  1. LOCAL   -- the queried device id falls in this worker's shard
                (``base <= q < base + n_per``): serve ``table[q - base]``.
  2. CACHED  -- the id binary-searches into the sorted hot set C_s:
                serve ``cache_feats[pos]``.
  3. PULLED  -- otherwise keep the pre-scattered all_to_all residual row
                (``pulled[i]``; zeros for padding ids).

Padding ids (-1) are never local (slot < 0) and never hit (cache ids are
non-negative or the INT32_MAX sentinel), so they keep their zero pulled
row -- exactly the legacy behaviour.
"""
from __future__ import annotations

import jax.numpy as jnp


def assemble_ref(table: jnp.ndarray, base, cache_ids: jnp.ndarray,
                 cache_feats: jnp.ndarray, query: jnp.ndarray,
                 pulled: jnp.ndarray) -> jnp.ndarray:
    """table (n_per, d); base scalar first slot; cache_ids (n_hot,)
    sorted int32; cache_feats (n_hot, d); query (m,) int32 (-1 padded);
    pulled (m, d) -> (m, d) assembled features."""
    n_per = table.shape[0]
    slot = query - base
    local = (slot >= 0) & (slot < n_per)
    rows_local = table[jnp.clip(slot, 0, n_per - 1)]
    n_hot = cache_ids.shape[0]
    if n_hot == 0:
        return jnp.where(local[:, None], rows_local.astype(pulled.dtype),
                         pulled)
    pos = jnp.searchsorted(cache_ids, query)
    pos_c = jnp.minimum(pos, n_hot - 1)
    hit = ((cache_ids[pos_c] == query) & (query >= 0)
           & (query != 2 ** 31 - 1))   # sentinel queries never hit
    rows_cache = cache_feats[pos_c]
    return jnp.where(
        local[:, None], rows_local.astype(pulled.dtype),
        jnp.where(hit[:, None], rows_cache.astype(pulled.dtype), pulled))
