"""Pallas TPU kernel: fused neighbor gather + masked mean aggregation.

This is the paper's hot loop (the AGG of Eq. 1) adapted to the TPU memory
hierarchy (DESIGN.md §2): instead of the GPU scatter-add idiom, we exploit
the deterministic sampler's fan-out-regular, dst-major edge layout --
every dst node owns exactly ``fanout`` contiguous edges -- so aggregation
is a sequence of VMEM-resident row accumulations with NO atomics and no
scatter.

Blocking: grid = (nd, fanout, d_tiles). The source row h[edge_src[e]] is
brought HBM->VMEM per grid step through a SCALAR-PREFETCHED BlockSpec
index map (pltpu.PrefetchScalarGridSpec) -- the TPU-native way to express
a data-dependent gather. The output block (1, dt) is revisited across the
fanout dimension (sequential TPU grid guarantees ordering): j==0 zeroes
the accumulator, j==fanout-1 divides by the valid-neighbor count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_D_TILE = 128


def _kernel(edge_src, edge_mask, cnt, h_ref, o_ref, *, fanout):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    i = pl.program_id(0)
    m = edge_mask[i * fanout + j].astype(h_ref.dtype)
    o_ref[...] += h_ref[...] * m

    @pl.when(j == fanout - 1)
    def _finish():
        c = jnp.maximum(cnt[i].astype(o_ref.dtype), 1.0)
        o_ref[...] = o_ref[...] / c


def gather_agg(h: jax.Array, edge_src: jax.Array, edge_mask: jax.Array,
               nd: int, fanout: int, d_tile: int = DEFAULT_D_TILE,
               interpret: bool = False) -> jax.Array:
    """h (m, d); edge_src/mask (nd*fanout,) dst-major -> (nd, d).

    A feature dim not divisible by ``d_tile`` pads internally (zeros,
    sliced off the output) instead of asserting, so arbitrary hidden
    sizes work.
    """
    from repro.kernels.cache_lookup.cache_lookup import pad_to

    m_nodes, d0 = h.shape
    dt = min(d0, d_tile)
    if d0 % dt:
        h = pad_to(h, dt, 1, 0)
    d = h.shape[1]
    grid = (nd, fanout, d // dt)

    cnt = jnp.sum(edge_mask.reshape(nd, fanout).astype(jnp.float32), axis=1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,      # edge_src, edge_mask, cnt
        grid=grid,
        in_specs=[
            pl.BlockSpec(           # one source row of h per grid step
                (1, dt),
                lambda i, j, k, es, em, c: (es[i * fanout + j], k)),
        ],
        out_specs=pl.BlockSpec((1, dt), lambda i, j, k, es, em, c: (i, k)),
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, fanout=fanout),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nd, d), h.dtype),
        interpret=interpret,
    )
    return fn(edge_src.astype(jnp.int32), edge_mask, cnt, h)[:, :d0]
