"""jit'd public wrapper for the gather_agg kernel.

On CPU (or when ``use_kernel=False``) dispatches to the jnp oracle; on TPU
it runs the Pallas kernel. ``interpret=True`` executes the kernel body in
Python on CPU -- the validation mode the tests sweep.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.gather_agg.gather_agg import gather_agg as _kernel_call
from repro.kernels.gather_agg.ref import gather_agg_ref


@partial(jax.jit, static_argnames=("nd", "fanout", "use_kernel",
                                   "interpret"))
def gather_agg(h: jax.Array, edge_src: jax.Array, edge_mask: jax.Array,
               *, nd: int, fanout: int, use_kernel: bool = False,
               interpret: bool = False) -> jax.Array:
    if use_kernel:
        return _kernel_call(h, edge_src, edge_mask, nd, fanout,
                            interpret=interpret)
    return gather_agg_ref(h, edge_src, edge_mask, nd, fanout)
