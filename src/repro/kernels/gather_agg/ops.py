"""jit'd public wrapper for the gather_agg kernel.

On CPU (or when ``use_kernel=False``) dispatches to the jnp oracle; on TPU
it runs the Pallas kernel. ``interpret=True`` executes the kernel body in
Python on CPU -- the validation mode the tests sweep.

The kernel path carries a custom VJP so it can sit inside ``loss_fn``
grads (the GNN forward's backend switch): the backward of the masked
neighbor mean is a plain scatter-add over ``edge_src`` --
``dh[src_e] += g[e // fanout] * mask_e / cnt[e // fanout]`` -- expressed
as one ``segment_sum``, which XLA already emits well; only the forward
gather+accumulate benefits from the fused VMEM kernel. The integer edge
operands get float0 cotangents (they carry no gradient).
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.gather_agg.gather_agg import gather_agg as _kernel_call
from repro.kernels.gather_agg.ref import gather_agg_ref


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _kernel_with_vjp(h, edge_src, edge_mask, nd, fanout, interpret):
    return _kernel_call(h, edge_src, edge_mask, nd, fanout,
                        interpret=interpret)


def _kernel_fwd(h, edge_src, edge_mask, nd, fanout, interpret):
    out = _kernel_call(h, edge_src, edge_mask, nd, fanout,
                       interpret=interpret)
    return out, (h.shape[0], edge_src, edge_mask)


def _kernel_bwd(nd, fanout, interpret, res, g):
    m, edge_src, edge_mask = res
    msk = edge_mask.reshape(nd, fanout)
    cnt = jnp.maximum(msk.sum(axis=1).astype(g.dtype), 1.0)
    ge = jnp.repeat(g / cnt[:, None], fanout, axis=0)       # (nd*fo, d)
    msg = ge * edge_mask[:, None].astype(g.dtype)
    dh = jax.ops.segment_sum(msg, edge_src, num_segments=m)
    f0 = jax.dtypes.float0
    return (dh, np.zeros(edge_src.shape, f0),
            np.zeros(edge_mask.shape, f0))


_kernel_with_vjp.defvjp(_kernel_fwd, _kernel_bwd)


@partial(jax.jit, static_argnames=("nd", "fanout", "use_kernel",
                                   "interpret"))
def gather_agg(h: jax.Array, edge_src: jax.Array, edge_mask: jax.Array,
               *, nd: int, fanout: int, use_kernel: bool = False,
               interpret: bool = False) -> jax.Array:
    if use_kernel:
        return _kernel_with_vjp(h, edge_src, edge_mask, nd, fanout,
                                interpret)
    return gather_agg_ref(h, edge_src, edge_mask, nd, fanout)
