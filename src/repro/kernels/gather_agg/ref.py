"""Pure-jnp oracle for the fused gather + neighbor-mean aggregation.

Fan-out-regular layout (the deterministic sampler's invariant): edges are
dst-major, exactly ``fanout`` edges per dst node, so
``edge_src.reshape(nd, fanout)`` and no scatter is ever needed.
"""
from __future__ import annotations

import jax.numpy as jnp


def gather_agg_ref(h: jnp.ndarray, edge_src: jnp.ndarray,
                   edge_mask: jnp.ndarray, nd: int,
                   fanout: int) -> jnp.ndarray:
    """h (m, d); edge_src/mask (nd*fanout,) dst-major -> (nd, d) mean."""
    src = edge_src.reshape(nd, fanout)
    msk = edge_mask.reshape(nd, fanout).astype(h.dtype)
    gathered = h[src] * msk[..., None]            # (nd, fanout, d)
    s = gathered.sum(axis=1)
    cnt = jnp.maximum(msk.sum(axis=1), 1.0)
    return s / cnt[:, None]
