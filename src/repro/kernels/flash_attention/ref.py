"""Pure-jnp oracle for the training/prefill flash-attention kernel:
the chunked online-softmax attention from the model substrate (itself
validated against naive attention in tests/test_transformer_units.py)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.transformer.attention import attention


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0) -> jnp.ndarray:
    """q (B,S,H,dh); k/v (B,S,kvH,dh) -> (B,S,H,dh)."""
    return attention(q, k, v, causal=causal, window=window,
                     attn_softcap=softcap)
