"""jit'd public wrapper for the training flash-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.flash_attention import (
    flash_attention as _kernel)
from repro.kernels.flash_attention.ref import flash_attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                   "use_kernel", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, use_kernel: bool = False,
                    interpret: bool = False) -> jax.Array:
    if use_kernel:
        return _kernel(q, k, v, causal=causal, window=window,
                       softcap=softcap, interpret=interpret)
    return flash_attention_ref(q, k, v, causal=causal, window=window,
                               softcap=softcap)
