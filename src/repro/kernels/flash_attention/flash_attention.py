"""Pallas TPU kernel: causal flash-attention forward (training/prefill).

The compute hot spot of 7/10 assigned architectures. Online-softmax
accumulation over KV tiles with VMEM-resident (m, l, acc) scratch; GQA is
handled by blocking per kv-head with the whole q-head group in one block
(q block (1, Tq, G, dh) x kv block (Tk, 1, dh) -> MXU-shaped
(G*Tq, Tk) score tiles). Causal masking is positional per tile; gemma2's
attention softcap is fused. VMEM footprint per grid step:
Tq*G*dh + 2*Tk*dh + G*Tq*(dh+2) floats -- tiles chosen so this sits well
under 16 MB with MXU-aligned (128-multiple) dims.

Grid: (B, kvH, nq, nk), KV innermost (sequential accumulation; output
block revisited across nk, same pattern the TPU guarantees in-order).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_TQ = 256
DEFAULT_TK = 512


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            tq: int, tk: int, nk: int, scale: float, softcap: float,
            causal: bool, window: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, :, 0].astype(jnp.float32)      # (Tq, G, dh)
    Tq, G, dh = q.shape
    k = k_ref[0, :, 0].astype(jnp.float32)      # (Tk, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jnp.einsum("qgd,kd->qgk", q * scale, k)  # (Tq, G, Tk)
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap

    qpos = qi * tq + jax.lax.broadcasted_iota(jnp.int32, (Tq, G, tk), 0)
    kpos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (Tq, G, tk), 2)
    valid = jnp.ones((Tq, G, tk), jnp.bool_)
    if causal:
        valid &= kpos <= qpos
    if window > 0:
        valid &= kpos > qpos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_s[...]                           # (Tq, G, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    p = jnp.where(valid, p, 0.0)
    l_s[...] = l_s[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_s[...] = (acc_s[...] * alpha
                  + jnp.einsum("qgk,kd->qgd", p, v))
    m_s[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, :, 0] = (acc_s[...] /
                          jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: Optional[float] = None,
                    tq: int = DEFAULT_TQ, tk: int = DEFAULT_TK,
                    interpret: bool = False) -> jax.Array:
    """q (B,S,H,dh); k/v (B,S,kvH,dh) -> (B,S,H,dh)."""
    B, S, H, dh = q.shape
    kvH = k.shape[2]
    G = H // kvH
    scale = scale if scale is not None else dh ** -0.5
    tq = min(tq, S)
    tk = min(tk, S)
    assert S % tq == 0 and S % tk == 0
    nq, nk = S // tq, S // tk
    qg = q.reshape(B, S, kvH, G, dh)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(B, kvH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, tq, 1, G, dh),
                         lambda b, h, qi, ki: (b, qi, h, 0, 0)),
            pl.BlockSpec((1, tk, 1, dh),
                         lambda b, h, qi, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, tk, 1, dh),
                         lambda b, h, qi, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, 1, G, dh),
                               lambda b, h, qi, ki: (b, qi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((tq, G, 1), jnp.float32),
            pltpu.VMEM((tq, G, 1), jnp.float32),
            pltpu.VMEM((tq, G, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, tq=tq, tk=tk, nk=nk, scale=scale,
                          softcap=softcap, causal=causal, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, kvH, G, dh), q.dtype),
        interpret=interpret,
    )(qg, k, v)
    return out.reshape(B, S, H, dh)
