"""Pallas TPU kernel: single-token flash-decode attention over a KV tile.

Serving hot spot for decode_32k / long_500k: one query token attends to a
(possibly sequence-sharded) KV cache. Online-softmax accumulation over
S-tiles keeps VMEM usage at  O(T_s * dh)  per kv head regardless of cache
length; the kernel emits UNNORMALIZED (acc, m, l) partials so the serving
layer can psum-combine across a `model`-axis sequence-sharded cache
(repro/serve/attention.py) -- that combine is what makes 500k-token caches
fit a v5e (DESIGN.md §5).

Grid: (kvH, S // T_s); the full query head-group for a kv head lives in
one block. Scratch carries (m, l, acc) across the sequence tiles; softcap
(gemma2) and sliding-window start offsets are supported via scalars.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_TS = 512


def _kernel(meta, q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
            m_s, l_s, acc_s, *, ts: int, scale: float, softcap: float,
            num_tiles: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32)          # (G, dh)
    k = k_ref[:, 0].astype(jnp.float32)       # (Ts, dh)
    v = v_ref[:, 0].astype(jnp.float32)       # (Ts, dh)

    s = (q * scale) @ k.T                     # (G, Ts)
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    pos = t * ts + jax.lax.broadcasted_iota(jnp.int32, (1, ts), 1)
    valid = (pos < meta[0]) & (pos >= meta[1])
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_s[...]                         # (G, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    p = jnp.where(valid, p, 0.0)
    l_s[...] = l_s[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + p @ v
    m_s[...] = m_cur

    @pl.when(t == num_tiles - 1)
    def _finish():
        acc_ref[0] = acc_s[...].astype(acc_ref.dtype)
        m_ref[0] = m_s[..., 0].astype(m_ref.dtype)
        l_ref[0] = l_s[..., 0].astype(l_ref.dtype)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 length: jax.Array, start: jax.Array | None = None,
                 scale: float | None = None, softcap: float = 0.0,
                 ts: int = DEFAULT_TS, interpret: bool = False
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """q (H, dh); k/v (S, kvH, dh) -> (acc (H, dh), m (H,), l (H,))."""
    H, dh = q.shape
    S, kvH, _ = k.shape
    group = H // kvH
    ts = min(ts, S)
    assert S % ts == 0, (S, ts)
    scale = scale if scale is not None else dh ** -0.5
    num_tiles = S // ts

    meta = jnp.stack([length.astype(jnp.int32),
                      (start if start is not None
                       else jnp.zeros((), jnp.int32)).astype(jnp.int32)])
    qg = q.reshape(kvH, group, dh)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,        # meta = [length, start]
        grid=(kvH, num_tiles),
        in_specs=[
            pl.BlockSpec((1, group, dh), lambda h, t, meta: (h, 0, 0)),
            pl.BlockSpec((ts, 1, dh), lambda h, t, meta: (t, h, 0)),
            pl.BlockSpec((ts, 1, dh), lambda h, t, meta: (t, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, group, dh), lambda h, t, meta: (h, 0, 0)),
            pl.BlockSpec((1, group), lambda h, t, meta: (h, 0)),
            pl.BlockSpec((1, group), lambda h, t, meta: (h, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, dh), jnp.float32),
        ],
    )
    acc, m, l = pl.pallas_call(
        functools.partial(_kernel, ts=ts, scale=scale, softcap=softcap,
                          num_tiles=num_tiles),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((kvH, group, dh), jnp.float32),
                   jax.ShapeDtypeStruct((kvH, group), jnp.float32),
                   jax.ShapeDtypeStruct((kvH, group), jnp.float32)],
        interpret=interpret,
    )(meta, qg, k, v)
    return acc.reshape(H, dh), m.reshape(H), l.reshape(H)
