"""jit'd public wrapper for flash_decode (+ batch vmap + shard combine)."""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.flash_decode import flash_decode as _kernel
from repro.kernels.flash_decode.ref import (flash_decode_ref, finalize,
                                            combine)


@partial(jax.jit, static_argnames=("scale", "softcap", "use_kernel",
                                   "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 length: jax.Array, start: jax.Array | None = None, *,
                 scale: float | None = None, softcap: float = 0.0,
                 use_kernel: bool = False, interpret: bool = False
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-element partials; see ref.py for the (acc, m, l) contract."""
    if use_kernel:
        return _kernel(q, k, v, length, start=start, scale=scale,
                       softcap=softcap, interpret=interpret)
    return flash_decode_ref(q, k, v, length, scale=scale, softcap=softcap,
                            start=start)


@partial(jax.jit, static_argnames=("scale", "softcap", "use_kernel",
                                   "interpret"))
def flash_decode_batched(q: jax.Array, k: jax.Array, v: jax.Array,
                         length: jax.Array, start: jax.Array | None = None,
                         *, scale: float | None = None, softcap: float = 0.0,
                         use_kernel: bool = False,
                         interpret: bool = False) -> jax.Array:
    """q (B, H, dh); k/v (B, S, kvH, dh); length/start (B,) -> (B, H, dh)."""
    fn = partial(flash_decode, scale=scale, softcap=softcap,
                 use_kernel=use_kernel, interpret=interpret)
    if start is None:
        acc, m, l = jax.vmap(lambda qq, kk, vv, ln: fn(qq, kk, vv, ln))(
            q, k, v, length)
    else:
        acc, m, l = jax.vmap(fn)(q, k, v, length, start)
    return jax.vmap(finalize)(acc, l)


__all__ = ["flash_decode", "flash_decode_batched", "finalize", "combine"]
