"""Pure-jnp oracle for single-token flash-decode attention.

Returns UNNORMALIZED partials (acc, m, l) so shard-level results can be
combined across a sequence-sharded KV cache:
  acc = sum_s exp(q.k_s - m) v_s,   l = sum_s exp(q.k_s - m),
  m   = max_s q.k_s  (masked positions excluded).
Final output = acc / l. GQA: q head h reads kv head h // (H // kvH).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

NEG_INF = -1e30


def flash_decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     length: jnp.ndarray, scale: float | None = None,
                     softcap: float = 0.0,
                     start: jnp.ndarray | None = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """q (H, dh); k/v (S, kvH, dh); length scalar = #valid positions;
    start scalar = first valid position (sliding window) -> (acc, m, l)."""
    H, dh = q.shape
    S, kvH, _ = k.shape
    group = H // kvH
    scale = scale if scale is not None else dh ** -0.5
    kk = jnp.repeat(k, group, axis=1)      # (S, H, dh)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("hd,shd->sh", q * scale, kk.astype(q.dtype))
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    pos = jnp.arange(S)
    valid = pos < length
    if start is not None:
        valid &= pos >= start
    s = jnp.where(valid[:, None], s, NEG_INF)
    m = jnp.max(s, axis=0)                               # (H,)
    p = jnp.exp(s - m[None, :])
    p = jnp.where(valid[:, None], p, 0.0)
    l = jnp.sum(p, axis=0)                               # (H,)
    acc = jnp.einsum("sh,shd->hd", p, vv.astype(p.dtype))
    return acc, m, l


def finalize(acc: jnp.ndarray, l: jnp.ndarray) -> jnp.ndarray:
    return acc / jnp.maximum(l, 1e-30)[:, None]


def combine(parts):
    """Combine per-shard (acc, m, l) partials -> (acc, m, l) global."""
    accs, ms, ls = zip(*parts)
    m_g = jnp.max(jnp.stack(ms), axis=0)
    acc_g = sum(a * jnp.exp(m - m_g)[:, None] for a, m in zip(accs, ms))
    l_g = sum(l * jnp.exp(m - m_g) for l, m in zip(ls, ms))
    return acc_g, m_g, l_g
