from repro.kernels.seg_sort.ops import (SEG_SORT_BACKENDS, seg_sort,
                                        resolve_backend)

__all__ = ["SEG_SORT_BACKENDS", "seg_sort", "resolve_backend"]
