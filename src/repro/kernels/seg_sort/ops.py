"""Public wrapper for the segmented-key sort backends.

Two interchangeable backends, bit-identical on the same inputs:

  * ``"radix"`` -- the Pallas LSD radix kernel (TPU; ``interpret=True``
    runs it anywhere for validation). Whole-vector VMEM residency, so
    inputs past ``MAX_VMEM_N`` lanes fall back to ``"ref"``.
  * ``"ref"``   -- ``jax.lax.sort`` (stable), the oracle the kernel's
    parity suite pins and the CPU/GPU default.

``backend="auto"`` resolves to ``"radix"`` on TPU and ``"ref"``
elsewhere, mirroring the assemble/cache_lookup convention, so the
device schedule compiler picks the right path per platform with no
caller changes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels.seg_sort.ref import seg_sort_ref
from repro.kernels.seg_sort.seg_sort import MAX_VMEM_N, radix_sort

SEG_SORT_BACKENDS = ("auto", "radix", "ref")


def resolve_backend(backend: str, n: int = 0) -> str:
    if backend not in SEG_SORT_BACKENDS:
        raise ValueError(f"seg_sort backend {backend!r} not in "
                         f"{SEG_SORT_BACKENDS}")
    if backend == "auto":
        backend = "radix" if jax.default_backend() == "tpu" else "ref"
    if backend == "radix" and n > MAX_VMEM_N:
        return "ref"            # key stream outgrew VMEM residency
    return backend


def seg_sort(keys: jax.Array, payload: Optional[jax.Array] = None, *,
             num_bits: int = 31, backend: str = "auto",
             interpret: bool = False
             ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Stable ascending sort of non-negative int32 composite keys
    (optional int32 payload permuted along). Sentinel-padded (INT32_MAX)
    tails sort last under both backends."""
    resolved = resolve_backend(backend, keys.shape[0])
    if resolved == "radix":
        return radix_sort(keys, payload, num_bits=num_bits,
                          interpret=interpret)
    return seg_sort_ref(keys, payload)
