"""jnp oracle for the segmented-key radix sort: ``jax.lax.sort``.

The device schedule compiler sorts composite ``(batch, id)`` keys, so a
single GLOBAL sort acts per batch (keys never cross segment boundaries
-- the same trick the numpy compiler plays with ``np.unique``). Keys are
int32, non-negative, padded with the INT32_MAX sentinel so padding sorts
after every real key. ``is_stable=True`` keeps equal keys (only the
sentinel pad tail, plus any payload-carrying duplicates) in input order,
matching the radix kernel's LSD stability.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def seg_sort_ref(keys: jax.Array, payload: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Sort int32 ``keys`` ascending; permute ``payload`` along with
    them (stable). Returns ``(sorted_keys, sorted_payload_or_None)``."""
    if payload is None:
        return jax.lax.sort(keys, is_stable=True), None
    ks, ps = jax.lax.sort((keys, payload), num_keys=1, is_stable=True)
    return ks, ps
