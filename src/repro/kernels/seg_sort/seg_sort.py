"""Pallas TPU kernel: stable LSD radix sort for int32 composite keys.

The device schedule compiler (DESIGN.md §2.2) is sort-bound exactly
where the numpy compiler was: one whole-epoch sort of ``(batch, id)``
composite keys per sampler layer. On the int32 key path those keys live
in a known space ``[0, nb * span)``, so a least-significant-digit radix
sort needs only ``ceil(log2(nb * span) / RADIX_BITS)`` passes over VMEM
instead of a comparison sort's ``log2(n)`` -- the same radix-beats-
comparison argument ``KEY_INT32_MAX_SLOTS`` encodes on the host.

Layout: one grid step, the whole key (and optional payload) vector
resident in VMEM -- epoch key streams are a few MB (≤ ``MAX_VMEM_N``
int32 lanes), far under the ~16 MB/core budget. Each pass:

  1. digit extraction  ``(k >> shift) & (RADIX - 1)``,
  2. per-digit counts + exclusive prefix (the 16-way base offsets),
  3. stable within-digit ranks via one masked cumsum per digit value,
  4. reorder through a ``fori_loop`` of dynamic single-element stores
     (``out_ref[pl.ds(pos, 1)]``  -- the supported dynamic-store form).

Stability makes the sentinel pad tail (INT32_MAX, truncated to all-ones
in every digit) stay behind real keys even when the key space is a
power of two, and makes payload order deterministic under duplicate
keys -- both load-bearing for the compiler's bit-parity contract.

Host-side ``num_bits`` is STATIC (derived from the key-space bound), so
pass count never depends on data.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

RADIX_BITS = 4
RADIX = 1 << RADIX_BITS

#: whole-vector VMEM residency bound (int32 lanes): keys + payload +
#: double buffer + positions ≈ 5 * 4 B * n must sit under ~16 MB/core.
MAX_VMEM_N = 1 << 19


def _radix_pass_kernel(k_ref, p_ref, ok_ref, op_ref, *, shift: int):
    k = k_ref[...]
    p = p_ref[...]
    n = k.shape[0]
    digit = jax.lax.shift_right_logical(k, shift) & (RADIX - 1)

    # stable destination: base offset of the digit class + rank among
    # equal digits before this element (one masked cumsum per class)
    pos = jnp.zeros((n,), jnp.int32)
    base = jnp.int32(0)
    for d in range(RADIX):
        m = digit == d
        mi = m.astype(jnp.int32)
        within = jnp.cumsum(mi) - 1
        pos = jnp.where(m, base + within, pos)
        base = base + jnp.sum(mi)

    def body(i, _):
        dst = jax.lax.dynamic_index_in_dim(pos, i, keepdims=False)
        ok_ref[pl.ds(dst, 1)] = jax.lax.dynamic_slice_in_dim(k, i, 1)
        op_ref[pl.ds(dst, 1)] = jax.lax.dynamic_slice_in_dim(p, i, 1)
        return 0

    jax.lax.fori_loop(0, n, body, 0)


@partial(jax.jit, static_argnames=("num_bits", "interpret"))
def radix_sort(keys: jax.Array, payload: Optional[jax.Array] = None, *,
               num_bits: int = 31, interpret: bool = False
               ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Stable ascending sort of non-negative int32 ``keys`` (and an
    optional int32 ``payload`` riding along). ``num_bits`` bounds the
    key space (sentinel-padded tails sort last by LSD stability even
    truncated to ``num_bits``)."""
    had_payload = payload is not None
    if payload is None:
        payload = jnp.zeros_like(keys)
    n = keys.shape[0]
    if n == 0:
        return keys, payload if had_payload else None
    passes = -(-max(num_bits, 1) // RADIX_BITS)
    for p_i in range(passes):
        keys, payload = pl.pallas_call(
            partial(_radix_pass_kernel, shift=p_i * RADIX_BITS),
            out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                       jax.ShapeDtypeStruct((n,), jnp.int32)],
            interpret=interpret,
        )(keys.astype(jnp.int32), payload.astype(jnp.int32))
    return keys, payload if had_payload else None
