"""Pallas TPU kernels for the device-resident steady cache C_s.

Two fused stages (DESIGN.md §3 kernels):

  1. ``search``  -- positions of queries in the SORTED cache-id vector.
     TPU adaptation: instead of a per-lane binary search (serial, gather-
     heavy), each (Tq x Tc) tile computes comparison-mask partial sums on
     the VPU:  pos(q) = #&#123;ids < q&#125;,  hit(q) = any(ids == q).  The cache-id
     vector streams through VMEM in Tc-sized tiles, so n_hot is unbounded
     by VMEM and every op is dense vector work (MXU/VPU aligned).
  2. ``merge_gather`` -- one cached feature row per grid step, selected by
     a scalar-prefetched BlockSpec index map, merged over the pre-filled
     base buffer (hits win, misses keep the SyncPull value).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_TQ = 256
DEFAULT_TC = 1024


def _search_kernel(q_ref, ids_ref, pos_ref, hit_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        pos_ref[...] = jnp.zeros_like(pos_ref)
        hit_ref[...] = jnp.zeros_like(hit_ref)

    q = q_ref[...]                     # (Tq,)
    ids = ids_ref[...]                 # (Tc,)
    lt = (ids[None, :] < q[:, None])
    eq = (ids[None, :] == q[:, None])
    pos_ref[...] += lt.sum(axis=1).astype(jnp.int32)
    hit_ref[...] |= eq.any(axis=1)


def search(cache_ids: jax.Array, query: jax.Array, tq: int = DEFAULT_TQ,
           tc: int = DEFAULT_TC, interpret: bool = False
           ) -> Tuple[jax.Array, jax.Array]:
    """cache_ids (n_hot,) sorted int32; query (m,) int32 -> (pos, hit)."""
    m = query.shape[0]
    n_hot = cache_ids.shape[0]
    tq = min(tq, m)
    tc = min(tc, n_hot)
    assert m % tq == 0 and n_hot % tc == 0, (m, tq, n_hot, tc)
    grid = (m // tq, n_hot // tc)
    pos, hit = pl.pallas_call(
        _search_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tq,), lambda i, j: (i,)),
                  pl.BlockSpec((tc,), lambda i, j: (j,))],
        out_specs=[pl.BlockSpec((tq,), lambda i, j: (i,)),
                   pl.BlockSpec((tq,), lambda i, j: (i,))],
        out_shape=[jax.ShapeDtypeStruct((m,), jnp.int32),
                   jax.ShapeDtypeStruct((m,), jnp.bool_)],
        interpret=interpret,
    )(query, cache_ids)
    return pos, hit


def _merge_kernel(pos, hit, feats_ref, base_ref, o_ref):
    i = pl.program_id(0)
    h = hit[i]
    f = feats_ref[...].astype(o_ref.dtype)
    b = base_ref[...]
    o_ref[...] = jnp.where(h, f, b)


def merge_gather(cache_feats: jax.Array, base: jax.Array, pos: jax.Array,
                 hit: jax.Array, d_tile: int = 128,
                 interpret: bool = False) -> jax.Array:
    """base (m, d) pre-filled buffer; cached rows win where hit."""
    m, d = base.shape
    dt = min(d, d_tile)
    assert d % dt == 0
    n_hot = cache_feats.shape[0]
    pos_c = jnp.minimum(pos, n_hot - 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,         # pos, hit
        grid=(m, d // dt),
        in_specs=[
            pl.BlockSpec((1, dt), lambda i, k, p, h: (p[i], k)),
            pl.BlockSpec((1, dt), lambda i, k, p, h: (i, k)),
        ],
        out_specs=pl.BlockSpec((1, dt), lambda i, k, p, h: (i, k)),
    )
    return pl.pallas_call(
        _merge_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, d), base.dtype),
        interpret=interpret,
    )(pos_c, hit, cache_feats, base)


def cache_lookup(cache_ids: jax.Array, cache_feats: jax.Array,
                 query: jax.Array, base: jax.Array,
                 interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    pos, hit = search(cache_ids, query, interpret=interpret)
    merged = merge_gather(cache_feats, base, pos, hit, interpret=interpret)
    return merged, hit
