"""Pallas TPU kernels for the device-resident steady cache C_s.

Two fused stages (DESIGN.md §3 kernels):

  1. ``search``  -- positions of queries in the SORTED cache-id vector.
     TPU adaptation: instead of a per-lane binary search (serial, gather-
     heavy), each (Tq x Tc) tile computes comparison-mask partial sums on
     the VPU:  pos(q) = #&#123;ids < q&#125;,  hit(q) = any(ids == q).  The cache-id
     vector streams through VMEM in Tc-sized tiles, so n_hot is unbounded
     by VMEM and every op is dense vector work (MXU/VPU aligned).
  2. ``merge_gather`` -- one cached feature row per grid step, selected by
     a scalar-prefetched BlockSpec index map, merged over the pre-filled
     base buffer (hits win, misses keep the SyncPull value).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_TQ = 256
DEFAULT_TC = 1024

#: int32 cache sentinel: compares >= every real device id, so padding the
#: cache-id vector with it never perturbs ``pos = #{ids < q}`` or ``hit``.
SENTINEL = 2 ** 31 - 1


def pad_to(x: jax.Array, mult: int, axis: int, value) -> jax.Array:
    """Pad ``x`` along ``axis`` up to the next multiple of ``mult`` with a
    constant. No-op (and no copy) when already aligned; this is how the
    kernels accept arbitrary m / n_hot / d instead of asserting
    divisibility (an awkward batch size used to crash the compiled
    epoch)."""
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x
    width = [(0, 0)] * x.ndim
    width[axis] = (0, rem)
    return jnp.pad(x, width, constant_values=value)


def _search_kernel(q_ref, ids_ref, pos_ref, hit_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        pos_ref[...] = jnp.zeros_like(pos_ref)
        hit_ref[...] = jnp.zeros_like(hit_ref)

    q = q_ref[...]                     # (Tq,)
    ids = ids_ref[...]                 # (Tc,)
    lt = (ids[None, :] < q[:, None])
    eq = (ids[None, :] == q[:, None])
    pos_ref[...] += lt.sum(axis=1).astype(jnp.int32)
    hit_ref[...] |= eq.any(axis=1)


def search(cache_ids: jax.Array, query: jax.Array, tq: int = DEFAULT_TQ,
           tc: int = DEFAULT_TC, interpret: bool = False
           ) -> Tuple[jax.Array, jax.Array]:
    """cache_ids (n_hot,) sorted int32; query (m,) int32 -> (pos, hit).

    Arbitrary ``m`` / ``n_hot`` (including 0-sized caches) are handled by
    internal padding: queries pad with -1 (matches nothing, pos rows
    sliced off), cache ids pad with the INT32_MAX sentinel (sorts after
    every real id, so no real query's rank or hit changes). Sentinel
    queries NEVER hit -- they would otherwise match the padded cache
    tail -- matching the jnp oracle's contract.
    """
    m = query.shape[0]
    if m == 0:
        return (jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.bool_))
    if cache_ids.shape[0] == 0:
        cache_ids = jnp.full((1,), SENTINEL, jnp.int32)
    tq = min(tq, m)
    tc = min(tc, cache_ids.shape[0])
    query = pad_to(query, tq, 0, -1)
    cache_ids = pad_to(cache_ids, tc, 0, SENTINEL)
    mp = query.shape[0]
    n_hot = cache_ids.shape[0]
    grid = (mp // tq, n_hot // tc)
    pos, hit = pl.pallas_call(
        _search_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tq,), lambda i, j: (i,)),
                  pl.BlockSpec((tc,), lambda i, j: (j,))],
        out_specs=[pl.BlockSpec((tq,), lambda i, j: (i,)),
                   pl.BlockSpec((tq,), lambda i, j: (i,))],
        out_shape=[jax.ShapeDtypeStruct((mp,), jnp.int32),
                   jax.ShapeDtypeStruct((mp,), jnp.bool_)],
        interpret=interpret,
    )(query, cache_ids)
    return pos[:m], hit[:m] & (query[:m] != SENTINEL)


def _merge_kernel(pos, hit, feats_ref, base_ref, o_ref):
    i = pl.program_id(0)
    h = hit[i]
    f = feats_ref[...].astype(o_ref.dtype)
    b = base_ref[...]
    o_ref[...] = jnp.where(h, f, b)


def merge_gather(cache_feats: jax.Array, base: jax.Array, pos: jax.Array,
                 hit: jax.Array, d_tile: int = 128,
                 interpret: bool = False) -> jax.Array:
    """base (m, d) pre-filled buffer; cached rows win where hit.

    A feature dim not divisible by ``d_tile`` pads internally (both
    operands, sliced off the output) instead of asserting.
    """
    m, d0 = base.shape
    if cache_feats.shape[0] == 0:       # empty cache: nothing can hit
        return base
    dt = min(d0, d_tile)
    if d0 % dt:
        cache_feats = pad_to(cache_feats, dt, 1, 0)
        base = pad_to(base, dt, 1, 0)
    d = base.shape[1]
    n_hot = cache_feats.shape[0]
    pos_c = jnp.minimum(pos, n_hot - 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,         # pos, hit
        grid=(m, d // dt),
        in_specs=[
            pl.BlockSpec((1, dt), lambda i, k, p, h: (p[i], k)),
            pl.BlockSpec((1, dt), lambda i, k, p, h: (i, k)),
        ],
        out_specs=pl.BlockSpec((1, dt), lambda i, k, p, h: (i, k)),
    )
    out = pl.pallas_call(
        _merge_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, d), base.dtype),
        interpret=interpret,
    )(pos_c, hit, cache_feats, base)
    return out[:, :d0]


def cache_lookup(cache_ids: jax.Array, cache_feats: jax.Array,
                 query: jax.Array, base: jax.Array,
                 interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    pos, hit = search(cache_ids, query, interpret=interpret)
    merged = merge_gather(cache_feats, base, pos, hit, interpret=interpret)
    return merged, hit
