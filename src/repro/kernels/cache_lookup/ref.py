"""Pure-jnp oracle for the steady-cache lookup (C_s hit resolution)."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def cache_lookup_ref(cache_ids: jnp.ndarray, cache_feats: jnp.ndarray,
                     query: jnp.ndarray, base: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cache_ids (n_hot,) sorted (padded with a huge sentinel);
    cache_feats (n_hot, d); query (m,); base (m, d) pre-filled buffer.
    -> (merged (m, d), hit (m,) bool). Padding (-1) and sentinel
    queries never hit."""
    n_hot = cache_ids.shape[0]
    if n_hot == 0:                      # empty cache: nothing can hit
        return base, jnp.zeros(query.shape, jnp.bool_)
    pos = jnp.searchsorted(cache_ids, query)
    pos_c = jnp.minimum(pos, max(n_hot - 1, 0))
    hit = ((cache_ids[pos_c] == query) & (query >= 0)
           & (query != 2 ** 31 - 1))
    vals = cache_feats[pos_c]
    merged = jnp.where(hit[:, None], vals.astype(base.dtype), base)
    return merged, hit
