"""jit'd public wrapper for the cache_lookup kernels.

Device ids are int32 (TPU-native); the int64 host sentinel CACHE_PAD maps
to INT32_MAX here. Queries use -1 for padding (never hits).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.cache_lookup.cache_lookup import cache_lookup as _kernel
from repro.kernels.cache_lookup.ref import cache_lookup_ref

INT32_SENTINEL = jnp.int32(2 ** 31 - 1)


def to_device_ids(ids64) -> jax.Array:
    """Clamp the int64 CACHE_PAD sentinel into int32 space."""
    return jnp.where(ids64 >= INT32_SENTINEL.astype(jnp.int64),
                     INT32_SENTINEL.astype(jnp.int64), ids64).astype(jnp.int32)


@partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def cache_lookup(cache_ids: jax.Array, cache_feats: jax.Array,
                 query: jax.Array, base: jax.Array, *,
                 use_kernel: bool = False, interpret: bool = False
                 ) -> Tuple[jax.Array, jax.Array]:
    if use_kernel:
        return _kernel(cache_ids.astype(jnp.int32),
                       cache_feats, query.astype(jnp.int32), base,
                       interpret=interpret)
    return cache_lookup_ref(cache_ids, cache_feats, query, base)
