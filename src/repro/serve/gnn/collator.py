"""Micro-batch collation for online inference (DESIGN.md §11).

Reuses the training stack end to end: each request is sampled by the
deterministic ``KHopSampler`` on its OWN Philox stream keyed
``H(s0, worker, SERVE_EPOCH, rid)``, the slot batches are packed through
``FlatEpoch.from_batches`` and collated from its zero-copy ``batch(i)``
views with the same ``collate`` used for training -- so a request's
computation graph is a pure function of (s0, rid, seeds), independent
of micro-batch composition. That is the whole bit-equality story: the
batched response can be checked against a clean single-request oracle
because batching cannot change what is computed per slot.

Shapes are WORST-CASE static: padding bounds assume every sampled
neighbour is new (``m`` grows by the full fan-out each hop), so every
micro-batch -- any traffic, any request sizes up to ``batch_size`` --
collates to one fixed (R, m_max)/(R, E_l) layout and the service
compiles exactly ONE XLA trace.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.schedule import CollatedBatch, collate
from repro.graph.sampler import FlatEpoch, KHopSampler
from repro.serve.gnn.request import InferenceRequest

#: sampling-epoch slot for serving streams. Domain separation per the
#: §2.2 RNG contract: training draws use epoch >= 0 and the epoch
#: shuffle uses index -1, so ``(s0, w, -2, rid)`` can never collide
#: with either for any rid.
SERVE_EPOCH = -2


def serve_pad_bounds(fanouts: Sequence[int],
                     batch_size: int) -> Tuple[int, List[int]]:
    """Worst-case ``(m_max, edge_max)`` for a ``batch_size``-seed
    request: walking output->input like the sampler, each hop emits
    exactly ``frontier * fanout`` edges and at worst every source is
    new, so the frontier grows by ``x(1 + fanout)``."""
    cur = int(batch_size)
    edge_rev: List[int] = []
    for fanout in reversed(list(fanouts)):
        edge_rev.append(cur * int(fanout))
        cur *= 1 + int(fanout)
    return cur, list(reversed(edge_rev))


def empty_collated(batch_size: int, m_max: int,
                   edge_max: Sequence[int]) -> CollatedBatch:
    """Fully-padded slot for micro-batches shorter than R: every id -1,
    every mask False -- the assemble kernel serves it zeros and the
    response slot is discarded."""
    L = len(edge_max)
    return CollatedBatch(
        seeds=np.full(batch_size, -1, np.int64),
        seed_mask=np.zeros(batch_size, bool),
        labels=np.zeros(batch_size, np.int32),
        input_nodes=np.full(m_max, -1, np.int64),
        input_mask=np.zeros(m_max, bool),
        num_inputs=0,
        edge_src=[np.zeros(edge_max[l], np.int32) for l in range(L)],
        edge_dst=[np.zeros(edge_max[l], np.int32) for l in range(L)],
        edge_mask=[np.zeros(edge_max[l], bool) for l in range(L)],
        num_dst=[0] * L)


@dataclasses.dataclass
class MicroBatch:
    """R request slots stacked into the service's one static layout."""
    requests: List[Optional[InferenceRequest]]   # None = padding slot
    collated: List[CollatedBatch]                # per slot, R entries
    input_nodes: np.ndarray                      # (R, m_max) int64, -1 pad
    input_mask: np.ndarray                       # (R, m_max) bool
    edge_src: List[np.ndarray]                   # per layer (R, E_l) int32
    edge_dst: List[np.ndarray]
    edge_mask: List[np.ndarray]                  # per layer (R, E_l) bool

    @property
    def num_slots(self) -> int:
        return len(self.collated)


class ServeCollator:
    """Stateless per-service collator: sampler + static pad bounds."""

    def __init__(self, sampler: KHopSampler, s0: int, worker: int,
                 max_requests: int):
        self.sampler = sampler
        self.s0 = int(s0)
        self.worker = int(worker)
        self.max_requests = int(max_requests)
        self.batch_size = sampler.batch_size
        self.m_max, self.edge_max = serve_pad_bounds(
            sampler.fanouts, sampler.batch_size)
        # labels are a training concern; inference collation feeds a
        # zero table so ``collate`` stays shared with the train path
        self._labels = np.zeros(sampler.graph.num_nodes, np.int32)
        self._empty = empty_collated(self.batch_size, self.m_max,
                                     self.edge_max)

    def collate_one(self, req: InferenceRequest) -> CollatedBatch:
        """The single-request form -- also the oracle's collation."""
        if req.seeds.shape[0] > self.batch_size:
            raise ValueError(
                f"request {req.rid} has {req.seeds.shape[0]} seeds > "
                f"batch_size {self.batch_size}")
        b = self.sampler.sample_batch(self.s0, self.worker, SERVE_EPOCH,
                                      req.rid, req.seeds)
        return collate(b, self._labels, self.batch_size, self.m_max,
                       self.edge_max)

    def collate_micro_batch(self,
                            reqs: Sequence[InferenceRequest]) -> MicroBatch:
        """Sample every request, pack through FlatEpoch, collate each
        zero-copy view, stack to the (R, ...) static layout."""
        if not 0 < len(reqs) <= self.max_requests:
            raise ValueError(f"{len(reqs)} requests for micro-batch of "
                             f"at most {self.max_requests}")
        sampled = [self.sampler.sample_batch(self.s0, self.worker,
                                             SERVE_EPOCH, r.rid, r.seeds)
                   for r in reqs]
        flat = FlatEpoch.from_batches(sampled, epoch=SERVE_EPOCH,
                                      worker=self.worker,
                                      num_layers=len(self.sampler.fanouts))
        cbs = [collate(flat.batch(i), self._labels, self.batch_size,
                       self.m_max, self.edge_max)
               for i in range(flat.num_batches)]
        requests: List[Optional[InferenceRequest]] = list(reqs)
        while len(cbs) < self.max_requests:     # pad to static R
            cbs.append(self._empty)
            requests.append(None)
        L = len(self.edge_max)
        return MicroBatch(
            requests=requests, collated=cbs,
            input_nodes=np.stack([cb.input_nodes for cb in cbs]),
            input_mask=np.stack([cb.input_mask for cb in cbs]),
            edge_src=[np.stack([cb.edge_src[l] for cb in cbs])
                      for l in range(L)],
            edge_dst=[np.stack([cb.edge_dst[l] for cb in cbs])
                      for l in range(L)],
            edge_mask=[np.stack([cb.edge_mask[l] for cb in cbs])
                       for l in range(L)])
