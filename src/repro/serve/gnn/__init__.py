"""Online batched GNN inference serving (DESIGN.md §11).

The serving tier over the training stack: bounded admission with load
shedding, deterministic rid-keyed micro-batch collation, fused-kernel
feature assembly from a continuously warmed hot cache, and explicit
degradation tiers (fresh -> stale -> uncached) under the chaos plane's
``serve_pull``/``serve_warm``/``serve_queue`` fault sites.
"""
from repro.serve.gnn.admission import AdmissionQueue
from repro.serve.gnn.collator import (SERVE_EPOCH, MicroBatch,
                                      ServeCollator, serve_pad_bounds)
from repro.serve.gnn.request import (TIER_FRESH, TIER_STALE, TIER_UNCACHED,
                                     InferenceRequest, InferenceResponse,
                                     Overloaded, PendingResponse,
                                     ServeClosed, ServeError,
                                     ServePullError, WarmerError)
from repro.serve.gnn.service import GNNInferenceService, ServeProgram
from repro.serve.gnn.warmer import CacheWarmer, WarmSnapshot

__all__ = [
    "AdmissionQueue", "CacheWarmer", "GNNInferenceService",
    "InferenceRequest", "InferenceResponse", "MicroBatch", "Overloaded",
    "PendingResponse", "SERVE_EPOCH", "ServeClosed", "ServeCollator",
    "ServeError", "ServeProgram", "ServePullError", "TIER_FRESH",
    "TIER_STALE",
    "TIER_UNCACHED", "WarmSnapshot", "WarmerError", "serve_pad_bounds",
]
