"""Continuous hot-cache warmer for the serving tier (DESIGN.md §11).

The training cache is built from the precomputed schedule; serving has
no schedule, so the warmer closes the loop ONLINE: the service reports
every remote id it touches (``observe``), the warmer periodically ranks
the observed traffic with the same deterministic ``select_hot_set``
(freq desc, id asc) and bulk-loads the top ``n_hot`` rows via
``vector_pull`` -- the paper's VectorPull/C_sec machinery re-aimed at
request traffic. Each successful cycle publishes an immutable
``WarmSnapshot`` (global-id FeatureCache + CACHE_PAD-padded device
arrays in the service's one static shape) under the lock; the previous
snapshot is retained as the C_sec-style last-good buffer.

Failure semantics (the serving degradation contract): a transient
``serve_warm`` fault is retried with backoff inside the cycle; an
exhausted budget marks the warmer UNHEALTHY and keeps the last-good
snapshot installed -- the service flags responses ``stale=True`` until
a later cycle heals. The warm loop itself never dies to an injected
fault: errors are captured under the lock (THREAD-DISCIPLINE) and
surfaced typed via ``pending_error``/``warm_now``.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.cache import FeatureCache
from repro.core.fetch import ShardedFeatureStore
from repro.core.metrics import EpochMetrics
from repro.core.schedule import select_hot_set
from repro.dist.gnn_step import CACHE_PAD, DeviceView
from repro.fault.inject import fault_point, retry_call
from repro.serve.gnn.request import WarmerError


@dataclasses.dataclass(frozen=True)
class WarmSnapshot:
    """One published cache generation, immutable once installed."""
    generation: int
    cache: FeatureCache          # global-id snapshot (staleness contract)
    dev_ids: np.ndarray          # (n_hot,) int32 sorted, CACHE_PAD padded
    dev_feats: np.ndarray        # (n_hot, d) float32, zero rows at pads


class CacheWarmer:
    """Background thread turning observed traffic into hot snapshots."""

    #: bounded retry budget for transient warm-cycle faults
    warm_retries = 2
    retry_base_s = 1e-3

    def __init__(self, store: ShardedFeatureStore, dv: DeviceView,
                 n_hot: int, metrics: EpochMetrics,
                 interval_s: float = 0.05):
        self.store = store
        self.dv = dv
        self.n_hot = int(n_hot)
        self.metrics = metrics
        self.interval_s = float(interval_s)
        self.worker = store.worker
        self._lock = threading.Lock()          # traffic + published state
        self._err_lock = threading.Lock()
        self._freq: Dict[int, int] = {}
        self._current: Optional[WarmSnapshot] = None
        self._prev: Optional[WarmSnapshot] = None
        self._generation = 0
        self._healthy = True
        self._warm_failures = 0
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        self._closed = False
        self._thread = threading.Thread(
            target=self._run, daemon=True,
            name=f"serve-warmer-w{self.worker}")

    def start(self) -> "CacheWarmer":
        self._thread.start()
        return self

    # -- traffic observation (called by the service per micro-batch) -------
    def observe(self, remote_ids: np.ndarray) -> None:
        if remote_ids.shape[0] == 0:
            return
        ids, counts = np.unique(remote_ids, return_counts=True)
        with self._lock:
            for i, c in zip(ids.tolist(), counts.tolist()):
                self._freq[i] = self._freq.get(i, 0) + c

    # -- published state ----------------------------------------------------
    def snapshot(self) -> Tuple[Optional[WarmSnapshot], bool]:
        """-> (last published snapshot or None, healthy flag)."""
        with self._lock:
            return self._current, self._healthy

    @property
    def generation(self) -> int:
        with self._lock:
            return self._generation

    @property
    def warm_failures(self) -> int:
        with self._lock:
            return self._warm_failures

    def pending_error(self) -> Optional[WarmerError]:
        """Last background-cycle failure, typed; cleared on read."""
        with self._err_lock:
            err, self._err = self._err, None
        if err is None:
            return None
        out = WarmerError("cache warm cycle failed")
        out.__cause__ = err
        return out

    # -- the warm cycle ------------------------------------------------------
    def warm_now(self) -> bool:
        """Synchronous cycle (deterministic tests / pre-warming): True if
        a new generation was published, False when there is no traffic
        yet. Raises typed ``WarmerError`` on an exhausted retry budget."""
        try:
            return self._warm_once()
        except BaseException as exc:
            with self._lock:
                self._healthy = False
                self._warm_failures += 1
            raise WarmerError("cache warm cycle failed") from exc

    def _warm_once(self) -> bool:
        with self._lock:
            if not self._freq:
                return False
            items = sorted(self._freq.items())   # id-ascending, unique
            gen = self._generation + 1
        ids = np.fromiter((k for k, _ in items), np.int64, len(items))
        freq = np.fromiter((v for _, v in items), np.int64, len(items))
        hot = select_hot_set(ids, freq, self.n_hot)

        def _attempt(a: int) -> np.ndarray:
            fault_point("serve_warm", attempt=a, epoch=gen,
                        worker=self.worker)
            return self.store.vector_pull(hot, self.metrics)

        feats = retry_call(_attempt, self.warm_retries, self.retry_base_s)
        snap = self._build_snapshot(gen, hot, feats)
        with self._lock:
            self._prev = self._current
            self._current = snap
            self._generation = gen
            self._healthy = True
        return True

    def _build_snapshot(self, gen: int, hot: np.ndarray,
                        feats: np.ndarray) -> WarmSnapshot:
        """Global snapshot + the (n_hot,) static device-space arrays the
        one-trace program consumes (sorted; CACHE_PAD tail never hits)."""
        dev = self.dv.g2d[hot]
        order = np.argsort(dev)
        k = hot.shape[0]
        dev_ids = np.full(self.n_hot, CACHE_PAD, np.int32)
        dev_feats = np.zeros((self.n_hot, self.store.d), np.float32)
        dev_ids[:k] = dev[order].astype(np.int32)
        dev_feats[:k] = feats[order].astype(np.float32)
        return WarmSnapshot(generation=gen,
                            cache=FeatureCache(hot, feats),
                            dev_ids=dev_ids, dev_feats=dev_feats)

    # -- thread lifecycle ----------------------------------------------------
    def _run(self) -> None:
        try:
            while not self._stop.wait(self.interval_s):
                try:
                    self._warm_once()
                except BaseException as exc:   # loop survives; degrade
                    with self._err_lock:
                        self._err = exc
                    with self._lock:
                        self._healthy = False
                        self._warm_failures += 1
        except BaseException as exc:           # never die silently
            with self._err_lock:
                self._err = exc

    def close(self, timeout: float = 5.0) -> None:
        """Idempotent deadline-bounded teardown; a hung warmer raises a
        loud ``TimeoutError`` naming the thread, never a silent leak."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        if self._thread.ident is not None:
            self._thread.join(timeout=timeout)
            if self._thread.is_alive():
                raise TimeoutError(
                    f"warmer thread {self._thread.name} still alive "
                    f"after {timeout}s join deadline")
