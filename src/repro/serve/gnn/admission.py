"""Bounded admission queue with load shedding (DESIGN.md §11).

Overload policy: admission fails FAST and TYPED. Past the high-water
mark the queue sheds with ``Overloaded`` instead of buffering unbounded
work it cannot serve before deadlines -- the client owns the retry
decision. The ``serve_queue`` fault site sits at admission (before the
depth check), so an injected admission fault is indistinguishable from
organic overload to the client: same typed rejection, same ``shed``
counter, which is exactly the degraded behaviour the chaos harness
verifies.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Deque, List, Optional, Tuple

import numpy as np

from repro.fault.inject import fault_point
from repro.fault.plan import InjectedFault
from repro.serve.gnn.request import (InferenceRequest, Overloaded,
                                     PendingResponse, ServeClosed)


class AdmissionQueue:
    """FIFO of (request, pending) pairs, bounded by ``high_water``."""

    def __init__(self, high_water: int, worker: int = 0):
        if high_water < 1:
            raise ValueError(f"high_water must be >= 1, got {high_water}")
        self.high_water = int(high_water)
        self.worker = worker
        self._dq: Deque[Tuple[InferenceRequest, PendingResponse]] = \
            collections.deque()
        self._lock = threading.Lock()
        self._ready = threading.Condition(self._lock)
        self._closed = False
        self._next_rid = 0
        self._shed = 0

    # -- client side --------------------------------------------------------
    def submit(self, seeds: np.ndarray,
               timeout_s: float) -> PendingResponse:
        """Admit one request or raise typed ``Overloaded``/``ServeClosed``.

        The fault probe runs OUTSIDE the lock (a "hang" rule sleeps) and
        before the depth check; both rejection paths count as shed.
        """
        with self._lock:
            if self._closed:
                raise ServeClosed("admission after close()")
            rid = self._next_rid
            self._next_rid += 1
        try:
            fault_point("serve_queue", worker=self.worker, index=rid)
        except InjectedFault as exc:
            with self._lock:
                self._shed += 1
            raise Overloaded(
                f"request {rid} shed: admission fault") from exc
        now = time.monotonic()
        req = InferenceRequest(
            rid=rid, seeds=np.asarray(seeds, dtype=np.int64),
            deadline=now + float(timeout_s), submitted_at=now)
        pending = PendingResponse(rid)
        with self._lock:
            if self._closed:
                raise ServeClosed("admission after close()")
            if len(self._dq) >= self.high_water:
                self._shed += 1
                raise Overloaded(
                    f"request {rid} shed: queue depth {len(self._dq)} at "
                    f"high-water mark {self.high_water}")
            self._dq.append((req, pending))
            self._ready.notify()
        return pending

    # -- dispatcher side ----------------------------------------------------
    def pop_batch(self, max_n: int, timeout: Optional[float] = None
                  ) -> List[Tuple[InferenceRequest, PendingResponse]]:
        """Up to ``max_n`` admitted requests, FIFO. Blocks up to
        ``timeout`` for the first one (None: no wait); empty list means
        nothing arrived or the queue closed."""
        with self._lock:
            if not self._dq and timeout and not self._closed:
                self._ready.wait(timeout=timeout)
            out = []
            while self._dq and len(out) < max_n:
                out.append(self._dq.popleft())
            return out

    def depth(self) -> int:
        with self._lock:
            return len(self._dq)

    @property
    def shed(self) -> int:
        with self._lock:
            return self._shed

    def close(self) -> List[Tuple[InferenceRequest, PendingResponse]]:
        """Idempotent: reject future submits, drain and return the
        backlog (the service fails each pending typed)."""
        with self._lock:
            self._closed = True
            out = list(self._dq)
            self._dq.clear()
            self._ready.notify_all()
            return out
