"""Online batched GNN inference service (DESIGN.md §11).

One process, one worker's view of the partitioned graph, one XLA trace:
requests admitted past the bounded queue are collated into a static
``(R, m_max)`` micro-batch, features are assembled by the SAME fused
kernel the trainer uses -- local shard > hot cache > pulled residuals,
flattened to one ``assemble_features`` call -- and a vmapped ``forward``
produces per-request logits. ``trace_count`` pins the one-trace claim.

Robustness ladder (every failure is typed or degrades, never silent):

  admission   queue past high-water  -> typed ``Overloaded`` (shed)
  fresh       healthy warmer         -> current hot snapshot
  stale       warmer down            -> last-good snapshot, ``stale=True``
                                        (bit-equal for cache-resident
                                        rows; table is immutable)
  uncached    no snapshot yet        -> every remote row sync-pulled
  pull        transient serve_pull   -> ``retry_call`` backoff; exhausted
                                        budget fails THAT request typed
                                        (``ServePullError``)
  deadline    remaining < slack      -> retries dropped to fail fast
                                        (backoff would blow the budget);
                                        late completions are counted
                                        ``deadline_miss``, still correct

The response carries tier + snapshot provenance, so the staleness
contract -- non-shed responses bit-equal to the clean single-request
oracle, or flagged stale with features bit-equal to the snapshot served
from -- is checkable per response.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fetch import ShardedFeatureStore
from repro.core.metrics import EpochMetrics, NetworkModel
from repro.dist.gnn_step import CACHE_PAD, DeviceView
from repro.fault.inject import fault_point, retry_call
from repro.fault.plan import InjectedFault
from repro.graph.partition import PartitionedGraph
from repro.graph.sampler import KHopSampler
from repro.kernels.assemble.ops import assemble_features
from repro.models.gnn import GNNConfig, forward
from repro.serve.gnn.admission import AdmissionQueue
from repro.serve.gnn.collator import SERVE_EPOCH, MicroBatch, ServeCollator
from repro.serve.gnn.request import (TIER_FRESH, TIER_STALE, TIER_UNCACHED,
                                     InferenceRequest, InferenceResponse,
                                     PendingResponse, ServeClosed,
                                     ServePullError)
from repro.serve.gnn.warmer import CacheWarmer, WarmSnapshot


class ServeProgram:
    """The ONE jitted inference program, shareable across service
    instances with identical static shapes (the chaos sweep hands every
    faulted run the same program, so ``trace_count == 1`` is asserted
    across the whole campaign, not just one service)."""

    def __init__(self, cfg: GNNConfig, max_requests: int, m_max: int,
                 batch_size: int, d: int, base: int, backend: str,
                 interpret: bool):
        self.key = (cfg, max_requests, m_max, batch_size, d, base,
                    backend, interpret)
        self.trace_count = 0

        def _one(params, feats, es, ed, em):
            return forward(cfg, params, feats, es, ed, em)

        @jax.jit
        def program(params, table, cache_ids, cache_feats, query, pulled,
                    edge_src, edge_dst, edge_mask):
            self.trace_count += 1   # fires once per XLA trace, not per call
            flat = assemble_features(
                table, base, cache_ids, cache_feats,
                query.reshape(-1), pulled.reshape(-1, d),
                backend=backend, interpret=interpret)
            h = flat.reshape(max_requests, m_max, d)
            logits = jax.vmap(_one, in_axes=(None, 0, 0, 0, 0))(
                params, h, edge_src, edge_dst, edge_mask)
            return logits[:, :batch_size]
        self._fn = program

    def __call__(self, params, table, cache_ids, cache_feats, query,
                 pulled, edge_src, edge_dst, edge_mask) -> np.ndarray:
        out = self._fn(params, table, jnp.asarray(cache_ids),
                       jnp.asarray(cache_feats), jnp.asarray(query),
                       jnp.asarray(pulled),
                       [jnp.asarray(e) for e in edge_src],
                       [jnp.asarray(e) for e in edge_dst],
                       [jnp.asarray(e) for e in edge_mask])
        return np.asarray(out)


class GNNInferenceService:
    """Admission queue -> collator -> fused assembly -> vmapped forward."""

    def __init__(self, pg: PartitionedGraph, sampler: KHopSampler,
                 cfg: GNNConfig, params: Any, *, s0: int = 0,
                 worker: int = 0, n_hot: int = 256,
                 max_batch_requests: int = 4, high_water: int = 64,
                 default_timeout_s: float = 1.0,
                 pressure_slack_s: float = 0.02,
                 warm_interval_s: float = 0.05,
                 net: Optional[NetworkModel] = None,
                 backend: str = "auto", interpret: bool = False,
                 program: Optional[ServeProgram] = None):
        self.cfg = cfg
        self.params = jax.device_put(params)
        self.worker = int(worker)
        self.default_timeout_s = float(default_timeout_s)
        self.pressure_slack_s = float(pressure_slack_s)
        self.backend = backend
        self.interpret = interpret

        self.dv = DeviceView.build(pg)
        self.store = ShardedFeatureStore(pg, self.worker, net=net)
        self.metrics = EpochMetrics(epoch=SERVE_EPOCH)
        self.collator = ServeCollator(sampler, s0, self.worker,
                                      max_batch_requests)
        self.queue = AdmissionQueue(high_water, worker=self.worker)
        self.warmer = CacheWarmer(self.store, self.dv, n_hot,
                                  self.metrics,
                                  interval_s=warm_interval_s)
        self.n_hot = int(n_hot)
        self._table = jnp.asarray(self.dv.table[self.worker])
        self._base = self.worker * self.dv.n_per
        self._empty_cache_ids = np.full(self.n_hot, CACHE_PAD, np.int32)
        self._empty_cache_feats = np.zeros((self.n_hot, self.store.d),
                                           np.float32)

        expect_key = (cfg, max_batch_requests, self.collator.m_max,
                      self.collator.batch_size, self.store.d, self._base,
                      backend, interpret)
        if program is not None and program.key != expect_key:
            raise ValueError(
                f"shared ServeProgram key {program.key} does not match "
                f"this service's static shape {expect_key}")
        self.program = program if program is not None else ServeProgram(
            cfg, max_batch_requests, self.collator.m_max,
            self.collator.batch_size, self.store.d, self._base, backend,
            interpret)

        self._lock = threading.Lock()         # stats + lifecycle
        self._stats = {"served_fresh": 0, "served_stale": 0,
                       "served_uncached": 0, "deadline_miss": 0,
                       "errors": 0, "completed": 0, "micro_batches": 0}
        self._err_lock = threading.Lock()
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        self._closed = False
        self._thread: Optional[threading.Thread] = None

    def _run_program(self, mb: MicroBatch, cache_ids: np.ndarray,
                     cache_feats: np.ndarray, query: np.ndarray,
                     pulled: np.ndarray) -> np.ndarray:
        return self.program(self.params, self._table, cache_ids,
                            cache_feats, query, pulled, mb.edge_src,
                            mb.edge_dst, mb.edge_mask)

    @property
    def trace_count(self) -> int:
        return self.program.trace_count

    # ------------------------------------------------------------------
    # client API
    # ------------------------------------------------------------------
    def submit(self, seeds: np.ndarray,
               timeout_s: Optional[float] = None) -> PendingResponse:
        """Admit one request (typed ``Overloaded``/``ServeClosed`` on
        rejection); the response resolves via the returned future."""
        if self._closed:
            raise ServeClosed("submit after close()")
        return self.queue.submit(
            seeds, timeout_s if timeout_s is not None
            else self.default_timeout_s)

    # ------------------------------------------------------------------
    # serving step (synchronous core; the dispatcher thread loops it)
    # ------------------------------------------------------------------
    def step(self, timeout: Optional[float] = None) -> int:
        """Serve one micro-batch; -> number of requests resolved (with a
        response OR a typed per-request error). 0 if nothing arrived."""
        pairs = self.queue.pop_batch(self.collator.max_requests,
                                     timeout=timeout)
        if not pairs:
            return 0
        reqs = [p[0] for p in pairs]
        pendings = [p[1] for p in pairs]
        try:
            mb = self.collator.collate_micro_batch(reqs)
            snap, healthy = self.warmer.snapshot()
            if snap is None:
                tier = TIER_UNCACHED
                cache_ids, cache_feats = (self._empty_cache_ids,
                                          self._empty_cache_feats)
            else:
                tier = TIER_FRESH if healthy else TIER_STALE
                cache_ids, cache_feats = snap.dev_ids, snap.dev_feats
            query, pulled, slot_errors = self._assemble_host(
                mb, reqs, snap)
            logits = self._run_program(mb, cache_ids, cache_feats, query,
                                       pulled)
        except BaseException as exc:
            for pending in pendings:          # never strand a future
                pending.fail(exc)
            raise
        now = time.monotonic()
        for r, (req, pending) in enumerate(zip(reqs, pendings)):
            if r in slot_errors:
                pending.fail(slot_errors[r])
                with self._lock:
                    self._stats["errors"] += 1
                continue
            missed = now > req.deadline
            pending.fulfill(InferenceResponse(
                rid=req.rid,
                logits=logits[r, :req.seeds.shape[0]].copy(),
                tier=tier, stale=tier == TIER_STALE,
                deadline_missed=missed,
                cache_generation=snap.generation if snap else -1,
                served_cache=snap.cache if snap else None,
                latency_s=now - req.submitted_at))
            with self._lock:
                self._stats["completed"] += 1
                self._stats[f"served_{tier}"] += 1
                if missed:
                    self._stats["deadline_miss"] += 1
        with self._lock:
            self._stats["micro_batches"] += 1
        return len(reqs)

    def _assemble_host(self, mb: MicroBatch, reqs: List[InferenceRequest],
                       snap: Optional[WarmSnapshot]
                       ) -> Tuple[np.ndarray, np.ndarray,
                                  Dict[int, BaseException]]:
        """Host half of assembly: device-id query, residual pulls into
        the (R, m_max, d) buffer, traffic observation. Mirrors the
        kernel's priority exactly: local and cache-hit slots are left to
        the kernel; only true misses are pulled."""
        R = len(mb.collated)
        m_max = self.collator.m_max
        query = np.full((R, m_max), -1, np.int32)
        pulled = np.zeros((R, m_max, self.store.d), np.float32)
        slot_errors: Dict[int, BaseException] = {}
        traffic: List[np.ndarray] = []
        for r, req in enumerate(reqs):
            ids = mb.input_nodes[r]
            mask = mb.input_mask[r]
            safe = np.where(mask, ids, 0)
            dev = self.dv.g2d[safe]
            query[r] = np.where(mask, dev, -1).astype(np.int32)
            remote = mask & (dev // self.dv.n_per != self.worker)
            rem_idx = np.flatnonzero(remote)
            if rem_idx.shape[0] == 0:
                continue
            rem_gids = ids[rem_idx]
            traffic.append(rem_gids)
            if snap is not None and snap.cache.ids.shape[0] > 0:
                _, hit = snap.cache.lookup(rem_gids)
                miss_idx = rem_idx[~hit]
            else:
                miss_idx = rem_idx
            if miss_idx.shape[0] == 0:
                continue
            miss_gids = ids[miss_idx]
            # deadline pressure drops the retry budget: exponential
            # backoff on a nearly-expired request only converts a
            # typed failure into a deadline miss
            retries = (0 if req.remaining < self.pressure_slack_s
                       else self.store.pull_retries)
            gen = snap.generation if snap else -1

            def _pull(a: int, _gids=miss_gids, _rid=req.rid,
                      _gen=gen) -> np.ndarray:
                fault_point("serve_pull", attempt=a, epoch=_gen,
                            worker=self.worker, index=_rid)
                return self.store.sync_pull(_gids, self.metrics,
                                            critical_path=True)
            def _count_retry(_a: int) -> None:
                with self.store._m_lock:
                    self.metrics.pull_retries += 1
            try:
                pulled[r, miss_idx] = retry_call(
                    _pull, retries, self.store.retry_base_s,
                    on_retry=_count_retry)
            except InjectedFault as exc:
                slot_errors[r] = ServePullError(
                    f"request {req.rid}: residual pull of "
                    f"{miss_gids.shape[0]} rows failed past "
                    f"{retries} retries")
                slot_errors[r].__cause__ = exc
        if traffic:
            self.warmer.observe(np.concatenate(traffic))
        return query, pulled, slot_errors

    # ------------------------------------------------------------------
    # clean single-request oracle (differential reference)
    # ------------------------------------------------------------------
    def oracle(self, seeds: np.ndarray, rid: int) -> np.ndarray:
        """Bit-equality reference: the same rid-keyed sampling and the
        same jitted program (same static shapes -- no retrace), but
        features read STRAIGHT from the authoritative table with no
        cache, no store accounting and no fault probes."""
        req = InferenceRequest(
            rid=rid, seeds=np.asarray(seeds, dtype=np.int64),
            deadline=float("inf"), submitted_at=0.0)
        mb = self.collator.collate_micro_batch([req])
        R = len(mb.collated)
        m_max = self.collator.m_max
        query = np.full((R, m_max), -1, np.int32)
        pulled = np.zeros((R, m_max, self.store.d), np.float32)
        ids, mask = mb.input_nodes[0], mb.input_mask[0]
        safe = np.where(mask, ids, 0)
        query[0] = np.where(mask, self.dv.g2d[safe], -1).astype(np.int32)
        pulled[0, mask] = self.store.feat[ids[mask]]
        logits = self._run_program(mb, self._empty_cache_ids,
                                   self._empty_cache_feats, query, pulled)
        return logits[0, :req.seeds.shape[0]].copy()

    # ------------------------------------------------------------------
    # lifecycle + health
    # ------------------------------------------------------------------
    def start(self) -> "GNNInferenceService":
        """Launch warmer + dispatcher threads (online mode; tests may
        instead drive ``step()``/``warm_now()`` synchronously)."""
        self.warmer.start()
        self._thread = threading.Thread(
            target=self._dispatch_loop, daemon=True,
            name=f"serve-dispatch-w{self.worker}")
        self._thread.start()
        return self

    def _dispatch_loop(self) -> None:
        try:
            while not self._stop.is_set():
                self.step(timeout=0.02)
        except BaseException as exc:          # surfaced at close()
            with self._err_lock:
                self._err = exc

    def health(self) -> Dict[str, Any]:
        """One consistent snapshot of the serving counters + degraded
        state; what an operator (and the chaos harness) reads."""
        with self._lock:
            stats = dict(self._stats)
        _, healthy = self.warmer.snapshot()
        stats.update(
            shed=self.queue.shed,
            queue_depth=self.queue.depth(),
            warm_generation=self.warmer.generation,
            warm_failures=self.warmer.warm_failures,
            warmer_healthy=healthy,
            trace_count=self.trace_count,
            pull_retries=self.metrics.pull_retries,
            remote_bytes=self.metrics.remote_bytes,
            rpc_count=self.metrics.rpc_count)
        return stats

    def pending_error(self) -> Optional[BaseException]:
        with self._err_lock:
            err, self._err = self._err, None
        return err

    def close(self, timeout: float = 5.0) -> None:
        """Idempotent teardown: stop dispatch, fail the backlog typed,
        deadline-bounded joins naming any stuck thread."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        if self._thread is not None and self._thread.ident is not None:
            self._thread.join(timeout=timeout)
            if self._thread.is_alive():
                raise TimeoutError(
                    f"dispatcher thread {self._thread.name} still alive "
                    f"after {timeout}s join deadline")
        for _req, pending in self.queue.close():
            pending.fail(ServeClosed("service closed before dispatch"))
        self.warmer.close(timeout=timeout)
