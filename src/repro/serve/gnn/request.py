"""Request/response types for the online GNN inference tier (DESIGN.md §11).

A request is a set of seed node ids plus a latency budget; a response is
the seed logits plus the PROVENANCE the robustness contract needs:
which degradation tier served it (``fresh`` / ``stale`` / ``uncached``),
the exact cache snapshot consulted (so the staleness contract --
"features bit-equal to the snapshot served from" -- is testable), and
whether the deadline was met. Failures are TYPED: overload sheds as
``Overloaded`` at admission, a dead residual pull surfaces as
``ServePullError``, teardown fails pendings with ``ServeClosed`` --
a caller can always tell "degraded but correct" from "no answer".
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np

from repro.core.cache import FeatureCache

#: degradation-tier ladder (DESIGN.md §11): fresh hot cache -> stale
#: last-good snapshot (warmer down; flagged) -> uncached sync pull.
TIER_FRESH = "fresh"
TIER_STALE = "stale"
TIER_UNCACHED = "uncached"
TIERS = (TIER_FRESH, TIER_STALE, TIER_UNCACHED)


class ServeError(RuntimeError):
    """Base of every typed serving failure."""


class Overloaded(ServeError):
    """Admission rejected the request: queue past the high-water mark
    (load shedding) or an injected admission fault. Retryable by the
    client after backoff; never enqueued, never counted as served."""


class ServeClosed(ServeError):
    """The service is (being) torn down; the request was not served."""


class WarmerError(ServeError):
    """The background cache warmer exhausted its retry budget; the
    service keeps serving from the last-good snapshot (``stale`` tier)
    while the warmer keeps retrying -- this error is advisory in the
    background loop and raised only from synchronous ``warm_now()``."""


class ServePullError(ServeError):
    """A residual sync pull failed past the retry budget (or past the
    deadline-pressure fast-fail), so the response would have violated
    bit-equality; the request fails typed instead of serving garbage."""


@dataclasses.dataclass
class InferenceRequest:
    """One client request: seed nodes + absolute monotonic deadline.

    ``rid`` keys the sampling stream (``rng_from(s0, w, SERVE_EPOCH,
    rid)``), so a request's sampled computation graph is a pure function
    of (service seed, rid, seeds) -- independent of which micro-batch it
    lands in, which is what makes the batched response bit-equal to the
    single-request oracle.
    """
    rid: int
    seeds: np.ndarray                 # (B,) int64 global node ids
    deadline: float                   # absolute time.monotonic() seconds
    submitted_at: float

    @property
    def remaining(self) -> float:
        return self.deadline - time.monotonic()


@dataclasses.dataclass
class InferenceResponse:
    rid: int
    logits: np.ndarray                # (B, num_classes) float32
    tier: str                         # TIER_FRESH | TIER_STALE | TIER_UNCACHED
    stale: bool                       # True iff served off-generation
    deadline_missed: bool
    cache_generation: int             # warm generation consulted (-1: none)
    #: the exact global-id snapshot consulted (None on the uncached
    #: tier) -- the staleness contract is verified against THIS object
    served_cache: Optional[FeatureCache]
    latency_s: float

    def __post_init__(self):
        if self.tier not in TIERS:
            raise ValueError(f"unknown tier {self.tier!r} (have {TIERS})")


class PendingResponse:
    """Single-slot future handed back by ``submit()``.

    Thread contract: the dispatcher thread fulfils it exactly once
    (result or typed error) under the lock; any number of client
    threads may ``result()``. A deadline-bounded wait that expires
    raises ``TimeoutError`` -- distinct from a *served-late* response,
    which still resolves (flagged ``deadline_missed``).
    """

    def __init__(self, rid: int):
        self.rid = rid
        self._done = threading.Event()
        self._lock = threading.Lock()
        self._response: Optional[InferenceResponse] = None
        self._error: Optional[BaseException] = None

    def fulfill(self, response: InferenceResponse) -> None:
        with self._lock:
            if self._done.is_set():
                return
            self._response = response
            self._done.set()

    def fail(self, error: BaseException) -> None:
        with self._lock:
            if self._done.is_set():
                return
            self._error = error
            self._done.set()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> InferenceResponse:
        if not self._done.wait(timeout=timeout):
            raise TimeoutError(
                f"request {self.rid} unresolved after {timeout}s")
        with self._lock:
            if self._error is not None:
                raise self._error
            assert self._response is not None
            return self._response
