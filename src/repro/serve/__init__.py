from repro.serve.attention import sharded_decode_attention

__all__ = ["sharded_decode_attention"]
