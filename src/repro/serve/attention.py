"""Sequence-parallel decode attention over the `model` mesh axis.

The KV cache is sharded on its SEQUENCE dim (DESIGN.md §5): each model
shard holds S/tp cache slots, runs flash-decode partials over its slice
(Pallas kernel on TPU, jnp oracle elsewhere), and the (acc, m, l) partials
are psum-combined -- numerically identical to unsharded attention (tested
against the oracle). This is what makes 500k-token caches fit a v5e and
frees GQA kv-head counts from having to divide the TP axis.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.flash_decode.ref import finalize


def sharded_decode_attention(mesh, q: jnp.ndarray, k_cache: jnp.ndarray,
                             v_cache: jnp.ndarray, length: jnp.ndarray, *,
                             attn_softcap: float = 0.0,
                             scale=None) -> jnp.ndarray:
    """q (B,1,H,dh); caches (B,S,kvH,dh) seq-sharded over `model`;
    length (B,) -> (B,1,H,dh)."""
    tp = mesh.shape.get("model", 1)
    S = k_cache.shape[1]
    assert S % tp == 0
    s_local = S // tp
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # batch may not divide dp (e.g. long_500k global_batch=1): replicate
    B = q.shape[0]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if B % dp_size != 0:
        dp = None

    def body(qb, kb, vb, ln):
        # local shard covers absolute positions [idx*s_local, ...)
        idx = jax.lax.axis_index("model")
        base = idx * s_local

        def one(qi, ki, vi, li):
            # valid count within this shard
            ln_loc = jnp.clip(li - base, 0, s_local)
            acc, m, l = flash_decode(qi, ki, vi, ln_loc,
                                     scale=scale, softcap=attn_softcap)
            return acc, m, l

        acc, m, l = jax.vmap(one)(qb[:, 0], kb, vb, ln)
        m_g = jax.lax.pmax(m, "model")
        w = jnp.exp(m - m_g)
        acc_g = jax.lax.psum(acc * w[..., None], "model")
        l_g = jax.lax.psum(l * w, "model")
        out = jax.vmap(finalize)(acc_g, l_g)
        return out[:, None].astype(qb.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(dp), P(dp, "model"), P(dp, "model"), P(dp)),
        out_specs=P(dp))(q, k_cache, v_cache, length)
