"""arctic-480b [moe] 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base].

35L, d_model=7168, 56 heads (GQA kv=8, head_dim=128), d_ff=4864,
MoE 128e top-2 with a dense FFN residual in parallel, vocab=32000.
"""
import dataclasses

from repro.models.transformer.common import ArchConfig

ARCH = ArchConfig(
    name="arctic-480b",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    pattern=("attn",),
    moe=True,
    num_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual=True,
    act="silu",
    tie_embeddings=False,
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        ARCH, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        head_dim=32, d_ff=256, moe_d_ff=256, num_experts=4, top_k=2,
        vocab_size=512, dtype="float32", capacity_factor=4.0)
