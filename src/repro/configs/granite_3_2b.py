"""granite-3-2b [dense] GQA [hf:ibm-granite/granite-3.0-2b-base].

40L, d_model=2048, 32 heads (GQA kv=8), d_ff=8192, vocab=49155.
"""
import dataclasses

from repro.models.transformer.common import ArchConfig

ARCH = ArchConfig(
    name="granite-3-2b",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49155,
    pattern=("attn",),
    act="silu",
    tie_embeddings=True,
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        ARCH, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        head_dim=32, d_ff=512, vocab_size=512, dtype="float32")
