"""qwen2-vl-72b [vlm] M-RoPE, dynamic resolution [arXiv:2409.12191].

80L, d_model=8192, 64 heads (GQA kv=8, head_dim=128), d_ff=29568,
vocab=152064. M-RoPE sections (16, 24, 24) over the 64 head_dim/2
frequency bands. The ViT vision encoder + projector are STUBBED per the
assignment: ``input_specs`` provides patch embeddings (B, S, d_model)
added onto token embeddings, plus the (3, B, S) M-RoPE position streams.
"""
import dataclasses

from repro.models.transformer.common import ArchConfig

ARCH = ArchConfig(
    name="qwen2-vl-72b",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    pattern=("attn",),
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    act="silu",
    tie_embeddings=False,
    rope_theta=1000000.0,
    frontend="vision",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        ARCH, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        head_dim=32, d_ff=512, vocab_size=512, mrope_sections=(4, 6, 6),
        dtype="float32")
