"""recurrentgemma-9b [hybrid] RG-LRU + local attention 1:2
[arXiv:2402.19427].

38L (12 full (rglru, rglru, local) repeats + 2 tail rglru blocks),
d_model=4096, 16 heads MQA (kv=1, head_dim=256), d_ff=12288,
vocab=256000, window 2048, lru_width=4096. Sub-quadratic (recurrence +
windowed attention) -> native long_500k support.
"""
import dataclasses

from repro.models.transformer.common import ArchConfig

ARCH = ArchConfig(
    name="recurrentgemma-9b",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    pattern=("rglru", "rglru", "local"),
    window=2048,
    lru_width=4096,
    embed_scale=True,
    act="gelu",
    tie_embeddings=True,
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        ARCH, num_layers=3, d_model=256, num_heads=4, num_kv_heads=1,
        head_dim=64, d_ff=512, vocab_size=512, window=16, lru_width=256,
        dtype="float32")
