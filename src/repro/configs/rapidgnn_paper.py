"""The paper's own experimental configs (Table 1 / §5.1).

GraphSAGE fan-outs follow DistDGL defaults (25, 10); the Dist-GCN
baseline builds larger computation blocks (fan-out 50, 50 capped full
neighborhood) exactly as §5.2 attributes its higher fetch volume to
"large subgraph construction".
"""
import dataclasses
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class GNNExperimentConfig:
    dataset: str
    model: str                  # "sage" | "gcn"
    fanouts: Tuple[int, ...]
    batch_size: int
    hidden_dim: int
    num_layers: int
    num_epochs: int
    n_hot: int                  # steady-cache size
    Q: int                      # prefetch window
    num_workers: int
    partition: str              # "metis" (greedy stand-in) | "random"
    s0: int = 42


def sage(dataset: str, batch: int, workers: int = 4,
         partition: str = "metis", n_hot: int = 4096,
         epochs: int = 10) -> GNNExperimentConfig:
    return GNNExperimentConfig(dataset=dataset, model="sage",
                               fanouts=(25, 10), batch_size=batch,
                               hidden_dim=256, num_layers=2,
                               num_epochs=epochs, n_hot=n_hot, Q=4,
                               num_workers=workers, partition=partition)


def gcn(dataset: str, batch: int, workers: int = 4,
        epochs: int = 10) -> GNNExperimentConfig:
    return GNNExperimentConfig(dataset=dataset, model="gcn",
                               fanouts=(50, 50), batch_size=batch,
                               hidden_dim=256, num_layers=2,
                               num_epochs=epochs, n_hot=0, Q=0,
                               num_workers=workers, partition="metis")


#: paper Table 2 grid: 3 datasets x 3 batch sizes
PAPER_GRID: List[GNNExperimentConfig] = [
    sage(ds, b)
    for ds in ("reddit_sim", "ogbn_products_sim", "ogbn_papers_sim")
    for b in (1000, 2000, 3000)
]
