"""qwen3-moe-30b-a3b [moe] 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L, d_model=2048, 32 heads (GQA kv=4, head_dim=128, q/k-norm), expert
d_ff=768, MoE 128e top-8, vocab=151936.
"""
import dataclasses

from repro.models.transformer.common import ArchConfig

ARCH = ArchConfig(
    name="qwen3-moe-30b-a3b",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    pattern=("attn",),
    moe=True,
    num_experts=128,
    top_k=8,
    moe_d_ff=768,
    qk_norm=True,
    act="silu",
    tie_embeddings=False,
    rope_theta=1000000.0,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        ARCH, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=128, moe_d_ff=128, num_experts=4, top_k=2,
        vocab_size=512, dtype="float32", capacity_factor=4.0)
