"""gemma2-2b [dense] local+global alternating, logit softcap
[arXiv:2408.00118].

26L, d_model=2304, 8 heads (GQA kv=4, head_dim=256), d_ff=9216,
vocab=256000. Pattern (local, global) with window 4096; attention logit
softcap 50, final logit softcap 30; sandwich (post) norms; embeddings
scaled by sqrt(d_model).
"""
import dataclasses

from repro.models.transformer.common import ArchConfig

ARCH = ArchConfig(
    name="gemma2-2b",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    pattern=("local", "attn"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    embed_scale=True,
    act="gelu",
    tie_embeddings=True,
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        ARCH, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512, window=16, dtype="float32")
