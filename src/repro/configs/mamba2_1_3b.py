"""mamba2-1.3b [ssm] SSD state-space duality [arXiv:2405.21060].

48L, d_model=2048 (attention-free), ssm_state=128, expand 2 (d_inner
4096, 64 heads of dim 64), conv 4, vocab=50280. Sub-quadratic by
construction -> native long_500k support.
"""
import dataclasses

from repro.models.transformer.common import ArchConfig

ARCH = ArchConfig(
    name="mamba2-1.3b",
    num_layers=48,
    d_model=2048,
    num_heads=64,            # ssm heads (d_inner / ssm_head_dim)
    num_kv_heads=64,
    head_dim=64,
    d_ff=0,                  # attention-free: no FFN sub-block
    vocab_size=50280,
    pattern=("ssm",),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        ARCH, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        head_dim=64, ssm_state=16, ssm_head_dim=64, ssm_chunk=16,
        vocab_size=512, dtype="float32")
