"""Architecture registry: the 10 assigned archs + the paper's GNN configs.

``get_arch(name)`` returns the full-fidelity ArchConfig;
``get_reduced(name)`` the CPU-sized smoke variant of the same family.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.transformer.common import ArchConfig

_MODULES = {
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "qwen1.5-32b": "repro.configs.qwen15_32b",
    "smollm-360m": "repro.configs.smollm_360m",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "arctic-480b": "repro.configs.arctic_480b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
}

ARCH_NAMES: List[str] = list(_MODULES)

#: archs with native sub-quadratic support for long_500k; the rest run it
#: with the sliding-window variant (DESIGN.md §5)
SUBQUADRATIC = {"mamba2-1.3b", "recurrentgemma-9b", "gemma2-2b"}

#: input-shape suite (assignment): name -> (seq_len, global_batch, kind)
INPUT_SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


def get_arch(name: str) -> ArchConfig:
    return importlib.import_module(_MODULES[name]).ARCH


def get_reduced(name: str) -> ArchConfig:
    return importlib.import_module(_MODULES[name]).reduced()


def all_archs() -> Dict[str, ArchConfig]:
    return {n: get_arch(n) for n in ARCH_NAMES}
