"""qwen1.5-32b [dense] QKV bias [hf:Qwen/Qwen1.5-0.5B family].

64L, d_model=5120, 40 heads (GQA kv=40 == MHA), d_ff=27392, vocab=152064.
"""
import dataclasses

from repro.models.transformer.common import ArchConfig

ARCH = ArchConfig(
    name="qwen1.5-32b",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    pattern=("attn",),
    qkv_bias=True,
    act="silu",
    tie_embeddings=False,
    rope_theta=1000000.0,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        ARCH, num_layers=2, d_model=256, num_heads=8, num_kv_heads=8,
        head_dim=32, d_ff=512, vocab_size=512, dtype="float32")
