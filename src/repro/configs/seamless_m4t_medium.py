"""seamless-m4t-medium [audio] enc-dec, multimodal [arXiv:2308.11596].

12 encoder + 12 decoder layers, d_model=1024, 16 heads (GQA kv=16 == MHA),
d_ff=4096, vocab=256206. The speech frontend (mel-spectrogram + conv
feature extractor) is STUBBED per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_src, d_model); this config implements
the transformer backbone (encoder + text decoder with cross-attention).
"""
import dataclasses

from repro.models.transformer.common import ArchConfig

ARCH = ArchConfig(
    name="seamless-m4t-medium",
    kind="encdec",
    num_layers=12,
    num_enc_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    pattern=("attn",),
    qkv_bias=True,
    act="gelu",
    tie_embeddings=True,
    frontend="audio",
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    """2-layer smoke variant (same family, CPU-sized)."""
    return dataclasses.replace(
        ARCH, num_layers=2, num_enc_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        dtype="float32")
