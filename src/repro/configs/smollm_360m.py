"""smollm-360m [dense] llama-arch small [hf:HuggingFaceTB/SmolLM-135M].

32L, d_model=960, 15 heads (GQA kv=5), d_ff=2560, vocab=49152.
"""
import dataclasses

from repro.models.transformer.common import ArchConfig

ARCH = ArchConfig(
    name="smollm-360m",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    pattern=("attn",),
    act="silu",
    tie_embeddings=True,
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        ARCH, num_layers=2, d_model=240, num_heads=5,   # keeps 15/5 ratio
        num_kv_heads=5, head_dim=48, d_ff=512, vocab_size=512,
        dtype="float32")
