"""Declarative campaign grids: which cells to run, on which backend.

A *cell* is one (backend, system, scenario) point: backend picks the
execution substrate (``host`` = the metered host-sim runners in
``repro.core.runtime``, ``device`` = the SPMD runners in
``repro.dist.runner`` on an emulated/real mesh), system picks the data
path (``rapidgnn`` vs the on-demand baselines), and the scenario --
dataset, batch size, worker count, cache budget, epochs, seed, fanouts,
partitioner -- is shared verbatim by every cell of a pair so measured
differences isolate exactly one axis.

Cells that share ``scenario_key()`` but differ in *backend* are
differentially verified against each other (repro.eval.differential);
cells that share it but differ in *system* yield the paper's headline
ratios (repro.eval.report).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

#: host-sim systems (benchmarks §5.1 naming); the device backend
#: realises the first two (rapid vs on-demand baseline) on the mesh.
HOST_SYSTEMS = ("rapidgnn", "dgl-metis", "dgl-random", "gcn")
DEVICE_SYSTEMS = ("rapidgnn", "dgl-metis")


@dataclasses.dataclass(frozen=True)
class CellSpec:
    backend: str                    # "host" | "device"
    system: str                     # one of HOST_SYSTEMS
    dataset: str
    batch_size: int
    workers: int
    n_hot: int                      # cache budget (rapidgnn only)
    epochs: int
    seed: int = 42
    fanouts: Tuple[int, ...] = (25, 10)
    partition: str = "metis"        # "dgl-random" forces "random"
    hidden: int = 32
    Q: int = 4                      # host prefetch queue depth (rapid)
    train: bool = True
    all_workers: bool = True        # host: run every worker (device always)
    net_enabled: bool = True        # host network-model sleeps
    #: epoch sampler: "batched" = the vectorized schedule compiler
    #: (default), "loop" = the per-batch oracle. Bit-identical schedules
    #: by the parity contract, so deliberately NOT part of
    #: ``scenario_key()`` -- cells differing only here still pair.
    schedule_compiler: str = "batched"
    #: where the schedule compiler runs: "numpy" = host compilers as
    #: picked by ``schedule_compiler``, "device" = the accelerator port
    #: (DESIGN.md §2.2) with lazy device-resident schedules on the
    #: device backend. Same bit-parity contract as ``schedule_compiler``,
    #: so likewise EXCLUDED from ``scenario_key()``.
    schedule_backend: str = "numpy"
    #: fault-plane profile (repro.fault.plan.PROFILES) activated around
    #: this cell's runs; "none" = clean. Faulted cells are a DIFFERENT
    #: scenario from clean ones (they may degrade epochs), so both fault
    #: fields ARE part of ``scenario_key()``; ``verify_fault_pairs``
    #: compares a faulted cell to its clean twin by neutralizing them.
    fault_profile: str = "none"
    fault_seed: int = 0
    #: deadline on the device runner's overlapped stage future (None =
    #: wait forever); a timing knob, NOT part of the scenario key.
    stage_deadline_s: Optional[float] = None
    #: device-mesh topology: "flat" = the classic ("data",) mesh, or
    #: "HxD" (e.g. "2x2") = H emulated hosts x D devices with two-tier
    #: pull plans (repro.dist.topology, DESIGN.md §6.7). The two-tier
    #: exchange is bit-equal to the flat one (the parity contract), so
    #: like the schedule knobs this is EXCLUDED from ``scenario_key()``
    #: -- a hierarchical cell pairs with its flat twin and the
    #: intra+inter byte-sum identity is checked against it.
    topology: str = "flat"

    def __post_init__(self):
        if self.backend not in ("host", "device"):
            raise ValueError(f"unknown backend {self.backend!r}")
        systems = HOST_SYSTEMS if self.backend == "host" else DEVICE_SYSTEMS
        if self.system not in systems:
            raise ValueError(f"system {self.system!r} not available on "
                             f"backend {self.backend!r} (have {systems})")
        if self.schedule_compiler not in ("batched", "loop"):
            raise ValueError(f"unknown schedule_compiler "
                             f"{self.schedule_compiler!r}")
        if self.schedule_backend not in ("numpy", "device"):
            raise ValueError(f"unknown schedule_backend "
                             f"{self.schedule_backend!r}")
        if self.fault_profile != "none":
            from repro.fault.plan import PROFILES
            if self.fault_profile not in PROFILES:
                raise ValueError(f"unknown fault_profile "
                                 f"{self.fault_profile!r}")
        if self.topology != "flat":
            if self.backend != "device":
                raise ValueError("hierarchical topology needs the device "
                                 f"backend, got {self.backend!r}")
            from repro.dist.topology import Topology
            Topology.parse(self.topology, self.workers)  # validates HxD
        object.__setattr__(self, "fanouts", tuple(self.fanouts))

    @property
    def is_rapid(self) -> bool:
        return self.system == "rapidgnn"

    @property
    def effective_compiler(self) -> str:
        """The ``build_schedule`` compiler this cell actually runs:
        ``schedule_backend="device"`` overrides the host compiler choice
        with the accelerator port."""
        return ("device" if self.schedule_backend == "device"
                else self.schedule_compiler)

    @property
    def partition_method(self) -> str:
        return "random" if self.system == "dgl-random" else self.partition

    @property
    def effective_fanouts(self) -> Tuple[int, ...]:
        """gcn is DEFINED as the wider-block baseline (paper §5.1), so
        its sampler ignores the grid's fanouts."""
        return (50, 50) if self.system == "gcn" else self.fanouts

    def scenario_key(self) -> Tuple:
        """Everything shared across a differential pair: two cells with
        equal keys consumed the IDENTICAL deterministic schedule. Built
        from the EFFECTIVE partition/fanouts, so dgl-random (random
        partition) and gcn (50,50 fanouts) cells never key-match a
        rapidgnn cell -- their schedules differ by design, and only the
        grid-level ratio pairing (repro.eval.report) may compare them."""
        return (self.dataset, self.batch_size, self.workers, self.n_hot,
                self.epochs, self.seed, self.effective_fanouts,
                self.partition_method, self.fault_profile,
                self.fault_seed)

    def topology_obj(self):
        """-> ``repro.dist.topology.Topology`` for this cell."""
        from repro.dist.topology import Topology
        return Topology.parse(self.topology, self.workers)

    def label(self) -> str:
        base = (f"{self.backend}/{self.system}/{self.dataset}"
                f"/b{self.batch_size}/w{self.workers}/h{self.n_hot}"
                f"/e{self.epochs}")
        if self.topology != "flat":
            base += f"/t{self.topology}"
        if self.fault_profile != "none":
            base += f"/f{self.fault_profile}"
        return base

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["fanouts"] = list(self.fanouts)
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "CellSpec":
        names = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in names}
        kw["fanouts"] = tuple(kw.get("fanouts", (25, 10)))
        return cls(**kw)


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    name: str
    cells: Tuple[CellSpec, ...]

    def __iter__(self):
        return iter(self.cells)

    def device_cells(self) -> List[CellSpec]:
        return [c for c in self.cells if c.backend == "device"]

    def host_cells(self) -> List[CellSpec]:
        return [c for c in self.cells if c.backend == "host"]


def grid(backends: Sequence[str], systems: Sequence[str],
         datasets: Sequence[str], batch_sizes: Sequence[int],
         workers: Sequence[int], n_hots: Sequence[int],
         epochs: int, **common) -> List[CellSpec]:
    """Cross-product cell builder; skips systems a backend lacks."""
    out = []
    for be, sy, ds, bs, w, nh in itertools.product(
            backends, systems, datasets, batch_sizes, workers, n_hots):
        if be == "device" and sy not in DEVICE_SYSTEMS:
            continue
        out.append(CellSpec(backend=be, system=sy, dataset=ds,
                            batch_size=bs, workers=w, n_hot=nh,
                            epochs=epochs, **common))
    return out


def fast_grid() -> CampaignSpec:
    """CPU-sized paired grid: rapid vs baseline on BOTH backends over the
    tiny graph, every cell of a scenario sharing schedules exactly, so
    the host-vs-device differential checks run on every pair. Each
    device cell additionally re-runs on the hierarchical 2x2 topology
    (2 emulated hosts x 2 devices), pairing with its flat twin for the
    cross-topology parity + byte-sum checks."""
    cells = grid(backends=("host", "device"),
                 systems=("rapidgnn", "dgl-metis"),
                 datasets=("tiny",), batch_sizes=(16,), workers=(4,),
                 n_hots=(64,), epochs=3, seed=42, fanouts=(5, 5),
                 partition="greedy")
    cells += [dataclasses.replace(c, topology="2x2")
              for c in cells if c.backend == "device"]
    return CampaignSpec(name="fast", cells=tuple(cells))


def full_grid() -> CampaignSpec:
    """Paper-scale host grid (Tables 2/3, Figs 4-6 axes) plus the device
    pair for differential coverage. Slow: minutes on CPU."""
    host = grid(backends=("host",), systems=HOST_SYSTEMS,
                datasets=("ogbn_products_sim", "reddit_sim"),
                batch_sizes=(100, 200), workers=(4,), n_hots=(32768,),
                epochs=2, seed=42, fanouts=(25, 10), partition="metis",
                all_workers=False)
    dev = grid(backends=("host", "device"),
               systems=("rapidgnn", "dgl-metis"),
               datasets=("tiny",), batch_sizes=(16,), workers=(4,),
               n_hots=(64,), epochs=3, seed=42, fanouts=(5, 5),
               partition="greedy")
    return CampaignSpec(name="full", cells=tuple(host + dev))


def fault_grid(fault_seed: int = 7) -> CampaignSpec:
    """Fault campaign (BENCH_fault.json): the fast-grid rapidgnn
    scenario re-run under named fault profiles on both backends, each
    faulted cell paired with a clean twin for bit-parity verification.
    Device profiles exercise staging/caching/crash sites, host profiles
    the prefetch/pull/C_sec sites; ``cache-loss`` guarantees the report
    its >=1 degraded-epoch cell."""
    common = dict(dataset="tiny", batch_size=16, workers=4, n_hot=64,
                  epochs=3, seed=42, fanouts=(5, 5), partition="greedy")
    cells = []
    for prof in ("none", "cache-loss", "stage-flaky"):
        cells.append(CellSpec(backend="device", system="rapidgnn",
                              fault_profile=prof,
                              fault_seed=0 if prof == "none"
                              else fault_seed, **common))
    for prof in ("none", "csec-loss", "pull-flaky", "prefetch-flaky"):
        cells.append(CellSpec(backend="host", system="rapidgnn",
                              fault_profile=prof,
                              fault_seed=0 if prof == "none"
                              else fault_seed, **common))
    return CampaignSpec(name="fault", cells=tuple(cells))


def tiny_host_grid(epochs: int = 2) -> CampaignSpec:
    """Host-only tiny pair -- the fast pytest lane's campaign (no
    subprocess, a few seconds end to end)."""
    cells = grid(backends=("host",), systems=("rapidgnn", "dgl-metis"),
                 datasets=("tiny",), batch_sizes=(16,), workers=(4,),
                 n_hots=(64,), epochs=epochs, seed=42, fanouts=(5, 5),
                 partition="greedy")
    return CampaignSpec(name="tiny-host", cells=tuple(cells))
