"""Cell execution: one `CellSpec` -> one unified `CellResult`.

Host cells run the metered host-sim runners (``repro.core.runtime``)
in-process, one per worker, and aggregate their ``RunMetrics``. Device
cells run the SPMD runners (``repro.dist.runner``) in a SUBPROCESS whose
``XLA_FLAGS`` pins the emulated device count to the cell's worker count
(device count locks at first jax init, so the parent process -- which
must stay single-device for the host cells -- can never host them).

Both backends land in the same ``CellResult`` schema, so the campaign's
differential checks (repro.eval.differential) and ratio derivations
(repro.eval.report) never branch on backend.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import tempfile
from typing import Any, Dict, List, Sequence

import numpy as np

from repro.eval.spec import CellSpec

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))

#: wall-clock guard for one device-cell subprocess batch
DEVICE_CHILD_TIMEOUT_S = 900


@dataclasses.dataclass
class CellResult:
    """Backend-agnostic record of one campaign cell.

    ``warm_*`` fields exclude epoch 0 (JIT/bootstrap warm-up) whenever
    the cell ran more than one epoch; time-derived ratios use them,
    byte/RPC counters always cover every epoch. ``miss_matrix[e][i]`` is
    worker ``workers_run[i]``'s epoch-``e`` residual-miss count -- the
    quantity the host-vs-device differential pins (host-sim
    ``cache_misses`` vs device pull-lane counts)."""
    spec: Dict[str, Any]
    feat_dim: int
    itemsize: int
    workers_run: List[int]
    num_steps: int
    warm_steps: int
    wall_time_s: float
    warm_wall_s: float
    step_time_ms: float
    rpc_count: int
    remote_requests: int
    cache_hits: int
    cache_misses: int
    hit_rate: float
    remote_bytes: int
    vector_pull_bytes: int
    payload_bytes: int
    sync_net_time_s: float
    warm_sync_net_time_s: float
    modeled_net_time_s: float
    miss_matrix: List[List[int]]
    losses: List[float]
    accs: List[float]
    energy: Dict[str, float]
    #: per-epoch detail records -- host: worker-0's ``EpochMetrics``
    #: dicts (``RunMetrics.to_dict``), device: ``DeviceEpochReport``
    #: dicts -- the drill-down layer of BENCH_paper.json
    epoch_metrics: List[Dict[str, Any]] = dataclasses.field(
        default_factory=list)
    wire_rows: int = 0
    trace_count: int = 0
    device_cache_bytes: int = 0
    #: request-leg wire bytes (the id/pos lane tensors shipped through
    #: the all_to_all BEFORE the payload comes back); device backend
    #: only, == wire_rows * index-lane itemsize by construction
    request_bytes: int = 0
    #: two-tier topology split (device backend; on a flat mesh the whole
    #: exchange is the intra tier and every ``inter_*`` field is 0).
    #: Identities pinned by repro.eval.differential:
    #:   intra_misses + inter_misses == cache_misses
    #:   intra_bytes  + inter_bytes  == remote_bytes (payload leg)
    intra_misses: int = 0
    inter_misses: int = 0
    intra_bytes: int = 0
    inter_bytes: int = 0
    intra_wire_rows: int = 0
    inter_wire_rows: int = 0
    stage_time_s: float = 0.0
    #: staging wall left exposed after training (device backend with
    #: background staging; ~stage_time_s on the legacy synchronous path)
    exposed_stage_s: float = 0.0
    #: fault-plane accounting (DESIGN.md §10); all zero on clean cells.
    #: ``fault_events`` counts injections that actually fired, the rest
    #: count the recoveries they forced: degraded epochs (stale C_sec /
    #: lost staged cache), bounded retries per site, spill heals,
    #: stage-deadline overruns, and the wall spent recovering.
    degraded_epochs: int = 0
    stage_retries: int = 0
    pull_retries: int = 0
    prefetch_retries: int = 0
    csec_degraded: int = 0
    spill_rebuilds: int = 0
    deadline_overruns: int = 0
    recovery_wall_s: float = 0.0
    fault_events: int = 0

    @property
    def backend(self) -> str:
        return self.spec["backend"]

    @property
    def system(self) -> str:
        return self.spec["system"]

    @property
    def row_bytes(self) -> int:
        return self.feat_dim * self.itemsize

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CellResult":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


def _energy(spec: CellSpec, warm_wall_s: float) -> Dict[str, float]:
    from repro.core import modelled_energy
    return modelled_energy(warm_wall_s,
                           "rapidgnn" if spec.is_rapid else "baseline")


# ---------------------------------------------------------------------------
# host backend
# ---------------------------------------------------------------------------

def run_host_cell(spec: CellSpec, worker: int = 0,
                  net=None) -> CellResult:
    """Run one host-sim cell. ``spec.all_workers`` runs every worker's
    schedule (each against its own feature-store view, as the paper's
    cluster would); otherwise only ``worker`` runs -- the single-worker
    mode the CSV benchmarks historically measured. ``net`` overrides
    the spec-derived ``NetworkModel`` (legacy benchmark hook)."""
    import jax

    from repro.graph import load_dataset, partition_graph, KHopSampler
    from repro.core import (build_schedule, ShardedFeatureStore,
                            RapidGNNRunner, BaselineRunner, NetworkModel)
    from repro.models import (GNNConfig, init_params, make_train_step,
                              batch_to_device)
    from repro.train import AdamW
    from repro.fault import active_plan, plan_from_profile

    if spec.backend != "host":
        raise ValueError(f"run_host_cell got backend {spec.backend!r}")
    plan = (plan_from_profile(spec.fault_profile, seed=spec.fault_seed)
            if spec.fault_profile != "none" else None)
    g = load_dataset(spec.dataset)
    pg = partition_graph(g, spec.workers, spec.partition_method)
    fanouts = (50, 50) if spec.system == "gcn" else spec.fanouts
    sampler = KHopSampler(g, fanouts=fanouts,
                          batch_size=spec.batch_size)
    workers = list(range(spec.workers)) if spec.all_workers else [worker]

    cfg = GNNConfig(kind="gcn" if spec.system == "gcn" else "sage",
                    in_dim=g.feat_dim, hidden_dim=spec.hidden,
                    num_classes=g.num_classes, num_layers=len(fanouts))
    opt = AdamW(lr=3e-3)
    step = make_train_step(cfg, opt) if spec.train else None

    runs = []           # (RunMetrics, losses, accs, cache_bytes, steps/ep)
    for w in workers:
        ws = build_schedule(sampler, pg, worker=w, s0=spec.seed,
                            num_epochs=spec.epochs,
                            n_hot=spec.n_hot if spec.is_rapid else 0,
                            compiler=spec.effective_compiler)
        state = {"losses": [], "accs": []}
        if spec.train:
            params = init_params(cfg, jax.random.key(spec.seed))
            box = {"p": params, "o": opt.init(params)}

            def train_fn(feats, cb, _box=box, _state=state):
                batch = batch_to_device(cb, feats)
                _box["p"], _box["o"], aux = step(_box["p"], _box["o"],
                                                 batch)
                _state["losses"].append(float(aux["loss"]))
                _state["accs"].append(float(aux["acc"]))
                return _state["losses"][-1]
        else:
            def train_fn(feats, cb):
                return 0.0

        store = ShardedFeatureStore(
            pg, worker=w,
            net=net if net is not None
            else NetworkModel(enabled=spec.net_enabled))
        if spec.is_rapid:
            runner = RapidGNNRunner(ws, store,
                                    batch_size=spec.batch_size,
                                    Q=spec.Q, train_fn=train_fn)
        else:
            runner = BaselineRunner(ws, store,
                                    batch_size=spec.batch_size,
                                    train_fn=train_fn)
        with active_plan(plan):     # None-tolerant: no-op when clean
            m = runner.run()
        runs.append((m, state["losses"], state["accs"],
                     getattr(runner, "device_cache_bytes", 0),
                     [ws.epoch(e).num_batches
                      for e in range(spec.epochs)],
                     int(ws.spill_rebuilds)))

    return _host_cell_result(spec, g, workers, runs,
                             fault_events=plan.total_fires() if plan
                             else 0)


def _host_cell_result(spec: CellSpec, g, workers, runs,
                      fault_events: int = 0) -> CellResult:
    E = spec.epochs
    tot: Dict[str, float] = {k: 0 for k in (
        "rpc_count", "remote_requests", "cache_hits", "cache_misses",
        "remote_bytes", "vector_pull_bytes", "sync_net_time_s",
        "warm_sync_net_time_s", "modeled_net_time_s", "pull_retries",
        "prefetch_retries", "csec_degraded")}
    miss = np.zeros((E, len(workers)), np.int64)
    wall = warm_wall = 0.0
    num_steps = warm_steps = 0
    spill_rebuilds = sum(r[5] for r in runs if len(r) > 5)
    for i, (m, *_rest) in enumerate(runs):
        steps_per_epoch = _rest[3]
        t = m.totals()
        for k in ("rpc_count", "remote_requests", "cache_hits",
                  "cache_misses", "remote_bytes", "vector_pull_bytes",
                  "sync_net_time_s", "modeled_net_time_s",
                  "pull_retries", "prefetch_retries", "csec_degraded"):
            tot[k] += t[k]
        warm_eps = m.epochs[1:] if E > 1 else m.epochs
        tot["warm_sync_net_time_s"] += sum(e.sync_net_time_s
                                           for e in warm_eps)
        miss[:, i] = [e.cache_misses for e in m.epochs]
        # workers run concurrently on a real cluster: the cell's wall
        # time is the slowest worker, counters are the cluster total
        wall = max(wall, sum(e.wall_time_s for e in m.epochs))
        warm_wall = max(warm_wall, sum(e.wall_time_s for e in warm_eps))
        num_steps = max(num_steps, sum(steps_per_epoch))
        warm_steps = max(warm_steps, sum(
            steps_per_epoch[1:] if E > 1 else steps_per_epoch))
    hits, misses = int(tot["cache_hits"]), int(tot["cache_misses"])
    losses, accs = runs[0][1], runs[0][2]
    return CellResult(
        spec=spec.to_dict(), feat_dim=g.feat_dim,
        itemsize=int(g.features.itemsize), workers_run=list(workers),
        num_steps=num_steps, warm_steps=warm_steps,
        wall_time_s=wall, warm_wall_s=warm_wall,
        step_time_ms=1e3 * warm_wall / max(warm_steps, 1),
        rpc_count=int(tot["rpc_count"]),
        remote_requests=int(tot["remote_requests"]),
        cache_hits=hits, cache_misses=misses,
        hit_rate=hits / max(hits + misses, 1),
        remote_bytes=int(tot["remote_bytes"]),
        vector_pull_bytes=int(tot["vector_pull_bytes"]),
        payload_bytes=int(tot["remote_bytes"]),
        sync_net_time_s=float(tot["sync_net_time_s"]),
        warm_sync_net_time_s=float(tot["warm_sync_net_time_s"]),
        modeled_net_time_s=float(tot["modeled_net_time_s"]),
        miss_matrix=miss.tolist(), losses=list(losses), accs=list(accs),
        energy=_energy(spec, warm_wall),
        epoch_metrics=runs[0][0].to_dict()["epochs"],
        device_cache_bytes=max(r[3] for r in runs),
        # a degraded host epoch == one that kept a stale steady cache
        degraded_epochs=int(tot["csec_degraded"]),
        pull_retries=int(tot["pull_retries"]),
        prefetch_retries=int(tot["prefetch_retries"]),
        csec_degraded=int(tot["csec_degraded"]),
        spill_rebuilds=spill_rebuilds,
        fault_events=fault_events)


# ---------------------------------------------------------------------------
# device backend: subprocess orchestration (parent side)
# ---------------------------------------------------------------------------

def run_device_cells(specs: Sequence[CellSpec],
                     timeout: int = DEVICE_CHILD_TIMEOUT_S
                     ) -> List[CellResult]:
    """Run device cells in child processes (one per distinct worker
    count), each pinned to that many emulated host devices. Results come
    back through a JSON file, never stdout (jax logs pollute it)."""
    by_P: Dict[int, List[CellSpec]] = {}
    for s in specs:
        if s.backend != "device":
            raise ValueError(f"run_device_cells got backend {s.backend!r}")
        by_P.setdefault(s.workers, []).append(s)

    out: List[CellResult] = []
    for P_, group in sorted(by_P.items()):
        with tempfile.TemporaryDirectory() as td:
            spec_path = os.path.join(td, "specs.json")
            out_path = os.path.join(td, "cells.json")
            with open(spec_path, "w") as f:
                json.dump([s.to_dict() for s in group], f)
            env = dict(os.environ)
            env["XLA_FLAGS"] = \
                f"--xla_force_host_platform_device_count={P_}"
            env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep +
                                 env.get("PYTHONPATH", ""))
            r = subprocess.run(
                [sys.executable, "-m", "repro.eval.campaign",
                 "--device-child", spec_path, out_path],
                capture_output=True, text=True, timeout=timeout,
                env=env, cwd=ROOT)
            if r.returncode != 0:
                raise RuntimeError(
                    f"device-cell child (P={P_}) failed:\n{r.stdout}\n"
                    f"{r.stderr}")
            with open(out_path) as f:
                out.extend(CellResult.from_dict(d) for d in json.load(f))
    return out


# ---------------------------------------------------------------------------
# device backend: the child (runs with device_count == workers)
# ---------------------------------------------------------------------------

def device_child_main(spec_path: str, out_path: str) -> None:
    import jax

    with open(spec_path) as f:
        specs = [CellSpec.from_dict(d) for d in json.load(f)]
    scenarios: Dict[tuple, dict] = {}
    results = []
    for spec in specs:
        if jax.device_count() < spec.workers:
            raise RuntimeError(
                f"{spec.workers} workers need {spec.workers} devices, "
                f"have {jax.device_count()} (set XLA_FLAGS)")
        key = spec.scenario_key()
        if key not in scenarios:
            scenarios[key] = _build_device_scenario(spec)
        results.append(_run_device_cell(spec, scenarios[key]))
    with open(out_path, "w") as f:
        json.dump([r.to_dict() for r in results], f)


def _build_device_scenario(spec: CellSpec) -> dict:
    from repro.graph import load_dataset, partition_graph, KHopSampler
    from repro.core import build_schedule
    from repro.dist import DeviceView

    g = load_dataset(spec.dataset)
    pg = partition_graph(g, spec.workers, spec.partition_method)
    sampler = KHopSampler(g, fanouts=list(spec.fanouts),
                          batch_size=spec.batch_size)
    # the device schedule backend also goes LAZY (device-resident): the
    # runner's staging thread rebuilds each epoch overlapped with train
    schedules = [build_schedule(sampler, pg, worker=w, s0=spec.seed,
                                num_epochs=spec.epochs, n_hot=spec.n_hot,
                                compiler=spec.effective_compiler,
                                lazy=spec.schedule_backend == "device")
                 for w in range(spec.workers)]
    # NOTE: no mesh here -- the scenario cache is keyed by
    # ``scenario_key()``, which deliberately excludes ``topology`` (flat
    # and hierarchical cells share schedules by the parity contract), so
    # the mesh is a per-CELL artifact built in ``_run_device_cell``.
    return {"g": g, "pg": pg, "schedules": schedules,
            "dv": DeviceView.build(pg)}


def _run_device_cell(spec: CellSpec, sc: dict) -> CellResult:
    from repro.models import GNNConfig
    from repro.train import AdamW
    from repro.dist import DeviceRapidGNNRunner, DeviceBaselineRunner
    from repro.fault import active_plan, plan_from_profile

    g, schedules = sc["g"], sc["schedules"]
    cfg = GNNConfig(kind="sage", in_dim=g.feat_dim,
                    hidden_dim=spec.hidden, num_classes=g.num_classes,
                    num_layers=len(spec.fanouts))
    topo = spec.topology_obj()
    cls = DeviceRapidGNNRunner if spec.is_rapid else DeviceBaselineRunner
    runner = cls(schedules, sc["dv"], cfg, AdamW(lr=3e-3),
                 topo.make_mesh(), spec.batch_size, g.labels,
                 seed=spec.seed, stage_deadline_s=spec.stage_deadline_s,
                 topology=topo)
    plan = (plan_from_profile(spec.fault_profile, seed=spec.fault_seed)
            if spec.fault_profile != "none" else None)
    with active_plan(plan):
        reports = runner.run()
    return device_cell_result(spec, g, schedules, runner, reports,
                              fault_events=plan.total_fires() if plan
                              else 0)


def device_cell_result(spec: CellSpec, g, schedules, runner,
                       reports, fault_events: int = 0) -> CellResult:
    """Fold DeviceEpochReports into the unified cell schema.

    ``rpc_count``/``cache_misses``/``remote_bytes`` are the pull-lane
    accounting (residual misses, == host-sim by the parity contract);
    ``vector_pull_bytes`` mirrors the host bootstrap + C_sec builds:
    every epoch's cache rows are staged exactly once."""
    row = g.feat_dim * g.features.itemsize
    E = len(reports)
    rep_dicts = [r.to_dict() for r in reports]
    lanes_total = sum(r.total_miss_lanes for r in reports)
    warm = reports[1:] if E > 1 else reports
    wall = sum(r.wall_time_s for r in reports)
    warm_wall = sum(r.wall_time_s for r in warm)
    num_steps = sum(r.steps for r in reports)
    warm_steps = sum(r.steps for r in warm)
    vec_bytes = 0
    if spec.is_rapid:
        vec_bytes = sum(int(ws.epoch(r.epoch).cache_ids.shape[0]) * row
                        for ws in schedules for r in reports)
    payload = lanes_total * row
    intra_misses = sum(sum(d["intra_lanes"]) for d in rep_dicts)
    inter_misses = sum(sum(d["inter_lanes"]) for d in rep_dicts)
    return CellResult(
        spec=spec.to_dict(), feat_dim=g.feat_dim,
        itemsize=int(g.features.itemsize),
        workers_run=list(range(spec.workers)),
        num_steps=num_steps, warm_steps=warm_steps,
        wall_time_s=wall, warm_wall_s=warm_wall,
        step_time_ms=1e3 * warm_wall / max(warm_steps, 1),
        rpc_count=lanes_total, remote_requests=lanes_total,
        cache_hits=0, cache_misses=lanes_total, hit_rate=0.0,
        remote_bytes=payload, vector_pull_bytes=vec_bytes,
        payload_bytes=payload,
        sync_net_time_s=0.0, warm_sync_net_time_s=0.0,
        modeled_net_time_s=0.0,
        miss_matrix=[r["miss_lanes"] for r in rep_dicts],
        losses=[x for r in rep_dicts for x in r["losses"]],
        accs=[x for r in rep_dicts for x in r["accs"]],
        energy=_energy(spec, warm_wall),
        epoch_metrics=rep_dicts,
        wire_rows=sum(int(r.wire_rows) for r in reports),
        request_bytes=sum(r.request_bytes() for r in reports),
        intra_misses=intra_misses, inter_misses=inter_misses,
        intra_bytes=intra_misses * row, inter_bytes=inter_misses * row,
        intra_wire_rows=sum(int(r.intra_wire_rows) for r in reports),
        inter_wire_rows=sum(int(r.inter_wire_rows) for r in reports),
        trace_count=int(runner.trace_count),
        stage_time_s=float(runner.stage_time_s),
        exposed_stage_s=float(runner.exposed_stage_s),
        degraded_epochs=sum(r.degraded for r in reports),
        stage_retries=int(getattr(runner, "stage_retries", 0)),
        spill_rebuilds=sum(int(ws.spill_rebuilds) for ws in schedules),
        deadline_overruns=int(getattr(runner, "deadline_overruns", 0)),
        recovery_wall_s=float(getattr(runner, "recovery_wall_s", 0.0)),
        fault_events=fault_events)
