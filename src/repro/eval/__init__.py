"""Paper-metrics campaign subsystem (DESIGN.md §7).

One evaluation pipeline for the whole repo: declarative ``CampaignSpec``
grids sweep {host-sim, device} x {rapid, baseline} x scenario cells into
a unified ``CellResult`` schema, derive the paper's headline ratios
(throughput speedup, remote-fetch reduction, modelled CPU/GPU energy),
and differentially verify every paired cell -- host miss counters vs
device pull lanes, byte accounting, loss-curve agreement -- so the
benchmark campaign doubles as a system-level correctness harness.

Entry point: ``python -m repro.eval.campaign --fast`` (or ``--full``);
artifact: ``artifacts/BENCH_paper.json``.
"""
from repro.eval.spec import (CellSpec, CampaignSpec, grid, fast_grid,
                             fault_grid, full_grid, tiny_host_grid,
                             HOST_SYSTEMS, DEVICE_SYSTEMS)
from repro.eval.cells import (CellResult, run_host_cell,
                              run_device_cells, device_cell_result)
from repro.eval.differential import (CheckResult, verify_cells,
                                     verify_fault_pairs,
                                     check_cell_internal,
                                     check_backend_pair,
                                     check_system_pair, all_pass,
                                     failures)
from repro.eval.report import (SCHEMA, FAULT_SCHEMA, PAPER_TARGETS,
                               derive_pair, derive_pairs, build_report,
                               build_fault_report, write_report,
                               validate_report, validate_fault_report)
from repro.eval.replay import replay_device_bytes
# NOTE: repro.eval.campaign (the CLI + run_campaign) is intentionally
# NOT imported here: `python -m repro.eval.campaign` would otherwise
# re-import it under two names (runpy RuntimeWarning).

__all__ = [
    "CellSpec", "CampaignSpec", "grid", "fast_grid", "fault_grid",
    "full_grid", "tiny_host_grid", "HOST_SYSTEMS", "DEVICE_SYSTEMS",
    "CellResult", "run_host_cell", "run_device_cells",
    "device_cell_result",
    "CheckResult", "verify_cells", "verify_fault_pairs",
    "check_cell_internal", "check_backend_pair", "check_system_pair",
    "all_pass", "failures",
    "SCHEMA", "FAULT_SCHEMA", "PAPER_TARGETS", "derive_pair",
    "derive_pairs", "build_report", "build_fault_report",
    "write_report", "validate_report", "validate_fault_report",
    "replay_device_bytes",
]
