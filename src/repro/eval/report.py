"""Headline-ratio derivation + the ``BENCH_paper.json`` schema.

Mapping to the paper (see DESIGN.md §7):

  * ``throughput_speedup``   -> Table 2 (2.46-3.00x end-to-end);
                                baseline step time / rapid step time,
                                both warm (epoch 0 excluded).
  * ``fetch_reduction_x``    -> §5.3 headline (9.70-15.39x fewer remote
                                fetches); baseline fetched rows / rapid
                                residual-miss rows.
  * ``bytes_reduction_x``    -> Fig. 4 (mean data per step); includes
                                rapid's off-critical-path VectorPull
                                staging bytes, so the cache is charged
                                for its own fills.
  * ``energy``               -> Table 3 (44 % CPU / 32 % GPU savings);
                                modelled E = P_mean x warm duration with
                                the paper's measured power envelopes.

Ratios are derived per (backend, grid-scenario) pair of cells -- rapid
vs each baseline system of that grid point. dgl-random and gcn run a
DIFFERENT schedule by definition (random partition / 50,50 fanouts,
recorded per pair as ``baseline_partition``/``baseline_fanouts``);
schedule-identical comparison is the differential layer's domain
(``repro.eval.differential``, keyed by ``CellSpec.scenario_key``).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Sequence

from repro.eval.cells import CellResult
from repro.eval.differential import CheckResult, all_pass

#: v2 adds the fault/degradation counters (degraded_epochs, retry
#: totals, recovery_wall_s, fault_events) to every cell record.
SCHEMA = "rapidgnn.bench_paper/v2"
#: BENCH_fault.json: the fault campaign's recovery scorecard.
FAULT_SCHEMA = "rapidgnn.bench_fault/v1"
#: BENCH_serve.json: online-serving latency under clean vs fault lanes.
SERVE_SCHEMA = "rapidgnn.bench_serve/v1"

#: the paper's headline claims, pinned so readers of the artifact can
#: compare without the PDF (ranges are across its dataset grid).
PAPER_TARGETS = {
    "throughput_speedup": [2.46, 3.00],
    "fetch_reduction_x": [9.70, 15.39],
    "cpu_energy_saving": 0.44,
    "gpu_energy_saving": 0.32,
}

_REQUIRED_CELL_FIELDS = (
    "spec", "feat_dim", "num_steps", "warm_steps", "wall_time_s",
    "warm_wall_s", "step_time_ms", "rpc_count", "remote_bytes",
    "vector_pull_bytes", "payload_bytes", "miss_matrix", "losses",
    "energy", "hit_rate",
    # v2: fault/degradation counters
    "degraded_epochs", "stage_retries", "pull_retries",
    "prefetch_retries", "recovery_wall_s", "fault_events")
_REQUIRED_PAIR_FIELDS = (
    "backend", "baseline_system", "scenario", "throughput_speedup",
    "fetch_reduction_x", "bytes_reduction_x", "energy")


def _scenario_dict(c: CellResult) -> Dict:
    s = c.spec
    d = {k: s[k] for k in ("dataset", "batch_size", "workers",
                           "n_hot", "epochs", "seed", "fanouts",
                           "partition")}
    d["topology"] = s.get("topology", "flat")
    return d


def derive_pair(rapid: CellResult, base: CellResult) -> Dict:
    """Headline ratios for one rapid-vs-baseline cell pair.

    Ratio pairing is GRID-level (paper Table 2 compares systems, not
    schedules): dgl-random and gcn intentionally run a different
    partition / fanouts, recorded as ``baseline_partition`` /
    ``baseline_fanouts`` so the scenario block (the rapid cell's
    schedule) is never read as shared. Schedule-identical pairing is
    the differential layer's job (``CellSpec.scenario_key``)."""
    from repro.eval.spec import CellSpec

    r_bytes = rapid.remote_bytes + rapid.vector_pull_bytes
    b_bytes = base.remote_bytes + base.vector_pull_bytes
    er, eb = rapid.energy, base.energy
    bspec = CellSpec.from_dict(base.spec)
    return {
        "backend": rapid.backend,
        "baseline_system": base.system,
        "baseline_partition": bspec.partition_method,
        "baseline_fanouts": list(bspec.effective_fanouts),
        "scenario": _scenario_dict(rapid),
        "throughput_speedup": round(
            base.step_time_ms / max(rapid.step_time_ms, 1e-9), 4),
        "fetch_reduction_x": round(
            base.rpc_count / max(rapid.rpc_count, 1), 4),
        "bytes_reduction_x": round(b_bytes / max(r_bytes, 1), 4),
        "net_time_speedup": round(
            base.warm_sync_net_time_s /
            max(rapid.warm_sync_net_time_s, 1e-9), 4)
        if base.warm_sync_net_time_s else None,
        "energy": {
            "cpu_ratio": round(er["cpu_J"] / max(eb["cpu_J"], 1e-9), 4),
            "gpu_ratio": round(er["gpu_J"] / max(eb["gpu_J"], 1e-9), 4),
            "total_ratio": round(
                er["total_J"] / max(eb["total_J"], 1e-9), 4),
            "cpu_saving": round(
                1.0 - er["cpu_J"] / max(eb["cpu_J"], 1e-9), 4),
            "gpu_saving": round(
                1.0 - er["gpu_J"] / max(eb["gpu_J"], 1e-9), 4),
        },
    }


def derive_pairs(cells: Sequence[CellResult]) -> List[Dict]:
    groups: Dict[tuple, Dict[str, CellResult]] = {}
    for c in cells:
        s = c.spec
        # topology is in the key: a hierarchical device cell must pair
        # with the hierarchical baseline, not overwrite the flat one
        key = (c.backend, s["dataset"], s["batch_size"], s["workers"],
               s["n_hot"], s["epochs"], s["seed"], tuple(s["fanouts"]),
               s["partition"], s.get("topology", "flat"))
        groups.setdefault(key, {})[c.system] = c
    out = []
    for _, group in sorted(groups.items(), key=lambda kv: str(kv[0])):
        rapid = group.get("rapidgnn")
        if rapid is None:
            continue
        for sysname in sorted(group):
            if sysname != "rapidgnn":
                out.append(derive_pair(rapid, group[sysname]))
    return out


def build_report(campaign: str, cells: Sequence[CellResult],
                 checks: Sequence[CheckResult]) -> Dict:
    return {
        "schema": SCHEMA,
        "campaign": campaign,
        "created_unix": time.time(),
        "paper_targets": PAPER_TARGETS,
        "num_cells": len(cells),
        "cells": [c.to_dict() for c in cells],
        "pairs": derive_pairs(cells),
        "differential": [c.to_dict() for c in checks],
        "all_checks_pass": all_pass(checks),
    }


def build_fault_report(campaign: str, cells: Sequence[CellResult],
                       checks: Sequence[CheckResult]) -> Dict:
    """BENCH_fault.json: per-cell recovery scorecard + the differential
    checks (including the ``fault_*`` recovery layer). No headline
    pairs -- the fault grid is rapidgnn-only by construction."""
    rows = []
    for c in cells:
        s = c.spec
        rows.append({
            "cell": f"{c.backend}/{s.get('fault_profile', 'none')}",
            "backend": c.backend,
            "fault_profile": s.get("fault_profile", "none"),
            "fault_seed": s.get("fault_seed", 0),
            "fault_events": c.fault_events,
            "degraded_epochs": c.degraded_epochs,
            "stage_retries": c.stage_retries,
            "pull_retries": c.pull_retries,
            "prefetch_retries": c.prefetch_retries,
            "csec_degraded": c.csec_degraded,
            "spill_rebuilds": c.spill_rebuilds,
            "deadline_overruns": c.deadline_overruns,
            "recovery_wall_s": round(c.recovery_wall_s, 6),
            "retry_total": (c.stage_retries + c.pull_retries
                            + c.prefetch_retries),
        })
    return {
        "schema": FAULT_SCHEMA,
        "campaign": campaign,
        "created_unix": time.time(),
        "num_cells": len(cells),
        "cells": [c.to_dict() for c in cells],
        "fault_summary": rows,
        "differential": [c.to_dict() for c in checks],
        "all_checks_pass": all_pass(checks),
    }


def validate_fault_report(report: Dict) -> List[str]:
    """Schema check for BENCH_fault.json. Beyond shape, enforces the
    campaign's reason to exist: at least one cell must have actually
    DEGRADED and recovered (a fault grid where nothing fires proves
    nothing)."""
    probs: List[str] = []
    for key in ("schema", "campaign", "num_cells", "cells",
                "fault_summary", "differential", "all_checks_pass"):
        if key not in report:
            probs.append(f"missing top-level key {key!r}")
    if probs:
        return probs
    if report["schema"] != FAULT_SCHEMA:
        probs.append(f"schema {report['schema']!r} != {FAULT_SCHEMA!r}")
    if report["num_cells"] != len(report["cells"]):
        probs.append("num_cells does not match len(cells)")
    for i, cell in enumerate(report["cells"]):
        for f in _REQUIRED_CELL_FIELDS:
            if f not in cell:
                probs.append(f"cells[{i}] missing {f!r}")
    for i, row in enumerate(report["fault_summary"]):
        for f in ("fault_profile", "fault_events", "degraded_epochs",
                  "retry_total", "recovery_wall_s"):
            if f not in row:
                probs.append(f"fault_summary[{i}] missing {f!r}")
    faulted = [r for r in report["fault_summary"]
               if r.get("fault_profile", "none") != "none"]
    if not faulted:
        probs.append("no faulted cells in the fault campaign")
    if not any(r.get("fault_events", 0) > 0 for r in faulted):
        probs.append("no fault actually fired across the campaign")
    if not any(r.get("degraded_epochs", 0) > 0
               for r in report["fault_summary"]):
        probs.append("no cell degraded an epoch -- the campaign must "
                     "exercise at least one degraded recovery")
    for i, chk in enumerate(report["differential"]):
        if chk.get("status") not in ("PASS", "FAIL", "SKIP"):
            probs.append(f"differential[{i}] bad status "
                         f"{chk.get('status')!r}")
    return probs


_REQUIRED_LANE_FIELDS = (
    "lane", "fault_profile", "requests", "served", "shed", "errors",
    "latency_ms", "health")


def build_serve_report(config: Dict, lanes: Sequence[Dict],
                       ratio_bound: float = 5.0) -> Dict:
    """BENCH_serve.json: p50/p99 serving latency per lane (clean vs
    fault-injected) plus the degradation-bound verdict. The fault lane
    may shed or degrade, but its p99 must stay within ``ratio_bound``x
    of the clean lane's -- the serving tier's 'graceful, not cliff'
    contract (DESIGN.md §11)."""
    clean = [r for r in lanes if r["fault_profile"] == "none"]
    fault = [r for r in lanes if r["fault_profile"] != "none"]
    clean_p99 = min(r["latency_ms"]["p99"] for r in clean)
    worst_p99 = max(r["latency_ms"]["p99"] for r in fault)
    ratio = worst_p99 / max(clean_p99, 1e-9)
    return {
        "schema": SERVE_SCHEMA,
        "created_unix": time.time(),
        "config": dict(config),
        "lanes": [dict(r) for r in lanes],
        "p99_ratio": round(ratio, 3),
        "ratio_bound": ratio_bound,
        "ok": bool(ratio <= ratio_bound),
    }


def validate_serve_report(report: Dict) -> List[str]:
    """Schema check for BENCH_serve.json. Beyond shape, enforces the
    bench's reason to exist: a clean lane AND at least one faulted lane
    that actually served traffic, every lane on a single XLA trace, and
    an ``ok`` verdict consistent with the recorded ratio."""
    probs: List[str] = []
    for key in ("schema", "config", "lanes", "p99_ratio", "ratio_bound",
                "ok"):
        if key not in report:
            probs.append(f"missing top-level key {key!r}")
    if probs:
        return probs
    if report["schema"] != SERVE_SCHEMA:
        probs.append(f"schema {report['schema']!r} != {SERVE_SCHEMA!r}")
    for i, lane in enumerate(report["lanes"]):
        for f in _REQUIRED_LANE_FIELDS:
            if f not in lane:
                probs.append(f"lanes[{i}] missing {f!r}")
        lat = lane.get("latency_ms", {})
        for f in ("p50", "p99", "mean"):
            if f not in lat:
                probs.append(f"lanes[{i}].latency_ms missing {f!r}")
        if {"p50", "p99"} <= set(lat) and lat["p50"] > lat["p99"]:
            probs.append(f"lanes[{i}] p50 > p99")
        if lane.get("served", 0) <= 0:
            probs.append(f"lanes[{i}] served no requests")
        if lane.get("health", {}).get("trace_count") != 1:
            probs.append(f"lanes[{i}] trace_count != 1 -- the static "
                         "collation contract broke (retrace)")
    lanes = report["lanes"]
    if not any(r.get("fault_profile") == "none" for r in lanes):
        probs.append("no clean lane")
    if not any(r.get("fault_profile", "none") != "none" for r in lanes):
        probs.append("no fault lane -- the bench must exercise serving "
                     "under an active fault plan")
    if report["ok"] != (report["p99_ratio"] <= report["ratio_bound"]):
        probs.append("ok verdict inconsistent with p99_ratio vs bound")
    return probs


def write_report(report: Dict, path: str) -> str:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
    return path


def validate_report(report: Dict) -> List[str]:
    """Schema check for BENCH_paper.json; returns a list of problems
    (empty == valid). Used by tests and by CI before upload."""
    probs: List[str] = []
    for key in ("schema", "campaign", "paper_targets", "num_cells",
                "cells", "pairs", "differential", "all_checks_pass"):
        if key not in report:
            probs.append(f"missing top-level key {key!r}")
    if probs:
        return probs
    if report["schema"] != SCHEMA:
        probs.append(f"schema {report['schema']!r} != {SCHEMA!r}")
    if report["num_cells"] != len(report["cells"]):
        probs.append("num_cells does not match len(cells)")
    for i, cell in enumerate(report["cells"]):
        for f in _REQUIRED_CELL_FIELDS:
            if f not in cell:
                probs.append(f"cells[{i}] missing {f!r}")
    if not report["pairs"]:
        probs.append("no rapid-vs-baseline pairs derived")
    for i, pair in enumerate(report["pairs"]):
        for f in _REQUIRED_PAIR_FIELDS:
            if f not in pair:
                probs.append(f"pairs[{i}] missing {f!r}")
        en = pair.get("energy", {})
        for f in ("cpu_ratio", "gpu_ratio", "total_ratio"):
            if f not in en:
                probs.append(f"pairs[{i}].energy missing {f!r}")
    for i, chk in enumerate(report["differential"]):
        if chk.get("status") not in ("PASS", "FAIL", "SKIP"):
            probs.append(f"differential[{i}] bad status "
                         f"{chk.get('status')!r}")
    return probs
