"""Paper-metrics campaign runner + CLI.

``python -m repro.eval.campaign --fast``   -- CPU-sized paired grid:
host-sim AND device runners over the tiny graph, every host/device and
rapid/baseline pair differentially verified in-line, headline ratios
(throughput speedup, fetch reduction, modelled energy) derived per
pair, everything written to ``artifacts/BENCH_paper.json``.

``--full`` swaps in the paper-scale host grid (Tables 2/3 axes; slow).
``--host-only`` skips the device subprocess (e.g. minimal CI images).
``--loop-sampler`` swaps every cell's schedule path to the per-batch
oracle (``build_schedule(compiler="loop")``); the default is the
vectorized epoch-at-once compiler -- schedules are bit-identical either
way, so all differential checks must pass under both.
``--schedule-backend device`` swaps every cell to the accelerator
schedule compiler (DESIGN.md §2.2; device cells also go lazy/device-
resident) -- same bit-parity contract, same all-checks-pass bar.
``--inject-miscount`` perturbs one cell's counters AFTER measurement --
the differential layer must then fail and the CLI exit non-zero; this
is the self-test proving the checks have teeth.

Exit code: 0 iff every differential check passes.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Callable, List, Optional

from repro.eval.spec import CampaignSpec, fast_grid, fault_grid, full_grid
from repro.eval.cells import (CellResult, run_host_cell,
                              run_device_cells, device_child_main)
from repro.eval.differential import verify_cells, verify_fault_pairs
from repro.eval.report import (build_fault_report, build_report,
                               validate_fault_report, write_report)

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
DEFAULT_OUT = os.path.join(ROOT, "artifacts", "BENCH_paper.json")
FAULT_OUT = os.path.join(ROOT, "artifacts", "BENCH_fault.json")


def run_campaign(spec: CampaignSpec, include_device: bool = True,
                 out_path: Optional[str] = None,
                 log: Callable[[str], None] = lambda s: None,
                 mutate_cells: Optional[Callable[[List[CellResult]],
                                                 None]] = None) -> dict:
    """Run every cell, verify, derive ratios, optionally write the
    artifact. ``mutate_cells`` is the injection hook: it edits the
    measured cells before verification (tests + ``--inject-miscount``
    use it to prove a perturbed counter is caught)."""
    cells: List[CellResult] = []
    for c in spec.host_cells():
        log(f"[cell] {c.label()} ...")
        cells.append(run_host_cell(c))
        log(f"[cell] {c.label()} done: "
            f"step={cells[-1].step_time_ms:.2f}ms "
            f"rpc={cells[-1].rpc_count}")
    dev = spec.device_cells()
    if dev and include_device:
        log(f"[cell] {len(dev)} device cell(s) via subprocess ...")
        cells.extend(run_device_cells(dev))
        for c in cells[-len(dev):]:
            log(f"[cell] {c.spec['backend']}/{c.spec['system']} done: "
                f"step={c.step_time_ms:.2f}ms lanes={c.rpc_count}")
    if mutate_cells is not None:
        mutate_cells(cells)
    checks = verify_cells(cells)
    report = build_report(spec.name, cells, checks)
    if out_path:
        write_report(report, out_path)
        log(f"[out] {out_path}")
    return report


def run_fault_campaign(include_device: bool = True,
                       out_path: Optional[str] = None,
                       log: Callable[[str], None] = lambda s: None
                       ) -> dict:
    """The fault campaign (ISSUE: robustness): the fast-grid rapidgnn
    scenario re-run under named fault profiles, each injection verified
    to (a) fire and (b) recover bit-exactly against its clean twin.
    Artifact: ``artifacts/BENCH_fault.json``."""
    spec = fault_grid()
    cells: List[CellResult] = []
    for c in spec.host_cells():
        log(f"[cell] {c.label()} ...")
        cells.append(run_host_cell(c))
        log(f"[cell] {c.label()} done: fires={cells[-1].fault_events} "
            f"degraded={cells[-1].degraded_epochs}")
    dev = spec.device_cells()
    if dev and include_device:
        log(f"[cell] {len(dev)} device cell(s) via subprocess ...")
        cells.extend(run_device_cells(dev))
        for c in cells[-len(dev):]:
            log(f"[cell] {c.spec['backend']}/"
                f"{c.spec.get('fault_profile', 'none')} done: "
                f"fires={c.fault_events} degraded={c.degraded_epochs} "
                f"retries={c.stage_retries}")
    checks = verify_cells(cells) + verify_fault_pairs(cells)
    report = build_fault_report(spec.name, cells, checks)
    if out_path:
        write_report(report, out_path)
        log(f"[out] {out_path}")
    return report


def _print_fault_report(report: dict) -> None:
    print(f"campaign={report['campaign']} cells={report['num_cells']}")
    for r in report["fault_summary"]:
        print(f"  {r['backend']:6s} f={r['fault_profile']:15s} "
              f"fires={r['fault_events']} degraded={r['degraded_epochs']} "
              f"retries={r['retry_total']} "
              f"recovery_wall={r['recovery_wall_s']}s")
    n_fail = sum(1 for c in report["differential"]
                 if c["status"] == "FAIL")
    n_pass = sum(1 for c in report["differential"]
                 if c["status"] == "PASS")
    print(f"differential: {n_pass} passed, {n_fail} failed")
    for c in report["differential"]:
        if c["status"] == "FAIL":
            print(f"  FAIL {c['check']} @ {c['cell']}: {c['detail']}")


def _print_report(report: dict) -> None:
    print(f"campaign={report['campaign']} cells={report['num_cells']} "
          f"pairs={len(report['pairs'])}")
    for p in report["pairs"]:
        sc = p["scenario"]
        print(f"  {p['backend']:6s} rapid vs {p['baseline_system']:10s} "
              f"{sc['dataset']}/b{sc['batch_size']}: "
              f"speedup={p['throughput_speedup']}x "
              f"fetch_reduction={p['fetch_reduction_x']}x "
              f"energy_total_ratio={p['energy']['total_ratio']}")
    n_fail = sum(1 for c in report["differential"]
                 if c["status"] == "FAIL")
    n_pass = sum(1 for c in report["differential"]
                 if c["status"] == "PASS")
    print(f"differential: {n_pass} passed, {n_fail} failed")
    for c in report["differential"]:
        if c["status"] == "FAIL":
            print(f"  FAIL {c['check']} @ {c['cell']}: {c['detail']}")


def _inject_miscount(cells: List[CellResult]) -> None:
    """Perturb one measured counter (the self-test of the checks)."""
    c = cells[0]
    c.rpc_count += 1
    if c.miss_matrix and c.miss_matrix[0]:
        c.miss_matrix[0][0] += 1
    print(f"[inject] perturbed counters of "
          f"{c.spec['backend']}/{c.spec['system']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="RapidGNN paper-metrics campaign")
    ap.add_argument("--fast", action="store_true",
                    help="CPU-sized paired grid (default)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale host grid + device pair (slow)")
    ap.add_argument("--host-only", action="store_true",
                    help="skip device-backend cells (no subprocess)")
    ap.add_argument("--fault", action="store_true",
                    help="run the fault-injection campaign instead "
                         "(artifacts/BENCH_fault.json)")
    ap.add_argument("--loop-sampler", action="store_true",
                    help="build schedules with the per-batch oracle "
                         "sampler instead of the batched compiler")
    ap.add_argument("--schedule-backend", choices=("numpy", "device"),
                    default="numpy",
                    help="where schedules compile: numpy (default) or "
                         "the accelerator port of the epoch compiler")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="artifact path (default artifacts/"
                         "BENCH_paper.json)")
    ap.add_argument("--inject-miscount", action="store_true",
                    help="perturb one cell's counters post-measurement; "
                         "differential checks must fail")
    # internal: the device-cell worker (spawned by run_device_cells
    # with XLA_FLAGS pinning the emulated device count)
    ap.add_argument("--device-child", nargs=2,
                    metavar=("SPECS", "OUT"), help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.device_child:
        device_child_main(*args.device_child)
        return 0

    if args.fault:
        out = (args.out if args.out != DEFAULT_OUT else FAULT_OUT)
        report = run_fault_campaign(include_device=not args.host_only,
                                    out_path=out, log=print)
        _print_fault_report(report)
        probs = validate_fault_report(report)
        for p in probs:
            print(f"  INVALID: {p}")
        if not report["all_checks_pass"]:
            print("recovery FAILED: fault campaign checks did not pass")
        return 0 if report["all_checks_pass"] and not probs else 1

    spec = full_grid() if args.full else fast_grid()
    if args.loop_sampler:
        import dataclasses
        spec = CampaignSpec(
            name=f"{spec.name}-loop",
            cells=tuple(dataclasses.replace(c, schedule_compiler="loop")
                        for c in spec.cells))
    if args.schedule_backend != "numpy":
        import dataclasses
        spec = CampaignSpec(
            name=f"{spec.name}-{args.schedule_backend}",
            cells=tuple(dataclasses.replace(
                c, schedule_backend=args.schedule_backend)
                for c in spec.cells))
    report = run_campaign(
        spec, include_device=not args.host_only, out_path=args.out,
        log=print,
        mutate_cells=_inject_miscount if args.inject_miscount else None)
    _print_report(report)
    return 0 if report["all_checks_pass"] else 1


if __name__ == "__main__":
    sys.exit(main())
