"""Host-side replay of the device pull plans for byte accounting.

Rebuilds the exact deterministic schedule a host-sim cell consumed and
pushes every batch through ``build_pull_plan``, yielding the device
path's payload (true residual-miss rows -- must equal the host sim's
``remote_bytes`` exactly) and wire bytes (the padded all_to_all lanes
the static-shape collective actually moves). Pure numpy: no mesh, no
subprocess -- this is the single-device cross-check used by the
``fig4_comm_volume`` benchmark; full on-mesh accounting comes from the
campaign's device cells.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


def replay_device_bytes(dataset: str, batch_size: int, workers: int,
                        epochs: int, n_hot: int, s0: int = 42,
                        worker: int = 0,
                        fanouts: Sequence[int] = (25, 10),
                        partition: str = "metis"
                        ) -> Tuple[int, int, int, int, int]:
    """-> (payload_bytes, wire_bytes, request_bytes, cache_bytes, steps)
    for one worker.

    The lane bound ``k_max`` is the ALL-workers epoch maximum
    (``epoch_k_max``), as the compiled collective uses -- wire bytes
    reflect what actually moves, not worker-local padding.
    ``request_bytes`` is the id-lane leg shipped BEFORE each payload
    comes back (the previously unaccounted half of the wire)."""
    from repro.graph import load_dataset, partition_graph, KHopSampler
    from repro.core import build_schedule
    from repro.dist import DeviceView, build_pull_plan, epoch_k_max
    from repro.dist.gnn_step import _batch_miss

    g = load_dataset(dataset)
    pg = partition_graph(g, workers, partition)
    sampler = KHopSampler(g, fanouts=list(fanouts),
                          batch_size=batch_size)
    ws_all = [build_schedule(sampler, pg, worker=w, s0=s0,
                             num_epochs=epochs, n_hot=n_hot)
              for w in range(workers)]
    dv = DeviceView.build(pg)
    row = g.feat_dim * g.features.itemsize
    payload = wire = request = cache = steps = 0
    for e in range(epochs):
        es_list = [ws.epoch(e) for ws in ws_all]
        caches = [dv.remap_cache(es.cache_ids) for es in es_list]
        cache += es_list[worker].cache_ids.shape[0] * row   # VectorPull
        k_max = epoch_k_max(es_list, caches, dv)
        for b in es_list[worker].batches:
            dev, miss = _batch_miss(b, caches[worker], dv, worker)
            plan = build_pull_plan(dev[miss].astype(np.int32),
                                   np.flatnonzero(miss).astype(np.int32),
                                   dv.owner_d, pg.num_parts, k_max)
            payload += plan.payload_bytes(row)
            wire += plan.wire_bytes(row)
            request += plan.request_bytes()
            steps += 1
    return payload, wire, request, cache, steps


def replay_topology_bytes(dataset: str, batch_size: int, workers: int,
                          epochs: int, n_hot: int, hosts: int,
                          s0: int = 42,
                          fanouts: Sequence[int] = (25, 10),
                          partition: str = "metis",
                          dcn_bias: float = 0.0) -> dict:
    """Two-tier traffic cut for the topology benchmark (Fig-4 style).

    Replays EVERY worker's schedule and splits each residual miss by the
    owner's host under a ``hosts x (workers // hosts)`` topology:
    same-host misses ride the cheap ici wire, cross-host misses the DCN.
    Returns totals for both tiers plus the flat total they must sum to
    (the byte-sum identity) -- and, when ``dcn_bias > 0``, the same
    accounting under a DCN-biased hot set (``select_hot_set`` weighted
    toward cross-host owners), quantifying how much inter-host traffic
    the bias removes."""
    from repro.graph import load_dataset, partition_graph, KHopSampler
    from repro.core import build_schedule
    from repro.dist import (DeviceView, Topology, build_pull_plan,
                            epoch_k_max)
    from repro.dist.gnn_step import _batch_miss

    topo = Topology.hierarchical(hosts, workers // hosts)
    g = load_dataset(dataset)
    pg = partition_graph(g, workers, partition)
    sampler = KHopSampler(g, fanouts=list(fanouts),
                          batch_size=batch_size)
    row = g.feat_dim * g.features.itemsize
    dv = DeviceView.build(pg)

    def _account(owner_bias):
        """-> (intra, inter, flat) bytes over all workers and epochs.

        ``intra``/``inter`` split each miss by the owner's host;
        ``flat`` re-counts the SAME misses through ``build_pull_plan``
        (the flat-mesh wire format), so intra + inter == flat is a
        cross-accounting identity, not a tautology."""
        ws_all = [build_schedule(sampler, pg, worker=w, s0=s0,
                                 num_epochs=epochs, n_hot=n_hot,
                                 owner_bias=owner_bias[w]
                                 if owner_bias is not None else None)
                  for w in range(workers)]
        intra = inter = flat = 0
        for e in range(epochs):
            es_list = [ws.epoch(e) for ws in ws_all]
            caches = [dv.remap_cache(es.cache_ids) for es in es_list]
            k_max = epoch_k_max(es_list, caches, dv)
            for w in range(workers):
                for b in es_list[w].batches:
                    dev, miss = _batch_miss(b, caches[w], dv, w)
                    owners = np.asarray(dv.owner_d)[dev[miss]]
                    same = int(np.count_nonzero(
                        topo.same_host(owners, w)))
                    intra += same * row
                    inter += (int(miss.sum()) - same) * row
                    plan = build_pull_plan(
                        dev[miss].astype(np.int32),
                        np.flatnonzero(miss).astype(np.int32),
                        dv.owner_d, pg.num_parts, k_max)
                    flat += plan.payload_bytes(row)
        return intra, inter, flat

    intra, inter, flat = _account(None)
    out = {"hosts": hosts, "devices_per_host": workers // hosts,
           "intra_bytes": intra, "inter_bytes": inter,
           "flat_bytes": flat}
    if dcn_bias > 0:
        bias = [topo.owner_bias(w, dcn_bias) for w in range(workers)]
        bi, bx, bf = _account(bias)
        out["biased_intra_bytes"] = bi
        out["biased_inter_bytes"] = bx
        out["biased_flat_bytes"] = bf
        out["dcn_bias"] = dcn_bias
    return out
