"""Host-side replay of the device pull plans for byte accounting.

Rebuilds the exact deterministic schedule a host-sim cell consumed and
pushes every batch through ``build_pull_plan``, yielding the device
path's payload (true residual-miss rows -- must equal the host sim's
``remote_bytes`` exactly) and wire bytes (the padded all_to_all lanes
the static-shape collective actually moves). Pure numpy: no mesh, no
subprocess -- this is the single-device cross-check used by the
``fig4_comm_volume`` benchmark; full on-mesh accounting comes from the
campaign's device cells.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


def replay_device_bytes(dataset: str, batch_size: int, workers: int,
                        epochs: int, n_hot: int, s0: int = 42,
                        worker: int = 0,
                        fanouts: Sequence[int] = (25, 10),
                        partition: str = "metis"
                        ) -> Tuple[int, int, int, int]:
    """-> (payload_bytes, wire_bytes, cache_bytes, steps) for one worker.

    The lane bound ``k_max`` is the ALL-workers epoch maximum
    (``epoch_k_max``), as the compiled collective uses -- wire bytes
    reflect what actually moves, not worker-local padding."""
    from repro.graph import load_dataset, partition_graph, KHopSampler
    from repro.core import build_schedule
    from repro.dist import DeviceView, build_pull_plan, epoch_k_max
    from repro.dist.gnn_step import _batch_miss

    g = load_dataset(dataset)
    pg = partition_graph(g, workers, partition)
    sampler = KHopSampler(g, fanouts=list(fanouts),
                          batch_size=batch_size)
    ws_all = [build_schedule(sampler, pg, worker=w, s0=s0,
                             num_epochs=epochs, n_hot=n_hot)
              for w in range(workers)]
    dv = DeviceView.build(pg)
    row = g.feat_dim * g.features.itemsize
    payload = wire = cache = steps = 0
    for e in range(epochs):
        es_list = [ws.epoch(e) for ws in ws_all]
        caches = [dv.remap_cache(es.cache_ids) for es in es_list]
        cache += es_list[worker].cache_ids.shape[0] * row   # VectorPull
        k_max = epoch_k_max(es_list, caches, dv)
        for b in es_list[worker].batches:
            dev, miss = _batch_miss(b, caches[worker], dv, worker)
            plan = build_pull_plan(dev[miss].astype(np.int32),
                                   np.flatnonzero(miss).astype(np.int32),
                                   dv.owner_d, pg.num_parts, k_max)
            payload += plan.payload_bytes(row)
            wire += plan.wire_bytes(row)
            steps += 1
    return payload, wire, cache, steps
