"""Differential verification: the campaign doubles as a correctness
harness (DESIGN.md §7).

Three layers, all operating on serialized ``CellResult`` records (so a
corrupted counter in the artifact is caught exactly like a live one):

  * internal      -- accounting identities within one cell: remote bytes
                     == fetched rows x row bytes, the per-(epoch, worker)
                     miss matrix sums to the scalar counter, device cells
                     compiled exactly once.
  * cross-backend -- host-sim vs device cells of the SAME system +
                     scenario: the device pull-lane miss matrix must
                     equal the host ``cache_misses`` matrix per (epoch,
                     worker) (the ``assert_host_parity`` contract,
                     generalized to every paired cell of a campaign),
                     payload bytes must match, and the rapid cells'
                     VectorPull staging bytes must match.
  * cross-system  -- rapid vs baseline cells of the SAME backend +
                     scenario: identical schedules + exact feature paths
                     imply bit-identical loss curves (the cache is
                     lossless), and rapid may never fetch more than the
                     baseline.

Every check yields a ``CheckResult``; ``verify_cells`` never raises --
the campaign collects FAILs into the report and the CLI exits non-zero.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.eval.cells import CellResult

PASS, FAIL, SKIP = "PASS", "FAIL", "SKIP"

#: loss agreement only holds between systems sampling identical blocks
#: (gcn uses wider fanouts, dgl-random a different partition -> different
#: schedules); these two share everything but the cache.
LOSS_COMPARABLE = {"rapidgnn", "dgl-metis"}


@dataclasses.dataclass
class CheckResult:
    cell: str                   # label of the (primary) cell checked
    check: str
    status: str                 # PASS | FAIL | SKIP
    detail: str = ""

    def to_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)


def _label(c: CellResult) -> str:
    s = c.spec
    return (f"{s['backend']}/{s['system']}/{s['dataset']}"
            f"/b{s['batch_size']}/w{s['workers']}/h{s['n_hot']}"
            f"/e{s['epochs']}")


def _scenario(c: CellResult) -> Tuple:
    """EFFECTIVE schedule key (CellSpec.scenario_key): equal keys really
    did consume identical schedules, so dgl-random / gcn cells -- whose
    partition/fanouts differ by design -- never pair with rapidgnn."""
    from repro.eval.spec import CellSpec
    return CellSpec.from_dict(c.spec).scenario_key()


# ---------------------------------------------------------------------------
# layer 1: internal identities
# ---------------------------------------------------------------------------

def check_cell_internal(c: CellResult) -> List[CheckResult]:
    out = []
    name = _label(c)

    want = c.rpc_count * c.row_bytes
    out.append(CheckResult(name, "bytes_identity",
                           PASS if c.remote_bytes == want else FAIL,
                           f"remote_bytes={c.remote_bytes} vs "
                           f"rpc_count*row={want}"))

    msum = int(np.asarray(c.miss_matrix, dtype=np.int64).sum())
    out.append(CheckResult(name, "miss_matrix_sum",
                           PASS if msum == c.cache_misses else FAIL,
                           f"sum(miss_matrix)={msum} vs "
                           f"cache_misses={c.cache_misses}"))

    if c.backend == "device":
        out.append(CheckResult(
            name, "one_compilation",
            PASS if c.trace_count == 1 else FAIL,
            f"trace_count={c.trace_count} (multi-epoch runner must "
            f"compile once)"))
        out.append(CheckResult(
            name, "payload_identity",
            PASS if c.payload_bytes == c.cache_misses * c.row_bytes
            else FAIL,
            f"payload_bytes={c.payload_bytes} vs "
            f"lanes*row={c.cache_misses * c.row_bytes}"))
    return out


# ---------------------------------------------------------------------------
# layer 2: host vs device (same system, same scenario)
# ---------------------------------------------------------------------------

def check_backend_pair(host: CellResult, dev: CellResult
                       ) -> List[CheckResult]:
    out = []
    name = f"{_label(host)} <> {_label(dev)}"
    if host.workers_run != dev.workers_run:
        return [CheckResult(name, "miss_parity", SKIP,
                            f"host ran workers {host.workers_run}, "
                            f"device {dev.workers_run} -- run the host "
                            f"cell with all_workers=True to pair")]

    hm = np.asarray(host.miss_matrix, dtype=np.int64)
    dm = np.asarray(dev.miss_matrix, dtype=np.int64)
    if hm.shape != dm.shape:
        out.append(CheckResult(name, "miss_parity", FAIL,
                               f"shape {hm.shape} vs {dm.shape}"))
    elif not np.array_equal(hm, dm):
        bad = np.argwhere(hm != dm)[:4].tolist()
        out.append(CheckResult(
            name, "miss_parity", FAIL,
            f"device pull-lane counts diverge from host cache_misses "
            f"at (epoch, worker) {bad}"))
    else:
        out.append(CheckResult(name, "miss_parity", PASS,
                               f"{hm.shape[0]}x{hm.shape[1]} matrix "
                               f"equal, total={int(hm.sum())}"))

    out.append(CheckResult(
        name, "payload_bytes",
        PASS if host.remote_bytes == dev.payload_bytes else FAIL,
        f"host remote_bytes={host.remote_bytes} vs device "
        f"payload={dev.payload_bytes}"))

    if host.system == "rapidgnn":
        out.append(CheckResult(
            name, "vector_pull_bytes",
            PASS if host.vector_pull_bytes == dev.vector_pull_bytes
            else FAIL,
            f"host C_s/C_sec staging={host.vector_pull_bytes} vs "
            f"device={dev.vector_pull_bytes}"))
    return out


# ---------------------------------------------------------------------------
# layer 3: rapid vs baseline (same backend, same scenario)
# ---------------------------------------------------------------------------

def check_system_pair(rapid: CellResult, base: CellResult
                      ) -> List[CheckResult]:
    out = []
    name = f"{_label(rapid)} <> {_label(base)}"

    out.append(CheckResult(
        name, "fetch_not_more",
        PASS if rapid.rpc_count <= base.rpc_count else FAIL,
        f"rapid fetches {rapid.rpc_count} vs baseline "
        f"{base.rpc_count}"))

    if (base.system in LOSS_COMPARABLE and rapid.spec["train"]
            and base.spec["train"]):
        rl, bl = np.asarray(rapid.losses), np.asarray(base.losses)
        if rl.shape != bl.shape:
            out.append(CheckResult(name, "loss_agreement", FAIL,
                                   f"curve lengths {rl.shape} vs "
                                   f"{bl.shape}"))
        elif not np.allclose(rl, bl, rtol=1e-4, atol=1e-5):
            i = int(np.argmax(np.abs(rl - bl)))
            out.append(CheckResult(
                name, "loss_agreement", FAIL,
                f"curves diverge at step {i}: {rl[i]:.6f} vs "
                f"{bl[i]:.6f} (cache must be lossless)"))
        else:
            out.append(CheckResult(name, "loss_agreement", PASS,
                                   f"{rl.shape[0]} steps agree"))
    return out


# ---------------------------------------------------------------------------
# campaign-level driver
# ---------------------------------------------------------------------------

def verify_cells(cells: Sequence[CellResult]) -> List[CheckResult]:
    """All applicable checks over a campaign's cells. Pairings are
    derived from the specs: equal scenario + system across backends,
    equal scenario + backend across systems."""
    out: List[CheckResult] = []
    for c in cells:
        out.extend(check_cell_internal(c))

    by_sys: Dict[Tuple, Dict[str, CellResult]] = {}
    by_backend: Dict[Tuple, Dict[str, CellResult]] = {}
    for c in cells:
        by_sys.setdefault((_scenario(c), c.system), {})[c.backend] = c
        by_backend.setdefault((_scenario(c), c.backend),
                              {})[c.system] = c

    for group in by_sys.values():
        if "host" in group and "device" in group:
            out.extend(check_backend_pair(group["host"],
                                          group["device"]))
    for group in by_backend.values():
        rapid = group.get("rapidgnn")
        if rapid is None:
            continue
        for sysname, cell in sorted(group.items()):
            if sysname != "rapidgnn":
                out.extend(check_system_pair(rapid, cell))
    return out


def all_pass(checks: Sequence[CheckResult]) -> bool:
    return all(c.status != FAIL for c in checks)


def failures(checks: Sequence[CheckResult]) -> List[CheckResult]:
    return [c for c in checks if c.status == FAIL]
