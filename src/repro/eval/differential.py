"""Differential verification: the campaign doubles as a correctness
harness (DESIGN.md §7).

Three layers, all operating on serialized ``CellResult`` records (so a
corrupted counter in the artifact is caught exactly like a live one):

  * internal      -- accounting identities within one cell: remote bytes
                     == fetched rows x row bytes, the per-(epoch, worker)
                     miss matrix sums to the scalar counter, device cells
                     compiled exactly once.
  * cross-backend -- host-sim vs device cells of the SAME system +
                     scenario: the device pull-lane miss matrix must
                     equal the host ``cache_misses`` matrix per (epoch,
                     worker) (the ``assert_host_parity`` contract,
                     generalized to every paired cell of a campaign),
                     payload bytes must match, and the rapid cells'
                     VectorPull staging bytes must match.
  * cross-system  -- rapid vs baseline cells of the SAME backend +
                     scenario: identical schedules + exact feature paths
                     imply bit-identical loss curves (the cache is
                     lossless), and rapid may never fetch more than the
                     baseline.
  * cross-topology -- flat vs hierarchical device cells of the SAME
                     system + scenario: the two-tier pull plan is a
                     repartition of the flat one, so miss matrices and
                     loss curves must be BIT-equal and the hierarchical
                     cell's intra + inter bytes must sum to the flat
                     cell's remote bytes exactly (DESIGN.md §6.7).

Every check yields a ``CheckResult``; ``verify_cells`` never raises --
the campaign collects FAILs into the report and the CLI exits non-zero.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.eval.cells import CellResult

PASS, FAIL, SKIP = "PASS", "FAIL", "SKIP"

#: loss agreement only holds between systems sampling identical blocks
#: (gcn uses wider fanouts, dgl-random a different partition -> different
#: schedules); these two share everything but the cache.
LOSS_COMPARABLE = {"rapidgnn", "dgl-metis"}


@dataclasses.dataclass
class CheckResult:
    cell: str                   # label of the (primary) cell checked
    check: str
    status: str                 # PASS | FAIL | SKIP
    detail: str = ""

    def to_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)


def _label(c: CellResult) -> str:
    s = c.spec
    base = (f"{s['backend']}/{s['system']}/{s['dataset']}"
            f"/b{s['batch_size']}/w{s['workers']}/h{s['n_hot']}"
            f"/e{s['epochs']}")
    if _topology(c) != "flat":
        base += f"/t{s['topology']}"
    if s.get("fault_profile", "none") != "none":
        base += f"/f{s['fault_profile']}"
    return base


def _topology(c: CellResult) -> str:
    return c.spec.get("topology", "flat")


def _scenario(c: CellResult) -> Tuple:
    """EFFECTIVE schedule key (CellSpec.scenario_key): equal keys really
    did consume identical schedules, so dgl-random / gcn cells -- whose
    partition/fanouts differ by design -- never pair with rapidgnn."""
    from repro.eval.spec import CellSpec
    return CellSpec.from_dict(c.spec).scenario_key()


# ---------------------------------------------------------------------------
# layer 1: internal identities
# ---------------------------------------------------------------------------

def check_cell_internal(c: CellResult) -> List[CheckResult]:
    out = []
    name = _label(c)

    want = c.rpc_count * c.row_bytes
    out.append(CheckResult(name, "bytes_identity",
                           PASS if c.remote_bytes == want else FAIL,
                           f"remote_bytes={c.remote_bytes} vs "
                           f"rpc_count*row={want}"))

    msum = int(np.asarray(c.miss_matrix, dtype=np.int64).sum())
    out.append(CheckResult(name, "miss_matrix_sum",
                           PASS if msum == c.cache_misses else FAIL,
                           f"sum(miss_matrix)={msum} vs "
                           f"cache_misses={c.cache_misses}"))

    if c.backend == "device":
        # a degraded (uncached) epoch may widen the pull-lane bound and
        # cost at most ONE extra trace each; non-degraded runs stay at 1
        bound = 1 + c.degraded_epochs
        out.append(CheckResult(
            name, "one_compilation",
            PASS if 1 <= c.trace_count <= bound else FAIL,
            f"trace_count={c.trace_count} (multi-epoch runner must "
            f"compile once, +<=1 per degraded epoch; "
            f"degraded={c.degraded_epochs})"))
        out.append(CheckResult(
            name, "payload_identity",
            PASS if c.payload_bytes == c.cache_misses * c.row_bytes
            else FAIL,
            f"payload_bytes={c.payload_bytes} vs "
            f"lanes*row={c.cache_misses * c.row_bytes}"))
        # request leg: the padded int32 id matrices every pull ships
        # BEFORE the payload comes back (satellite bugfix: previously
        # never accounted anywhere)
        want_req = c.wire_rows * 4
        out.append(CheckResult(
            name, "request_bytes_identity",
            PASS if c.request_bytes == want_req else FAIL,
            f"request_bytes={c.request_bytes} vs "
            f"wire_rows*4={want_req}"))
        # two-tier split: tiers partition the flat counters exactly
        # (flat cells: intra == total, inter == 0)
        tier_ok = (c.intra_misses + c.inter_misses == c.cache_misses
                   and c.intra_bytes + c.inter_bytes == c.remote_bytes
                   and c.intra_wire_rows + c.inter_wire_rows
                   == c.wire_rows)
        out.append(CheckResult(
            name, "tier_sum_identity",
            PASS if tier_ok else FAIL,
            f"intra+inter misses={c.intra_misses}+{c.inter_misses} vs "
            f"{c.cache_misses}, bytes={c.intra_bytes}+{c.inter_bytes} "
            f"vs {c.remote_bytes}, wire={c.intra_wire_rows}+"
            f"{c.inter_wire_rows} vs {c.wire_rows}"))
    return out


# ---------------------------------------------------------------------------
# layer 2: host vs device (same system, same scenario)
# ---------------------------------------------------------------------------

def check_backend_pair(host: CellResult, dev: CellResult
                       ) -> List[CheckResult]:
    out = []
    name = f"{_label(host)} <> {_label(dev)}"
    if host.workers_run != dev.workers_run:
        return [CheckResult(name, "miss_parity", SKIP,
                            f"host ran workers {host.workers_run}, "
                            f"device {dev.workers_run} -- run the host "
                            f"cell with all_workers=True to pair")]

    hm = np.asarray(host.miss_matrix, dtype=np.int64)
    dm = np.asarray(dev.miss_matrix, dtype=np.int64)
    if hm.shape != dm.shape:
        out.append(CheckResult(name, "miss_parity", FAIL,
                               f"shape {hm.shape} vs {dm.shape}"))
    elif not np.array_equal(hm, dm):
        bad = np.argwhere(hm != dm)[:4].tolist()
        out.append(CheckResult(
            name, "miss_parity", FAIL,
            f"device pull-lane counts diverge from host cache_misses "
            f"at (epoch, worker) {bad}"))
    else:
        out.append(CheckResult(name, "miss_parity", PASS,
                               f"{hm.shape[0]}x{hm.shape[1]} matrix "
                               f"equal, total={int(hm.sum())}"))

    out.append(CheckResult(
        name, "payload_bytes",
        PASS if host.remote_bytes == dev.payload_bytes else FAIL,
        f"host remote_bytes={host.remote_bytes} vs device "
        f"payload={dev.payload_bytes}"))

    if host.system == "rapidgnn":
        out.append(CheckResult(
            name, "vector_pull_bytes",
            PASS if host.vector_pull_bytes == dev.vector_pull_bytes
            else FAIL,
            f"host C_s/C_sec staging={host.vector_pull_bytes} vs "
            f"device={dev.vector_pull_bytes}"))
    return out


# ---------------------------------------------------------------------------
# layer 3: rapid vs baseline (same backend, same scenario)
# ---------------------------------------------------------------------------

def check_system_pair(rapid: CellResult, base: CellResult
                      ) -> List[CheckResult]:
    out = []
    name = f"{_label(rapid)} <> {_label(base)}"

    out.append(CheckResult(
        name, "fetch_not_more",
        PASS if rapid.rpc_count <= base.rpc_count else FAIL,
        f"rapid fetches {rapid.rpc_count} vs baseline "
        f"{base.rpc_count}"))

    if (base.system in LOSS_COMPARABLE and rapid.spec["train"]
            and base.spec["train"]):
        rl, bl = np.asarray(rapid.losses), np.asarray(base.losses)
        if rl.shape != bl.shape:
            out.append(CheckResult(name, "loss_agreement", FAIL,
                                   f"curve lengths {rl.shape} vs "
                                   f"{bl.shape}"))
        elif not np.allclose(rl, bl, rtol=1e-4, atol=1e-5):
            i = int(np.argmax(np.abs(rl - bl)))
            out.append(CheckResult(
                name, "loss_agreement", FAIL,
                f"curves diverge at step {i}: {rl[i]:.6f} vs "
                f"{bl[i]:.6f} (cache must be lossless)"))
        else:
            out.append(CheckResult(name, "loss_agreement", PASS,
                                   f"{rl.shape[0]} steps agree"))
    return out


# ---------------------------------------------------------------------------
# layer 3b: flat vs hierarchical topology (same system, same scenario)
# ---------------------------------------------------------------------------

def check_topology_pair(flat: CellResult, hier: CellResult
                        ) -> List[CheckResult]:
    """Two-tier exchange vs its flat twin: the hierarchical plan is a
    bit-exact repartition of the flat one (verified empirically for the
    all_to_all semantics, pinned here for whole campaigns): same misses,
    same losses, and the tier bytes sum to the flat payload exactly."""
    out = []
    name = f"{_label(flat)} <> {_label(hier)}"

    fm = np.asarray(flat.miss_matrix, dtype=np.int64)
    hm = np.asarray(hier.miss_matrix, dtype=np.int64)
    if fm.shape != hm.shape or not np.array_equal(fm, hm):
        out.append(CheckResult(
            name, "topology_miss_parity", FAIL,
            f"hierarchical miss matrix diverges from flat "
            f"(flat total={int(fm.sum())}, hier total={int(hm.sum())})"))
    else:
        out.append(CheckResult(name, "topology_miss_parity", PASS,
                               f"{fm.shape[0]}x{fm.shape[1]} matrix "
                               f"equal, total={int(fm.sum())}"))

    tier_sum = hier.intra_bytes + hier.inter_bytes
    out.append(CheckResult(
        name, "topology_byte_sum",
        PASS if tier_sum == flat.remote_bytes else FAIL,
        f"intra+inter={hier.intra_bytes}+{hier.inter_bytes}={tier_sum} "
        f"vs flat remote_bytes={flat.remote_bytes}"))

    fl, hl = np.asarray(flat.losses), np.asarray(hier.losses)
    if fl.shape != hl.shape:
        out.append(CheckResult(name, "topology_loss_parity", FAIL,
                               f"curve lengths {fl.shape} vs "
                               f"{hl.shape}"))
    elif not np.array_equal(fl, hl):
        i = int(np.argmax(fl != hl))
        out.append(CheckResult(
            name, "topology_loss_parity", FAIL,
            f"curves diverge at step {i}: {fl[i]!r} vs {hl[i]!r} "
            f"(two-tier exchange must be bit-equal to flat)"))
    else:
        out.append(CheckResult(name, "topology_loss_parity", PASS,
                               f"{fl.shape[0]} steps bit-equal"))
    return out


# ---------------------------------------------------------------------------
# layer 4: faulted vs clean twin (same backend+system, fault neutralized)
# ---------------------------------------------------------------------------

def verify_fault_pairs(cells: Sequence[CellResult]) -> List[CheckResult]:
    """Recovery verification for the fault campaign: each faulted cell
    must (a) actually have fired its injections, (b) end with a loss
    curve BIT-equal to its clean twin (every tolerated fault recovers
    losslessly -- DESIGN.md §10), and (c) on the device backend keep
    non-degraded epochs' pull-lane rows identical to the clean cell's
    (degraded epochs legitimately pull more)."""
    from repro.eval.spec import CellSpec

    out: List[CheckResult] = []
    groups: Dict[Tuple, Dict[str, CellResult]] = {}
    for c in cells:
        spec = CellSpec.from_dict(c.spec)
        neutral = dataclasses.replace(spec, fault_profile="none",
                                      fault_seed=0)
        groups.setdefault((c.backend, c.system, neutral.scenario_key()),
                          {})[spec.fault_profile] = c

    for (_be, _sy, _key), group in sorted(groups.items(),
                                          key=lambda kv: str(kv[0])):
        clean = group.get("none")
        for prof in sorted(group):
            if prof == "none":
                continue
            c = group[prof]
            name = _label(c)
            out.append(CheckResult(
                name, "fault_fired",
                PASS if c.fault_events > 0 else FAIL,
                f"fault_events={c.fault_events} (profile {prof!r} must "
                f"actually inject)"))
            if clean is None:
                out.append(CheckResult(name, "fault_loss_parity", SKIP,
                                       "no clean twin cell in campaign"))
                continue
            fl = np.asarray(c.losses)
            cl = np.asarray(clean.losses)
            if fl.shape != cl.shape:
                out.append(CheckResult(
                    name, "fault_loss_parity", FAIL,
                    f"curve lengths {fl.shape} vs clean {cl.shape}"))
            elif not np.array_equal(fl, cl):
                i = int(np.argmax(fl != cl))
                out.append(CheckResult(
                    name, "fault_loss_parity", FAIL,
                    f"recovered curve diverges from clean at step {i}: "
                    f"{fl[i]!r} vs {cl[i]!r} (recovery must be "
                    f"bit-exact)"))
            else:
                out.append(CheckResult(
                    name, "fault_loss_parity", PASS,
                    f"{fl.shape[0]} steps bit-equal under {prof!r}"))
            if c.backend == "device":
                flags = [int(em.get("degraded", 0))
                         for em in c.epoch_metrics]
                fm = np.asarray(c.miss_matrix, np.int64)
                cm = np.asarray(clean.miss_matrix, np.int64)
                keep = [e for e, d in enumerate(flags) if not d]
                ok = (fm.shape == cm.shape
                      and np.array_equal(fm[keep], cm[keep]))
                out.append(CheckResult(
                    name, "fault_miss_parity",
                    PASS if ok else FAIL,
                    f"non-degraded epochs {keep}: pull lanes "
                    f"{'equal clean' if ok else 'diverge from clean'}"))
    return out


# ---------------------------------------------------------------------------
# campaign-level driver
# ---------------------------------------------------------------------------

def verify_cells(cells: Sequence[CellResult]) -> List[CheckResult]:
    """All applicable checks over a campaign's cells. Pairings are
    derived from the specs: equal scenario + system across backends,
    equal scenario + backend across systems."""
    out: List[CheckResult] = []
    for c in cells:
        out.extend(check_cell_internal(c))

    # topology is part of every grouping key: a hierarchical device cell
    # shares its scenario key with its flat twin BY DESIGN (identical
    # schedules), so keying on scenario alone would silently overwrite
    # one of them and drop its checks
    by_sys: Dict[Tuple, Dict[str, CellResult]] = {}
    by_backend: Dict[Tuple, Dict[str, CellResult]] = {}
    by_topo: Dict[Tuple, Dict[str, CellResult]] = {}
    for c in cells:
        topo = _topology(c)
        by_sys.setdefault((_scenario(c), c.system, topo),
                          {})[c.backend] = c
        by_backend.setdefault((_scenario(c), c.backend, topo),
                              {})[c.system] = c
        if c.backend == "device":
            by_topo.setdefault((_scenario(c), c.system), {})[topo] = c

    for group in by_sys.values():
        if "host" in group and "device" in group:
            out.extend(check_backend_pair(group["host"],
                                          group["device"]))
    for group in by_backend.values():
        rapid = group.get("rapidgnn")
        if rapid is None:
            continue
        for sysname, cell in sorted(group.items()):
            if sysname != "rapidgnn":
                out.extend(check_system_pair(rapid, cell))
    for group in by_topo.values():
        flat = group.get("flat")
        if flat is None:
            continue
        for topo, cell in sorted(group.items()):
            if topo != "flat":
                out.extend(check_topology_pair(flat, cell))
    return out


def all_pass(checks: Sequence[CheckResult]) -> bool:
    return all(c.status != FAIL for c in checks)


def failures(checks: Sequence[CheckResult]) -> List[CheckResult]:
    return [c for c in checks if c.status == FAIL]
