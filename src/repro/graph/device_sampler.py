"""Device-resident whole-epoch schedule compiler (DESIGN.md §2.2).

Ports the sort-bound middle of ``KHopSampler.sample_epoch_batched`` --
the composite-key segment-unique, frontier membership, new-source
extraction and local-index resolution -- onto the accelerator as JAX
ops (``repro.kernels.seg_sort`` for the key sort, scatter/gather tables
for the unique-inverse), plus device remote-frequency counting and
hot-set ordering. The result is BIT-IDENTICAL to the numpy compiler:
every derived quantity is a deterministic function of the sorted unique
key set (frontier keys are globally distinct and ``np.unique`` outputs
are sets), so no sort-stability caveat survives into the payload.

RNG contract (the part that does NOT move): numpy's
``Generator.integers`` with broadcast (per-row) bounds consumes its
Philox stream data-dependently (masked rejection sampling), which no
fixed-shape device program can replay. The per-batch offset draws
therefore stay on the host -- the EXACT ``rngs[i].integers`` calls
``sample_batch`` makes, one independent stream per ``H(s0, w, e, i)``
(Prop 3.1) -- and the device consumes their output. Determinism is
preserved blockwise by construction, not re-derived.

Fallbacks (all bit-equal by definition -- they ARE the numpy path):
  * composite key spaces past ``KEY_INT32_MAX_SLOTS`` (device sorts are
    int32-only: jax canonicalizes int64 away without x64 mode),
  * empty epochs (``nb == 0``).

Static shapes: per-layer streams pad to power-of-two buckets with the
INT32_MAX sentinel, so XLA traces once per (bucket, nb, span) tuple and
epochs re-use each other's compiled steps.
"""
from __future__ import annotations

from functools import partial
from typing import List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graph.sampler import (FlatEpoch, KEY_INT32_MAX_SLOTS,
                                 KHopSampler, _starts, rng_from)
from repro.kernels.seg_sort import seg_sort

#: int32 padding sentinel: sorts after every real composite key (key
#: spaces are gated below 2^31, so max real key <= 2^31 - 2).
SENT = 2 ** 31 - 1

#: dense scatter-table bound for the unique-inverse / frontier-membership
#: lookups (int32 slots; same budget class as gnn_step's stamp table).
#: Wider key spaces use searchsorted instead -- still device ops, just
#: O(n log n) lanes instead of O(n) table probes.
DEVICE_TABLE_MAX_SLOTS = 1 << 26


def _bucket(n: int) -> int:
    """Power-of-two pad bucket (>= 128): bounds distinct XLA traces at
    log2(stream) per layer instead of one per exact shape."""
    return 128 if n <= 128 else 1 << (n - 1).bit_length()


def _pad_i32(x: np.ndarray, n_pad: int, fill: int = SENT) -> jnp.ndarray:
    out = np.full(n_pad, fill, np.int32)
    out[:x.shape[0]] = x
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# the per-layer device step
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("nb", "span", "use_table",
                                   "sort_backend", "interpret"))
def _frontier_step(cand_key: jax.Array, cur_key: jax.Array,
                   cur_within: jax.Array, counts: jax.Array, *,
                   nb: int, span: int, use_table: bool,
                   sort_backend: str, interpret: bool
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One sampler layer's segment-unique on device.

    cand_key (n_pad,) int32 composite ``batch * span + src`` edge keys,
    SENT-padded; cur_key (c_pad,) the current frontier's composite keys
    (globally unique), SENT-padded; cur_within (c_pad,) each frontier
    node's within-batch position; counts (nb,) per-batch frontier sizes.

    Returns (src_idx, ext_key, ext_counts): per-edge local source index
    into the NEXT frontier (pad slots garbage, host slices), the compact
    ascending stream of new composite keys (SENT-padded), and per-batch
    new-source counts -- exactly ``np.unique`` + setdiff semantics.
    """
    n_pad = cand_key.shape[0]
    ks = nb * span
    num_bits = max(int(ks - 1).bit_length(), 1)

    # segment-unique: ONE global sort acts per batch (composite keys
    # never cross segment boundaries), then head flags + compaction
    sk, _ = seg_sort(cand_key, num_bits=num_bits, backend=sort_backend,
                     interpret=interpret)
    valid = sk != SENT
    head = valid & jnp.concatenate(
        [jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    rank = jnp.cumsum(head.astype(jnp.int32)) - 1
    uk = jnp.full(n_pad, SENT, jnp.int32).at[
        jnp.where(head, rank, n_pad)].set(sk, mode="drop")
    valid_u = uk != SENT

    # frontier membership + old-slot resolution
    if use_table:
        # dense probes over the key space: frontier table answers both
        # "is this unique key old" and "at which within-batch position"
        cur_tbl = jnp.full(ks, -1, jnp.int32).at[cur_key].set(
            cur_within, mode="drop")          # SENT pads drop (>= ks)
        old_within = cur_tbl[jnp.minimum(uk, ks - 1)]
    else:
        cks, cw = seg_sort(cur_key, cur_within, num_bits=num_bits,
                           backend=sort_backend, interpret=interpret)
        pos = jnp.minimum(jnp.searchsorted(cks, uk),
                          cks.shape[0] - 1).astype(jnp.int32)
        old_within = jnp.where(cks[pos] == uk, cw[pos], -1)
    is_new = valid_u & (old_within < 0)

    # compact new sources (ascending per batch == setdiff1d contract)
    ext_rank = jnp.cumsum(is_new.astype(jnp.int32)) - 1
    n_ext = ext_rank[-1] + 1
    ext_key = jnp.full(n_pad, SENT, jnp.int32).at[
        jnp.where(is_new, ext_rank, n_pad)].set(uk, mode="drop")
    bounds = jnp.arange(nb, dtype=jnp.int32) * jnp.int32(span)
    ext_starts = jnp.concatenate(
        [jnp.searchsorted(ext_key, bounds).astype(jnp.int32),
         n_ext[None]])
    ext_counts = jnp.diff(ext_starts)

    # resolve each UNIQUE key once: old keys sit at their frontier
    # position, new keys at prefix + extra rank; then fan out to edges
    ub = jnp.clip(jnp.where(valid_u, uk, 0) // jnp.int32(span), 0, nb - 1)
    uk_local = jnp.where(is_new,
                         counts[ub] + ext_rank - ext_starts[ub],
                         old_within)
    if use_table:
        val_tbl = jnp.full(ks, 0, jnp.int32).at[uk].set(
            uk_local, mode="drop")
        src_idx = val_tbl[jnp.minimum(cand_key, ks - 1)]
    else:
        inv = jnp.searchsorted(
            uk, jnp.minimum(cand_key, ks - 1)).astype(jnp.int32)
        src_idx = uk_local[jnp.minimum(inv, n_pad - 1)]
    return src_idx, ext_key, ext_counts


# ---------------------------------------------------------------------------
# the epoch driver (host orchestration + draws, device segment-unique)
# ---------------------------------------------------------------------------

def sample_epoch_batched_device(sampler: KHopSampler, s0: int, worker: int,
                                epoch: int, train_nodes: np.ndarray, *,
                                sort_backend: str = "auto",
                                interpret: bool = False) -> FlatEpoch:
    """Whole-epoch compile with the per-layer segment-unique on device;
    bit-identical to ``sample_epoch_batched`` (the differential suite
    pins it array-for-array). Falls back to the numpy compiler for
    int64 key spaces and empty epochs."""
    g = sampler.graph
    L = len(sampler.fanouts)
    span = int(g.num_nodes)
    seed_batches = sampler.epoch_seed_batches(s0, worker, epoch,
                                              train_nodes)
    nb = len(seed_batches)
    if nb == 0 or nb * span >= KEY_INT32_MAX_SLOTS:
        return sampler.sample_epoch_batched(s0, worker, epoch, train_nodes)

    seeds_flat = np.concatenate(seed_batches).astype(np.int64)
    seed_counts = np.fromiter((b.shape[0] for b in seed_batches),
                              np.int64, nb)
    seed_starts = _starts(seed_counts)
    rngs = [rng_from(s0, worker, epoch, i) for i in range(nb)]
    use_table = nb * span <= DEVICE_TABLE_MAX_SLOTS
    bids = np.arange(nb, dtype=np.int32)

    cur = seeds_flat                 # flat frontier, batch-segmented
    counts, starts = seed_counts, seed_starts
    num_dst = np.zeros((L, nb), np.int64)
    rev_src: List[np.ndarray] = []
    rev_dst: List[np.ndarray] = []
    rev_mask: List[np.ndarray] = []
    rev_starts: List[np.ndarray] = []

    for j, fanout in enumerate(reversed(sampler.fanouts)):
        num_dst[L - 1 - j] = counts
        batch_of = np.repeat(bids, counts)
        within = np.arange(cur.shape[0], dtype=np.int64) \
            - starts[batch_of]
        deg = (g.indptr[cur + 1] - g.indptr[cur]).astype(np.int64)
        hi = np.maximum(deg, 1)
        offs = np.empty((cur.shape[0], fanout), np.int64)
        for i in range(nb):     # host Philox: the RNG contract (§2.2)
            sl = slice(starts[i], starts[i + 1])
            offs[sl] = rngs[i].integers(
                0, hi[sl][:, None], size=(int(counts[i]), fanout))
        src_pos = g.indptr[cur][:, None] + offs
        zero = np.flatnonzero(deg == 0)
        if zero.size:
            src_pos[zero] = 0
        src_flat = g.indices[src_pos].reshape(-1).astype(np.int32,
                                                         copy=False)
        mask = np.repeat(deg > 0, fanout)
        if zero.size:
            bad = np.flatnonzero(~mask)
            src_flat[bad] = cur[bad // fanout]

        dst_idx = np.repeat(within, fanout).astype(np.int32)
        ecount = counts * fanout
        n_edges = int(ecount.sum())
        cand_key = (np.repeat(bids, ecount).astype(np.int32)
                    * np.int32(span) + src_flat)
        cur_key = (batch_of.astype(np.int32) * np.int32(span)
                   + cur.astype(np.int32, copy=False))

        n_pad, c_pad = _bucket(n_edges), _bucket(cur.shape[0])
        d_src, d_ext, d_cnt = _frontier_step(
            _pad_i32(cand_key, n_pad),
            _pad_i32(cur_key, c_pad),
            _pad_i32(within.astype(np.int32), c_pad, fill=0),
            jnp.asarray(counts.astype(np.int32)),
            nb=nb, span=span, use_table=use_table,
            sort_backend=sort_backend, interpret=interpret)

        src_idx = np.asarray(d_src)[:n_edges].astype(np.int32,
                                                     copy=False)
        ext_counts = np.asarray(d_cnt).astype(np.int64)
        n_ext = int(ext_counts.sum())
        ext_key = np.asarray(d_ext)[:n_ext].astype(np.int64)
        ext_batch = ext_key // span
        ext_id = ext_key - ext_batch * span
        ext_starts = _starts(ext_counts)
        ewithin = np.arange(n_ext, dtype=np.int64) \
            - ext_starts[ext_batch]

        # next frontier: dst prefix then the new unique sources
        new_counts = counts + ext_counts
        new_starts = _starts(new_counts)
        new_cur = np.empty(int(new_starts[-1]), np.int64)
        new_cur[new_starts[batch_of] + within] = cur
        new_cur[new_starts[ext_batch] + counts[ext_batch]
                + ewithin] = ext_id

        rev_src.append(src_idx)
        rev_dst.append(dst_idx)
        rev_mask.append(mask)
        rev_starts.append(_starts(ecount))
        cur, counts, starts = new_cur, new_counts, new_starts

    return FlatEpoch(
        epoch=epoch, worker=worker, seeds=seeds_flat,
        seed_starts=seed_starts, input_nodes=cur, input_starts=starts,
        num_dst=num_dst,
        edge_src=list(reversed(rev_src)),
        edge_dst=list(reversed(rev_dst)),
        edge_mask=list(reversed(rev_mask)),
        edge_starts=list(reversed(rev_starts)))


# ---------------------------------------------------------------------------
# device remote-frequency counting + hot-set ordering
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("span", "sort_backend", "interpret"))
def _freq_step(r: jax.Array, *, span: int, sort_backend: str,
               interpret: bool):
    m_pad = r.shape[0]
    num_bits = max(int(span - 1).bit_length(), 1)
    sk, _ = seg_sort(r, num_bits=num_bits, backend=sort_backend,
                     interpret=interpret)
    valid = sk != SENT
    head = valid & jnp.concatenate(
        [jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    rank = jnp.cumsum(head.astype(jnp.int32)) - 1
    nu = rank[-1] + 1
    uk = jnp.full(m_pad, SENT, jnp.int32).at[
        jnp.where(head, rank, m_pad)].set(sk, mode="drop")
    # run lengths: start index of each unique value, then boundary diff
    iota = jnp.arange(m_pad, dtype=jnp.int32)
    st = jnp.zeros(m_pad + 1, jnp.int32).at[
        jnp.where(head, rank, m_pad + 1)].set(iota, mode="drop")
    st = st.at[jnp.minimum(nu, m_pad)].set(
        jnp.sum(valid.astype(jnp.int32)))
    freq = jnp.diff(st)
    return uk, freq, nu


@jax.jit
def _hot_order(ids: jax.Array, freq: jax.Array) -> jax.Array:
    """ids by (freq desc, id asc): SENT-padded slots sort last (their
    sort key +1 exceeds every real ``-freq <= -1``)."""
    negf = jnp.where(ids != SENT, -freq, 1)
    _, sid = jax.lax.sort((negf, ids), num_keys=2)
    return sid


def device_remote_freq(remote: np.ndarray, span: int, *,
                       sort_backend: str = "auto",
                       interpret: bool = False
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """``np.unique(remote, return_counts=True)`` as device ops (sort +
    run-length compaction). ``remote`` is the flat stream of remote
    input-node ids; ids are unique per batch, so run lengths ARE the
    per-batch indicator sums the paper's freq(.) wants."""
    if remote.size == 0 or span >= KEY_INT32_MAX_SLOTS:
        ids, freq = (np.unique(remote, return_counts=True)
                     if remote.size else (np.zeros(0, np.int64),) * 2)
        return ids.astype(np.int64), np.asarray(freq, np.int64)
    m_pad = _bucket(remote.size)
    uk, freq, nu = _freq_step(_pad_i32(remote.astype(np.int64), m_pad),
                              span=span, sort_backend=sort_backend,
                              interpret=interpret)
    k = int(nu)
    return (np.asarray(uk)[:k].astype(np.int64),
            np.asarray(freq)[:k].astype(np.int64))


def device_select_hot_set(remote_ids: np.ndarray, remote_freq: np.ndarray,
                          n_hot: int) -> np.ndarray:
    """``core.schedule.select_hot_set`` with the (freq desc, id asc)
    ordering done by a device lexicographic sort; the top-k slice and
    final ascending sort stay host-side (k <= n_hot rows)."""
    k = min(n_hot, remote_ids.shape[0])
    if k <= 0:
        return np.zeros(0, np.int64)
    if remote_ids.size and int(remote_ids.max()) >= SENT:
        from repro.core.schedule import select_hot_set
        return select_hot_set(remote_ids, remote_freq, n_hot)
    m_pad = _bucket(remote_ids.shape[0])
    sid = _hot_order(_pad_i32(remote_ids, m_pad),
                     _pad_i32(remote_freq.astype(np.int32), m_pad,
                              fill=0))
    return np.sort(np.asarray(sid)[:k].astype(np.int64))
