"""CSR graph container.

The whole substrate is host-side numpy (this mirrors the paper: graph
structure + features live in the DistGraph/KV-store host layer; only
per-batch blocks and features are shipped to the device).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Graph:
    """Directed CSR graph (edges point from src -> dst; for GNN message
    passing we store the *incoming* adjacency: indices[indptr[v]:indptr[v+1]]
    are the in-neighbors u of v, i.e. messages u -> v)."""

    indptr: np.ndarray          # (n+1,) int64
    indices: np.ndarray         # (nnz,) int32  in-neighbor ids
    features: np.ndarray        # (n, d) float32
    labels: np.ndarray          # (n,) int32
    num_classes: int
    train_mask: Optional[np.ndarray] = None  # (n,) bool

    @property
    def num_nodes(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    @property
    def feat_dim(self) -> int:
        return int(self.features.shape[1])

    def in_degree(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]: self.indptr[v + 1]]

    def validate(self) -> None:
        n = self.num_nodes
        assert self.indptr[0] == 0 and self.indptr[-1] == self.indices.shape[0]
        assert np.all(np.diff(self.indptr) >= 0), "indptr must be monotone"
        if self.num_edges:
            assert self.indices.min() >= 0 and self.indices.max() < n
        assert self.features.shape[0] == n
        assert self.labels.shape[0] == n

    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, num_nodes: int,
                   features: np.ndarray, labels: np.ndarray,
                   num_classes: int) -> "Graph":
        """Build in-CSR from an edge list (src -> dst)."""
        order = np.argsort(dst, kind="stable")
        dst_sorted = dst[order]
        src_sorted = src[order].astype(np.int32)
        counts = np.bincount(dst_sorted, minlength=num_nodes)
        indptr = np.zeros(num_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return Graph(indptr=indptr, indices=src_sorted, features=features,
                     labels=labels, num_classes=num_classes)
