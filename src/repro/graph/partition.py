"""Graph partitioning: random baseline + greedy balanced edge-cut.

The paper partitions with METIS (balanced edge-cut) and compares against a
random partitioner. METIS itself is unavailable offline; ``greedy_partition``
is a multilevel-flavoured stand-in: BFS-grown regions seeded at high-degree
nodes with a balance constraint, followed by a boundary-refinement pass
(Kernighan-Lin flavoured, single sweep). Its cut quality is below real
METIS, which *increases* the remote-node fraction every method sees --
conservative for RapidGNN's relative claims (see DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.graph.graph import Graph
from repro.graph.sampler import rng_from


@dataclasses.dataclass
class PartitionedGraph:
    graph: Graph
    num_parts: int
    owner: np.ndarray            # (n,) int32: worker owning node v
    local_nodes: List[np.ndarray]  # per worker, global ids it owns

    @property
    def part_sizes(self) -> np.ndarray:
        return np.array([ln.shape[0] for ln in self.local_nodes])

    def edge_cut_fraction(self) -> float:
        g = self.graph
        dst = np.repeat(np.arange(g.num_nodes), g.in_degree())
        cut = self.owner[g.indices] != self.owner[dst]
        return float(cut.mean()) if cut.size else 0.0

    def remote_fraction(self, nodes: np.ndarray, worker: int) -> float:
        return float((self.owner[nodes] != worker).mean()) if nodes.size else 0.0


def _finalize(graph: Graph, owner: np.ndarray, num_parts: int) -> PartitionedGraph:
    local = [np.flatnonzero(owner == p).astype(np.int64)
             for p in range(num_parts)]
    return PartitionedGraph(graph=graph, num_parts=num_parts,
                            owner=owner.astype(np.int32), local_nodes=local)


def random_partition(graph: Graph, num_parts: int, seed: int = 0) -> PartitionedGraph:
    rng = rng_from(seed)        # RNG-CONTRACT: keyed Philox stream
    n = graph.num_nodes
    # balanced random: shuffle then chunk
    perm = rng.permutation(n)
    owner = np.empty(n, dtype=np.int32)
    for p, chunk in enumerate(np.array_split(perm, num_parts)):
        owner[chunk] = p
    return _finalize(graph, owner, num_parts)


def greedy_partition(graph: Graph, num_parts: int, seed: int = 0,
                     refine_sweeps: int = 1) -> PartitionedGraph:
    """BFS-grown balanced edge-cut partitioning (METIS stand-in)."""
    n = graph.num_nodes
    cap = int(np.ceil(n / num_parts))
    owner = np.full(n, -1, dtype=np.int32)
    sizes = np.zeros(num_parts, dtype=np.int64)

    # undirected adjacency for growth
    deg = graph.in_degree()
    order = np.argsort(-deg)            # seeds at high-degree nodes
    rng = rng_from(seed)        # RNG-CONTRACT: keyed Philox stream

    from collections import deque
    frontiers = [deque() for _ in range(num_parts)]
    si = 0
    for p in range(num_parts):
        while si < n and owner[order[si]] != -1:
            si += 1
        if si < n:
            v = int(order[si])
            owner[v] = p
            sizes[p] += 1
            frontiers[p].append(v)

    active = list(range(num_parts))
    while active:
        nxt = []
        for p in active:
            grew = False
            budget = max(1, cap // 8)
            while frontiers[p] and sizes[p] < cap and budget > 0:
                v = frontiers[p].popleft()
                for u in graph.neighbors(v):
                    u = int(u)
                    if owner[u] == -1 and sizes[p] < cap:
                        owner[u] = p
                        sizes[p] += 1
                        frontiers[p].append(u)
                        grew = True
                        budget -= 1
            if frontiers[p] and sizes[p] < cap:
                nxt.append(p)
            _ = grew
        active = nxt

    # orphans (disconnected remainder): fill smallest parts
    orphans = np.flatnonzero(owner == -1)
    if orphans.size:
        rng.shuffle(orphans)
        for v in orphans:
            p = int(np.argmin(sizes))
            owner[v] = p
            sizes[p] += 1

    # single boundary refinement sweep: move a node to the majority
    # partition of its neighbors if balance allows
    dst_of_edge = np.repeat(np.arange(n), graph.in_degree())
    for _ in range(refine_sweeps):
        moved = 0
        for v in rng.permutation(n)[: n // 4]:
            nb = graph.neighbors(int(v))
            if nb.size == 0:
                continue
            counts = np.bincount(owner[nb], minlength=num_parts)
            best = int(np.argmax(counts))
            cur = int(owner[v])
            if best != cur and counts[best] > counts[cur] and \
                    sizes[best] < cap and sizes[cur] > cap // 2:
                owner[v] = best
                sizes[best] += 1
                sizes[cur] -= 1
                moved += 1
        if moved == 0:
            break
    _ = dst_of_edge
    return _finalize(graph, owner, num_parts)


def partition_graph(graph: Graph, num_parts: int, method: str = "greedy",
                    seed: int = 0) -> PartitionedGraph:
    if method == "random":
        return random_partition(graph, num_parts, seed)
    if method in ("greedy", "metis"):
        return greedy_partition(graph, num_parts, seed)
    raise ValueError(f"unknown partition method {method!r}")
