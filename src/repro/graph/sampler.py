"""Deterministic K-hop neighbor sampler (paper §3, §4 components 1-3).

Seeds: ``s_{e,i}^{(w)} = H(s0, w, e, i)`` with H = BLAKE2b (a cryptographic
hash, exactly as the paper specifies). Distinct (w, e, i) tuples hash to
independent uniform 64-bit values seeding non-overlapping Philox streams,
which gives Proposition 3.1 (a)-(c): marginal law identical to an online
uniform draw, independence across tuples, unbiased gradients.

The sampler emits MFG-style blocks (DGL convention): for each GNN layer,
``dst`` nodes are a prefix of ``src`` nodes; edges are (src_idx, dst_idx)
pairs indexing the per-layer node arrays. Only METADATA is produced here
(ids / offsets / locality) -- features are materialized later by the
cache/prefetch machinery, mirroring the paper's sampler->prefetcher split.

Neighbors are drawn uniformly WITH replacement (fan-out F per node), which
keeps per-layer edge counts static (num_dst x F) -- the shape-static form
XLA needs -- while preserving the uniform marginal Prop 3.1 relies on.
Zero-degree nodes contribute masked edges.
"""
from __future__ import annotations

import dataclasses
import hashlib
import struct
from typing import List, Optional, Sequence

import numpy as np

from repro.graph.graph import Graph


def _starts(counts: np.ndarray) -> np.ndarray:
    """(k,) segment counts -> (k+1,) int64 exclusive-prefix offsets."""
    out = np.zeros(counts.shape[0] + 1, np.int64)
    np.cumsum(counts, out=out[1:])
    return out


#: composite (batch, id) key spaces below this bound sort as int32
#: keys: numpy's stable sort on 32-bit integers is a radix sort, which
#: turns the segment-unique argsorts O(n) and cache-friendly. Larger
#: spaces fall back to int64 keys (same algorithm, comparison sort).
KEY_INT32_MAX_SLOTS = 2 ** 31


def derive_seed(s0: int, *fields: int) -> int:
    """H(s0, w, e, i, ...) -> uint64, H = BLAKE2b-8."""
    h = hashlib.blake2b(digest_size=8)
    h.update(struct.pack("<q", s0))
    for f in fields:
        h.update(struct.pack("<q", int(f)))
    return struct.unpack("<Q", h.digest())[0]


def rng_from(s0: int, *fields: int) -> np.random.Generator:
    return np.random.default_rng(np.random.Philox(derive_seed(s0, *fields)))


@dataclasses.dataclass
class Block:
    """One message-passing layer: edges src->dst.

    src nodes of the layer are ``input_nodes[:num_src]`` of the parent
    batch at that depth; dst nodes are the prefix ``[:num_dst]``.
    """
    num_src: int
    num_dst: int
    edge_src: np.ndarray     # (E,) int32 local idx into layer src array
    edge_dst: np.ndarray     # (E,) int32 local idx into layer dst array
    edge_mask: np.ndarray    # (E,) bool  False for zero-degree padding


@dataclasses.dataclass
class SampledBatch:
    epoch: int
    index: int
    worker: int
    seeds: np.ndarray         # (B,) int64 global ids (dst of last layer)
    input_nodes: np.ndarray   # (m,) int64 global ids, dst-prefix ordering
    blocks: List[Block]       # ordered input-layer -> output-layer

    @property
    def num_input_nodes(self) -> int:
        return int(self.input_nodes.shape[0])


@dataclasses.dataclass
class FlatEpoch:
    """One worker-epoch of sampled batches, packed CSR-style.

    The canonical schedule payload (DESIGN.md §2.1): every batch's
    seeds / input nodes / per-layer edges live in ONE flat array per
    field with ``(nb+1,)`` per-batch segment offsets, so whole-epoch
    consumers (frequency counting, device collation, npz spill) work on
    a handful of contiguous arrays instead of ``nb`` small ones. The
    legacy per-batch ``SampledBatch`` form is materialized lazily as
    zero-copy slice views (``batch``/``to_batches``) for the oracle and
    compat paths.

    Layer widths chain as in the MFG convention: layer ``l``'s src
    count is ``m_counts`` for ``l == 0`` and ``num_dst[l-1]`` above, so
    only ``num_dst`` is stored.
    """
    epoch: int
    worker: int
    seeds: np.ndarray               # (sum B_i,) int64 concatenated seeds
    seed_starts: np.ndarray         # (nb+1,) int64
    input_nodes: np.ndarray         # (sum m_i,) int64, dst-prefix order
    input_starts: np.ndarray        # (nb+1,) int64
    num_dst: np.ndarray             # (L, nb) int64 per-layer dst counts
    edge_src: List[np.ndarray]      # per layer: (sum E_l,) int32
    edge_dst: List[np.ndarray]      # per layer: (sum E_l,) int32
    edge_mask: List[np.ndarray]     # per layer: (sum E_l,) bool
    edge_starts: List[np.ndarray]   # per layer: (nb+1,) int64

    @property
    def num_batches(self) -> int:
        return int(self.seed_starts.shape[0] - 1)

    @property
    def num_layers(self) -> int:
        return int(self.num_dst.shape[0])

    @property
    def m_counts(self) -> np.ndarray:
        """(nb,) input-node count per batch."""
        return np.diff(self.input_starts)

    def num_src(self, l: int) -> np.ndarray:
        """(nb,) src-node count of layer ``l`` (width-chain identity)."""
        return self.m_counts if l == 0 else self.num_dst[l - 1]

    def batch(self, i: int) -> SampledBatch:
        """Materialize batch ``i`` as zero-copy views into the flat arrays."""
        s0, s1 = self.input_starts[i], self.input_starts[i + 1]
        blocks: List[Block] = []
        for l in range(self.num_layers):
            e0, e1 = self.edge_starts[l][i], self.edge_starts[l][i + 1]
            blocks.append(Block(
                num_src=int(s1 - s0) if l == 0
                else int(self.num_dst[l - 1, i]),
                num_dst=int(self.num_dst[l, i]),
                edge_src=self.edge_src[l][e0:e1],
                edge_dst=self.edge_dst[l][e0:e1],
                edge_mask=self.edge_mask[l][e0:e1]))
        return SampledBatch(
            epoch=self.epoch, index=i, worker=self.worker,
            seeds=self.seeds[self.seed_starts[i]:self.seed_starts[i + 1]],
            input_nodes=self.input_nodes[s0:s1], blocks=blocks)

    def to_batches(self) -> List[SampledBatch]:
        return [self.batch(i) for i in range(self.num_batches)]

    @staticmethod
    def empty(epoch: int, worker: int, num_layers: int) -> "FlatEpoch":
        z64 = np.zeros(0, np.int64)
        zs = np.zeros(1, np.int64)
        return FlatEpoch(
            epoch=epoch, worker=worker, seeds=z64, seed_starts=zs,
            input_nodes=z64.copy(), input_starts=zs.copy(),
            num_dst=np.zeros((num_layers, 0), np.int64),
            edge_src=[np.zeros(0, np.int32) for _ in range(num_layers)],
            edge_dst=[np.zeros(0, np.int32) for _ in range(num_layers)],
            edge_mask=[np.zeros(0, bool) for _ in range(num_layers)],
            edge_starts=[zs.copy() for _ in range(num_layers)])

    @staticmethod
    def from_batches(batches: Sequence[SampledBatch], epoch: int,
                     worker: int,
                     num_layers: Optional[int] = None) -> "FlatEpoch":
        """Pack per-batch samples into the flat layout (the inverse of
        ``to_batches``; round-trips bit-exactly)."""
        nb = len(batches)
        if nb == 0:
            return FlatEpoch.empty(epoch, worker, num_layers or 0)
        L = len(batches[0].blocks)
        seed_starts = _starts(np.fromiter(
            (b.seeds.shape[0] for b in batches), np.int64, nb))
        input_starts = _starts(np.fromiter(
            (b.num_input_nodes for b in batches), np.int64, nb))
        num_dst = np.array([[b.blocks[l].num_dst for b in batches]
                            for l in range(L)], np.int64).reshape(L, nb)
        return FlatEpoch(
            epoch=epoch, worker=worker,
            seeds=np.concatenate([b.seeds for b in batches]).astype(
                np.int64),
            seed_starts=seed_starts,
            input_nodes=np.concatenate(
                [b.input_nodes for b in batches]).astype(np.int64),
            input_starts=input_starts, num_dst=num_dst,
            edge_src=[np.concatenate([b.blocks[l].edge_src
                                      for b in batches]) for l in range(L)],
            edge_dst=[np.concatenate([b.blocks[l].edge_dst
                                      for b in batches]) for l in range(L)],
            edge_mask=[np.concatenate([b.blocks[l].edge_mask
                                       for b in batches]) for l in range(L)],
            edge_starts=[_starts(np.fromiter(
                (b.blocks[l].edge_src.shape[0] for b in batches),
                np.int64, nb)) for l in range(L)])


class KHopSampler:
    def __init__(self, graph: Graph, fanouts: Sequence[int],
                 batch_size: int):
        self.graph = graph
        self.fanouts = list(fanouts)     # fanouts[l] for layer l (input->output)
        self.batch_size = batch_size

    # ---- batch enumeration (deterministic shuffle per worker/epoch) ----
    def epoch_seed_batches(self, s0: int, worker: int, epoch: int,
                           train_nodes: np.ndarray) -> List[np.ndarray]:
        rng = rng_from(s0, worker, epoch, -1)   # i=-1 : the shuffle stream
        perm = rng.permutation(train_nodes)
        nb = int(np.ceil(perm.shape[0] / self.batch_size))
        return [perm[i * self.batch_size:(i + 1) * self.batch_size]
                for i in range(nb)]

    # ---- one batch ----
    def sample_batch(self, s0: int, worker: int, epoch: int, index: int,
                     seed_nodes: np.ndarray) -> SampledBatch:
        g = self.graph
        rng = rng_from(s0, worker, epoch, index)
        cur = np.asarray(seed_nodes, dtype=np.int64)
        blocks_rev: List[Block] = []
        # walk output layer -> input layer
        for fanout in reversed(self.fanouts):
            deg = (g.indptr[cur + 1] - g.indptr[cur]).astype(np.int64)
            nd = cur.shape[0]
            offs = rng.integers(0, np.maximum(deg, 1)[:, None],
                                size=(nd, fanout))
            src_pos = g.indptr[cur][:, None] + offs
            src = g.indices[np.minimum(src_pos, g.num_edges - 1)].astype(np.int64)
            mask = np.broadcast_to((deg > 0)[:, None], (nd, fanout)).reshape(-1)
            src_flat = src.reshape(-1)
            dst_idx = np.repeat(np.arange(nd, dtype=np.int32), fanout)
            # masked (zero-degree) edges self-loop onto their dst so their
            # src id is always present in the node array
            src_flat = np.where(mask, src_flat, cur[dst_idx])

            # src node array = dst prefix + new unique sources
            uniq = np.unique(src_flat)
            extra = np.setdiff1d(uniq, cur, assume_unique=False)
            src_nodes = np.concatenate([cur, extra])
            sorter = np.argsort(src_nodes, kind="stable")
            src_idx = sorter[np.searchsorted(src_nodes, src_flat,
                                             sorter=sorter)].astype(np.int32)
            blocks_rev.append(Block(num_src=src_nodes.shape[0], num_dst=nd,
                                    edge_src=src_idx, edge_dst=dst_idx,
                                    edge_mask=mask))
            cur = src_nodes
        blocks = list(reversed(blocks_rev))
        return SampledBatch(epoch=epoch, index=index, worker=worker,
                            seeds=np.asarray(seed_nodes, dtype=np.int64),
                            input_nodes=cur, blocks=blocks)

    def sample_epoch(self, s0: int, worker: int, epoch: int,
                     train_nodes: np.ndarray) -> List[SampledBatch]:
        """Per-batch reference epoch sampler: one ``sample_batch`` call
        per batch. Kept as the parity oracle ``sample_epoch_batched`` is
        tested and benchmarked against (repo convention: the loop
        survives as the oracle of every vectorized pass)."""
        out = []
        for i, seeds in enumerate(
                self.epoch_seed_batches(s0, worker, epoch, train_nodes)):
            out.append(self.sample_batch(s0, worker, epoch, i, seeds))
        return out

    # ---- whole-epoch compiler (DESIGN.md §2.1) ----
    def sample_epoch_batched(self, s0: int, worker: int, epoch: int,
                             train_nodes: np.ndarray) -> FlatEpoch:
        """Sample a whole epoch in a handful of vectorized passes,
        BIT-IDENTICAL to ``sample_epoch`` (the hypothesis parity suite
        pins it batch-for-batch, array-for-array).

        All batches' frontiers ride one flat, batch-segmented stream:
        per layer there is ONE degree gather, ONE neighbor-table gather
        and ONE composite-key sort for the segment-aware unique /
        dst-prefix construction, replacing the per-batch
        ``unique``/``setdiff1d``/``argsort``/``searchsorted`` quartet.
        Only the offset draw stays per batch -- each batch owns an
        independent Philox stream seeded ``H(s0, w, e, i)`` (Prop 3.1
        demands it), so its draw is one blockwise ``Generator.integers``
        call on that stream, exactly the call ``sample_batch`` makes.

        This numpy path doubles as the ORACLE for the accelerator port
        (``graph.device_sampler.sample_epoch_batched_device``, DESIGN.md
        §2.2), which moves the sort-bound middle on device and must stay
        bit-identical to it.
        """
        g = self.graph
        L = len(self.fanouts)
        seed_batches = self.epoch_seed_batches(s0, worker, epoch,
                                               train_nodes)
        nb = len(seed_batches)
        if nb == 0:
            return FlatEpoch.empty(epoch, worker, L)
        seeds_flat = np.concatenate(seed_batches).astype(np.int64)
        seed_counts = np.fromiter((b.shape[0] for b in seed_batches),
                                  np.int64, nb)
        seed_starts = _starts(seed_counts)
        rngs = [rng_from(s0, worker, epoch, i) for i in range(nb)]
        span = np.int64(g.num_nodes)

        cur = seeds_flat                 # flat frontier, batch-segmented
        counts, starts = seed_counts, seed_starts
        num_dst = np.zeros((L, nb), np.int64)
        rev_src: List[np.ndarray] = []
        rev_dst: List[np.ndarray] = []
        rev_mask: List[np.ndarray] = []
        rev_starts: List[np.ndarray] = []

        # int32 composite keys whenever the key space allows: the
        # per-layer segment-unique argsorts are memory-bound at epoch
        # scale, and halving the key width buys ~1.6x there
        kdt = (np.int32 if nb * int(span) < KEY_INT32_MAX_SLOTS
               else np.int64)
        span_k = kdt(span)
        bids = np.arange(nb, dtype=kdt)

        # walk output layer -> input layer, as sample_batch does
        for j, fanout in enumerate(reversed(self.fanouts)):
            num_dst[L - 1 - j] = counts
            batch_of = np.repeat(bids, counts)
            within = np.arange(cur.shape[0], dtype=np.int64) \
                - starts[batch_of]
            deg = (g.indptr[cur + 1] - g.indptr[cur]).astype(np.int64)
            hi = np.maximum(deg, 1)
            offs = np.empty((cur.shape[0], fanout), np.int64)
            for i in range(nb):     # one blockwise draw per Philox stream
                sl = slice(starts[i], starts[i + 1])
                offs[sl] = rngs[i].integers(
                    0, hi[sl][:, None], size=(int(counts[i]), fanout))
            src_pos = g.indptr[cur][:, None] + offs
            zero = np.flatnonzero(deg == 0)
            if zero.size:       # only deg-0 rows can index past the end
                src_pos[zero] = 0
            src_flat = g.indices[src_pos].reshape(-1) \
                .astype(kdt, copy=False)
            mask = np.repeat(deg > 0, fanout)
            if zero.size:
                # masked (zero-degree) edges self-loop onto their dst:
                # patch just those slots (edge e <- frontier row e // F)
                bad = np.flatnonzero(~mask)
                src_flat[bad] = cur[bad // fanout]

            dst_idx = np.repeat(within, fanout).astype(np.int32)
            ecount = counts * fanout
            cand_key = np.repeat(bids, ecount) * span_k + src_flat

            # segment-aware unique: composite (batch, id) keys make one
            # global sort act per batch (keys never cross segments);
            # the inverse indices replace every per-batch searchsorted
            uk, inv = np.unique(cand_key, return_inverse=True)

            cur_key = (batch_of * span_k
                       + cur.astype(kdt, copy=False))
            csort = np.argsort(cur_key)
            cks = cur_key[csort]
            pos = np.minimum(np.searchsorted(cks, uk),
                             cks.shape[0] - 1)
            is_new = cks[pos] != uk
            ext_key = uk[is_new]
            ext_batch = (ext_key // span_k).astype(np.int64)
            ext_id = (ext_key - ext_batch * span_k).astype(np.int64)
            ext_counts = np.bincount(ext_batch, minlength=nb) \
                .astype(np.int64)
            ext_starts = _starts(ext_counts)
            ewithin = np.arange(ext_id.shape[0], dtype=np.int64) \
                - ext_starts[ext_batch]

            # next frontier: dst prefix then the new unique sources
            # (ascending per batch == the setdiff1d contract)
            new_counts = counts + ext_counts
            new_starts = _starts(new_counts)
            new_cur = np.empty(int(new_starts[-1]), np.int64)
            new_cur[new_starts[batch_of] + within] = cur
            new_cur[new_starts[ext_batch] + counts[ext_batch]
                    + ewithin] = ext_id

            # resolve each UNIQUE key once (old keys sit at their
            # dst-prefix position, new keys at prefix + extra rank),
            # then fan out to edges through the unique-inverse -- no
            # edge-sized searchsorted ever runs
            uk_local = np.empty(uk.shape[0], np.int64)
            uk_local[~is_new] = within[csort[pos[~is_new]]]
            uk_local[is_new] = counts[ext_batch] + ewithin
            src_idx = uk_local[inv].astype(np.int32)

            rev_src.append(src_idx)
            rev_dst.append(dst_idx)
            rev_mask.append(mask)
            rev_starts.append(_starts(ecount))
            cur, counts, starts = new_cur, new_counts, new_starts

        return FlatEpoch(
            epoch=epoch, worker=worker, seeds=seeds_flat,
            seed_starts=seed_starts, input_nodes=cur, input_starts=starts,
            num_dst=num_dst,
            edge_src=list(reversed(rev_src)),
            edge_dst=list(reversed(rev_dst)),
            edge_mask=list(reversed(rev_mask)),
            edge_starts=list(reversed(rev_starts)))
