"""Deterministic K-hop neighbor sampler (paper §3, §4 components 1-3).

Seeds: ``s_{e,i}^{(w)} = H(s0, w, e, i)`` with H = BLAKE2b (a cryptographic
hash, exactly as the paper specifies). Distinct (w, e, i) tuples hash to
independent uniform 64-bit values seeding non-overlapping Philox streams,
which gives Proposition 3.1 (a)-(c): marginal law identical to an online
uniform draw, independence across tuples, unbiased gradients.

The sampler emits MFG-style blocks (DGL convention): for each GNN layer,
``dst`` nodes are a prefix of ``src`` nodes; edges are (src_idx, dst_idx)
pairs indexing the per-layer node arrays. Only METADATA is produced here
(ids / offsets / locality) -- features are materialized later by the
cache/prefetch machinery, mirroring the paper's sampler->prefetcher split.

Neighbors are drawn uniformly WITH replacement (fan-out F per node), which
keeps per-layer edge counts static (num_dst x F) -- the shape-static form
XLA needs -- while preserving the uniform marginal Prop 3.1 relies on.
Zero-degree nodes contribute masked edges.
"""
from __future__ import annotations

import dataclasses
import hashlib
import struct
from typing import List, Sequence

import numpy as np

from repro.graph.graph import Graph


def derive_seed(s0: int, *fields: int) -> int:
    """H(s0, w, e, i, ...) -> uint64, H = BLAKE2b-8."""
    h = hashlib.blake2b(digest_size=8)
    h.update(struct.pack("<q", s0))
    for f in fields:
        h.update(struct.pack("<q", int(f)))
    return struct.unpack("<Q", h.digest())[0]


def rng_from(s0: int, *fields: int) -> np.random.Generator:
    return np.random.default_rng(np.random.Philox(derive_seed(s0, *fields)))


@dataclasses.dataclass
class Block:
    """One message-passing layer: edges src->dst.

    src nodes of the layer are ``input_nodes[:num_src]`` of the parent
    batch at that depth; dst nodes are the prefix ``[:num_dst]``.
    """
    num_src: int
    num_dst: int
    edge_src: np.ndarray     # (E,) int32 local idx into layer src array
    edge_dst: np.ndarray     # (E,) int32 local idx into layer dst array
    edge_mask: np.ndarray    # (E,) bool  False for zero-degree padding


@dataclasses.dataclass
class SampledBatch:
    epoch: int
    index: int
    worker: int
    seeds: np.ndarray         # (B,) int64 global ids (dst of last layer)
    input_nodes: np.ndarray   # (m,) int64 global ids, dst-prefix ordering
    blocks: List[Block]       # ordered input-layer -> output-layer

    @property
    def num_input_nodes(self) -> int:
        return int(self.input_nodes.shape[0])


class KHopSampler:
    def __init__(self, graph: Graph, fanouts: Sequence[int],
                 batch_size: int):
        self.graph = graph
        self.fanouts = list(fanouts)     # fanouts[l] for layer l (input->output)
        self.batch_size = batch_size

    # ---- batch enumeration (deterministic shuffle per worker/epoch) ----
    def epoch_seed_batches(self, s0: int, worker: int, epoch: int,
                           train_nodes: np.ndarray) -> List[np.ndarray]:
        rng = rng_from(s0, worker, epoch, -1)   # i=-1 : the shuffle stream
        perm = rng.permutation(train_nodes)
        nb = int(np.ceil(perm.shape[0] / self.batch_size))
        return [perm[i * self.batch_size:(i + 1) * self.batch_size]
                for i in range(nb)]

    # ---- one batch ----
    def sample_batch(self, s0: int, worker: int, epoch: int, index: int,
                     seed_nodes: np.ndarray) -> SampledBatch:
        g = self.graph
        rng = rng_from(s0, worker, epoch, index)
        cur = np.asarray(seed_nodes, dtype=np.int64)
        blocks_rev: List[Block] = []
        # walk output layer -> input layer
        for fanout in reversed(self.fanouts):
            deg = (g.indptr[cur + 1] - g.indptr[cur]).astype(np.int64)
            nd = cur.shape[0]
            offs = rng.integers(0, np.maximum(deg, 1)[:, None],
                                size=(nd, fanout))
            src_pos = g.indptr[cur][:, None] + offs
            src = g.indices[np.minimum(src_pos, g.num_edges - 1)].astype(np.int64)
            mask = np.broadcast_to((deg > 0)[:, None], (nd, fanout)).reshape(-1)
            src_flat = src.reshape(-1)
            dst_idx = np.repeat(np.arange(nd, dtype=np.int32), fanout)
            # masked (zero-degree) edges self-loop onto their dst so their
            # src id is always present in the node array
            src_flat = np.where(mask, src_flat, cur[dst_idx])

            # src node array = dst prefix + new unique sources
            uniq = np.unique(src_flat)
            extra = np.setdiff1d(uniq, cur, assume_unique=False)
            src_nodes = np.concatenate([cur, extra])
            sorter = np.argsort(src_nodes, kind="stable")
            src_idx = sorter[np.searchsorted(src_nodes, src_flat,
                                             sorter=sorter)].astype(np.int32)
            blocks_rev.append(Block(num_src=src_nodes.shape[0], num_dst=nd,
                                    edge_src=src_idx, edge_dst=dst_idx,
                                    edge_mask=mask))
            cur = src_nodes
        blocks = list(reversed(blocks_rev))
        return SampledBatch(epoch=epoch, index=index, worker=worker,
                            seeds=np.asarray(seed_nodes, dtype=np.int64),
                            input_nodes=cur, blocks=blocks)

    def sample_epoch(self, s0: int, worker: int, epoch: int,
                     train_nodes: np.ndarray) -> List[SampledBatch]:
        out = []
        for i, seeds in enumerate(
                self.epoch_seed_batches(s0, worker, epoch, train_nodes)):
            out.append(self.sample_batch(s0, worker, epoch, i, seeds))
        return out
