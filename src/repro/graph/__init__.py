"""Graph substrate: CSR storage, synthetic datasets, partitioning, sampling."""
from repro.graph.graph import Graph
from repro.graph.generate import make_powerlaw_graph, DATASETS, load_dataset
from repro.graph.partition import random_partition, greedy_partition, PartitionedGraph, partition_graph
from repro.graph.sampler import FlatEpoch, KHopSampler, SampledBatch

__all__ = [
    "Graph", "make_powerlaw_graph", "DATASETS", "load_dataset",
    "random_partition", "greedy_partition", "PartitionedGraph", "partition_graph",
    "KHopSampler", "SampledBatch", "FlatEpoch",
]
