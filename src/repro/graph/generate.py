"""Synthetic benchmark graphs with paper-matched statistics.

The paper evaluates on Reddit (233 k nodes / 114.8 M edges / d=602 / 50
classes), OGBN-Products (2.45 M / 123.7 M / d=100 / 47) and OGBN-Papers100M
(111 M / 1.62 B / d=128 / 172). Those datasets are not redistributable
offline, so we generate scaled-down graphs that preserve the properties
RapidGNN's claims depend on:

  * long-tail (power-law) access popularity -> hub "celebrity" nodes
    (paper Fig. 3: ~45 % of remote nodes touched once, max freq ~66),
  * community structure (so an edge-cut partitioner has locality to find,
    and a random partitioner does not),
  * exact feature dimensionality / class counts (these set the bytes that
    move on the wire),
  * a learnable node-classification task (labels correlated with the
    community + features) for the convergence-parity experiment.

Generation model: nodes are assigned to clusters; each node draws an
in-degree from a heavy-tailed lognormal; in-neighbors are sampled with
probability ``p_intra`` from the node's own cluster (else globally), in
both cases weighted by a Zipf popularity over nodes. Popularity-weighted
endpoint choice is what produces hub nodes with huge *out*-fanin, i.e.
nodes whose features every worker keeps re-fetching -- the access pattern
in the paper's Fig. 3.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.graph import Graph
from repro.graph.sampler import rng_from


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    num_nodes: int
    avg_degree: float
    feat_dim: int
    num_classes: int
    num_clusters: int
    zipf_a: float            # popularity exponent (p ~ rank^-a)
    p_intra: float           # probability an edge stays inside the cluster
    train_frac: float
    # paper-scale statistics, kept for reporting / extrapolation
    paper_nodes: int = 0
    paper_edges: int = 0


DATASETS = {
    # name:                 nodes   deg  d    C   clus  a     intra train
    "reddit_sim": DatasetSpec("reddit_sim", 60_000, 90.0, 602, 50, 50, 1.05,
                              0.75, 0.66, paper_nodes=232_965,
                              paper_edges=114_800_000),
    "ogbn_products_sim": DatasetSpec("ogbn_products_sim", 192_000, 50.0, 100,
                                     47, 96, 0.95, 0.80, 0.40,
                                     paper_nodes=2_449_029,
                                     paper_edges=123_700_000),
    "ogbn_papers_sim": DatasetSpec("ogbn_papers_sim", 256_000, 15.0, 128, 172,
                                   128, 0.90, 0.85, 0.08,
                                   paper_nodes=111_059_956,
                                   paper_edges=1_620_000_000),
    # tiny variant for unit tests
    "tiny": DatasetSpec("tiny", 1_000, 8.0, 32, 8, 8, 1.0, 0.7, 0.5),
}


def _zipf_weights(n: int, a: float, rng: np.random.Generator) -> np.ndarray:
    """Popularity ~ rank^-a, randomly permuted over node ids."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-a)
    rng.shuffle(w)
    return w / w.sum()


def make_powerlaw_graph(spec: DatasetSpec, seed: int = 0) -> Graph:
    rng = rng_from(seed)        # RNG-CONTRACT: keyed Philox stream
    n = spec.num_nodes

    clusters = rng.integers(0, spec.num_clusters, size=n).astype(np.int32)
    popularity = _zipf_weights(n, spec.zipf_a, rng)

    # heavy-tailed in-degrees around avg_degree
    deg = np.maximum(
        1, rng.lognormal(mean=np.log(spec.avg_degree) - 0.5, sigma=1.0,
                         size=n)).astype(np.int64)
    deg = np.minimum(deg, n - 1)

    dst = np.repeat(np.arange(n, dtype=np.int64), deg)
    total = int(dst.shape[0])
    intra = rng.random(total) < spec.p_intra

    src = np.empty(total, dtype=np.int64)
    # global (inter-cluster) endpoints: one big popularity-weighted draw
    n_inter = int((~intra).sum())
    src[~intra] = rng.choice(n, size=n_inter, p=popularity)

    # intra-cluster endpoints: draw per cluster (vectorized inside cluster)
    dst_cluster = clusters[dst]
    for c in range(spec.num_clusters):
        members = np.flatnonzero(clusters == c)
        if members.size == 0:
            continue
        sel = np.flatnonzero(intra & (dst_cluster == c))
        if sel.size == 0:
            continue
        w = popularity[members]
        w = w / w.sum()
        src[sel] = members[rng.choice(members.size, size=sel.size, p=w)]

    # no self loops (redirect to a random neighbor)
    self_loop = src == dst
    src[self_loop] = (dst[self_loop] + 1 + rng.integers(
        0, n - 2, size=int(self_loop.sum()))) % n

    labels = (clusters % spec.num_classes).astype(np.int32)
    centers = rng.normal(0.0, 1.0, size=(spec.num_classes, spec.feat_dim))
    features = (centers[labels] +
                rng.normal(0.0, 2.0, size=(n, spec.feat_dim))
                ).astype(np.float32)

    train_mask = rng.random(n) < spec.train_frac

    g = Graph.from_edges(src=src.astype(np.int64), dst=dst, num_nodes=n,
                         features=features, labels=labels,
                         num_classes=spec.num_classes)
    g.train_mask = train_mask
    g.validate()
    return g


_CACHE: dict = {}


def load_dataset(name: str, seed: int = 0) -> Graph:
    key = (name, seed)
    if key not in _CACHE:
        _CACHE[key] = make_powerlaw_graph(DATASETS[name], seed=seed)
    return _CACHE[key]
