"""Import-graph reachability report (``--report-dead``).

Builds the ``repro.*`` module graph purely from ``ast`` import
statements (nested/lazy imports included) and computes which modules
are unreachable from the live entry surfaces. Inventory only -- the
report never deletes anything; DESIGN.md's appendix records the
current dead set.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, List, Sequence, Set, Tuple

#: the live entry surfaces: the distributed trainer, the paper-metrics
#: campaign, the graph substrate -- plus this tool itself.
DEFAULT_ROOTS = ("repro.dist", "repro.eval", "repro.graph",
                 "repro.analysis")


@dataclasses.dataclass
class ImportReport:
    modules: Dict[str, str]          # dotted name -> display path
    edges: Dict[str, Set[str]]       # importer -> imported (repro.* only)
    roots: List[str]                 # root module names (expanded)
    reachable: Set[str]
    dead: List[str]                  # sorted unreachable module names

    def format(self) -> str:
        lines = [f"import graph: {len(self.modules)} modules, "
                 f"{sum(len(v) for v in self.edges.values())} edges, "
                 f"{len(self.roots)} root modules "
                 f"({len(self.reachable)} reachable)"]
        if self.dead:
            lines.append(f"dead modules (unreachable from "
                         f"{', '.join(sorted(set(DEFAULT_ROOTS)))}):")
            lines += [f"  {m}  ({self.modules[m]})" for m in self.dead]
        else:
            lines.append("no dead modules")
        return "\n".join(lines)


def _module_name(relposix: str) -> str:
    """'repro/graph/sampler.py' -> 'repro.graph.sampler';
    package __init__ maps to the package itself."""
    mod = relposix[:-3].replace("/", ".")
    if mod.endswith(".__init__"):
        mod = mod[: -len(".__init__")]
    return mod


def build_import_report(path: str,
                        roots: Sequence[str] = DEFAULT_ROOTS
                        ) -> ImportReport:
    """``path`` is the scan root holding the ``repro`` package (e.g.
    ``src``); ``roots`` are dotted prefixes whose modules seed the
    reachability closure."""
    modules: Dict[str, str] = {}
    trees: Dict[str, ast.AST] = {}
    for dirpath, dirs, files in os.walk(path):
        dirs[:] = [d for d in dirs
                   if not d.startswith(".") and d != "__pycache__"]
        for f in sorted(files):
            if not f.endswith(".py"):
                continue
            disp = os.path.join(dirpath, f)
            rel = os.path.relpath(disp, path).replace(os.sep, "/")
            mod = _module_name(rel)
            if not mod.startswith("repro"):
                continue
            modules[mod] = disp
            with open(disp, "r", encoding="utf-8") as fh:
                try:
                    trees[mod] = ast.parse(fh.read())
                except SyntaxError:
                    continue

    def resolve_dep(name: str) -> List[str]:
        """Dotted import target -> existing module(s): the module
        itself if present, else walk up to the nearest package."""
        parts = name.split(".")
        while parts:
            cand = ".".join(parts)
            if cand in modules:
                return [cand]
            parts = parts[:-1]
        return []

    edges: Dict[str, Set[str]] = {m: set() for m in modules}
    for mod, tree in trees.items():
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name.startswith("repro"):
                        edges[mod].update(resolve_dep(a.name))
            elif isinstance(node, ast.ImportFrom) and node.module and \
                    node.level == 0 and node.module.startswith("repro"):
                edges[mod].update(resolve_dep(node.module))
                for a in node.names:
                    # 'from repro.graph import sampler' pulls a module
                    edges[mod].update(
                        resolve_dep(f"{node.module}.{a.name}"))
        # importing a package executes its __init__
        pkg = mod.rsplit(".", 1)[0] if "." in mod else None
        if pkg and pkg in modules:
            edges[mod].add(pkg)

    root_mods = sorted(m for m in modules
                       if any(m == r or m.startswith(r + ".")
                              for r in roots))
    reachable: Set[str] = set()
    stack = list(root_mods)
    while stack:
        m = stack.pop()
        if m in reachable:
            continue
        reachable.add(m)
        stack.extend(edges.get(m, ()))
    dead = sorted(m for m in modules if m not in reachable)
    return ImportReport(modules=modules, edges=edges, roots=root_mods,
                        reachable=reachable, dead=dead)
