"""Inline waiver syntax for the invariant checker.

    some_call()   # repro: allow(RULE-ID) -- why this is safe here

A waiver suppresses findings of exactly that RULE-ID on exactly one
line: the comment's own line when it trails code, or the line
immediately below when the comment stands alone. The justification
after ``--`` is REQUIRED -- a waiver without one is itself a finding
(``WAIVER-SYNTAX``), so every suppression in the tree documents why
the contract does not apply (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import io
import re
import tokenize
from typing import Dict, List, Tuple

from repro.analysis.findings import Finding

WAIVER_RULE = "WAIVER-SYNTAX"

#: any comment that *tries* to be a waiver (so typos don't silently
#: waive nothing)
_ATTEMPT_RE = re.compile(r"#\s*repro\s*:\s*allow\b")
_WAIVER_RE = re.compile(
    r"#\s*repro\s*:\s*allow\(\s*(?P<rule>[A-Za-z0-9_-]+)\s*\)"
    r"\s*(?:--\s*(?P<why>\S.*))?")


@dataclasses.dataclass
class Waiver:
    rule: str
    target_line: int     # the single line this waiver suppresses on
    justification: str


def parse_waivers(source: str,
                  path: str) -> Tuple[List[Waiver], List[Finding]]:
    """Scan comments (via tokenize, so '#' inside strings never
    matches) -> (waivers, malformed-waiver findings)."""
    waivers: List[Waiver] = []
    findings: List[Finding] = []
    try:
        tokens = list(tokenize.generate_tokens(
            io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return waivers, findings     # parse errors reported elsewhere
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        if not _ATTEMPT_RE.search(tok.string):
            continue
        line, col = tok.start
        m = _WAIVER_RE.search(tok.string)
        if m is None:
            findings.append(Finding(
                path=path, line=line, col=col, rule=WAIVER_RULE,
                message="malformed waiver; expected "
                        "'# repro: allow(RULE-ID) -- justification'"))
            continue
        if not m.group("why"):
            findings.append(Finding(
                path=path, line=line, col=col, rule=WAIVER_RULE,
                message=f"waiver for {m.group('rule')} lacks a "
                        f"justification after '--'"))
            continue
        standalone = tok.line[:col].strip() == ""
        waivers.append(Waiver(rule=m.group("rule"),
                              target_line=line + 1 if standalone else line,
                              justification=m.group("why").strip()))
    return waivers, findings


def apply_waivers(findings: List[Finding],
                  waivers_by_path: Dict[str, List[Waiver]]
                  ) -> Tuple[List[Finding], int]:
    """Drop findings covered by a waiver -> (kept, waived_count).
    ``WAIVER-SYNTAX`` findings are never waivable."""
    kept: List[Finding] = []
    waived = 0
    for f in findings:
        ws = waivers_by_path.get(f.path, ())
        if f.rule != WAIVER_RULE and any(
                w.rule == f.rule and w.target_line == f.line for w in ws):
            waived += 1
            continue
        kept.append(f)
    return kept, waived
