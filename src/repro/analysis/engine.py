"""The rule engine: file discovery, per-module AST models, rule driver.

Stdlib-only by design (``ast`` + ``tokenize``): the checker runs as a
CI gate before any heavyweight import, so it must never pay (or
require) a numpy/jax import. Each scanned file becomes a
``ModuleContext`` -- parsed tree, parent links, import-alias table,
waivers -- shared by every rule; rules are ``ast.NodeVisitor``
subclasses (see ``RuleVisitor``) yielding ``Finding`` records, plus an
optional whole-tree pass for layout-shaped contracts
(``Rule.check_project``). DESIGN.md §8 documents the catalog.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding
from repro.analysis.waivers import Waiver, apply_waivers, parse_waivers

PARSE_RULE = "PARSE-ERROR"

#: builtins rules reason about; resolve() maps them to themselves so
#: ``int(x)`` and ``print(...)`` get canonical names like imports do
_BUILTINS = {"int", "float", "bool", "print", "open", "input", "len",
             "exec", "eval", "breakpoint"}


def build_aliases(tree: ast.AST) -> Dict[str, str]:
    """name-in-scope -> canonical dotted module path, from every
    import statement in the file (nested ones included: lazy imports
    inside functions are how this repo dodges cycles)."""
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    root = a.name.split(".")[0]
                    aliases[root] = root
        elif isinstance(node, ast.ImportFrom) and node.level == 0 \
                and node.module:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


class ModuleContext:
    """Everything the rules need about one file, built exactly once."""

    def __init__(self, display_path: str, abspath: str, source: str):
        self.path = display_path
        self.abspath = abspath
        self.posix = abspath.replace(os.sep, "/")
        self.source = source
        self.tree = ast.parse(source)
        self.aliases = build_aliases(self.tree)
        self.parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent
        self.waivers: List[Waiver] = []

    # -- canonical-name resolution ------------------------------------

    def resolve(self, node: Optional[ast.AST]) -> Optional[str]:
        """Expression -> canonical dotted name ('numpy.random.seed',
        'jax.lax.scan', builtin 'int'), or None when not statically
        resolvable (locals, call results, subscripts...)."""
        if isinstance(node, ast.Name):
            if node.id in self.aliases:
                return self.aliases[node.id]
            if node.id in _BUILTINS:
                return node.id
            return None
        if isinstance(node, ast.Attribute):
            base = self.resolve(node.value)
            return f"{base}.{node.attr}" if base else None
        return None

    def in_file(self, *suffixes: str) -> bool:
        """Does this module live at one of the given path suffixes?
        Matched on the absolute posix path, so sanctioned-location
        checks survive tmp-dir copies in tests."""
        return any(self.posix.endswith(s) for s in suffixes)

    def finding(self, node: ast.AST, rule: str, message: str) -> Finding:
        return Finding(path=self.path, line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0), rule=rule,
                       message=message)


class RuleVisitor(ast.NodeVisitor):
    """Base visitor handed the module model; rules collect into
    ``self.found``."""

    def __init__(self, rule: "Rule", ctx: ModuleContext):
        self.rule = rule
        self.ctx = ctx
        self.found: List[Finding] = []

    def flag(self, node: ast.AST, message: str) -> None:
        self.found.append(
            self.ctx.finding(node, self.rule.rule_id, message))


class Rule:
    """One invariant. ``check_module`` runs per file;
    ``check_project`` once over the whole scanned tree (for contracts
    about files-that-must-exist rather than code-that-must-not)."""

    rule_id: str = "RULE"
    description: str = ""

    def check_module(self, ctx: ModuleContext) -> Iterable[Finding]:
        return ()

    def check_project(self,
                      ctxs: Sequence[ModuleContext]) -> Iterable[Finding]:
        return ()


@dataclasses.dataclass
class AnalysisResult:
    findings: List[Finding]
    waived: int
    files_scanned: int
    elapsed_s: float

    @property
    def ok(self) -> bool:
        return not self.findings


def discover(paths: Sequence[str]) -> List[Tuple[str, str]]:
    """paths (files or dirs) -> sorted [(display_path, abspath)] of
    .py files; hidden dirs and __pycache__ skipped."""
    out: List[Tuple[str, str]] = []
    for p in paths:
        if os.path.isfile(p):
            out.append((p, os.path.abspath(p)))
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if not d.startswith(".") and d != "__pycache__")
            for f in sorted(files):
                if f.endswith(".py"):
                    disp = os.path.join(root, f)
                    out.append((disp, os.path.abspath(disp)))
    return sorted(set(out))


def analyze_paths(paths: Sequence[str],
                  rules: Optional[Sequence[Rule]] = None) -> AnalysisResult:
    """Run the catalog over every .py under ``paths`` and apply
    waivers. Unparseable files surface as PARSE-ERROR findings rather
    than aborting the scan."""
    if rules is None:
        from repro.analysis.rules import ALL_RULES
        rules = ALL_RULES
    t0 = time.perf_counter()
    findings: List[Finding] = []
    ctxs: List[ModuleContext] = []
    waivers_by_path: Dict[str, List[Waiver]] = {}
    files = discover(paths)
    for disp, abspath in files:
        with open(abspath, "r", encoding="utf-8") as f:
            source = f.read()
        try:
            ctx = ModuleContext(disp, abspath, source)
        except SyntaxError as exc:
            findings.append(Finding(
                path=disp, line=exc.lineno or 1, col=exc.offset or 0,
                rule=PARSE_RULE, message=f"cannot parse: {exc.msg}"))
            continue
        ws, wfinds = parse_waivers(source, disp)
        ctx.waivers = ws
        waivers_by_path[disp] = ws
        findings.extend(wfinds)
        ctxs.append(ctx)
    for ctx in ctxs:
        for rule in rules:
            findings.extend(rule.check_module(ctx))
    for rule in rules:
        findings.extend(rule.check_project(ctxs))
    kept, waived = apply_waivers(findings, waivers_by_path)
    return AnalysisResult(findings=sorted(kept), waived=waived,
                          files_scanned=len(files),
                          elapsed_s=time.perf_counter() - t0)
