"""Finding records and report formatting for the invariant checker.

One ``Finding`` per contract violation, rendered compiler-style as
``path:line:col RULE-ID message`` so editors and CI logs can jump
straight to the site (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    path: str       # display path (as discovered, e.g. src/repro/...)
    line: int       # 1-based
    col: int        # 0-based, as ast reports
    rule: str       # RULE-ID, e.g. "RNG-CONTRACT"
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col} {self.rule} " \
               f"{self.message}"


def render(findings: List[Finding]) -> str:
    return "\n".join(f.format() for f in sorted(findings))
