"""``repro.analysis``: the AST invariant checker (DESIGN.md §8).

A stdlib-only static-analysis gate enforcing the repo's determinism
contracts at lint time, before any test runs:

    python -m repro.analysis src --strict

Rules: RNG-CONTRACT, TRACE-PURITY, KERNEL-LAYOUT, THREAD-DISCIPLINE,
SPILL-SAFETY. Violations print as ``path:line:col RULE-ID message``
and are waivable inline with
``# repro: allow(RULE-ID) -- justification``.
"""
from repro.analysis.engine import (AnalysisResult, Finding,
                                   ModuleContext, Rule, RuleVisitor,
                                   analyze_paths)
from repro.analysis.imports import build_import_report
from repro.analysis.rules import ALL_RULES, RULE_IDS

__all__ = ["AnalysisResult", "Finding", "ModuleContext", "Rule",
           "RuleVisitor", "analyze_paths", "build_import_report",
           "ALL_RULES", "RULE_IDS"]
