"""CLI for the invariant checker.

    python -m repro.analysis src --strict            # CI hard gate
    python -m repro.analysis tests benchmarks        # report mode
    python -m repro.analysis src --report-dead       # import graph
    python -m repro.analysis src --strict --max-seconds 10

Exit codes: 0 clean (or report mode), 1 unwaived findings under
``--strict``, 2 wall-time budget exceeded.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.engine import analyze_paths
from repro.analysis.imports import DEFAULT_ROOTS, build_import_report
from repro.analysis.rules import ALL_RULES, RULE_IDS


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST invariant checker for the determinism "
                    "contracts (DESIGN.md §8)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/dirs to scan (default: src)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any unwaived finding (the CI gate)")
    ap.add_argument("--rules", default=None,
                    help=f"comma-separated subset of {RULE_IDS}")
    ap.add_argument("--report-dead", action="store_true",
                    help="also print the import-graph dead-module "
                         "inventory (roots: %s)" % ", ".join(DEFAULT_ROOTS))
    ap.add_argument("--roots", default=None,
                    help="override --report-dead root prefixes "
                         "(comma-separated dotted names)")
    ap.add_argument("--max-seconds", type=float, default=None,
                    help="exit 2 if the scan takes longer than this")
    args = ap.parse_args(argv)
    paths = args.paths or ["src"]

    rules = ALL_RULES
    if args.rules:
        want = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = want - set(RULE_IDS)
        if unknown:
            ap.error(f"unknown rules {sorted(unknown)}; "
                     f"known: {RULE_IDS}")
        rules = tuple(r for r in ALL_RULES if r.rule_id in want)

    res = analyze_paths(paths, rules=rules)
    for f in res.findings:
        print(f.format())
    mode = "strict" if args.strict else "report"
    print(f"repro.analysis [{mode}]: {len(res.findings)} finding(s) "
          f"({res.waived} waived) across {res.files_scanned} files "
          f"in {res.elapsed_s:.2f}s")

    if args.report_dead:
        roots = tuple(r.strip() for r in args.roots.split(",")) \
            if args.roots else DEFAULT_ROOTS
        for p in paths:
            print(build_import_report(p, roots=roots).format())

    if args.max_seconds is not None and res.elapsed_s > args.max_seconds:
        print(f"repro.analysis: wall time {res.elapsed_s:.2f}s exceeds "
              f"budget {args.max_seconds:.2f}s", file=sys.stderr)
        return 2
    if args.strict and res.findings:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
