"""TRACE-PURITY: no host escapes inside trace-reachable functions.

The device runner compiles ONE program for all epochs
(``trace_count == 1`` in ``dist/runner.py``); that invariant dies the
moment a traced function forces a host sync -- ``.item()`` /
``int(tracer)`` / ``float(tracer)`` concretize an abstract value (a
TracerError at best, a silent retrace at worst), host IO and
``time.*`` run at TRACE time (once, not per step, a classic silent
bug), and ``threading`` primitives inside a traced region are never
what the author meant (DESIGN.md §8).

Reachability is computed per module, syntactically: a function is
TRACED when it is decorated with (or passed by name to) a jax tracing
wrapper -- ``jax.jit``, ``shard_map``, ``lax.scan`` and friends,
``pl.pallas_call``, ``custom_vjp``/``defvjp`` -- plus the transitive
closure over same-module calls. Casts of provably shape-static
expressions (``int(x.shape[0])``, ``len(...)``, constant arithmetic)
are exempt: shapes are static under trace.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.engine import (Finding, ModuleContext, Rule)

#: calls whose function-valued arguments become traced regions
TRACE_WRAPPERS = {
    "jax.jit", "jax.pmap", "jax.vmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.custom_vjp", "jax.custom_jvp",
    "jax.linearize", "jax.linear_transpose", "jax.make_jaxpr",
    "jax.lax.scan", "jax.lax.while_loop", "jax.lax.fori_loop",
    "jax.lax.cond", "jax.lax.switch", "jax.lax.map",
    "jax.lax.associative_scan", "jax.lax.custom_root",
    "jax.experimental.shard_map.shard_map",
    "jax.experimental.pallas.pallas_call",
}

#: method names that seed their args regardless of receiver
#: (``f.defvjp(fwd, bwd)`` on a custom_vjp object)
_SEEDING_METHODS = {"defvjp", "defjvp"}

_CASTS = {"int", "float", "bool"}
_HOST_IO = {"print", "open", "input", "breakpoint"}

#: call targets allowed inside a static (shape-arithmetic) expression
_STATIC_CALL_PREFIXES = ("math.",)
_STATIC_CALLS = {"len", "int", "float", "min", "max", "abs", "round",
                 "divmod"}

_FN_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)


def _is_static(node: ast.AST, static_names: Set[str],
               ctx: ModuleContext) -> bool:
    """Conservatively: does this expression only depend on shapes /
    constants (static under jax tracing)?"""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Name):
        return node.id in static_names
    if isinstance(node, ast.Attribute):
        # .shape/.ndim/.dtype of ANYTHING is static under trace
        return node.attr in ("shape", "ndim", "dtype")
    if isinstance(node, ast.Subscript):
        return _is_static(node.value, static_names, ctx) and \
            _is_static(node.slice, static_names, ctx)
    if isinstance(node, ast.Index):        # py<3.9 compat slot
        return _is_static(node.value, static_names, ctx)
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(_is_static(e, static_names, ctx) for e in node.elts)
    if isinstance(node, ast.BinOp):
        return _is_static(node.left, static_names, ctx) and \
            _is_static(node.right, static_names, ctx)
    if isinstance(node, ast.UnaryOp):
        return _is_static(node.operand, static_names, ctx)
    if isinstance(node, ast.Compare):
        return _is_static(node.left, static_names, ctx) and \
            all(_is_static(c, static_names, ctx)
                for c in node.comparators)
    if isinstance(node, ast.IfExp):
        return all(_is_static(e, static_names, ctx)
                   for e in (node.test, node.body, node.orelse))
    if isinstance(node, ast.Call):
        canon = ctx.resolve(node.func)
        if canon is None:
            return False
        if canon in _STATIC_CALLS and canon != "len":
            return all(_is_static(a, static_names, ctx)
                       for a in node.args)
        if canon == "len":       # len() of a traced array is its shape
            return True
        if canon.startswith(_STATIC_CALL_PREFIXES):
            return all(_is_static(a, static_names, ctx)
                       for a in node.args)
        return False
    return False


def _iter_stmts(body: List[ast.stmt]):
    """Statements of a function body in source order, descending into
    compound statements but NOT into nested function/class defs."""
    for stmt in body:
        yield stmt
        for field in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, field, None)
            if sub and not isinstance(stmt, _FN_NODES + (ast.ClassDef,)):
                yield from _iter_stmts(sub)
        for h in getattr(stmt, "handlers", ()):
            yield from _iter_stmts(h.body)


def _static_params(fn: ast.AST) -> Set[str]:
    """Parameters declared static via ``static_argnames`` /
    ``static_argnums`` in a jit-style decorator: plain Python values
    under trace, so casting them is fine."""
    out: Set[str] = set()
    posonly = getattr(fn.args, "posonlyargs", [])
    positional = [a.arg for a in list(posonly) + list(fn.args.args)]
    for dec in fn.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        for k in dec.keywords:
            v = k.value
            if k.arg == "static_argnames":
                if isinstance(v, ast.Constant) and \
                        isinstance(v.value, str):
                    out.add(v.value)
                elif isinstance(v, (ast.Tuple, ast.List)):
                    out.update(e.value for e in v.elts
                               if isinstance(e, ast.Constant)
                               and isinstance(e.value, str))
            elif k.arg == "static_argnums":
                nums = [v] if isinstance(v, ast.Constant) else \
                    list(getattr(v, "elts", []))
                for e in nums:
                    if isinstance(e, ast.Constant) and \
                            isinstance(e.value, int) and \
                            e.value < len(positional):
                        out.add(positional[e.value])
    # keyword-only static_argnames params also count
    return out


def _static_names(fn: ast.AST, ctx: ModuleContext) -> Set[str]:
    """Names assigned (in order) from static-only expressions inside
    ``fn``: a one-pass, loop-free dataflow good enough for the
    ``m = x.shape[0]; int(m // bm)`` idiom kernels live on. Seeded
    with the function's jit-static parameters."""
    static: Set[str] = set(_static_params(fn))
    for stmt in _iter_stmts(fn.body):
        targets: List[ast.expr] = []
        value: Optional[ast.expr] = None
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        elif isinstance(stmt, ast.AugAssign):
            targets, value = [stmt.target], stmt.value
        if value is None:
            continue
        ok = _is_static(value, static, ctx)
        for t in targets:
            names = [t] if isinstance(t, ast.Name) else \
                [e for e in getattr(t, "elts", []) if isinstance(e, ast.Name)]
            for n in names:
                if ok and (not isinstance(stmt, ast.AugAssign)
                           or n.id in static):
                    static.add(n.id)
                else:
                    static.discard(n.id)
    return static


class _FnIndex:
    """All function defs in a module, with lexical-scope resolution of
    ``Name`` references to the innermost visible def."""

    def __init__(self, tree: ast.AST):
        self.defs: List[Tuple[ast.AST, Tuple[ast.AST, ...]]] = []
        self.lambdas: List[Tuple[ast.Lambda, Tuple[ast.AST, ...]]] = []
        self._walk(tree, ())

    def _walk(self, node: ast.AST, scope: Tuple[ast.AST, ...]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, _FN_NODES):
                self.defs.append((child, scope))
                self._walk(child, scope + (child,))
            elif isinstance(child, ast.Lambda):
                self.lambdas.append((child, scope))
                self._walk(child, scope)
            else:
                self._walk(child, scope)

    def resolve_ref(self, name: str,
                    from_scope: Tuple[ast.AST, ...]) -> Optional[ast.AST]:
        best, best_len = None, -1
        for fn, scope in self.defs:
            if fn.name != name:
                continue
            if len(scope) <= len(from_scope) and \
                    scope == from_scope[:len(scope)] and \
                    len(scope) > best_len:
                best, best_len = fn, len(scope)
        return best

    def scope_of(self, fn: ast.AST) -> Tuple[ast.AST, ...]:
        for f, scope in self.defs:
            if f is fn:
                return scope
        return ()


class TracePurityRule(Rule):
    rule_id = "TRACE-PURITY"
    description = ("no .item()/int()/float() on traced values, host "
                   "IO, time.* or threading inside jax.jit / "
                   "shard_map / lax.scan-reachable functions")

    def check_module(self, ctx: ModuleContext) -> Iterable[Finding]:
        idx = _FnIndex(ctx.tree)
        traced: Set[ast.AST] = set()
        traced_lambdas: Set[ast.Lambda] = set()

        def seed_arg(arg: ast.expr, scope: Tuple[ast.AST, ...]) -> None:
            if isinstance(arg, ast.Name):
                fn = idx.resolve_ref(arg.id, scope)
                if fn is not None:
                    traced.add(fn)
            elif isinstance(arg, ast.Lambda):
                traced_lambdas.add(arg)

        # -- seeds: decorators and wrapper-call arguments ------------
        scope_of_node: Dict[ast.AST, Tuple[ast.AST, ...]] = {}

        def index_scopes(node: ast.AST,
                         scope: Tuple[ast.AST, ...]) -> None:
            for child in ast.iter_child_nodes(node):
                scope_of_node[child] = scope
                index_scopes(child, scope + (child,)
                             if isinstance(child, _FN_NODES) else scope)

        index_scopes(ctx.tree, ())

        for fn, scope in idx.defs:
            for dec in fn.decorator_list:
                canon = ctx.resolve(dec)
                if canon in TRACE_WRAPPERS:
                    traced.add(fn)
                elif isinstance(dec, ast.Call):
                    if ctx.resolve(dec.func) in TRACE_WRAPPERS:
                        traced.add(fn)
                    elif ctx.resolve(dec.func) in ("functools.partial",
                                                   "partial"):
                        if any(ctx.resolve(a) in TRACE_WRAPPERS
                               for a in dec.args):
                            traced.add(fn)

        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            canon = ctx.resolve(node.func)
            scope = scope_of_node.get(node, ())
            is_wrapper = canon in TRACE_WRAPPERS
            is_seeding_method = (isinstance(node.func, ast.Attribute)
                                 and node.func.attr in _SEEDING_METHODS)
            if is_wrapper or is_seeding_method:
                for a in list(node.args) + [k.value for k in node.keywords]:
                    seed_arg(a, scope)

        # -- transitive closure over same-module calls ---------------
        changed = True
        while changed:
            changed = False
            for fn in list(traced):
                scope = idx.scope_of(fn) + (fn,)
                for node in ast.walk(fn):
                    if isinstance(node, ast.Call) and \
                            isinstance(node.func, ast.Name):
                        callee = idx.resolve_ref(node.func.id, scope)
                        if callee is not None and callee not in traced:
                            traced.add(callee)
                            changed = True

        # -- violations inside traced regions ------------------------
        def region_nodes(root_body: List[ast.AST]):
            """Every node under the region, NOT descending into nested
            defs/lambdas (those are audited as their own regions iff
            they are themselves traced)."""
            stack = list(root_body)
            while stack:
                node = stack.pop()
                yield node
                for child in ast.iter_child_nodes(node):
                    if not isinstance(child, _FN_NODES + (ast.Lambda,)):
                        stack.append(child)

        found: List[Finding] = []
        regions = [(fn, fn.name, fn.body, _static_names(fn, ctx))
                   for fn in traced] + \
                  [(lam, "<lambda>", [lam.body], set())
                   for lam in traced_lambdas]
        for _, where, body, names in regions:
            for node in region_nodes(body):
                if not isinstance(node, ast.Call):
                    continue
                f = self._check_call(node, names, ctx, where)
                if f is not None:
                    found.append(f)
        return found

    def _check_call(self, node: ast.Call, static_names: Set[str],
                    ctx: ModuleContext, where: str) -> Optional[Finding]:
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in ("item", "tolist") and not node.args:
            return ctx.finding(
                node, self.rule_id,
                f".{node.func.attr}() in traced '{where}' forces a "
                f"host sync (breaks trace_count == 1)")
        canon = ctx.resolve(node.func)
        if canon is None:
            return None
        if canon in _CASTS and len(node.args) == 1 and \
                not _is_static(node.args[0], static_names, ctx):
            return ctx.finding(
                node, self.rule_id,
                f"{canon}(...) on a non-shape value in traced "
                f"'{where}' concretizes a tracer; hoist to the host "
                f"or compute from .shape")
        if canon in _HOST_IO:
            return ctx.finding(
                node, self.rule_id,
                f"host IO {canon}(...) in traced '{where}' runs at "
                f"trace time, not per step")
        if canon.startswith("time."):
            return ctx.finding(
                node, self.rule_id,
                f"{canon}() in traced '{where}' measures trace time, "
                f"not step time")
        if canon == "threading" or canon.startswith("threading."):
            return ctx.finding(
                node, self.rule_id,
                f"{canon} in traced '{where}': thread primitives "
                f"cannot live inside a traced region")
        return None
