"""The invariant catalog (DESIGN.md §8): one rule per contract."""
from repro.analysis.rules.rng_contract import RngContractRule
from repro.analysis.rules.trace_purity import TracePurityRule
from repro.analysis.rules.kernel_layout import KernelLayoutRule
from repro.analysis.rules.thread_discipline import ThreadDisciplineRule
from repro.analysis.rules.spill_safety import SpillSafetyRule

ALL_RULES = (
    RngContractRule(),
    TracePurityRule(),
    KernelLayoutRule(),
    ThreadDisciplineRule(),
    SpillSafetyRule(),
)

RULE_IDS = tuple(r.rule_id for r in ALL_RULES)

__all__ = ["ALL_RULES", "RULE_IDS", "RngContractRule", "TracePurityRule",
           "KernelLayoutRule", "ThreadDisciplineRule", "SpillSafetyRule"]
