"""RNG-CONTRACT: all randomness flows through the sanctioned Philox
block in ``repro/graph/sampler.py``.

The paper's §2.2 bit-exactness contract (Prop 3.1) keys every stream
as ``H(s0, w, e, i)`` via ``derive_seed``/``rng_from``; the cache
construction and prefetch schedule are only replayable because no
other generator exists. A stray ``np.random.default_rng(...)`` (or
worse, the global ``np.random.seed`` / stdlib ``random``) introduces a
stream whose consumption depends on call order -- exactly the
PR-6 ``Generator.integers`` rejection-sampling trap generalized
(DESIGN.md §8). Construction therefore happens in one file; everybody
else calls ``rng_from(...)`` or receives a Generator.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import (Finding, ModuleContext, Rule,
                                   RuleVisitor)

#: the one file allowed to touch numpy.random directly
SANCTIONED = ("repro/graph/sampler.py",)

#: RNG constructors whose seed argument must never be wall-clock
_SEEDED = {"numpy.random.default_rng", "numpy.random.seed",
           "numpy.random.Philox", "numpy.random.Generator",
           "repro.graph.sampler.rng_from",
           "repro.graph.sampler.derive_seed"}

_TIME_SOURCES = {"time.time", "time.time_ns", "time.monotonic",
                 "time.perf_counter", "time.process_time"}


class _Visitor(RuleVisitor):
    def __init__(self, rule, ctx, sanctioned_file: bool):
        super().__init__(rule, ctx)
        self.sanctioned_file = sanctioned_file

    def visit_Call(self, node: ast.Call) -> None:
        canon = self.ctx.resolve(node.func)
        if canon:
            if canon in _SEEDED:
                for arg in ast.walk(node):
                    if isinstance(arg, ast.Call) and \
                            self.ctx.resolve(arg.func) in _TIME_SOURCES:
                        self.flag(node, f"time-seeded RNG "
                                        f"({canon} seeded from "
                                        f"{self.ctx.resolve(arg.func)}) "
                                        f"is unreplayable; derive seeds "
                                        f"via rng_from(s0, ...)")
                        break
            if not self.sanctioned_file:
                if canon == "numpy.random" or \
                        canon.startswith("numpy.random."):
                    self.flag(node, f"{canon} outside the sanctioned "
                                    f"Philox block "
                                    f"(repro/graph/sampler.py); use "
                                    f"rng_from(s0, ...) so the stream "
                                    f"is keyed by H(s0, fields) "
                                    f"(paper §2.2)")
                elif canon == "random" or canon.startswith("random."):
                    self.flag(node, f"stdlib {canon} is process-global "
                                    f"and unkeyed; use rng_from(s0, "
                                    f"...) from repro/graph/sampler.py")
        self.generic_visit(node)


class RngContractRule(Rule):
    rule_id = "RNG-CONTRACT"
    description = ("randomness must come from the sanctioned Philox "
                   "block (graph/sampler.py rng_from); no np.random / "
                   "stdlib random / time-seeded generators elsewhere")

    def check_module(self, ctx: ModuleContext) -> Iterable[Finding]:
        v = _Visitor(self, ctx, sanctioned_file=ctx.in_file(*SANCTIONED))
        v.visit(ctx.tree)
        return v.found
