"""KERNEL-LAYOUT: every kernel family ships the ops/ref/impl triple,
and Pallas never leaks out of ``kernels/``.

The repo's kernel contract (DESIGN.md §3): each ``kernels/<family>/``
directory exposes ``ops.py`` (the jit'd public wrapper with an
interpret-mode backend so CPU CI can validate it), ``ref.py`` (the
pure-jnp oracle the parity suites pin the kernel to), and
``<family>.py`` (the Pallas implementation). ``pl.pallas_call``
outside ``kernels/`` would create an un-oracled, un-interpretable
kernel -- the exact structure the differential tests exist to prevent.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterable, List, Sequence

from repro.analysis.engine import (Finding, ModuleContext, Rule,
                                   RuleVisitor)

_PALLAS_CALL = "jax.experimental.pallas.pallas_call"


class _Visitor(RuleVisitor):
    def visit_Call(self, node: ast.Call) -> None:
        canon = self.ctx.resolve(node.func)
        is_pallas = canon == _PALLAS_CALL or (
            canon is None and isinstance(node.func, ast.Attribute)
            and node.func.attr == "pallas_call")
        if is_pallas and "/kernels/" not in self.ctx.posix:
            self.flag(node, "pl.pallas_call outside kernels/: kernels "
                            "live in kernels/<family>/ with the "
                            "ops.py/ref.py/impl triple (DESIGN.md §3)")
        self.generic_visit(node)


class KernelLayoutRule(Rule):
    rule_id = "KERNEL-LAYOUT"
    description = ("kernels/<family>/ must expose ops.py + ref.py + "
                   "<family>.py with an interpret-mode backend; "
                   "pl.pallas_call only under kernels/")

    def check_module(self, ctx: ModuleContext) -> Iterable[Finding]:
        v = _Visitor(self, ctx)
        v.visit(ctx.tree)
        return v.found

    def check_project(self,
                      ctxs: Sequence[ModuleContext]) -> Iterable[Finding]:
        # group scanned files into kernel families by directory
        families: Dict[str, List[ModuleContext]] = {}
        for ctx in ctxs:
            parts = ctx.posix.split("/")
            if "kernels" in parts[:-1]:
                ki = parts.index("kernels")
                if ki + 2 < len(parts):       # kernels/<family>/<file>
                    families.setdefault(
                        "/".join(parts[:ki + 2]), []).append(ctx)
        found: List[Finding] = []
        for famdir, members in sorted(families.items()):
            family = famdir.rsplit("/", 1)[1]
            names = {os.path.basename(c.posix): c for c in members}
            anchor = members[0]
            for required in ("ops.py", "ref.py", f"{family}.py"):
                if required not in names:
                    found.append(Finding(
                        path=anchor.path, line=1, col=0,
                        rule=self.rule_id,
                        message=f"kernel family '{family}' is missing "
                                f"{required} (ops/ref/impl triple, "
                                f"DESIGN.md §3)"))
            ops = names.get("ops.py")
            if ops is not None and not self._has_interpret(ops):
                found.append(Finding(
                    path=ops.path, line=1, col=0, rule=self.rule_id,
                    message=f"kernel family '{family}' ops.py exposes "
                            f"no interpret-mode backend (needed for "
                            f"CPU parity CI)"))
        return found

    @staticmethod
    def _has_interpret(ctx: ModuleContext) -> bool:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.keyword) and \
                    node.arg == "interpret":
                return True
            if isinstance(node, ast.arg) and node.arg == "interpret":
                return True
            if isinstance(node, ast.Constant) and \
                    node.value == "interpret":
                return True
            if isinstance(node, ast.Name) and node.id == "interpret":
                return True
        return False
