"""THREAD-DISCIPLINE: background threads are owned, joined, and
propagate their failures.

Three background workers keep the training critical path clear --
``core/schedule.py`` SpillWriter, the ``core/prefetch.py`` producer
pair, and the runner's staging executor -- and each earned the same
hard-won shape: a handle somebody joins, a broad exception capture in
the target (a daemon thread that dies silently turns into a consumer
blocked forever), and lock- or queue-mediated shared state. This rule
pins that shape (DESIGN.md §8):

  * ``threading.Thread(daemon=True)`` not stored on an owner with a
    ``.join()`` path is flagged (a fire-and-forget daemon);
  * a resolvable thread ``target`` whose body has no broad
    ``try/except`` is flagged (exceptions must be captured and
    re-raised on the submitting side);
  * a ``self.<attr>`` written inside the target and read from other
    methods is flagged unless the class takes a ``threading.Lock`` (or
    the traffic rides a ``queue.Queue``);
  * a ``ThreadPoolExecutor`` outside a ``with`` block with no
    ``.shutdown`` call in the file is flagged.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.engine import Finding, ModuleContext, Rule

_THREAD = "threading.Thread"
_EXECUTOR_SUFFIXES = ("ThreadPoolExecutor", "ProcessPoolExecutor")
_MEDIATED = {"queue.Queue", "queue.SimpleQueue", "queue.LifoQueue",
             "queue.PriorityQueue", "threading.Lock", "threading.RLock",
             "threading.Event", "threading.Condition",
             "threading.Semaphore", "threading.BoundedSemaphore"}


def _kw(node: ast.Call, name: str) -> Optional[ast.expr]:
    for k in node.keywords:
        if k.arg == name:
            return k.value
    return None


def _broad_capture(fn: ast.AST) -> bool:
    """Does the function body contain a try with a bare / Exception /
    BaseException handler?"""
    for node in ast.walk(fn):
        if isinstance(node, ast.ExceptHandler):
            if node.type is None:
                return True
            t = node.type
            names = t.elts if isinstance(t, ast.Tuple) else [t]
            for n in names:
                base = n.attr if isinstance(n, ast.Attribute) else \
                    getattr(n, "id", None)
                if base in ("Exception", "BaseException"):
                    return True
    return False


class _ClassModel:
    def __init__(self, node: ast.ClassDef):
        self.node = node
        self.methods: Dict[str, ast.AST] = {
            m.name: m for m in node.body
            if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))}

    def self_attr_calls(self, attr: str, method_filter=None) -> bool:
        """Does any method call ``self.<attr>.<anything>`` -- e.g. a
        ``self._t.join(...)``?  ``attr='_t.join'`` style: pass the
        attribute chain as 'X' and the method name separately."""
        raise NotImplementedError

    def calls_join_on(self, attr: str) -> bool:
        for m in self.methods.values():
            for node in ast.walk(m):
                if isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "join":
                    rcv = node.func.value
                    if isinstance(rcv, ast.Attribute) and \
                            rcv.attr == attr and \
                            isinstance(rcv.value, ast.Name) and \
                            rcv.value.id == "self":
                        return True
        return False

    def has_lock(self, ctx: ModuleContext) -> bool:
        for node in ast.walk(self.node):
            if isinstance(node, ast.Call) and ctx.resolve(node.func) in (
                    "threading.Lock", "threading.RLock"):
                return True
        return False

    def mediated_attrs(self, ctx: ModuleContext) -> Set[str]:
        """self attrs initialized to queue/lock primitives anywhere in
        the class."""
        out: Set[str] = set()
        for node in ast.walk(self.node):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    ctx.resolve(node.value.func) in _MEDIATED:
                for t in node.targets:
                    if isinstance(t, ast.Attribute) and \
                            isinstance(t.value, ast.Name) and \
                            t.value.id == "self":
                        out.add(t.attr)
        return out

    def attr_writes(self, method: ast.AST) -> Set[str]:
        out: Set[str] = set()
        for node in ast.walk(method):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    for e in ([t] + list(getattr(t, "elts", []))):
                        if isinstance(e, ast.Attribute) and \
                                isinstance(e.value, ast.Name) and \
                                e.value.id == "self":
                            out.add(e.attr)
        return out

    def attr_reads(self, method: ast.AST) -> Set[str]:
        out: Set[str] = set()
        for node in ast.walk(method):
            if isinstance(node, ast.Attribute) and \
                    isinstance(node.ctx, ast.Load) and \
                    isinstance(node.value, ast.Name) and \
                    node.value.id == "self":
                out.add(node.attr)
        return out


class ThreadDisciplineRule(Rule):
    rule_id = "THREAD-DISCIPLINE"
    description = ("background threads need a join-able owner with "
                   "exception propagation; thread-written shared "
                   "attrs need a Lock or queue")

    def check_module(self, ctx: ModuleContext) -> Iterable[Finding]:
        found: List[Finding] = []
        classes = {n: _ClassModel(n) for n in ast.walk(ctx.tree)
                   if isinstance(n, ast.ClassDef)}

        def owning_class(node: ast.AST) -> Optional[_ClassModel]:
            cur = ctx.parents.get(node)
            while cur is not None:
                if isinstance(cur, ast.ClassDef):
                    return classes[cur]
                cur = ctx.parents.get(cur)
            return None

        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            canon = ctx.resolve(node.func)
            if canon == _THREAD:
                found.extend(self._check_thread(node, ctx,
                                                owning_class(node)))
            elif canon and canon.endswith(_EXECUTOR_SUFFIXES):
                found.extend(self._check_executor(node, ctx))
        return found

    # -- threading.Thread(...) ----------------------------------------

    def _check_thread(self, node: ast.Call, ctx: ModuleContext,
                      cls: Optional[_ClassModel]) -> List[Finding]:
        found: List[Finding] = []
        daemon = _kw(node, "daemon")
        is_daemon = isinstance(daemon, ast.Constant) and \
            daemon.value is True
        parent = ctx.parents.get(node)

        stored_attr: Optional[str] = None
        stored_name: Optional[str] = None
        if isinstance(parent, ast.Assign) and len(parent.targets) == 1:
            t = parent.targets[0]
            if isinstance(t, ast.Attribute) and \
                    isinstance(t.value, ast.Name) and t.value.id == "self":
                stored_attr = t.attr
            elif isinstance(t, ast.Name):
                stored_name = t.id

        if stored_attr is not None and cls is not None:
            if not cls.calls_join_on(stored_attr):
                found.append(ctx.finding(
                    node, self.rule_id,
                    f"thread handle self.{stored_attr} is never "
                    f"joined; expose a close()/join() path"))
            found.extend(self._check_target(node, ctx, cls))
            found.extend(self._check_shared_state(node, ctx, cls))
        elif stored_name is not None:
            fn = self._enclosing_fn(node, ctx)
            if not (fn is not None
                    and self._local_join(fn, stored_name)):
                if is_daemon:
                    found.append(ctx.finding(
                        node, self.rule_id,
                        f"daemon thread '{stored_name}' has no local "
                        f"join path; own it with a join-able handle"))
        elif is_daemon:
            found.append(ctx.finding(
                node, self.rule_id,
                "bare daemon thread: not stored on any owner, "
                "cannot be joined, failures die silently"))
        return found

    def _check_target(self, node: ast.Call, ctx: ModuleContext,
                      cls: _ClassModel) -> List[Finding]:
        target = _kw(node, "target")
        method: Optional[ast.AST] = None
        if isinstance(target, ast.Attribute) and \
                isinstance(target.value, ast.Name) and \
                target.value.id == "self":
            method = cls.methods.get(target.attr)
        if method is not None and not _broad_capture(method):
            return [ctx.finding(
                node, self.rule_id,
                f"thread target '{method.name}' has no broad "
                f"exception capture; a failure dies silently instead "
                f"of re-raising on the submitting side")]
        return []

    def _check_shared_state(self, node: ast.Call, ctx: ModuleContext,
                            cls: _ClassModel) -> List[Finding]:
        target = _kw(node, "target")
        if not (isinstance(target, ast.Attribute)
                and isinstance(target.value, ast.Name)
                and target.value.id == "self"):
            return []
        method = cls.methods.get(target.attr)
        if method is None or cls.has_lock(ctx):
            return []
        mediated = cls.mediated_attrs(ctx)
        writes = cls.attr_writes(method) - mediated
        if not writes:
            return []
        read_elsewhere: Set[str] = set()
        for name, m in cls.methods.items():
            if m is method:
                continue
            read_elsewhere |= cls.attr_reads(m)
        shared = sorted(writes & read_elsewhere)
        return [ctx.finding(
            node, self.rule_id,
            f"attr self.{a} is written by thread target "
            f"'{method.name}' and read from the main path with no "
            f"threading.Lock in the class") for a in shared]

    # -- executors ------------------------------------------------------

    def _check_executor(self, node: ast.Call,
                        ctx: ModuleContext) -> List[Finding]:
        parent = ctx.parents.get(node)
        if isinstance(parent, ast.withitem):
            return []
        # assigned somewhere + a .shutdown( anywhere in the file: ok
        if ".shutdown(" in ctx.source:
            return []
        return [ctx.finding(
            node, self.rule_id,
            "executor outside a 'with' block and no .shutdown() in "
            "file; worker threads leak past the owning scope")]

    # -- helpers --------------------------------------------------------

    @staticmethod
    def _enclosing_fn(node: ast.AST, ctx: ModuleContext):
        cur = ctx.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = ctx.parents.get(cur)
        return None

    @staticmethod
    def _local_join(fn: ast.AST, name: str) -> bool:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "join" and \
                    isinstance(node.func.value, ast.Name) and \
                    node.func.value.id == name:
                return True
        return False
