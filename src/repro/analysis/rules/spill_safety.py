"""SPILL-SAFETY: the npz spill surface stays flat, un-pickled, and in
one place.

Schedules spill as plain ndarray blocks (``core/schedule.py``,
DESIGN.md §2.1): no pickled object graphs, so a spill file can never
execute code on load and always reloads without per-batch
reconstruction. ``allow_pickle=True`` anywhere -- or an
``np.save``/``np.load`` call sprouting outside the sanctioned spill
module -- reopens both holes, so both are flagged (waiver required
for deliberate, documented exceptions like the checkpoint shards).
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import Finding, ModuleContext, Rule, RuleVisitor

SANCTIONED = ("repro/core/schedule.py",)

_NP_IO = {"numpy.save", "numpy.load", "numpy.savez",
          "numpy.savez_compressed"}
_PICKLE = {"pickle.dump", "pickle.dumps", "pickle.load", "pickle.loads",
           "dill.dump", "dill.dumps", "dill.load", "dill.loads"}


class _Visitor(RuleVisitor):
    def __init__(self, rule, ctx, sanctioned_file: bool):
        super().__init__(rule, ctx)
        self.sanctioned_file = sanctioned_file

    def visit_Call(self, node: ast.Call) -> None:
        for k in node.keywords:
            if k.arg == "allow_pickle" and \
                    isinstance(k.value, ast.Constant) and \
                    k.value.value is True:
                self.flag(node, "allow_pickle=True: spill/checkpoint "
                                "files must stay flat ndarray blocks "
                                "(arbitrary-code-on-load hazard)")
        canon = self.ctx.resolve(node.func)
        if canon and not self.sanctioned_file:
            if canon in _NP_IO:
                self.flag(node, f"{canon} outside the sanctioned "
                                f"spill module repro/core/schedule.py; "
                                f"route array IO through the flat npz "
                                f"spill format (DESIGN.md §2.1)")
            elif canon in _PICKLE:
                self.flag(node, f"{canon}: pickled object graphs are "
                                f"banned from the spill/checkpoint "
                                f"surface")
        self.generic_visit(node)


class SpillSafetyRule(Rule):
    rule_id = "SPILL-SAFETY"
    description = ("no allow_pickle=True anywhere; np.save/np.load "
                   "only inside core/schedule.py")

    def check_module(self, ctx: ModuleContext) -> Iterable[Finding]:
        v = _Visitor(self, ctx, sanctioned_file=ctx.in_file(*SANCTIONED))
        v.visit(ctx.tree)
        return v.found
