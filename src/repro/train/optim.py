"""Optimizers built from scratch (no optax): AdamW + SGD, pytree-generic.

Used by both the GNN reproduction and the transformer substrate. Moments
are kept in fp32 regardless of parameter dtype (bf16-safe); weight decay
is decoupled (AdamW). ``clip_by_global_norm`` is applied inside ``update``
when ``max_grad_norm`` is set.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    max_grad_norm: Optional[float] = None

    def init(self, params: PyTree) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, dtype=jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros))

    def update(self, grads: PyTree, state: AdamWState,
               params: PyTree, lr_scale: float | jnp.ndarray = 1.0):
        if self.max_grad_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.max_grad_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self.lr * lr_scale

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: PyTree


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: float = 1e-2
    momentum: float = 0.9

    def init(self, params: PyTree) -> SGDState:
        return SGDState(
            step=jnp.zeros((), jnp.int32),
            momentum=jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(self, grads: SGDState, state: SGDState, params: PyTree,
               lr_scale: float | jnp.ndarray = 1.0):
        mom = jax.tree.map(
            lambda m, g: self.momentum * m + g.astype(jnp.float32),
            state.momentum, grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32)
                          - self.lr * lr_scale * m).astype(p.dtype),
            params, mom)
        return new_params, SGDState(step=state.step + 1, momentum=mom)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def cosine_schedule(base_lr_scale: float, warmup: int, total: int):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        return base_lr_scale * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return fn
