from repro.train.optim import AdamW, SGD, cosine_schedule, global_norm
from repro.train.checkpoint import save_checkpoint, load_checkpoint, checkpoint_step

__all__ = ["AdamW", "SGD", "cosine_schedule", "global_norm",
           "save_checkpoint", "load_checkpoint", "checkpoint_step"]
