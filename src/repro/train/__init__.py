from repro.train.optim import AdamW, SGD, cosine_schedule, global_norm
from repro.train.checkpoint import (CheckpointCorruptError, checkpoint_step,
                                    latest_step, load_checkpoint,
                                    load_run_state, save_checkpoint,
                                    save_run_state)

__all__ = ["AdamW", "SGD", "cosine_schedule", "global_norm",
           "save_checkpoint", "load_checkpoint", "checkpoint_step",
           "CheckpointCorruptError", "save_run_state", "load_run_state",
           "latest_step"]
