"""Checkpointing: pytree -> per-leaf npz shards + JSON manifest.

Works for host numpy trees and for sharded jax.Arrays (each process saves
the addressable shards it owns; restore re-assembles and re-shards with
the provided sharding tree). No orbax dependency.

Crash safety (DESIGN.md §10): every file is written tmp + fsync +
rename, and the manifest is renamed LAST -- it is the commit marker, so
a crash at any point leaves either the previous checkpoint or a
complete new one, never a torn mix under the final names. Loads
validate leaf set, shapes, manifest agreement, and (optionally) the
step, raising ``CheckpointCorruptError`` instead of raw numpy errors.
``save_run_state``/``load_run_state`` layer per-step directories and an
atomic ``LATEST`` pointer on top for periodic crash-resume.
"""
from __future__ import annotations

import json
import os
import zipfile
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.fault.inject import fault_point

PyTree = Any
_SEP = "/"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed integrity at load: torn archive, manifest
    missing/disagreeing, leaf-set/shape/step mismatch."""


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = leaf
    return flat, treedef


def _commit_bytes(path: str, write_fn) -> None:
    """Atomic file write: tmp + flush + fsync + rename."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        write_fn(f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def save_checkpoint(path: str, tree: PyTree, step: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    flat, _ = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        arrays[key] = arr
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    named = {k.replace(_SEP, "::"): v for k, v in arrays.items()}

    def _write_arrays(f):
        # repro: allow(SPILL-SAFETY) -- checkpoint shards are flat ndarrays keyed by leaf path; allow_pickle stays off
        np.savez(f, **named)

    _commit_bytes(os.path.join(path, "arrays.npz"), _write_arrays)
    # crash probe between the two commits: dying here must leave any
    # PREVIOUS checkpoint valid (the manifest rename below is the
    # commit marker, so a stale manifest + new arrays cannot happen)
    fault_point("checkpoint", epoch=step)
    _commit_bytes(os.path.join(path, "manifest.json"),
                  lambda f: f.write(json.dumps(manifest,
                                               indent=1).encode()))


def load_checkpoint(path: str, like: PyTree,
                    shardings: Optional[PyTree] = None,
                    expect_step: Optional[int] = None) -> PyTree:
    mpath = os.path.join(path, "manifest.json")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as exc:
        raise CheckpointCorruptError(
            f"unreadable checkpoint manifest {mpath}: {exc!r}") from exc
    if expect_step is not None and manifest.get("step") != expect_step:
        raise CheckpointCorruptError(
            f"checkpoint step mismatch at {path}: manifest says "
            f"{manifest.get('step')}, expected {expect_step}")
    apath = os.path.join(path, "arrays.npz")
    try:
        # repro: allow(SPILL-SAFETY) -- reads back the flat npz checkpoint shards; allow_pickle stays off
        with np.load(apath) as z:
            data = {k.replace("::", _SEP): z[k] for k in z.files}
    except (OSError, ValueError, KeyError, EOFError,
            zipfile.BadZipFile) as exc:
        raise CheckpointCorruptError(
            f"torn checkpoint shards {apath}: {exc!r}") from exc
    flat_like, treedef = _flatten(like)
    if set(data) != set(flat_like):
        missing = sorted(set(flat_like) - set(data))[:4]
        extra = sorted(set(data) - set(flat_like))[:4]
        raise CheckpointCorruptError(
            f"checkpoint leaf set at {path} does not match the restore "
            f"target: missing {missing}, unexpected {extra}")
    mleaves = manifest.get("leaves", {})
    if set(mleaves) != set(data):
        raise CheckpointCorruptError(
            f"manifest/arrays leaf sets disagree at {path} (torn commit)")
    leaves = []
    for key, leaf in flat_like.items():
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise CheckpointCorruptError(
                f"shape mismatch for {key} at {path}: saved "
                f"{tuple(arr.shape)}, restore target "
                f"{tuple(np.shape(leaf))}")
        ml = mleaves[key]
        if (list(arr.shape) != list(ml["shape"])
                or str(arr.dtype) != ml["dtype"]):
            raise CheckpointCorruptError(
                f"manifest disagrees with arrays for {key} at {path}")
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree,
                            shardings)
    return tree


def checkpoint_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]


# ---------------------------------------------------------------------------
# periodic run state: per-step dirs + atomic LATEST pointer
# ---------------------------------------------------------------------------

def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def save_run_state(root: str, tree: PyTree, step: int) -> str:
    """One periodic checkpoint: ``root/step_XXXXXXXX/`` committed first,
    then the ``LATEST`` pointer renamed in -- so a crash anywhere leaves
    ``LATEST`` naming a COMPLETE checkpoint (possibly the previous one,
    never a torn one)."""
    os.makedirs(root, exist_ok=True)
    d = _step_dir(root, step)
    save_checkpoint(d, tree, step=step)
    _commit_bytes(os.path.join(root, "LATEST"),
                  lambda f: f.write(f"{step}\n".encode()))
    return d


def latest_step(root: str) -> Optional[int]:
    p = os.path.join(root, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def load_run_state(root: str, like: PyTree,
                   shardings: Optional[PyTree] = None
                   ) -> Tuple[PyTree, int]:
    """Resume from the newest committed checkpoint under ``root``."""
    step = latest_step(root)
    if step is None:
        raise CheckpointCorruptError(f"no LATEST pointer under {root}")
    tree = load_checkpoint(_step_dir(root, step), like, shardings,
                           expect_step=step)
    return tree, step
