"""Checkpointing: pytree -> per-leaf npz shards + JSON manifest.

Works for host numpy trees and for sharded jax.Arrays (each process saves
the addressable shards it owns; restore re-assembles and re-shards with
the provided sharding tree). No orbax dependency.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = leaf
    return flat, treedef


def save_checkpoint(path: str, tree: PyTree, step: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    flat, _ = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        arrays[key] = arr
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    # repro: allow(SPILL-SAFETY) -- checkpoint shards are flat ndarrays keyed by leaf path; allow_pickle stays off
    np.savez(os.path.join(path, "arrays.npz"),
             **{k.replace(_SEP, "::"): v for k, v in arrays.items()})
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str, like: PyTree,
                    shardings: Optional[PyTree] = None) -> PyTree:
    # repro: allow(SPILL-SAFETY) -- reads back the flat npz checkpoint shards; allow_pickle stays off
    with np.load(os.path.join(path, "arrays.npz")) as z:
        data = {k.replace("::", _SEP): z[k] for k in z.files}
    flat_like, treedef = _flatten(like)
    leaves = []
    for key, leaf in flat_like.items():
        arr = data[key]
        assert tuple(arr.shape) == tuple(np.shape(leaf)), \
            f"shape mismatch for {key}"
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree,
                            shardings)
    return tree


def checkpoint_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]
