"""Paper Table 3: modelled energy for RapidGNN vs DGL-METIS.

Thin campaign wrapper: the two systems run as host-backend campaign
cells and the ratios come from ``repro.eval.report.derive_pair`` (the
``energy`` block of ``BENCH_paper.json``). Durations are measured on
this box; component power envelopes are the paper's own Table 3
measurements (CPU 36.73/42.70 W, GPU 30.84/29.45 W). Reported as
MODELLED energy: E = P_mean x duration. The paper's headline ratios
(CPU -44 %, GPU -32 %) reproduce iff our duration ratio matches its
35 % time reduction."""
from __future__ import annotations

from repro.core import POWER
from repro.eval.cells import run_host_cell
from repro.eval.report import derive_pair
from repro.eval.spec import CellSpec


def run(dataset="ogbn_products_sim", batch_size=300, workers=3,
        epochs=2):
    def cell(system):
        return run_host_cell(CellSpec(
            backend="host", system=system, dataset=dataset,
            batch_size=batch_size, workers=workers, n_hot=32768,
            epochs=epochs, hidden=64, train=True, all_workers=False))

    r, m = cell("rapidgnn"), cell("dgl-metis")
    pair = derive_pair(r, m)
    er, em = r.energy, m.energy
    rows = ["metric,rapidgnn,dgl_metis,ratio"]
    rows.append(f"duration_s,{r.warm_wall_s:.2f},{m.warm_wall_s:.2f},"
                f"{r.warm_wall_s / m.warm_wall_s:.2f}")
    for k, ratio in (("cpu_J", pair["energy"]["cpu_ratio"]),
                     ("gpu_J", pair["energy"]["gpu_ratio"]),
                     ("total_J", pair["energy"]["total_ratio"])):
        rows.append(f"{k},{er[k]:.1f},{em[k]:.1f},{ratio:.2f}")
    rows.append(f"mean_power_cpu_W,{POWER['rapidgnn']['cpu']},"
                f"{POWER['baseline']['cpu']},-")
    rows.append(f"mean_power_gpu_W,{POWER['rapidgnn']['gpu']},"
                f"{POWER['baseline']['gpu']},-")
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
