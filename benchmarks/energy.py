"""Paper Table 3: modelled energy for RapidGNN vs DGL-METIS.

Durations are measured on this box; component power envelopes are the
paper's own Table 3 measurements (CPU 36.73/42.70 W, GPU 30.84/29.45 W).
Reported as MODELLED energy: E = P_mean x duration. The paper's headline
ratios (CPU -44 %, GPU -32 %) reproduce iff our duration ratio matches
its 35 % time reduction."""
from __future__ import annotations

from repro.core import modelled_energy, POWER
from benchmarks.common import run_gnn_system


def run(dataset="ogbn_products_sim", batch_size=300, workers=3,
        epochs=2):
    r = run_gnn_system("rapidgnn", dataset, batch_size, workers=workers,
                       epochs=epochs, train=True)
    m = run_gnn_system("dgl-metis", dataset, batch_size, workers=workers,
                       epochs=epochs, train=True)
    er = modelled_energy(r.wall_time_s, "rapidgnn")
    em = modelled_energy(m.wall_time_s, "baseline")
    rows = ["metric,rapidgnn,dgl_metis,ratio"]
    rows.append(f"duration_s,{r.wall_time_s:.2f},{m.wall_time_s:.2f},"
                f"{r.wall_time_s / m.wall_time_s:.2f}")
    for k in ("cpu_J", "gpu_J", "total_J"):
        rows.append(f"{k},{er[k]:.1f},{em[k]:.1f},"
                    f"{er[k] / em[k]:.2f}")
    rows.append(f"mean_power_cpu_W,{POWER['rapidgnn']['cpu']},"
                f"{POWER['baseline']['cpu']},-")
    rows.append(f"mean_power_gpu_W,{POWER['rapidgnn']['gpu']},"
                f"{POWER['baseline']['gpu']},-")
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
