"""Paper Fig. 4: mean data transferred per training step, RapidGNN vs
DGL-METIS, across datasets and batch sizes.

Two independent accountings of the same schedule are reported side by
side so they can cross-checked (DESIGN.md §7):

  * host-sim bytes  -- ``ShardedFeatureStore`` metering from the runner
    (remote_bytes + vector_pull_bytes), and
  * device-path bytes -- replayed from the ``build_pull_plan`` send
    masks: the payload (true residual-miss rows) must MATCH the host
    sim's remote_bytes exactly, while the wire column adds the padded
    all_to_all lanes (P * k_max rows/step) the static-shape collective
    actually moves.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_gnn_system
from repro.graph import load_dataset, partition_graph, KHopSampler
from repro.core import build_schedule
from repro.dist import DeviceView, build_pull_plan, epoch_k_max
from repro.dist.gnn_step import _batch_miss


def device_path_bytes(dataset: str, batch_size: int, workers: int,
                      epochs: int, n_hot: int, s0: int = 42,
                      worker: int = 0):
    """-> (payload_bytes, wire_bytes, cache_bytes, steps) for one worker,
    replaying the exact schedule ``run_gnn_system`` uses through the
    device-path pull plans. The lane bound ``k_max`` is the ALL-workers
    epoch maximum (``epoch_k_max``), as the compiled collective uses --
    wire bytes reflect what actually moves, not worker-local padding."""
    g = load_dataset(dataset)
    pg = partition_graph(g, workers, "metis")
    sampler = KHopSampler(g, fanouts=(25, 10), batch_size=batch_size)
    ws_all = [build_schedule(sampler, pg, worker=w, s0=s0,
                             num_epochs=epochs, n_hot=n_hot)
              for w in range(workers)]
    dv = DeviceView.build(pg)
    row = g.feat_dim * g.features.itemsize
    payload = wire = cache = steps = 0
    for e in range(epochs):
        es_list = [ws.epoch(e) for ws in ws_all]
        caches = [dv.remap_cache(es.cache_ids) for es in es_list]
        cache += es_list[worker].cache_ids.shape[0] * row   # VectorPull
        k_max = epoch_k_max(es_list, caches, dv)
        for b in es_list[worker].batches:
            dev, miss = _batch_miss(b, caches[worker], dv, worker)
            plan = build_pull_plan(dev[miss].astype(np.int32),
                                   np.flatnonzero(miss).astype(np.int32),
                                   dv.owner_d, pg.num_parts, k_max)
            payload += plan.payload_bytes(row)
            wire += plan.wire_bytes(row)
            steps += 1
    return payload, wire, cache, steps


def run(datasets=("ogbn_products_sim", "reddit_sim"),
        batch_sizes=(100, 200), epochs=2, workers=4, n_hot=32768):
    rows = ["dataset,batch,rapidgnn_MB_per_step,dglmetis_MB_per_step,"
            "reduction_x,device_payload_MB_per_step,"
            "device_wire_MB_per_step,host_vs_device_payload"]
    for ds in datasets:
        for b in batch_sizes:
            r = run_gnn_system("rapidgnn", ds, b, workers=workers,
                               epochs=epochs, n_hot=n_hot, train=False)
            m = run_gnn_system("dgl-metis", ds, b, workers=workers,
                               epochs=epochs, train=False)
            payload, wire, cache, steps = device_path_bytes(
                ds, b, workers, epochs, n_hot)
            # ONE denominator for every per-step column: all steps of all
            # epochs (GNNResult.bytes_per_step drops epoch 0's steps but
            # keeps its bytes -- not comparable across accountings).
            n = max(steps, 1)
            rmb = (r.remote_bytes + r.vector_pull_bytes) / n / 1e6
            mmb = (m.remote_bytes + m.vector_pull_bytes) / n / 1e6
            dp = payload / n / 1e6
            dw = wire / n / 1e6
            match = ("MATCH" if payload == r.remote_bytes
                     else f"DIFF({payload}vs{r.remote_bytes})")
            rows.append(f"{ds},{b},{rmb:.2f},{mmb:.2f},"
                        f"{mmb / max(rmb, 1e-9):.2f},{dp:.2f},{dw:.2f},"
                        f"{match}")
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
