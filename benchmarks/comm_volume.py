"""Paper Fig. 4: mean data transferred per training step, RapidGNN vs
DGL-METIS, across datasets and batch sizes.

Thin campaign wrapper: the host-sim cells come from ``repro.eval.cells``
and the device-path accounting from ``repro.eval.replay`` -- two
independent accountings of the same schedule, reported side by side so
they cross-check (DESIGN.md §7):

  * host-sim bytes  -- ``ShardedFeatureStore`` metering from the runner
    (remote_bytes + vector_pull_bytes), and
  * device-path bytes -- replayed from the ``build_pull_plan`` send
    masks: the payload (true residual-miss rows) must MATCH the host
    sim's remote_bytes exactly, while the wire column adds the padded
    all_to_all lanes (P * k_max rows/step) the static-shape collective
    actually moves and the request column the id-lane leg shipped the
    other way (previously unaccounted).

The same contract runs on the REAL device runners (not a replay) inside
``python -m repro.eval.campaign`` as the ``miss_parity`` /
``payload_bytes`` differential checks.
"""
from __future__ import annotations

from benchmarks.common import run_gnn_system
from repro.eval.replay import replay_device_bytes


def run(datasets=("ogbn_products_sim", "reddit_sim"),
        batch_sizes=(100, 200), epochs=2, workers=4, n_hot=32768):
    rows = ["dataset,batch,rapidgnn_MB_per_step,dglmetis_MB_per_step,"
            "reduction_x,device_payload_MB_per_step,"
            "device_wire_MB_per_step,device_request_MB_per_step,"
            "host_vs_device_payload"]
    for ds in datasets:
        for b in batch_sizes:
            r = run_gnn_system("rapidgnn", ds, b, workers=workers,
                               epochs=epochs, n_hot=n_hot, train=False)
            m = run_gnn_system("dgl-metis", ds, b, workers=workers,
                               epochs=epochs, train=False)
            payload, wire, request, cache, steps = replay_device_bytes(
                ds, b, workers, epochs, n_hot)
            # ONE denominator for every per-step column: all steps of all
            # epochs (GNNResult.bytes_per_step drops epoch 0's steps but
            # keeps its bytes -- not comparable across accountings).
            n = max(steps, 1)
            rmb = (r.remote_bytes + r.vector_pull_bytes) / n / 1e6
            mmb = (m.remote_bytes + m.vector_pull_bytes) / n / 1e6
            dp = payload / n / 1e6
            dw = wire / n / 1e6
            dq = request / n / 1e6
            match = ("MATCH" if payload == r.remote_bytes
                     else f"DIFF({payload}vs{r.remote_bytes})")
            rows.append(f"{ds},{b},{rmb:.2f},{mmb:.2f},"
                        f"{mmb / max(rmb, 1e-9):.2f},{dp:.2f},{dw:.2f},"
                        f"{dq:.2f},{match}")
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
