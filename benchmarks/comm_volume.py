"""Paper Fig. 4: mean data transferred per training step, RapidGNN vs
DGL-METIS, across datasets and batch sizes."""
from __future__ import annotations

from benchmarks.common import run_gnn_system


def run(datasets=("ogbn_products_sim", "reddit_sim"),
        batch_sizes=(100, 200), epochs=2, workers=4):
    rows = ["dataset,batch,rapidgnn_MB_per_step,dglmetis_MB_per_step,"
            "reduction_x"]
    for ds in datasets:
        for b in batch_sizes:
            r = run_gnn_system("rapidgnn", ds, b, workers=workers,
                               epochs=epochs, train=False)
            m = run_gnn_system("dgl-metis", ds, b, workers=workers,
                               epochs=epochs, train=False)
            rmb = r.bytes_per_step / 1e6
            mmb = m.bytes_per_step / 1e6
            rows.append(f"{ds},{b},{rmb:.2f},{mmb:.2f},"
                        f"{mmb / max(rmb, 1e-9):.2f}")
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
