"""Online-serving latency bench: p50/p99 under Poisson load, clean vs
fault-injected lanes (ISSUE 10 / DESIGN.md §11).

Each lane runs the SAME Philox-keyed request stream against a fresh
``GNNInferenceService`` sharing one pre-compiled ``ServeProgram`` (the
compile is paid once in warmup, so lane latencies are steady-state and
the one-trace contract holds sweep-wide). Fault lanes activate a named
profile from ``repro.fault.plan``:

  * ``serve-pull-flaky`` -- every residual sync pull fails once, then
    the retry recovers (measures the retry-backoff latency tax).
  * ``serve-warm-stale`` -- warm generation 2 dies forever, pinning the
    warmer unhealthy; requests degrade to the stale last-good snapshot
    (measures the stale tier, which must NOT be slower than fresh).

The gate: worst fault-lane p99 must stay within 5x of the clean lane's
p99 -- degrade gracefully, don't cliff. Emits
``artifacts/BENCH_serve.json`` (schema ``rapidgnn.bench_serve/v1``) and
CSV rows for ``benchmarks.run``; raises (-> section FAILED + a
``recovery FAILED`` line CI greps for) when the bound breaks.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

HEADER = ("lane,fault_profile,requests,served,shed,errors,"
          "p50_ms,p99_ms,stale,pull_retries")

#: fault lanes: (lane label, PROFILES name)
FAULT_LANES = (("pull_flaky", "serve-pull-flaky"),
               ("warm_stale", "serve-warm-stale"))
RATIO_BOUND = 5.0


def _build(seed: int):
    import jax

    from repro.graph import KHopSampler, load_dataset, partition_graph
    from repro.models import GNNConfig, init_params

    g = load_dataset("tiny", seed=seed)
    pg = partition_graph(g, 4, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=8)
    cfg = GNNConfig(kind="sage", in_dim=g.feat_dim, hidden_dim=32,
                    num_classes=g.num_classes, num_layers=2)
    params = init_params(cfg, jax.random.key(seed))
    return g, pg, sampler, cfg, params


def _service(built, program, seed: int):
    from repro.serve.gnn import GNNInferenceService

    g, pg, sampler, cfg, params = built
    return GNNInferenceService(
        pg, sampler, cfg, params, s0=seed, worker=0, n_hot=64,
        max_batch_requests=4, high_water=256, default_timeout_s=30.0,
        program=program)


def _lane(built, program, lane: str, profile: Optional[str],
          streams, gaps, seed: int) -> Dict:
    from repro.fault.inject import active_plan
    from repro.fault.plan import plan_from_profile
    from repro.serve.gnn import Overloaded

    plan = plan_from_profile(profile, seed=seed) if profile else None
    svc = _service(built, program, seed).start()
    try:
        pendings, shed = [], 0
        with active_plan(plan):
            for gap, seeds in zip(gaps, streams):
                time.sleep(float(gap))
                try:
                    pendings.append(svc.submit(seeds))
                except Overloaded:
                    shed += 1
            lat, errors = [], 0
            for p in pendings:
                try:
                    lat.append(p.result(timeout=30.0).latency_s)
                except Exception:
                    errors += 1
        health = svc.health()
    finally:
        svc.close()
    lat_ms = np.asarray(lat) * 1e3
    return {
        "lane": lane,
        "fault_profile": profile or "none",
        "requests": len(streams),
        "served": len(lat),
        "shed": shed,
        "errors": errors,
        "latency_ms": {
            "p50": round(float(np.percentile(lat_ms, 50)), 3),
            "p99": round(float(np.percentile(lat_ms, 99)), 3),
            "mean": round(float(lat_ms.mean()), 3),
        },
        "health": health,
    }


def run(requests: int = 32, rate: float = 400.0,
        seed: int = 0) -> List[str]:
    from repro.eval.report import (build_serve_report,
                                   validate_serve_report, write_report)
    from repro.graph.sampler import rng_from

    built = _build(seed)
    g = built[0]
    rng = rng_from(seed, 0xBE5E)        # bench serve arrival stream
    gaps = rng.exponential(1.0 / rate, size=requests)
    streams = [rng.integers(0, g.num_nodes, size=int(n))
               for n in rng.integers(1, 9, size=requests)]

    # pay the XLA compile once, outside every lane's clock
    warm = _service(built, None, seed)
    warm.oracle(streams[0], rid=0)
    program = warm.program
    warm.close()

    lanes = [_lane(built, program, "clean", None, streams, gaps, seed)]
    for label, profile in FAULT_LANES:
        lanes.append(_lane(built, program, label, profile, streams,
                           gaps, seed))

    config = {"dataset": "tiny", "parts": 4, "fanouts": [5, 5],
              "batch_size": 8, "requests": requests, "rate": rate,
              "seed": seed}
    report = build_serve_report(config, lanes, ratio_bound=RATIO_BOUND)
    probs = validate_serve_report(report)
    if probs:
        raise RuntimeError("BENCH_serve schema: " + "; ".join(probs))
    art = os.path.join(ROOT, "artifacts")
    write_report(report, os.path.join(art, "BENCH_serve.json"))

    rows = [HEADER]
    for r in lanes:
        h = r["health"]
        rows.append(f"{r['lane']},{r['fault_profile']},{r['requests']},"
                    f"{r['served']},{r['shed']},{r['errors']},"
                    f"{r['latency_ms']['p50']},{r['latency_ms']['p99']},"
                    f"{h['served_stale']},{h['pull_retries']}")
    rows.append(f"summary,p99_ratio,{report['p99_ratio']},"
                f"bound,{RATIO_BOUND},"
                f"{'OK' if report['ok'] else 'BAD'},,,,")
    if not report["ok"]:
        raise RuntimeError(
            f"recovery FAILED: serve fault-lane p99 ratio "
            f"{report['p99_ratio']} exceeds {RATIO_BOUND}x clean")
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
