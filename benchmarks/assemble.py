"""Microbenchmark: fused single-pass feature assembly vs the legacy
staged chain, and vectorized vs per-(step, worker) loop epoch collation
(ISSUE 3 / DESIGN.md §3, §6.6).

Two sections:

  * device assembly -- jit'd ``assemble_features`` per backend on
    realistic per-step shapes. On CPU the comparison is the single-pass
    jnp path (one output materialization, what ``backend="auto"``
    resolves to off-TPU) against the staged three-materialization chain;
    on TPU the fused Pallas kernel joins in via ``backend="fused"``.
  * host collation -- ``collate_device_epoch`` (vectorized: one g2d
    gather, one searchsorted, batched lane packing) against
    ``collate_device_epoch_loop`` on a synthetic randomized schedule at
    64 and 256 workers, asserting batch-for-batch identity before
    timing. This is the double-buffer staging path that must keep up
    with the device (dist/runner.py).

Emits ``artifacts/BENCH_assemble.json`` and CSV rows for
``benchmarks.run``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import List

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

HEADER = "section,case,variant,ms_per_call,speedup_vs_ref,identical"


def _time(fn, *args, warmup: int = 2, iters: int = 50,
          repeats: int = 3) -> float:
    """min-of-repeats mean ms/call (min defeats scheduler/thermal noise)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) * 1e3 / iters)
    return best


def _time_host(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """min-of-iters ms/call (min defeats scheduler/thermal noise)."""
    for _ in range(warmup):
        fn(*args)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


# ---------------------------------------------------------------------------
# section 1: device assembly
# ---------------------------------------------------------------------------

def bench_assemble(m: int = 4096, d: int = 128, n_per: int = 16384,
                   n_hot: int = 4096, P_: int = 4):
    import jax
    import jax.numpy as jnp
    from repro.kernels.assemble.ops import assemble_features

    rng = np.random.default_rng(0)
    worker = 1
    base = worker * n_per
    table = jnp.asarray(rng.normal(size=(n_per, d)).astype(np.float32))
    remote = np.setdiff1d(
        rng.choice(P_ * n_per, size=3 * n_hot, replace=False),
        np.arange(base, base + n_per))
    cids = np.sort(remote[:n_hot]).astype(np.int32)
    miss_pool = remote[n_hot:]
    q = np.concatenate([
        rng.integers(base, base + n_per, size=m // 2),      # local
        rng.choice(cids, size=3 * m // 8),                  # C_s hits
        rng.choice(miss_pool, size=m - m // 2 - 3 * m // 8,
                   replace=False)]).astype(np.int32)        # pulled
    rng.shuffle(q)
    cfeats = jnp.asarray(rng.normal(size=(n_hot, d)).astype(np.float32))
    pulled = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    args = (table, jnp.int32(base), jnp.asarray(cids), cfeats,
            jnp.asarray(q), pulled)

    backends = ["staged", "ref"]
    if jax.default_backend() == "tpu":
        backends.append("fused")

    fns, ms, outs = {}, {}, {}
    for b in backends:
        fns[b] = jax.jit(lambda *a, _b=b: assemble_features(*a, backend=_b))
        outs[b] = np.asarray(fns[b](*args))
        ms[b] = _time(fns[b], *args)
    same = all(np.array_equal(outs[b], outs["staged"]) for b in backends)
    rows, rec = [], {}
    for b in backends:
        sp = ms["staged"] / max(ms[b], 1e-9)
        rows.append(f"assemble,m{m}_d{d}_nhot{n_hot},{b},{ms[b]:.3f},"
                    f"{sp:.2f}x,{same}")
        rec[b] = {"ms_per_call": round(ms[b], 4),
                  "speedup_vs_staged": round(sp, 3)}
    rec.update(shape={"m": m, "d": d, "n_per": n_per, "n_hot": n_hot},
               identical=bool(same))
    return rows, rec


# ---------------------------------------------------------------------------
# section 2: host collation at 64 / 256 workers
# ---------------------------------------------------------------------------

def _synthetic_epoch(P_: int, S: int, m: int, B: int, n_per: int,
                     n_hot: int, fanouts, seed: int,
                     hit_rate: float = 0.65):
    """Randomized schedule straight in device-view terms: identity g2d,
    per-worker sorted hot sets, fan-out-regular blocks -- everything
    ``collate_device_epoch`` touches, none of the sampler cost. Remote
    accesses hit the hot set at ``hit_rate`` (the paper's cache is
    top-frequency, so high hit rates are the operating regime)."""
    from repro.graph.sampler import Block, SampledBatch
    from repro.core.schedule import EpochSchedule
    from repro.dist import DeviceCache, DeviceView

    rng = np.random.default_rng(seed)
    n = P_ * n_per
    dv = DeviceView(num_parts=P_, n_per=n_per,
                    table=np.zeros((P_, 1, 1), np.float32),
                    offsets=(np.arange(P_, dtype=np.int32) * n_per)[:, None],
                    g2d=np.arange(n, dtype=np.int64),
                    features=np.zeros((n, 1), np.float32))
    labels = rng.integers(0, 40, size=n).astype(np.int64)
    es_list, caches = [], []
    for w in range(P_):
        lo = w * n_per
        # per-worker hot set C_s, drawn from the remote id space first so
        # batches can sample from it at the target hit rate
        remote_pool = rng.choice(n - n_per, size=4 * n_hot, replace=False)
        remote_pool = np.where(remote_pool >= lo, remote_pool + n_per,
                               remote_pool)
        cache_ids = np.sort(remote_pool[:n_hot]).astype(np.int64)
        miss_pool = remote_pool[n_hot:]
        batches = []
        for i in range(S):
            mm = int(rng.integers(int(0.8 * m), m + 1))
            n_rem = mm - mm // 2
            n_hit = int(hit_rate * n_rem)
            local = rng.choice(n_per, size=mm // 2, replace=False) + lo
            rem = np.concatenate([
                rng.choice(cache_ids, size=n_hit, replace=False),
                rng.choice(miss_pool, size=n_rem - n_hit, replace=False)])
            ids = np.concatenate([local, rem])
            rng.shuffle(ids)
            blocks = []
            nd = max(mm // 3, 1)
            for fo in fanouts:
                E = nd * fo
                blocks.append(Block(
                    num_src=mm, num_dst=nd,
                    edge_src=rng.integers(0, mm, size=E).astype(np.int32),
                    edge_dst=np.repeat(np.arange(nd, dtype=np.int32), fo),
                    edge_mask=rng.random(E) > 0.1))
                nd = max(nd // 2, 1)
            batches.append(SampledBatch(
                epoch=0, index=i, worker=w,
                seeds=ids[:B].copy(), input_nodes=ids, blocks=blocks))
        caches.append(DeviceCache(ids=cache_ids,
                                  feats=np.zeros((n_hot, 1), np.float32)))
        es_list.append(EpochSchedule(
            epoch=0, batches=batches,
            remote_ids=np.zeros(0, np.int64),
            remote_freq=np.zeros(0, np.int64),
            cache_ids=cache_ids, m_max=m))
    return es_list, caches, dv, labels


def bench_collation(workers=(64, 256), S: int = 24, m: int = 1000,
                    B: int = 100):
    from repro.core.schedule import epoch_edge_maxima
    from repro.dist import epoch_k_max
    from repro.dist.gnn_step import (collate_device_epoch,
                                     collate_device_epoch_loop)

    rows, recs = [], []
    for P_ in workers:
        # paper-proportioned per-worker shapes: B=100 is the repo's own
        # benchmark batch size (speedup/comm_volume sweep bs 100-300),
        # fanouts [5,5] its sampler default, n_hot=32768 the per-worker
        # hot set dryrun_gnn stages at 256 workers, and S=24 a
        # papers100M-like step count (1.2M train nodes / 256 workers /
        # B=100 is ~47 steps/epoch; S=24 keeps the loop reference
        # affordable)
        es_list, caches, dv, labels = _synthetic_epoch(
            P_, S, m, B, n_per=8192, n_hot=32768, fanouts=(5, 5),
            seed=P_)
        edge_max = [0, 0]
        for es in es_list:
            em = epoch_edge_maxima(es)
            edge_max = [max(a, b) for a, b in zip(edge_max, em)]
        k_max = epoch_k_max(es_list, caches, dv)
        args = (es_list, caches, dv, labels, B, m, edge_max, k_max, S)
        vec = collate_device_epoch(*args)
        loop = collate_device_epoch_loop(*args)
        same = all(
            np.array_equal(vec[k], loop[k])
            for k in ("input_nodes", "labels", "seed_mask", "send_ids",
                      "send_pos", "send_mask")) and all(
            np.array_equal(vec[k][l], loop[k][l])
            for k in ("edge_src", "edge_dst", "edge_mask")
            for l in range(len(edge_max)))
        t_loop = _time_host(collate_device_epoch_loop, *args, iters=2)
        t_vec = _time_host(collate_device_epoch, *args, iters=4)
        sp = t_loop / max(t_vec, 1e-9)
        rows.append(f"collation,P{P_}_S{S}_m{m},loop,{t_loop:.1f},1.00x,"
                    f"{same}")
        rows.append(f"collation,P{P_}_S{S}_m{m},vectorized,{t_vec:.1f},"
                    f"{sp:.2f}x,{same}")
        recs.append({"workers": P_, "steps": S, "m": m,
                     "loop_ms": round(t_loop, 2),
                     "vectorized_ms": round(t_vec, 2),
                     "speedup": round(sp, 2), "identical": bool(same)})
    return rows, recs


def run() -> List[str]:
    rows = [HEADER]
    a_rows, a_rec = bench_assemble()
    rows += a_rows
    c_rows, c_rec = bench_collation()
    rows += c_rows
    art = os.path.join(ROOT, "artifacts")
    os.makedirs(art, exist_ok=True)
    with open(os.path.join(art, "BENCH_assemble.json"), "w") as f:
        json.dump({"assemble": a_rec, "collation": c_rec}, f, indent=1)
    best = max(c_rec, key=lambda r: r["workers"])
    rows.append(f"summary,collation_P{best['workers']},vectorized,"
                f"{best['vectorized_ms']},{best['speedup']}x,"
                f"{best['identical']}")
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
