"""Fig-4-style intra-vs-inter-host traffic cut on the hierarchical
topology (DESIGN.md §6.7).

Replays every worker's deterministic schedule under a ``hosts x
devices_per_host`` topology and splits the residual-miss payload by
tier: same-host misses ride the cheap intra-host (ICI) wire, cross-host
misses the slow DCN. Two identities gate the section (raise -> section
FAILED -> CI bench grep fails):

  * byte-sum   -- intra + inter bytes == the flat-mesh payload counted
    independently through ``build_pull_plan`` (the same identity the
    campaign's ``topology_byte_sum`` differential check pins against
    REAL device cells);
  * bias       -- the DCN-biased hot set (``select_hot_set`` weighted
    toward cross-host owners, ``Topology.owner_bias``) must not RAISE
    inter-host bytes; the table reports how much it removes.
"""
from __future__ import annotations

from repro.eval.replay import replay_topology_bytes


def run(datasets=("ogbn_products_sim",), batch_sizes=(100,), epochs=2,
        workers=4, hosts=2, n_hot=32768, dcn_bias=4.0):
    rows = ["dataset,batch,topology,intra_MB,inter_MB,flat_MB,"
            "byte_sum_identity,biased_inter_MB,inter_reduction_x"]
    bad = []
    for ds in datasets:
        for b in batch_sizes:
            t = replay_topology_bytes(ds, b, workers, epochs, n_hot,
                                      hosts, dcn_bias=dcn_bias)
            tier_sum = t["intra_bytes"] + t["inter_bytes"]
            ident = ("MATCH" if tier_sum == t["flat_bytes"]
                     else f"DIFF({tier_sum}vs{t['flat_bytes']})")
            if ident != "MATCH":
                bad.append(f"{ds}/b{b}:{ident}")
            if t["biased_inter_bytes"] > t["inter_bytes"]:
                bad.append(f"{ds}/b{b}:bias_raised_inter("
                           f"{t['biased_inter_bytes']}vs"
                           f"{t['inter_bytes']})")
            red = t["inter_bytes"] / max(t["biased_inter_bytes"], 1)
            rows.append(
                f"{ds},{b},{t['hosts']}x{t['devices_per_host']},"
                f"{t['intra_bytes'] / 1e6:.2f},"
                f"{t['inter_bytes'] / 1e6:.2f},"
                f"{t['flat_bytes'] / 1e6:.2f},{ident},"
                f"{t['biased_inter_bytes'] / 1e6:.2f},{red:.2f}")
    if bad:
        raise RuntimeError("topology identity FAILED: " + ";".join(bad))
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
