"""Roofline analysis (assignment deliverable g).

Reads the dry-run artifacts (full compiles + unrolled cost variants) and
derives, per (arch x shape) on the single-pod 16x16 mesh:

  compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
  memory term     = HLO_bytes / (chips x 819 GB/s)
  collective term = collective_bytes / (chips x 50 GB/s/link)

HLO terms are extrapolated from the unrolled variants because XLA's
cost_analysis counts a scan body ONCE (launch/dryrun.py):

  per_repeat(S) = X(r=2,S) - X(r=1,S)   fitted as  alpha + beta*S + gamma*S^2
  non_layer(S)  = X(r=1,S) - per_repeat(S)  fitted as  a + b*S
  X_full = a + b*S_f*Bs + R_eff*(alpha_B + beta*S_f*Bs + gamma*S_f*span*Bs)

with Bs = B_full/B_variant applied to token-proportional terms,
R_eff = num_layers/len(pattern), and `span` the pattern-mean effective
attention span at full scale (S_f for global layers, the window for
local/sliding layers, 0 for ssm/rglru whose cost is linear and lands in
beta). For decode, the B-variant pair splits alpha into its per-token and
B-independent (weight-collective) parts.

Also reports MODEL_FLOPS = 6*N_active*D and the usefulness ratio
MODEL_FLOPS / HLO_FLOPs (remat / masked-attention / padding waste).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

import numpy as np

from repro.configs import (ARCH_NAMES, INPUT_SHAPES, SUBQUADRATIC,
                           get_arch)
from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW

CHIPS = 256
LONG_WINDOW = 8192          # mirrors launch/specs.py


def _load(art_dir: str) -> Dict[str, dict]:
    out = {}
    for p in glob.glob(os.path.join(art_dir, "*.json")):
        with open(p) as f:
            out[os.path.basename(p)[:-5]] = json.load(f)
    return out


def _terms(rec: dict) -> Dict[str, float]:
    cost = rec.get("cost", {})
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "coll": float(rec.get("collectives", {}).get("total", 0.0))}


def _fit_quad(S: np.ndarray, y: np.ndarray) -> np.ndarray:
    A = np.stack([np.ones_like(S), S, S ** 2], axis=1).astype(np.float64)
    coef, *_ = np.linalg.lstsq(A, y.astype(np.float64), rcond=None)
    return coef                     # [alpha, beta, gamma]


def _fit_lin(S: np.ndarray, y: np.ndarray) -> np.ndarray:
    A = np.stack([np.ones_like(S), S], axis=1).astype(np.float64)
    coef, *_ = np.linalg.lstsq(A, y.astype(np.float64), rcond=None)
    return coef                     # [a, b]


def _spans(cfg, S_f: int, kind: str, sliding: bool) -> float:
    """Pattern-mean effective attention span at full scale (0 if the
    pattern has no attention positions -- gamma/beta attn terms absent)."""
    spans = []
    for k in cfg.pattern:
        if k == "attn":
            if kind == "decode" and sliding:
                spans.append(min(LONG_WINDOW, S_f))
            else:
                spans.append(S_f)
        elif k == "local":
            w = cfg.window + (0 if kind == "decode" else cfg.attn_q_chunk)
            spans.append(min(w, S_f))
    return float(np.mean(spans)) if spans else 0.0


def extrapolate(arch: str, shape: str, cvs: Dict[str, dict]
                ) -> Optional[Dict[str, float]]:
    cfg = get_arch(arch)
    S_f, B_f, kind = INPUT_SHAPES[shape]
    kind_cv = {"train": "train", "prefill": "prefill",
               "decode": "decode"}[kind]
    sliding = shape == "long_500k" and arch not in SUBQUADRATIC
    if kind == "decode":
        S_like = S_f if not sliding else min(LONG_WINDOW, S_f)
    grid = [(r, S, B) for r in (1, 2) for S in (512, 1024, 2048, 4096)
            for B in (16, 32)]
    recs = {}
    for r, S, B in grid:
        key = f"{arch}__cv_{kind_cv}_r{r}_S{S}_B{B}"
        if key in cvs:
            recs[(r, S, B)] = _terms(cvs[(key)])
    if not recs:
        return None

    B_v = 16
    S_pts = sorted({S for (r, S, B) in recs if B == B_v and (1, S, B_v)
                    in recs and (2, S, B_v) in recs})
    if len(S_pts) < 2:
        return None
    S_arr = np.array(S_pts, np.float64)

    out = {}
    R_eff = cfg.num_layers / len(cfg.pattern)
    span = _spans(cfg, S_f, kind, sliding)
    Bs = B_f / B_v
    for term in ("flops", "bytes", "coll"):
        pr = np.array([recs[(2, S, B_v)][term] - recs[(1, S, B_v)][term]
                       for S in S_pts])
        nl = np.array([recs[(1, S, B_v)][term] for S in S_pts]) - pr
        if len(S_pts) >= 3:
            al, be, ga = _fit_quad(S_arr, pr)
        else:
            al, be = _fit_lin(S_arr, pr)
            ga = 0.0
        a, b = _fit_lin(S_arr, nl)

        alpha_tok = 0.0
        alpha_fixed = al
        if kind == "decode":
            # split alpha into per-token vs B-independent (weight-
            # collective) parts via the B=32 variant pair
            keys = [(2, 1024, 32), (1, 1024, 32), (2, 1024, 16),
                    (1, 1024, 16)]
            if all(k in recs for k in keys):
                prB32 = recs[keys[0]][term] - recs[keys[1]][term]
                prB16 = recs[keys[2]][term] - recs[keys[3]][term]
                c_tok = max((prB32 - prB16) / 16.0, 0.0)  # per B unit
                alpha_tok = c_tok * 16.0                  # value at B_v
                alpha_fixed = max(al - alpha_tok, 0.0)
            # the attention-span term scales with tokens (= B at decode);
            # ssm/rglru decode cost is S-independent -> beta ~ 0
            per_rep_full = (alpha_fixed + alpha_tok * Bs + be * span * Bs)
            non_layer_full = a + b * 1.0    # lm head: one token position
        else:
            quad_unit = ga              # fitted on S^2 where span == S_v
            per_rep_full = (alpha_fixed + be * S_f * Bs
                            + quad_unit * S_f * span * Bs)
            non_layer_full = a + b * S_f * Bs
        out[term] = float(non_layer_full + R_eff * per_rep_full)
    return out


def roofline_table(art_dir: str = "artifacts/dryrun") -> List[dict]:
    arts = _load(art_dir)
    rows = []
    for arch in ARCH_NAMES:
        for shape in INPUT_SHAPES:
            full = arts.get(f"{arch}__{shape}__pod1")
            if full is None:
                continue
            ext = extrapolate(arch, shape, arts)
            terms = ext if ext else _terms(full)
            src = "extrapolated" if ext else "raw(scan-undercount)"
            # UNITS (validated empirically, EXPERIMENTS.md §Roofline):
            # post-SPMD cost_analysis flops/bytes and the HLO-parsed
            # collective bytes are all PER-DEVICE quantities.
            t_comp = terms["flops"] / PEAK_FLOPS_BF16
            t_mem = terms["bytes"] / HBM_BW
            t_coll = terms["coll"] / ICI_BW
            dom = max(("compute", t_comp), ("memory", t_mem),
                      ("collective", t_coll), key=lambda kv: kv[1])[0]
            cfg = get_arch(arch)
            S_f, B_f, kind = INPUT_SHAPES[shape]
            toks = B_f * (S_f if kind != "decode" else 1)
            mult = 6 if kind == "train" else 2
            model_flops = mult * full["params_active"] * toks / CHIPS
            rows.append({
                "arch": arch, "shape": shape, "source": src,
                "flops": terms["flops"], "bytes": terms["bytes"],
                "coll_bytes": terms["coll"],
                "t_compute_s": t_comp, "t_memory_s": t_mem,
                "t_collective_s": t_coll, "bottleneck": dom,
                "model_flops": model_flops,
                "useful_ratio": model_flops / max(terms["flops"], 1.0),
                "attn_variant": full.get("attn_variant", "full"),
            })
    return rows


def main() -> None:
    rows = roofline_table()
    hdr = ("arch,shape,bottleneck,t_compute_s,t_memory_s,t_collective_s,"
           "useful_ratio,attn_variant,source")
    print(hdr)
    for r in rows:
        print(f"{r['arch']},{r['shape']},{r['bottleneck']},"
              f"{r['t_compute_s']:.4g},{r['t_memory_s']:.4g},"
              f"{r['t_collective_s']:.4g},{r['useful_ratio']:.3f},"
              f"{r['attn_variant']},{r['source']}")


if __name__ == "__main__":
    main()
