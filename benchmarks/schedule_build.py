"""Microbenchmark: the vectorized epoch-at-once schedule compiler vs the
per-batch oracle (ISSUE 5 / DESIGN.md §2.1).

Three sections; the first two at a 64- and a 256-worker partition point:

  * sampler -- ``KHopSampler.sample_epoch_batched`` vs the per-batch
    ``sample_epoch`` loop AND the device compiler port
    (``sample_epoch_batched_device``, DESIGN.md §2.2), asserting
    bit-exact batch parity before any timing.
  * build   -- one end-to-end worker-epoch build (sampling + remote
    frequency counting + deterministic hot-set selection; the loop
    variant additionally pays ``FlatEpoch.from_batches`` packing, which
    IS its pipeline -- the canonical schedule payload is flat), all
    three compilers.
  * overlap -- the device runner's train-overlapped next-epoch builds
    on LAZY schedules (one emulated device; the parent process must
    stay single-device): total staging wall vs the slice left EXPOSED
    on the critical path after training hides the rest, with lazy-vs-
    eager loss-curve parity asserted before timing.

Device-compiler caveat (recorded honestly, PR 5 precedent): on a
single-CPU host the device columns lose to numpy -- XLA's comparison
sort vs numpy's radix sort on one core. The port's case is the TPU
radix path (``repro.kernels.seg_sort``) + staging-thread overlap, not
single-core CPU throughput.

Per-worker train mass follows the assemble-bench convention of
paper-proportioned shapes: ogbn-papers100M has ~1.2 M train nodes, so a
P-worker cluster hands each worker ~1.2M/P seeds (capped at
``MAX_TRAIN`` to keep the loop reference affordable; the sim partitions
themselves are far smaller than papers100M's, so the seed stream is
drawn graph-wide -- schedule-build cost depends on the stream size and
the graph, not on who owns the seeds). Loop/batched iterations are
INTERLEAVED and min-of-N so machine drift cancels out of the ratio.

Emits ``artifacts/BENCH_schedule.json`` and CSV rows for
``benchmarks.run``; any batched-vs-loop divergence raises
``RuntimeError("... parity FAILED")``, which fails the section and the
CI bench job (same pattern as the campaign section).
"""
from __future__ import annotations

import json
import os
import time
from typing import List

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

HEADER = "section,case,variant,ms_per_worker_epoch,speedup_vs_loop,identical"

#: workers sampled per partition point (timing every one of 256 loop
#: builds would dominate the bench job for no extra signal)
SAMPLE_WORKERS = 3
#: papers100M train mass and the per-worker cap keeping the loop
#: reference affordable
PAPER_TRAIN, MAX_TRAIN = 1_200_000, 2_400


def _time_pair(fn_a, fn_b, iters: int = 5):
    """Interleaved min-of-iters (ms, ms): A/B alternate call-for-call so
    scheduler/thermal drift hits both variants equally."""
    fn_a()
    fn_b()
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn_a()
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        tb.append(time.perf_counter() - t0)
    return min(ta) * 1e3, min(tb) * 1e3


def _batches_equal(flat, loop_batches) -> bool:
    if flat.num_batches != len(loop_batches):
        return False
    for br, bn in zip(loop_batches, flat.to_batches()):
        if not (np.array_equal(br.seeds, bn.seeds)
                and np.array_equal(br.input_nodes, bn.input_nodes)):
            return False
        for x, y in zip(br.blocks, bn.blocks):
            if not ((x.num_src, x.num_dst) == (y.num_src, y.num_dst)
                    and np.array_equal(x.edge_src, y.edge_src)
                    and np.array_equal(x.edge_dst, y.edge_dst)
                    and np.array_equal(x.edge_mask, y.edge_mask)):
                return False
    return True


def _epochs_equal(a, b) -> bool:
    return (a.m_max == b.m_max
            and np.array_equal(a.remote_ids, b.remote_ids)
            and np.array_equal(a.remote_freq, b.remote_freq)
            and np.array_equal(a.cache_ids, b.cache_ids)
            and np.array_equal(a.flat.input_nodes, b.flat.input_nodes)
            and np.array_equal(a.flat.seeds, b.flat.seeds))


def bench_schedule_build(workers=(64, 256),
                         dataset: str = "ogbn_products_sim",
                         batch_size: int = 100, fanouts=(25, 10),
                         n_hot: int = 4096, s0: int = 42):
    from repro.graph import load_dataset, partition_graph, KHopSampler
    from repro.core.schedule import _build_epoch

    from repro.graph.device_sampler import sample_epoch_batched_device

    g = load_dataset(dataset)
    rng = np.random.default_rng(s0)
    rows, recs = [], []
    for P_ in workers:
        pg = partition_graph(g, P_, "metis")
        sampler = KHopSampler(g, fanouts=list(fanouts),
                              batch_size=batch_size)
        n_train = min(PAPER_TRAIN // P_, MAX_TRAIN)
        t_samp = {"loop": 0.0, "batched": 0.0, "device": 0.0}
        t_build = {"loop": 0.0, "batched": 0.0, "device": 0.0}
        parity = dev_parity = True
        for w in range(SAMPLE_WORKERS):
            train = rng.choice(g.num_nodes, size=n_train, replace=False)
            batched_flat = sampler.sample_epoch_batched(s0, w, 0, train)
            parity &= _batches_equal(batched_flat,
                                     sampler.sample_epoch(s0, w, 0, train))
            dev_parity &= _batches_equal(
                batched_flat,
                sample_epoch_batched_device(sampler, s0, w, 0,
                                            train).to_batches())
            eb = _build_epoch(sampler, pg, w, s0, 0, train, n_hot,
                              compiler="batched")
            parity &= _epochs_equal(
                _build_epoch(sampler, pg, w, s0, 0, train, n_hot,
                             compiler="loop"), eb)
            dev_parity &= _epochs_equal(
                eb, _build_epoch(sampler, pg, w, s0, 0, train, n_hot,
                                 compiler="device"))
            tl, tb = _time_pair(
                lambda: sampler.sample_epoch(s0, w, 0, train),
                lambda: sampler.sample_epoch_batched(s0, w, 0, train))
            t_samp["loop"] += tl
            t_samp["batched"] += tb
            _, td = _time_pair(
                lambda: sampler.sample_epoch_batched(s0, w, 0, train),
                lambda: sample_epoch_batched_device(sampler, s0, w, 0,
                                                    train))
            t_samp["device"] += td
            tl, tb = _time_pair(
                lambda: _build_epoch(sampler, pg, w, s0, 0, train,
                                     n_hot, compiler="loop"),
                lambda: _build_epoch(sampler, pg, w, s0, 0, train,
                                     n_hot, compiler="batched"))
            t_build["loop"] += tl
            t_build["batched"] += tb
            _, td = _time_pair(
                lambda: _build_epoch(sampler, pg, w, s0, 0, train,
                                     n_hot, compiler="batched"),
                lambda: _build_epoch(sampler, pg, w, s0, 0, train,
                                     n_hot, compiler="device"))
            t_build["device"] += td
        rec = {"workers": P_, "dataset": dataset,
               "batch_size": batch_size, "fanouts": list(fanouts),
               "train_per_worker": n_train,
               "batches_per_worker": -(-n_train // batch_size),
               "parity": bool(parity),
               "device_parity": bool(dev_parity)}
        for sec, t in (("sampler", t_samp), ("build", t_build)):
            for variant in ("loop", "batched", "device"):
                ms = t[variant] / SAMPLE_WORKERS
                sp = t["loop"] / max(t[variant], 1e-9)
                ok = parity if variant != "device" else dev_parity
                rows.append(f"{sec},P{P_}_b{batch_size}_n{n_train},"
                            f"{variant},{ms:.2f},{sp:.2f}x,{ok}")
                rec[f"{sec}_{variant}_ms"] = round(ms, 3)
            rec[f"{sec}_speedup"] = round(
                t["loop"] / max(t["batched"], 1e-9), 2)
            rec[f"{sec}_device_speedup"] = round(
                t["loop"] / max(t["device"], 1e-9), 2)
        recs.append(rec)
    return rows, recs


def bench_overlapped_runner(dataset: str = "ogbn_products_sim",
                            batch_size: int = 100, fanouts=(25, 10),
                            n_hot: int = 4096, epochs: int = 3,
                            s0: int = 42):
    """Train-overlapped next-epoch builds through the device runner on
    ONE emulated device (the bench process must stay single-device):
    lazy device-resident schedules are rebuilt + collated by the
    background staging thread while the device trains, so the metric
    pair is the TOTAL staging wall vs the slice left EXPOSED after
    training completes. Lazy-vs-eager loss parity is asserted first."""
    import jax

    from repro.graph import load_dataset, partition_graph, KHopSampler
    from repro.core import build_schedule
    from repro.dist import DeviceRapidGNNRunner, DeviceView, make_mesh
    from repro.models import GNNConfig
    from repro.train import AdamW

    P_ = 1
    if jax.device_count() < P_:
        raise RuntimeError("no device for the overlap section")
    g = load_dataset(dataset)
    n_train = min(PAPER_TRAIN // 64, MAX_TRAIN)     # 64-worker seed mass
    rng = np.random.default_rng(s0)
    mask = np.zeros(g.num_nodes, bool)
    mask[rng.choice(g.num_nodes, size=n_train, replace=False)] = True
    g.train_mask = mask                 # bound the per-epoch seed stream
    pg = partition_graph(g, P_, "metis")
    sampler = KHopSampler(g, fanouts=list(fanouts),
                          batch_size=batch_size)
    dv = DeviceView.build(pg)
    mesh = make_mesh((P_,), ("data",))
    cfg = GNNConfig(kind="sage", in_dim=g.feat_dim, hidden_dim=32,
                    num_classes=g.num_classes, num_layers=len(fanouts))

    def runners():
        out = []
        for lazy in (False, True):
            schedules = [build_schedule(sampler, pg, worker=w, s0=s0,
                                        num_epochs=epochs, n_hot=n_hot,
                                        lazy=lazy)
                         for w in range(P_)]
            out.append(DeviceRapidGNNRunner(
                schedules, dv, cfg, AdamW(lr=3e-3), mesh, batch_size,
                g.labels, seed=s0))
        return out

    eager, lazy = runners()
    rep_e = eager.run()
    rep_l = lazy.run()
    if not np.array_equal(np.concatenate([r.losses for r in rep_e]),
                          np.concatenate([r.losses for r in rep_l])):
        return ["overlap,P1,lazy,nan,nan,False"], {
            "parity": False}
    staged = [r for r in rep_l if r.stage_s > 0.0]
    stage_ms = 1e3 * sum(r.stage_s for r in staged) / max(len(staged), 1)
    exposed_ms = 1e3 * sum(r.exposed_stage_s for r in staged) \
        / max(len(staged), 1)
    hidden_ratio = stage_ms / max(exposed_ms, 1e-6)
    train_ms = 1e3 * sum(r.wall_time_s for r in rep_l[1:]) \
        / max(len(rep_l) - 1, 1)
    case = f"P{P_}_b{batch_size}_n{n_train}"
    rows = [
        f"overlap,{case},staged_wall,{stage_ms:.2f},-,True",
        f"overlap,{case},exposed_wall,{exposed_ms:.2f},"
        f"{hidden_ratio:.1f}x,True",
    ]
    rec = {"workers": P_, "dataset": dataset, "batch_size": batch_size,
           "fanouts": list(fanouts), "train_nodes": n_train,
           "epochs": epochs, "parity": True,
           "train_ms_per_epoch": round(train_ms, 3),
           "stage_ms_per_epoch": round(stage_ms, 3),
           "exposed_ms_per_epoch": round(exposed_ms, 3),
           "hidden_ratio": round(hidden_ratio, 2),
           "trace_count": int(lazy.trace_count)}
    return rows, rec


def run() -> List[str]:
    rows = [HEADER]
    b_rows, recs = bench_schedule_build()
    rows += b_rows
    o_rows, o_rec = bench_overlapped_runner()
    rows += o_rows
    art = os.path.join(ROOT, "artifacts")
    os.makedirs(art, exist_ok=True)
    with open(os.path.join(art, "BENCH_schedule.json"), "w") as f:
        json.dump({"schedule_build": recs,
                   "overlapped_runner": o_rec}, f, indent=1)
    if not all(r["parity"] for r in recs):
        raise RuntimeError("batched-vs-loop schedule parity FAILED")
    if not all(r["device_parity"] for r in recs):
        raise RuntimeError("device-vs-batched schedule parity FAILED")
    if not o_rec["parity"]:
        raise RuntimeError("overlapped-runner loss parity FAILED")
    best = max(recs, key=lambda r: r["workers"])
    rows.append(f"summary,build_P{best['workers']},batched,"
                f"{best['build_batched_ms']},{best['build_speedup']}x,"
                f"{best['parity']}")
    rows.append(f"summary,overlap_P1,exposed_wall,"
                f"{o_rec['exposed_ms_per_epoch']},"
                f"{o_rec['hidden_ratio']}x,{o_rec['parity']}")
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
