"""Microbenchmark: the vectorized epoch-at-once schedule compiler vs the
per-batch oracle (ISSUE 5 / DESIGN.md §2.1).

Two sections, each at a 64- and a 256-worker partition point:

  * sampler -- ``KHopSampler.sample_epoch_batched`` vs the per-batch
    ``sample_epoch`` loop, asserting bit-exact batch parity before any
    timing.
  * build   -- one end-to-end worker-epoch build (sampling + remote
    frequency counting + deterministic hot-set selection; the loop
    variant additionally pays ``FlatEpoch.from_batches`` packing, which
    IS its pipeline -- the canonical schedule payload is flat).

Per-worker train mass follows the assemble-bench convention of
paper-proportioned shapes: ogbn-papers100M has ~1.2 M train nodes, so a
P-worker cluster hands each worker ~1.2M/P seeds (capped at
``MAX_TRAIN`` to keep the loop reference affordable; the sim partitions
themselves are far smaller than papers100M's, so the seed stream is
drawn graph-wide -- schedule-build cost depends on the stream size and
the graph, not on who owns the seeds). Loop/batched iterations are
INTERLEAVED and min-of-N so machine drift cancels out of the ratio.

Emits ``artifacts/BENCH_schedule.json`` and CSV rows for
``benchmarks.run``; any batched-vs-loop divergence raises
``RuntimeError("... parity FAILED")``, which fails the section and the
CI bench job (same pattern as the campaign section).
"""
from __future__ import annotations

import json
import os
import time
from typing import List

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

HEADER = "section,case,variant,ms_per_worker_epoch,speedup_vs_loop,identical"

#: workers sampled per partition point (timing every one of 256 loop
#: builds would dominate the bench job for no extra signal)
SAMPLE_WORKERS = 3
#: papers100M train mass and the per-worker cap keeping the loop
#: reference affordable
PAPER_TRAIN, MAX_TRAIN = 1_200_000, 2_400


def _time_pair(fn_a, fn_b, iters: int = 5):
    """Interleaved min-of-iters (ms, ms): A/B alternate call-for-call so
    scheduler/thermal drift hits both variants equally."""
    fn_a()
    fn_b()
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn_a()
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        tb.append(time.perf_counter() - t0)
    return min(ta) * 1e3, min(tb) * 1e3


def _batches_equal(flat, loop_batches) -> bool:
    if flat.num_batches != len(loop_batches):
        return False
    for br, bn in zip(loop_batches, flat.to_batches()):
        if not (np.array_equal(br.seeds, bn.seeds)
                and np.array_equal(br.input_nodes, bn.input_nodes)):
            return False
        for x, y in zip(br.blocks, bn.blocks):
            if not ((x.num_src, x.num_dst) == (y.num_src, y.num_dst)
                    and np.array_equal(x.edge_src, y.edge_src)
                    and np.array_equal(x.edge_dst, y.edge_dst)
                    and np.array_equal(x.edge_mask, y.edge_mask)):
                return False
    return True


def _epochs_equal(a, b) -> bool:
    return (a.m_max == b.m_max
            and np.array_equal(a.remote_ids, b.remote_ids)
            and np.array_equal(a.remote_freq, b.remote_freq)
            and np.array_equal(a.cache_ids, b.cache_ids)
            and np.array_equal(a.flat.input_nodes, b.flat.input_nodes)
            and np.array_equal(a.flat.seeds, b.flat.seeds))


def bench_schedule_build(workers=(64, 256),
                         dataset: str = "ogbn_products_sim",
                         batch_size: int = 100, fanouts=(25, 10),
                         n_hot: int = 4096, s0: int = 42):
    from repro.graph import load_dataset, partition_graph, KHopSampler
    from repro.core.schedule import _build_epoch

    g = load_dataset(dataset)
    rng = np.random.default_rng(s0)
    rows, recs = [], []
    for P_ in workers:
        pg = partition_graph(g, P_, "metis")
        sampler = KHopSampler(g, fanouts=list(fanouts),
                              batch_size=batch_size)
        n_train = min(PAPER_TRAIN // P_, MAX_TRAIN)
        t_samp = {"loop": 0.0, "batched": 0.0}
        t_build = {"loop": 0.0, "batched": 0.0}
        parity = True
        for w in range(SAMPLE_WORKERS):
            train = rng.choice(g.num_nodes, size=n_train, replace=False)
            parity &= _batches_equal(
                sampler.sample_epoch_batched(s0, w, 0, train),
                sampler.sample_epoch(s0, w, 0, train))
            parity &= _epochs_equal(
                _build_epoch(sampler, pg, w, s0, 0, train, n_hot,
                             compiler="loop"),
                _build_epoch(sampler, pg, w, s0, 0, train, n_hot,
                             compiler="batched"))
            tl, tb = _time_pair(
                lambda: sampler.sample_epoch(s0, w, 0, train),
                lambda: sampler.sample_epoch_batched(s0, w, 0, train))
            t_samp["loop"] += tl
            t_samp["batched"] += tb
            tl, tb = _time_pair(
                lambda: _build_epoch(sampler, pg, w, s0, 0, train,
                                     n_hot, compiler="loop"),
                lambda: _build_epoch(sampler, pg, w, s0, 0, train,
                                     n_hot, compiler="batched"))
            t_build["loop"] += tl
            t_build["batched"] += tb
        rec = {"workers": P_, "dataset": dataset,
               "batch_size": batch_size, "fanouts": list(fanouts),
               "train_per_worker": n_train,
               "batches_per_worker": -(-n_train // batch_size),
               "parity": bool(parity)}
        for sec, t in (("sampler", t_samp), ("build", t_build)):
            for variant in ("loop", "batched"):
                ms = t[variant] / SAMPLE_WORKERS
                sp = t["loop"] / max(t[variant], 1e-9)
                rows.append(f"{sec},P{P_}_b{batch_size}_n{n_train},"
                            f"{variant},{ms:.2f},{sp:.2f}x,{parity}")
                rec[f"{sec}_{variant}_ms"] = round(ms, 3)
            rec[f"{sec}_speedup"] = round(
                t["loop"] / max(t["batched"], 1e-9), 2)
        recs.append(rec)
    return rows, recs


def run() -> List[str]:
    rows = [HEADER]
    b_rows, recs = bench_schedule_build()
    rows += b_rows
    art = os.path.join(ROOT, "artifacts")
    os.makedirs(art, exist_ok=True)
    with open(os.path.join(art, "BENCH_schedule.json"), "w") as f:
        json.dump({"schedule_build": recs}, f, indent=1)
    if not all(r["parity"] for r in recs):
        raise RuntimeError("batched-vs-loop schedule parity FAILED")
    best = max(recs, key=lambda r: r["workers"])
    rows.append(f"summary,build_P{best['workers']},batched,"
                f"{best['build_batched_ms']},{best['build_speedup']}x,"
                f"{best['parity']}")
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
