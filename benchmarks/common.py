"""Shared GNN experiment runner for the paper-table benchmarks.

Systems (paper §5.1):
  rapidgnn    -- full pipeline: greedy edge-cut partition + steady cache +
                 prefetcher (the paper's system; METIS stand-in)
  dgl-metis   -- on-demand synchronous fetch, greedy edge-cut partition
  dgl-random  -- on-demand synchronous fetch, random partition
  gcn         -- on-demand, larger computation blocks (fan-out 50,50)

All systems share the deterministic schedule machinery so measured
differences isolate the paper's contribution. The 10 GbE network model is
ENABLED for time measurements (critical-path fetches sleep for modelled
transfer time; prefetched fetches overlap) and all byte counts are exact.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import numpy as np

from repro.graph import load_dataset, partition_graph, KHopSampler
from repro.core import (build_schedule, ShardedFeatureStore,
                        RapidGNNRunner, BaselineRunner, NetworkModel)
from repro.models import (GNNConfig, init_params, make_train_step,
                          batch_to_device)
from repro.train import AdamW

SYSTEMS = ("rapidgnn", "dgl-metis", "dgl-random", "gcn")


@dataclasses.dataclass
class GNNResult:
    system: str
    dataset: str
    batch_size: int
    workers: int
    epochs: int
    wall_time_s: float
    step_time_ms: float
    net_time_s: float          # modelled critical-path network time
    rpc_count: int
    remote_bytes: int
    vector_pull_bytes: int
    hit_rate: float
    num_steps: int
    losses: list
    accs: list
    device_cache_bytes: int = 0

    @property
    def bytes_per_step(self) -> float:
        return (self.remote_bytes + self.vector_pull_bytes) / max(
            self.num_steps, 1)


def run_gnn_system(system: str, dataset: str, batch_size: int,
                   workers: int = 4, epochs: int = 3, n_hot: int = 32768,
                   Q: int = 4, s0: int = 42, hidden: int = 64,
                   train: bool = True, net: Optional[NetworkModel] = None,
                   worker: int = 0) -> GNNResult:
    g = load_dataset(dataset)
    part = "random" if system == "dgl-random" else "metis"
    pg = partition_graph(g, workers, part)
    fanouts = (50, 50) if system == "gcn" else (25, 10)
    sampler = KHopSampler(g, fanouts=fanouts, batch_size=batch_size)
    ws = build_schedule(sampler, pg, worker=worker, s0=s0,
                        num_epochs=epochs,
                        n_hot=n_hot if system == "rapidgnn" else 0)

    state = {"losses": [], "accs": []}
    if train:
        cfg = GNNConfig(kind="gcn" if system == "gcn" else "sage",
                        in_dim=g.feat_dim, hidden_dim=hidden,
                        num_classes=g.num_classes, num_layers=2)
        params = init_params(cfg, jax.random.key(s0))
        opt = AdamW(lr=3e-3)
        opt_state = opt.init(params)
        step = make_train_step(cfg, opt)
        box = {"p": params, "o": opt_state}

        def train_fn(feats, cb):
            batch = batch_to_device(cb, feats)
            box["p"], box["o"], aux = step(box["p"], box["o"], batch)
            state["losses"].append(float(aux["loss"]))
            state["accs"].append(float(aux["acc"]))
            return state["losses"][-1]
    else:
        def train_fn(feats, cb):
            return 0.0

    net = net if net is not None else NetworkModel(enabled=True)
    store = ShardedFeatureStore(pg, worker=worker, net=net)
    if system == "rapidgnn":
        runner = RapidGNNRunner(ws, store, batch_size=batch_size, Q=Q,
                                train_fn=train_fn)
    else:
        runner = BaselineRunner(ws, store, batch_size=batch_size,
                                train_fn=train_fn)
    t0 = time.time()
    m = runner.run()
    wall = time.time() - t0
    tot = m.totals()
    # drop epoch 0 from time metrics (JIT warm-up), keep byte/RPC counts
    if epochs > 1:
        warm = m.epochs[0].wall_time_s
        wall = sum(e.wall_time_s for e in m.epochs[1:])
        tot["modeled_net_time_s"] -= m.epochs[0].modeled_net_time_s
        tot["sync_net_time_s"] -= m.epochs[0].sync_net_time_s
    steps_all = [ws.epoch(e).num_batches for e in range(epochs)]
    steps = sum(steps_all[1:]) if epochs > 1 else sum(steps_all)
    return GNNResult(
        system=system, dataset=dataset, batch_size=batch_size,
        workers=workers, epochs=epochs, wall_time_s=wall,
        step_time_ms=1e3 * wall / max(steps, 1),
        net_time_s=tot["sync_net_time_s"],
        rpc_count=int(tot["rpc_count"]),
        remote_bytes=int(tot["remote_bytes"]),
        vector_pull_bytes=int(tot["vector_pull_bytes"]),
        hit_rate=tot["hit_rate"], num_steps=steps,
        losses=state["losses"], accs=state["accs"],
        device_cache_bytes=getattr(runner, "device_cache_bytes", 0))


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
