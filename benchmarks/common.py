"""Shared GNN experiment runner for the paper-table benchmarks.

Since the campaign subsystem landed (``repro.eval``, DESIGN.md §7) this
is a thin compatibility wrapper: ``run_gnn_system`` builds one
``CellSpec`` and delegates to ``repro.eval.cells.run_host_cell`` -- the
same cell executor the paper-metrics campaign sweeps -- then re-shapes
the unified ``CellResult`` into the historical ``GNNResult`` the CSV
benchmarks format. Systems (paper §5.1):

  rapidgnn    -- full pipeline: greedy edge-cut partition + steady cache +
                 prefetcher (the paper's system; METIS stand-in)
  dgl-metis   -- on-demand synchronous fetch, greedy edge-cut partition
  dgl-random  -- on-demand synchronous fetch, random partition
  gcn         -- on-demand, larger computation blocks (fan-out 50,50)

All systems share the deterministic schedule machinery so measured
differences isolate the paper's contribution. The 10 GbE network model is
ENABLED for time measurements (critical-path fetches sleep for modelled
transfer time; prefetched fetches overlap) and all byte counts are exact.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import NetworkModel
from repro.eval.cells import run_host_cell
from repro.eval.spec import CellSpec

SYSTEMS = ("rapidgnn", "dgl-metis", "dgl-random", "gcn")


@dataclasses.dataclass
class GNNResult:
    """Historical single-worker view of a cell. Time/step fields are
    WARM (epoch 0's JIT warm-up excluded when epochs > 1); byte/RPC
    counters cover every epoch -- exactly the convention the CSV
    benchmarks always used."""
    system: str
    dataset: str
    batch_size: int
    workers: int
    epochs: int
    wall_time_s: float
    step_time_ms: float
    net_time_s: float          # modelled critical-path network time
    rpc_count: int
    remote_bytes: int
    vector_pull_bytes: int
    hit_rate: float
    num_steps: int
    losses: list
    accs: list
    device_cache_bytes: int = 0

    @property
    def bytes_per_step(self) -> float:
        return (self.remote_bytes + self.vector_pull_bytes) / max(
            self.num_steps, 1)


def run_gnn_system(system: str, dataset: str, batch_size: int,
                   workers: int = 4, epochs: int = 3, n_hot: int = 32768,
                   Q: int = 4, s0: int = 42, hidden: int = 64,
                   train: bool = True, net: Optional[NetworkModel] = None,
                   worker: int = 0) -> GNNResult:
    spec = CellSpec(backend="host", system=system, dataset=dataset,
                    batch_size=batch_size, workers=workers, n_hot=n_hot,
                    epochs=epochs, seed=s0, hidden=hidden, Q=Q,
                    train=train, all_workers=False,
                    net_enabled=net.enabled if net is not None else True)
    cell = run_host_cell(spec, worker=worker, net=net)
    return GNNResult(
        system=system, dataset=dataset, batch_size=batch_size,
        workers=workers, epochs=epochs, wall_time_s=cell.warm_wall_s,
        step_time_ms=cell.step_time_ms,
        net_time_s=cell.warm_sync_net_time_s,
        rpc_count=cell.rpc_count, remote_bytes=cell.remote_bytes,
        vector_pull_bytes=cell.vector_pull_bytes,
        hit_rate=cell.hit_rate, num_steps=cell.warm_steps,
        losses=cell.losses, accs=cell.accs,
        device_cache_bytes=cell.device_cache_bytes)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
