"""Device rapid-vs-baseline epoch benchmark (paper Table 2, device path).

Runs the multi-epoch device runners (``repro.dist.runner``) on 4 emulated
host devices: ``DeviceRapidGNNRunner`` (C_s/C_sec double buffer +
pipelined pull, one compilation across epochs) against
``DeviceBaselineRunner`` (no cache, pull on the critical path). Step time
excludes the compile epoch; lane counts are the exact residual-miss
accounting the parity tests pin to the host-sim runner.

The device count locks at first jax init, so the measurement runs in a
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(same pattern as tests/test_distributed.py); ``run()`` is safe to call
from the single-device ``benchmarks.run`` process.

Caveat: on EMULATED host devices the all_to_all is a shared-memory copy,
so the step-time ratio does not show the paper's network win -- the
miss-lane / payload columns carry that signal (9.7-15.4x fewer remote
fetches at paper scale; ~2-3x on the tiny graph), and step time becomes
meaningful on a real mesh where the pull has wire latency to hide.

``python -m benchmarks.device_epoch``           -- parent (spawns child)
``python -m benchmarks.device_epoch --child``   -- the measurement itself
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
from typing import List

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

HEADER = ("system,workers,epochs,steps_per_epoch,step_time_ms,"
          "miss_lanes_per_epoch,payload_kb,wire_rows")


def _child(epochs: int = 3, batch: int = 16, n_hot: int = 64) -> None:
    import numpy as np
    import jax

    from repro.graph import load_dataset, partition_graph, KHopSampler
    from repro.core import build_schedule
    from repro.models import GNNConfig
    from repro.train import AdamW
    from repro.dist import (DeviceView, DeviceRapidGNNRunner,
                            DeviceBaselineRunner, make_mesh)

    P_ = jax.device_count()
    g = load_dataset("tiny")
    pg = partition_graph(g, P_, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=batch)
    schedules = [build_schedule(sampler, pg, worker=w, s0=42,
                                num_epochs=epochs, n_hot=n_hot)
                 for w in range(P_)]
    dv = DeviceView.build(pg)
    mesh = make_mesh((P_,), ("data",))
    cfg = GNNConfig(kind="sage", in_dim=g.feat_dim, hidden_dim=32,
                    num_classes=g.num_classes, num_layers=2)

    print(HEADER)
    step_ms = {}
    for name, cls in (("device_rapidgnn", DeviceRapidGNNRunner),
                      ("device_baseline", DeviceBaselineRunner)):
        runner = cls(schedules, dv, cfg, AdamW(lr=3e-3), mesh, batch,
                     g.labels)
        reports = runner.run()
        assert runner.trace_count == 1, \
            f"{name}: {runner.trace_count} traces for {epochs} epochs"
        warm = reports[1:] if len(reports) > 1 else reports   # skip compile
        steps = sum(r.steps for r in warm)
        ms = 1e3 * sum(r.wall_time_s for r in warm) / max(steps, 1)
        step_ms[name] = ms
        lanes = ";".join(str(r.total_miss_lanes) for r in reports)
        payload = sum(r.payload_bytes(g.feat_dim) for r in reports)
        print(f"{name},{P_},{epochs},{runner.num_steps},{ms:.3f},"
              f"{lanes},{payload / 1024:.1f},{reports[0].wire_rows}")
    speedup = step_ms["device_baseline"] / max(step_ms["device_rapidgnn"],
                                               1e-9)
    print(f"device_speedup,{P_},{epochs},-,{speedup:.2f}x,-,-,-")


def run(epochs: int = 3) -> List[str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep +
                         env.get("PYTHONPATH", ""))
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.device_epoch", "--child",
         "--epochs", str(epochs)],
        capture_output=True, text=True, timeout=600, env=env, cwd=ROOT)
    if r.returncode != 0:
        raise RuntimeError(f"device_epoch child failed:\n{r.stdout}\n"
                           f"{r.stderr}")
    return [ln for ln in r.stdout.splitlines()
            if ln.startswith(("system,", "device_"))]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--epochs", type=int, default=3)
    args = ap.parse_args()
    if args.child:
        _child(epochs=args.epochs)
    else:
        for row in run(epochs=args.epochs):
            print(row)


if __name__ == "__main__":
    main()
