"""Device rapid-vs-baseline epoch benchmark (paper Table 2, device path).

Thin campaign wrapper: the two device-backend cells of the campaign's
fast grid (``repro.eval.spec.fast_grid``) run through the SAME
subprocess machinery the campaign uses (``repro.eval.cells.
run_device_cells`` -- the device count locks at first jax init, so the
cells execute in a child pinned to 4 emulated host devices), and the
rows below are formatted from their unified ``CellResult`` records.
Step time excludes the compile epoch; lane counts are the exact
residual-miss accounting the campaign's ``miss_parity`` differential
check pins to the host-sim runners.

Caveat: on EMULATED host devices the all_to_all is a shared-memory copy,
so the step-time ratio does not show the paper's network win -- the
miss-lane / payload columns carry that signal (9.7-15.4x fewer remote
fetches at paper scale; ~2-3x on the tiny graph), and step time becomes
meaningful on a real mesh where the pull has wire latency to hide.

``python -m benchmarks.device_epoch``   -- runs the cells, prints rows
"""
from __future__ import annotations

import argparse
from typing import List

HEADER = ("system,workers,epochs,steps,step_time_ms,"
          "miss_lanes_per_epoch,payload_kb,wire_rows")


def run(epochs: int = 3, results=None) -> List[str]:
    """``results`` short-circuits measurement with already-run device
    ``CellResult``s (benchmarks.run passes the paper_campaign section's
    cells so the expensive SPMD subprocess runs once per invocation)."""
    import dataclasses

    from repro.eval.cells import run_device_cells
    from repro.eval.spec import fast_grid

    if results is None:
        cells = [dataclasses.replace(c, epochs=epochs)
                 for c in fast_grid().device_cells()]
        results = run_device_cells(cells)
    rows = [HEADER]
    step_ms = {}
    for c in results:
        name = ("device_rapidgnn" if c.system == "rapidgnn"
                else "device_baseline")
        step_ms[name] = c.step_time_ms
        lanes = ";".join(str(sum(row)) for row in c.miss_matrix)
        rows.append(
            f"{name},{c.spec['workers']},{c.spec['epochs']},"
            f"{c.num_steps},{c.step_time_ms:.3f},{lanes},"
            f"{c.payload_bytes / 1024:.1f},{c.wire_rows}")
        assert c.trace_count == 1, \
            f"{name}: {c.trace_count} traces for " \
            f"{c.spec['epochs']} epochs"
    speedup = (step_ms["device_baseline"] /
               max(step_ms["device_rapidgnn"], 1e-9))
    rows.append(f"device_speedup,{results[0].spec['workers']},"
                f"{results[0].spec['epochs']},-,{speedup:.2f}x,-,-,-")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=3)
    args = ap.parse_args()
    for row in run(epochs=args.epochs):
        print(row)


if __name__ == "__main__":
    main()
