"""Paper Fig. 7: memory scaling -- device (cache) bytes vs baseline and
the paper's bound 2*n_hot*d + Q*m_max*d."""
from __future__ import annotations

from repro.graph import load_dataset, partition_graph, KHopSampler
from repro.core import build_schedule, global_pad_bounds
from benchmarks.common import run_gnn_system


def run(dataset="ogbn_products_sim", batch_size=200,
        worker_counts=(2, 4), n_hot=8192, Q=4, epochs=2):
    g = load_dataset(dataset)
    rows = ["workers,device_cache_MB,bound_MB,baseline_device_MB"]
    for w in worker_counts:
        r = run_gnn_system("rapidgnn", dataset, batch_size, workers=w,
                           epochs=epochs, n_hot=n_hot, Q=Q, train=False)
        pg = partition_graph(g, w, "metis")
        sampler = KHopSampler(g, fanouts=(25, 10), batch_size=batch_size)
        ws = build_schedule(sampler, pg, worker=0, s0=42,
                            num_epochs=epochs, n_hot=n_hot)
        m_max, _ = global_pad_bounds(ws)
        bound = (2 * n_hot * g.feat_dim + Q * m_max * g.feat_dim) * 4
        rows.append(f"{w},{r.device_cache_bytes / 1e6:.1f},"
                    f"{bound / 1e6:.1f},0.0")
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
