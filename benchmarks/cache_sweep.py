"""Paper Fig. 5: average remote feature fetches per epoch vs cache size
(n_hot sweep), demonstrating the long-tail capture."""
from __future__ import annotations

from benchmarks.common import run_gnn_system


def run(dataset="ogbn_products_sim", batch_sizes=(100, 200),
        cache_sizes=(0, 2048, 8192, 32768, 131072), workers=2, epochs=2):
    rows = ["batch,n_hot,remote_fetches_per_epoch,hit_rate"]
    for b in batch_sizes:
        for nh in cache_sizes:
            r = run_gnn_system("rapidgnn", dataset, b, workers=workers,
                               epochs=epochs, n_hot=max(nh, 1),
                               train=False)
            rows.append(f"{b},{nh},{r.rpc_count / epochs:.0f},"
                        f"{r.hit_rate:.3f}")
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
