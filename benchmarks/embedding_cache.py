"""Beyond-paper: RapidGNN's technique on the transformer embedding table
(DESIGN.md §4) -- bytes/RPC reduction for Zipf token streams with a
hot-token cache sized by the offline deterministic enumeration."""
from __future__ import annotations

import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import zipf_tokens, enumerate_token_accesses
from repro.graph.sampler import rng_from
from repro.models.transformer.embedding import HotEmbeddingSim


def run(arch="gemma2-2b", workers=16, batch=32, seq=512, steps=20,
        n_hots=(0, 1024, 8192, 65536), s0=7):
    cfg = get_arch(arch)
    counts = enumerate_token_accesses(cfg, batch, seq, steps, s0=s0)
    rows = ["n_hot,baseline_MB,cached_MB,reduction_x,hit_rate"]
    for nh in n_hots:
        sim = HotEmbeddingSim(vocab=cfg.vocab_size, d=cfg.d_model,
                              num_workers=workers, n_hot=max(nh, 1),
                              counts=counts)
        base = cach = hits = total_remote = 0
        for i in range(steps):
            toks = zipf_tokens(rng_from(s0, 0, i), cfg.vocab_size,
                               (batch, seq))
            b, c, h = sim.batch_traffic(toks, worker=0)
            base += b
            cach += c
            hits += h
            total_remote += b // (cfg.d_model * 4)
        cach += sim.cache_build_bytes()      # charge the VectorPull
        rows.append(f"{nh},{base / 1e6:.1f},{cach / 1e6:.1f},"
                    f"{base / max(cach, 1):.2f},"
                    f"{hits / max(total_remote, 1):.3f}")
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
