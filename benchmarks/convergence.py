"""Paper Fig. 9 / Prop. 3.1 validation: training accuracy of RapidGNN's
deterministic-schedule pipeline vs the on-demand baseline, same model and
init -- curves must coincide (identical batches by construction) and both
must converge."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_gnn_system


def run(dataset="tiny", batch_size=64, workers=2, epochs=6):
    r = run_gnn_system("rapidgnn", dataset, batch_size, workers=workers,
                       epochs=epochs, train=True, hidden=64)
    b = run_gnn_system("dgl-metis", dataset, batch_size, workers=workers,
                       epochs=epochs, train=True, hidden=64)
    rows = ["step,rapidgnn_acc,baseline_acc,rapidgnn_loss,baseline_loss"]
    n = min(len(r.accs), len(b.accs))
    for i in range(0, n, max(n // 20, 1)):
        rows.append(f"{i},{r.accs[i]:.3f},{b.accs[i]:.3f},"
                    f"{r.losses[i]:.3f},{b.losses[i]:.3f}")
    d = float(np.max(np.abs(np.array(r.losses[:n])
                            - np.array(b.losses[:n]))))
    rows.append(f"# max_loss_divergence,{d:.2e}")
    rows.append(f"# final_acc_rapidgnn,{r.accs[-1]:.3f}")
    rows.append(f"# final_acc_baseline,{b.accs[-1]:.3f}")
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
