"""Benchmark harness entrypoint: one section per paper table/figure.

``python -m benchmarks.run``        -- fast CPU-sized defaults
``python -m benchmarks.run --full`` -- paper-scale grids (slow)

Prints CSV blocks per benchmark plus a ``name,us_per_call,derived``
summary line per section (harness contract).
"""
from __future__ import annotations

import argparse
import time
import traceback


def _section(name, fn, summary):
    print(f"\n===== {name} =====")
    t0 = time.time()
    try:
        rows = fn()
        for r in rows:
            print(r)
        dt = (time.time() - t0) * 1e6
        print(f"#summary {name},{dt:.0f},{summary(rows)}")
        return rows
    except Exception as e:
        print(f"#summary {name},0,FAILED:{e}")
        traceback.print_exc()
        return []


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    from benchmarks import (speedup, access_dist, comm_volume, cache_sweep,
                            scaling, memory, energy, convergence,
                            embedding_cache, device_epoch, assemble)

    if args.full:
        ds = ("reddit_sim", "ogbn_products_sim", "ogbn_papers_sim")
        bs = (100, 200, 300)
        epochs = 4
    else:
        ds = ("ogbn_products_sim",)
        bs = (100, 200)
        epochs = 2

    _section("table2_speedup",
             lambda: speedup.run(datasets=ds, batch_sizes=bs,
                                 epochs=epochs),
             lambda rows: rows[-1] if rows else "-")
    _section("fig3_access_distribution", access_dist.run,
             lambda rows: next((r for r in rows if "once" in r), "-"))
    _section("fig4_comm_volume",
             lambda: comm_volume.run(datasets=ds, batch_sizes=bs,
                                     epochs=epochs),
             lambda rows: rows[-1] if rows else "-")
    _section("fig5_cache_sweep",
             lambda: cache_sweep.run(batch_sizes=bs[:1]),
             lambda rows: rows[-1] if rows else "-")
    _section("fig6_scaling", scaling.run,
             lambda rows: rows[-1] if rows else "-")
    _section("fig7_memory", memory.run,
             lambda rows: rows[-1] if rows else "-")
    _section("table3_energy", energy.run,
             lambda rows: next((r for r in rows if r.startswith("total")),
                               "-"))
    _section("fig9_convergence", convergence.run,
             lambda rows: rows[-1] if rows else "-")
    _section("beyond_embedding_cache", embedding_cache.run,
             lambda rows: rows[-1] if rows else "-")
    _section("device_epoch",
             lambda: device_epoch.run(epochs=epochs + 1),
             lambda rows: rows[-1] if rows else "-")
    _section("assemble_collation", assemble.run,
             lambda rows: rows[-1] if rows else "-")
    if not args.skip_roofline:
        from benchmarks import roofline

        def _roof():
            rows = roofline.roofline_table()
            out = ["arch,shape,bottleneck,t_compute_s,t_memory_s,"
                   "t_collective_s,useful_ratio,attn_variant,source"]
            for r in rows:
                out.append(
                    f"{r['arch']},{r['shape']},{r['bottleneck']},"
                    f"{r['t_compute_s']:.4g},{r['t_memory_s']:.4g},"
                    f"{r['t_collective_s']:.4g},{r['useful_ratio']:.3f},"
                    f"{r['attn_variant']},{r['source']}")
            return out

        _section("roofline", _roof,
                 lambda rows: f"{len(rows) - 1}_combos")


if __name__ == "__main__":
    main()
