"""Benchmark harness entrypoint: one section per paper table/figure.

``python -m benchmarks.run``        -- fast CPU-sized defaults
``python -m benchmarks.run --full`` -- paper-scale grids (slow)

Prints CSV blocks per benchmark plus a ``name,us_per_call,derived``
summary line per section (harness contract), and writes EVERY section
as machine-readable JSON to ``artifacts/BENCH_<name>.json``:
``{"section", "status", "us", "summary", "rows"}`` -- the rows split
into header/records when the first row is a CSV header. Sections with
richer native records (assemble) additionally write their own files,
and the cross-backend paper grid lives in ``BENCH_paper.json``
(``python -m repro.eval.campaign``, DESIGN.md §7).
"""
from __future__ import annotations

import argparse
import json
import os
import time
import traceback

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(ROOT, "artifacts")


def _write_section_json(name, status, us, summary, rows):
    rec = {"section": name, "status": status, "us": round(us, 1),
           "summary": summary, "rows": rows}
    if rows and isinstance(rows[0], str) and "," in rows[0]:
        header = rows[0].split(",")
        body = [r.split(",") for r in rows[1:]]
        if all(len(b) == len(header) for b in body):
            rec["columns"] = header
            rec["records"] = [dict(zip(header, b)) for b in body]
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, f"BENCH_{name}.json"), "w") as f:
        json.dump(rec, f, indent=1)


def _section(name, fn, summary):
    print(f"\n===== {name} =====")
    t0 = time.time()
    try:
        rows = fn()
        for r in rows:
            print(r)
        dt = (time.time() - t0) * 1e6
        s = summary(rows)
        print(f"#summary {name},{dt:.0f},{s}")
        _write_section_json(name, "ok", dt, str(s), list(rows))
        return rows
    except Exception as e:
        print(f"#summary {name},0,FAILED:{e}")
        traceback.print_exc()
        _write_section_json(name, "failed", 0.0, f"FAILED:{e}", [])
        return []


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    from benchmarks import (speedup, access_dist, comm_volume, cache_sweep,
                            scaling, memory, energy, convergence,
                            embedding_cache, device_epoch, assemble,
                            schedule_build, topology)

    if args.full:
        ds = ("reddit_sim", "ogbn_products_sim", "ogbn_papers_sim")
        bs = (100, 200, 300)
        epochs = 4
    else:
        ds = ("ogbn_products_sim",)
        bs = (100, 200)
        epochs = 2

    _section("table2_speedup",
             lambda: speedup.run(datasets=ds, batch_sizes=bs,
                                 epochs=epochs),
             lambda rows: rows[-1] if rows else "-")
    _section("fig3_access_distribution", access_dist.run,
             lambda rows: next((r for r in rows if "once" in r), "-"))
    _section("fig4_comm_volume",
             lambda: comm_volume.run(datasets=ds, batch_sizes=bs,
                                     epochs=epochs),
             lambda rows: rows[-1] if rows else "-")
    _section("fig5_cache_sweep",
             lambda: cache_sweep.run(batch_sizes=bs[:1]),
             lambda rows: rows[-1] if rows else "-")
    # raises (-> section FAILED) on a broken intra+inter byte-sum
    # identity or a DCN bias that raises cross-host traffic
    _section("topology",
             lambda: topology.run(datasets=ds, batch_sizes=bs[:1],
                                  epochs=epochs),
             lambda rows: rows[-1] if rows else "-")
    _section("fig6_scaling", scaling.run,
             lambda rows: rows[-1] if rows else "-")
    _section("fig7_memory", memory.run,
             lambda rows: rows[-1] if rows else "-")
    _section("table3_energy", energy.run,
             lambda rows: next((r for r in rows if r.startswith("total")),
                               "-"))
    _section("fig9_convergence", convergence.run,
             lambda rows: rows[-1] if rows else "-")
    _section("beyond_embedding_cache", embedding_cache.run,
             lambda rows: rows[-1] if rows else "-")
    campaign_box = {}

    def _campaign():
        """Paired host+device grid -> BENCH_paper.json (DESIGN.md §7);
        a differential-check failure fails the whole section. The
        device CellResults are stashed so the device_epoch section
        reuses them instead of re-running the SPMD subprocess."""
        from repro.eval.campaign import run_campaign
        from repro.eval.spec import fast_grid, full_grid

        rep = run_campaign(full_grid() if args.full else fast_grid(),
                           out_path=os.path.join(ART,
                                                 "BENCH_paper.json"))
        campaign_box["report"] = rep
        rows = ["backend,baseline,dataset,batch,throughput_speedup,"
                "fetch_reduction_x,energy_total_ratio"]
        for p in rep["pairs"]:
            sc = p["scenario"]
            rows.append(f"{p['backend']},{p['baseline_system']},"
                        f"{sc['dataset']},{sc['batch_size']},"
                        f"{p['throughput_speedup']},"
                        f"{p['fetch_reduction_x']},"
                        f"{p['energy']['total_ratio']}")
        n_fail = sum(1 for c in rep["differential"]
                     if c["status"] == "FAIL")
        n_pass = sum(1 for c in rep["differential"]
                     if c["status"] == "PASS")
        rows.append(f"differential,-,-,-,{n_pass}_pass,{n_fail}_fail,"
                    f"{'OK' if rep['all_checks_pass'] else 'BAD'}")
        if not rep["all_checks_pass"]:
            raise RuntimeError(f"{n_fail} differential check(s) failed")
        return rows

    _section("paper_campaign", _campaign,
             lambda rows: rows[-1] if rows else "-")

    def _device_epoch():
        from repro.eval.cells import CellResult

        rep = campaign_box.get("report")
        reuse = None
        if rep is not None:
            dev = [CellResult.from_dict(d) for d in rep["cells"]
                   if d["spec"]["backend"] == "device"]
            if dev and all(d.spec["epochs"] == epochs + 1 for d in dev):
                reuse = dev
        return device_epoch.run(epochs=epochs + 1, results=reuse)

    _section("device_epoch", _device_epoch,
             lambda rows: rows[-1] if rows else "-")
    _section("assemble_collation", assemble.run,
             lambda rows: rows[-1] if rows else "-")
    # raises (-> section FAILED -> CI bench job fails) on any
    # batched-vs-loop schedule parity mismatch, campaign-style
    _section("schedule_build", schedule_build.run,
             lambda rows: rows[-1] if rows else "-")

    def _fault_recovery():
        """Seeded chaos sweep (DESIGN.md §10): every injected fault
        plan must recover BIT-equal to the clean oracle or surface a
        typed error, and the checkpoint-atomicity drill must hold.
        Raises -> section FAILED + a ``recovery FAILED`` line in the
        log (CI greps for it)."""
        from repro.fault.chaos import run_chaos

        out = run_chaos(seed=0, fast=not args.full,
                        n_random=8 if args.full else 2)
        rows = ["plan,fires,outcome"]
        for r in out["runs"]:
            rows.append(f"{r['plan']},{r['fires']},{r['outcome']}")
        rows.append("checkpoint_drill,-,"
                    + ("ok" if out["checkpoint_drill"] else "failed"))
        if not out["ok"]:
            raise RuntimeError(
                "recovery FAILED: "
                + (",".join(out["failed_plans"]) or "checkpoint drill"))
        return rows

    _section("fault_recovery", _fault_recovery,
             lambda rows: rows[-1] if rows else "-")

    def _serve():
        """Online-serving latency lanes (DESIGN.md §11): clean vs
        fault-injected Poisson streams; writes BENCH_serve.json and
        raises (-> ``recovery FAILED`` in the log, CI greps for it)
        when the fault-lane p99 exceeds 5x the clean lane's."""
        from benchmarks import serve_latency

        return serve_latency.run(requests=64 if args.full else 32)

    _section("serve_latency", _serve,
             lambda rows: rows[-1] if rows else "-")
    if not args.skip_roofline:
        from benchmarks import roofline

        def _roof():
            rows = roofline.roofline_table()
            out = ["arch,shape,bottleneck,t_compute_s,t_memory_s,"
                   "t_collective_s,useful_ratio,attn_variant,source"]
            for r in rows:
                out.append(
                    f"{r['arch']},{r['shape']},{r['bottleneck']},"
                    f"{r['t_compute_s']:.4g},{r['t_memory_s']:.4g},"
                    f"{r['t_collective_s']:.4g},{r['useful_ratio']:.3f},"
                    f"{r['attn_variant']},{r['source']}")
            return out

        _section("roofline", _roof,
                 lambda rows: f"{len(rows) - 1}_combos")


if __name__ == "__main__":
    main()
