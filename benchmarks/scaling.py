"""Paper Fig. 6: throughput scaling with the number of workers.

Epoch time is the max over workers (the slowest worker gates the epoch,
synchronous data-parallel SGD); each worker's run is simulated with its
own schedule + store."""
from __future__ import annotations

from benchmarks.common import run_gnn_system


def run(dataset="ogbn_products_sim", batch_size=200,
        worker_counts=(2, 3, 4, 8), epochs=2):
    rows = ["workers,epoch_time_s,speedup_vs_2w,hit_rate"]
    base = None
    for w in worker_counts:
        # slowest-worker epoch time over all partitions
        times, hits = [], []
        for wk in range(w):
            r = run_gnn_system("rapidgnn", dataset, batch_size, workers=w,
                               epochs=epochs, train=False, worker=wk)
            times.append(r.wall_time_s / epochs)
            hits.append(r.hit_rate)
        t = max(times)
        base = base or t
        rows.append(f"{w},{t:.2f},{base / t:.2f},"
                    f"{sum(hits) / len(hits):.3f}")
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
