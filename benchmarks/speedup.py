"""Paper Table 2: step-time + network-time speedup of RapidGNN over
DGL-METIS / DGL-Random / Dist-GCN across datasets x batch sizes."""
from __future__ import annotations

from typing import List

from benchmarks.common import run_gnn_system, csv_row, GNNResult


def run(datasets=("ogbn_products_sim", "reddit_sim"),
        batch_sizes=(100, 200), epochs=2, workers=4,
        train=False) -> List[str]:
    rows = [
        "dataset,batch,step_speedup_metis,step_speedup_random,"
        "step_speedup_gcn,net_speedup_metis,net_speedup_random,"
        "net_speedup_gcn"]
    agg = {k: [] for k in ("sm", "sr", "sg", "nm", "nr", "ng")}
    for ds in datasets:
        for b in batch_sizes:
            res = {s: run_gnn_system(s, ds, b, workers=workers,
                                     epochs=epochs, train=train)
                   for s in ("rapidgnn", "dgl-metis", "dgl-random", "gcn")}
            r = res["rapidgnn"]

            def step_x(s):
                return res[s].step_time_ms / max(r.step_time_ms, 1e-9)

            def net_x(s):
                return res[s].net_time_s / max(r.net_time_s, 1e-9)

            vals = (step_x("dgl-metis"), step_x("dgl-random"),
                    step_x("gcn"), net_x("dgl-metis"),
                    net_x("dgl-random"), net_x("gcn"))
            for k, v in zip(agg, vals):
                agg[k].append(v)
            rows.append(f"{ds},{b}," + ",".join(f"{v:.2f}" for v in vals))
    mean = [sum(v) / len(v) for v in agg.values()]
    rows.append("average,-," + ",".join(f"{v:.2f}" for v in mean))
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
