"""Paper Table 2: step-time + network-time speedup of RapidGNN over
DGL-METIS / DGL-Random / Dist-GCN across datasets x batch sizes.

Thin campaign wrapper: each (dataset, batch) point runs the four
systems as host-backend campaign cells (``repro.eval.cells``) and the
ratios come from ``repro.eval.report.derive_pair`` -- the identical
derivation ``BENCH_paper.json`` pins."""
from __future__ import annotations

from typing import List

from repro.eval.cells import run_host_cell
from repro.eval.report import derive_pair
from repro.eval.spec import CellSpec, HOST_SYSTEMS


def _cells_for(ds: str, b: int, workers: int, epochs: int, train: bool):
    return {s: run_host_cell(CellSpec(
        backend="host", system=s, dataset=ds, batch_size=b,
        workers=workers, n_hot=32768, epochs=epochs, hidden=64,
        train=train, all_workers=False)) for s in HOST_SYSTEMS}


def run(datasets=("ogbn_products_sim", "reddit_sim"),
        batch_sizes=(100, 200), epochs=2, workers=4,
        train=False) -> List[str]:
    rows = [
        "dataset,batch,step_speedup_metis,step_speedup_random,"
        "step_speedup_gcn,net_speedup_metis,net_speedup_random,"
        "net_speedup_gcn"]
    agg = {k: [] for k in ("sm", "sr", "sg", "nm", "nr", "ng")}
    for ds in datasets:
        for b in batch_sizes:
            res = _cells_for(ds, b, workers, epochs, train)
            pairs = {s: derive_pair(res["rapidgnn"], res[s])
                     for s in HOST_SYSTEMS if s != "rapidgnn"}

            def step_x(s):
                return pairs[s]["throughput_speedup"]

            def net_x(s):
                return pairs[s]["net_time_speedup"] or 0.0

            vals = (step_x("dgl-metis"), step_x("dgl-random"),
                    step_x("gcn"), net_x("dgl-metis"),
                    net_x("dgl-random"), net_x("gcn"))
            for k, v in zip(agg, vals):
                agg[k].append(v)
            rows.append(f"{ds},{b}," + ",".join(f"{v:.2f}" for v in vals))
    mean = [sum(v) / len(v) for v in agg.values()]
    rows.append("average,-," + ",".join(f"{v:.2f}" for v in mean))
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
