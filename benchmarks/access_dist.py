"""Paper Fig. 3: long-tail frequency distribution of remote feature
accesses per node (one epoch of the deterministic schedule)."""
from __future__ import annotations

import numpy as np

from repro.graph import load_dataset, partition_graph, KHopSampler
from repro.core import build_schedule


def run(dataset="ogbn_products_sim", batch_size=1000, workers=2, s0=42):
    g = load_dataset(dataset)
    pg = partition_graph(g, workers, "metis")
    sampler = KHopSampler(g, fanouts=(25, 10), batch_size=batch_size)
    ws = build_schedule(sampler, pg, worker=0, s0=s0, num_epochs=1,
                        n_hot=0)
    es = ws.epoch(0)
    freq = es.remote_freq
    if freq.size == 0:
        return ["freq,count", "0,0"]
    hist = np.bincount(freq)
    rows = ["freq,count"]
    for f in range(1, hist.shape[0]):
        if hist[f]:
            rows.append(f"{f},{hist[f]}")
    once = (freq == 1).mean()
    rows.append(f"# accessed_exactly_once_frac,{once:.3f}")
    rows.append(f"# max_freq,{int(freq.max())}")
    rows.append(f"# unique_remote_nodes,{freq.shape[0]}")
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
