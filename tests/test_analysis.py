"""Tier-1 tests for ``repro.analysis``: pinned fixture findings, waiver
semantics, contract-injection checks against copies of the real tree,
CLI exit codes, and the CI wall-time budget.

The fixture corpus lives in ``tests/analysis_fixtures/`` -- one bad and
one good file per rule, with expected (rule, line) pairs pinned here so
any drift in a rule's reach shows up as an exact-diff failure.
"""
import os
import shutil
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import analyze_paths
from repro.analysis.imports import build_import_report
from repro.analysis.rules import RULE_IDS

REPO = Path(__file__).resolve().parent.parent
FIX = REPO / "tests" / "analysis_fixtures"
SRC = REPO / "src"


def scan(*paths, rules=None):
    return analyze_paths([str(p) for p in paths], rules=rules)


def keyed(res):
    return sorted((f.rule, f.line) for f in res.findings)


# -- pinned bad fixtures -------------------------------------------------

BAD_CASES = [
    ("rng_bad.py",
     [("RNG-CONTRACT", 9), ("RNG-CONTRACT", 14), ("RNG-CONTRACT", 15),
      ("RNG-CONTRACT", 19), ("RNG-CONTRACT", 20),
      # L24 carries two findings: outside-sanctioned AND time-seeded
      ("RNG-CONTRACT", 24), ("RNG-CONTRACT", 24)]),
    ("trace_bad.py",
     [("TRACE-PURITY", 9), ("TRACE-PURITY", 10), ("TRACE-PURITY", 11),
      ("TRACE-PURITY", 12), ("TRACE-PURITY", 17)]),
    ("thread_bad.py",
     # L8 carries three findings: never joined, no broad capture,
     # unlocked shared attr
     [("THREAD-DISCIPLINE", 8), ("THREAD-DISCIPLINE", 8),
      ("THREAD-DISCIPLINE", 8), ("THREAD-DISCIPLINE", 19),
      ("THREAD-DISCIPLINE", 23)]),
    ("spill_bad.py",
     # L14 carries two findings: np IO outside spill AND allow_pickle
     [("SPILL-SAFETY", 8), ("SPILL-SAFETY", 10),
      ("SPILL-SAFETY", 14), ("SPILL-SAFETY", 14)]),
]


@pytest.mark.parametrize("fname,expected",
                         BAD_CASES, ids=[c[0] for c in BAD_CASES])
def test_bad_fixture_pinned_findings(fname, expected):
    res = scan(FIX / fname)
    assert keyed(res) == sorted(expected)
    assert res.waived == 0


def test_kernel_bad_fixture():
    res = scan(FIX / "kernel_bad")
    assert keyed(res) == [("KERNEL-LAYOUT", 1), ("KERNEL-LAYOUT", 1),
                          ("KERNEL-LAYOUT", 1), ("KERNEL-LAYOUT", 6)]
    msgs = sorted(f.message for f in res.findings)
    assert any("missing ref.py" in m for m in msgs)
    assert any("missing foo.py" in m for m in msgs)
    assert any("no interpret-mode backend" in m for m in msgs)
    assert any("outside kernels/" in m for m in msgs)


GOOD_FIXTURES = ["rng_good.py", "trace_good.py", "thread_good.py",
                 "spill_good.py", "kernel_good"]


@pytest.mark.parametrize("fname", GOOD_FIXTURES)
def test_good_fixture_clean(fname):
    res = scan(FIX / fname)
    assert res.findings == []
    assert res.waived == 0


# -- waiver semantics ----------------------------------------------------

def test_waiver_suppresses_exactly_one():
    res = scan(FIX / "waiver_one_of_two.py")
    assert keyed(res) == [("RNG-CONTRACT", 10)]
    assert res.waived == 1


def test_malformed_waiver_is_a_finding_and_waives_nothing():
    res = scan(FIX / "waiver_malformed.py")
    assert keyed(res) == [("RNG-CONTRACT", 6), ("RNG-CONTRACT", 11),
                          ("WAIVER-SYNTAX", 6), ("WAIVER-SYNTAX", 10)]
    assert res.waived == 0


def test_rule_subset_filter():
    res = scan(FIX / "rng_bad.py", rules=())
    assert res.findings == []  # no rules -> only waiver syntax checks


# -- contract injection against copies of the real tree ------------------

def _copy_into(tmp_path: Path, rel: str) -> Path:
    """Copy src/<rel> under tmp preserving the repro/... suffix so the
    sanctioned-location matching still applies."""
    dst = tmp_path / rel
    dst.parent.mkdir(parents=True, exist_ok=True)
    shutil.copy(SRC / rel, dst)
    return dst


def test_injected_global_seed_is_caught(tmp_path):
    dst = _copy_into(tmp_path, "repro/dist/runner.py")
    assert scan(tmp_path).findings == []          # clean before injection
    with open(dst, "a") as f:
        f.write("\n\nnp.random.seed(1234)\n")
    res = scan(tmp_path)
    assert [f.rule for f in res.findings] == ["RNG-CONTRACT"]
    # the finding is on the appended last line
    assert res.findings[0].line == len(dst.read_text().splitlines())


def test_injected_daemon_thread_is_caught(tmp_path):
    dst = _copy_into(tmp_path, "repro/graph/sampler.py")
    assert scan(tmp_path).findings == []          # sanctioned np.random
    with open(dst, "a") as f:
        f.write("\n\nimport threading\n"
                "threading.Thread(target=print, daemon=True).start()\n")
    res = scan(tmp_path)
    assert [f.rule for f in res.findings] == ["THREAD-DISCIPLINE"]
    assert "bare daemon thread" in res.findings[0].message


# -- the real tree -------------------------------------------------------

def test_src_tree_is_clean_and_fast():
    res = scan(SRC)
    assert res.findings == []
    assert res.files_scanned > 50
    assert res.elapsed_s < 10.0, \
        f"invariant scan took {res.elapsed_s:.2f}s (budget 10s)"


def test_import_report_reaches_live_surfaces():
    rep = build_import_report(str(SRC))
    assert "repro.core.schedule" in rep.reachable
    assert "repro.graph.sampler" in rep.reachable
    assert "repro.dist.runner" in rep.reachable
    # inventory only: every module is either reachable or listed dead
    assert set(rep.dead) | rep.reachable == set(rep.modules)
    assert rep.format().splitlines()[0].startswith("import graph:")


# -- CLI exit codes ------------------------------------------------------

def _cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=REPO, capture_output=True, text=True, env=env)


@pytest.mark.parametrize("bad", ["rng_bad.py", "trace_bad.py",
                                 "thread_bad.py", "spill_bad.py",
                                 "kernel_bad", "waiver_malformed.py"])
def test_cli_strict_exit_1_on_bad_fixture(bad):
    p = _cli(str(FIX / bad), "--strict")
    assert p.returncode == 1, p.stdout + p.stderr
    first = p.stdout.splitlines()[0]
    # path:line:col RULE-ID message
    loc, rest = first.split(" ", 1)
    assert loc.count(":") == 2 and rest.split()[0] in set(RULE_IDS) | {
        "WAIVER-SYNTAX"}


def test_cli_strict_exit_0_on_src():
    p = _cli("src", "--strict")
    assert p.returncode == 0, p.stdout + p.stderr
    assert "0 finding(s)" in p.stdout


def test_cli_report_mode_never_fails():
    p = _cli(str(FIX / "rng_bad.py"))
    assert p.returncode == 0
    assert "7 finding(s)" in p.stdout


def test_cli_wall_time_budget_exit_2():
    p = _cli(str(FIX / "rng_good.py"), "--max-seconds", "0")
    assert p.returncode == 2
    assert "exceeds" in p.stderr


def test_cli_report_dead():
    p = _cli("src", "--report-dead")
    assert p.returncode == 0
    assert "import graph:" in p.stdout


def test_cli_unknown_rule_rejected():
    p = _cli("src", "--rules", "NO-SUCH-RULE")
    assert p.returncode == 2  # argparse error
