"""Distributed-path tests: run on 4 emulated host devices in a
subprocess (XLA device count locks at first jax init, so these cannot
run in the main pytest process, which must see 1 device)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow      # subprocess + 4-device jax init each

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(which: str, devices: int = 4):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "_dist_checks.py"),
         which],
        capture_output=True, text=True, timeout=420, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_pull_features_a2a():
    assert "pull_features OK" in _run("pull")


def test_pipelined_gnn_epoch_on_mesh():
    assert "pipelined_gnn_epoch OK" in _run("epoch")


def test_device_runner_multi_epoch_one_compile_and_parity():
    """DeviceRapidGNNRunner: 3 epochs, ONE XLA trace, per-epoch miss
    lanes == host-sim cache_misses, C_sec swap shrinks epoch-1 lanes,
    baseline parity curves."""
    assert "device_runner OK" in _run("runner")


def test_device_runner_uneven_workers():
    """Workers with fewer/zero batches pad with masked empty steps."""
    assert "uneven_workers OK" in _run("uneven")


def test_device_runner_end_to_end_determinism():
    """Same seed => bit-identical staged pull plans, cache ids, and loss
    curves across two fresh device-runner builds."""
    assert "determinism OK" in _run("determinism")


def test_checkpoint_resume_through_device_runner():
    """Save at an epoch boundary mid-campaign, restore into a fresh
    runner, resumed loss curve == uninterrupted run."""
    assert "checkpoint_resume OK" in _run("checkpoint")


def test_overlapped_staging_bit_consistent():
    """Lazy device-compiled schedules staged by the background thread:
    bit-consistent with a cold eager build, one XLA trace, loss curve
    equal to the eager runner, overlap accounting recorded."""
    assert "overlapped_staging OK" in _run("overlap")


def test_fault_recovery_through_device_runner():
    """Injected staging faults: transient death retried bit-exactly,
    persistent death surfaces StagingError, deadline overrun rebuilt on
    the critical path, lost staged cache degrades ONE epoch to uncached
    -- loss curves bit-equal to the clean run throughout."""
    assert "fault_recovery OK" in _run("fault")


def test_crash_resume_bit_parity():
    """Injected crash at an epoch boundary + periodic atomic run-state
    checkpoints: resume from LATEST reproduces the uninterrupted loss
    curve bit-for-bit; crash inside the checkpoint commit leaves LATEST
    on the previous complete step."""
    assert "crash_resume OK" in _run("crashresume")


def test_topology_two_tier_8dev():
    """Hierarchical 2 hosts x 4 devices (8 emulated devices): two-tier
    runner keeps one XLA trace, loss curves bit-equal to the flat mesh,
    intra + inter lanes sum to the flat counts with both tiers live,
    host parity holds."""
    assert "topology_two_tier OK" in _run("topology", devices=8)


def test_serve_gnn_per_worker_bit_equal_4dev():
    """Online serving on 4 emulated devices: every worker's service
    serves the same streams bit-equal to its own oracle through the
    uncached -> fresh tier ladder and under a flaky-pull plan, one XLA
    trace each."""
    assert "serve_gnn OK" in _run("serve")


def test_moe_expert_parallel_matches_single_device():
    assert "moe_expert_parallel OK" in _run("moe")


def test_sharded_decode_attention_matches_reference():
    assert "sharded_decode_attention OK" in _run("decode")
