"""Multi-device checks, run in a subprocess with 4 host devices
(tests/test_distributed.py sets XLA_FLAGS before python starts)."""
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp


def check_pull_features():
    from repro.dist import make_mesh, build_pull_plan, pull_features
    P_, n_per, d, m_max, k_max = 4, 16, 8, 12, 6
    mesh = make_mesh((4,), ("data",))
    rng = np.random.default_rng(0)
    table_global = rng.normal(size=(P_ * n_per, d)).astype(np.float32)
    owner = np.repeat(np.arange(P_), n_per)
    plans, want = [], []
    for w in range(P_):
        ids = rng.choice(P_ * n_per, size=m_max, replace=False)
        pos = np.arange(m_max)
        plans.append(build_pull_plan(ids.astype(np.int32),
                                     pos.astype(np.int32), owner, P_,
                                     k_max))
        exp = np.zeros((m_max, d), np.float32)
        exp[pos] = table_global[ids]
        want.append(exp)
    with mesh:
        out = pull_features(
            mesh, jnp.asarray(table_global.reshape(P_, n_per, d)),
            jnp.asarray(np.stack([p.send_ids for p in plans])),
            jnp.asarray(np.stack([p.send_pos for p in plans])),
            jnp.asarray(np.stack([p.send_mask for p in plans])),
            jnp.asarray((np.arange(P_) * n_per).astype(np.int32)), m_max)
    np.testing.assert_allclose(np.asarray(out), np.stack(want), rtol=1e-6)
    print("pull_features OK")


def check_pipelined_gnn_epoch():
    from repro.graph import load_dataset, partition_graph, KHopSampler
    from repro.core import build_schedule
    from repro.core.schedule import epoch_edge_maxima
    from repro.dist import (make_mesh, DeviceView, epoch_k_max,
                            collate_device_epoch, stack_caches,
                            make_pipelined_epoch)
    from repro.models import GNNConfig, init_params
    from repro.train import AdamW

    P_, n_hot, B = 4, 64, 16
    g = load_dataset("tiny")
    pg = partition_graph(g, P_, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=B)
    schedules = [build_schedule(sampler, pg, worker=w, s0=7,
                                num_epochs=1, n_hot=n_hot)
                 for w in range(P_)]
    dv = DeviceView.build(pg)
    es_list = [ws.epoch(0) for ws in schedules]
    m_max = max(es.m_max for es in es_list)
    edge_max = None
    for es in es_list:
        em = epoch_edge_maxima(es)
        edge_max = em if edge_max is None else [max(a, b) for a, b
                                                in zip(edge_max, em)]
    caches = [dv.remap_cache(es.cache_ids) for es in es_list]
    S = max(es.num_batches for es in es_list)
    k_max = epoch_k_max(es_list, caches, dv)
    batches = collate_device_epoch(es_list, caches, dv, g.labels, B,
                                   m_max, edge_max, k_max, S)
    cids, cfeats = stack_caches(caches, dv, n_hot)

    mesh = make_mesh((P_,), ("data",))
    cfg = GNNConfig(kind="sage", in_dim=g.feat_dim, hidden_dim=32,
                    num_classes=g.num_classes, num_layers=2)
    params = init_params(cfg, jax.random.key(0))
    opt = AdamW(lr=3e-3)
    epoch_fn = make_pipelined_epoch(cfg, opt, mesh, m_max)
    with mesh:
        _, _, losses, _ = epoch_fn(
            params, opt.init(params), jnp.asarray(dv.table),
            jnp.asarray(dv.offsets), jnp.asarray(cids),
            jnp.asarray(cfeats), jax.tree.map(jnp.asarray, batches))
        losses = np.asarray(losses)
    assert not np.isnan(losses).any()
    assert losses[-1] < losses[0]
    print("pipelined_gnn_epoch OK")


def _runner_setup(P_=4, B=16, epochs=3, n_hot=64, uneven=False):
    from repro.dist import make_mesh

    if uneven:
        from _uneven import build_uneven_case
        g, pg, schedules, dv = build_uneven_case(P_=P_, B=B, epochs=epochs,
                                                 n_hot=n_hot)
    else:
        from repro.graph import load_dataset, partition_graph, KHopSampler
        from repro.core import build_schedule
        from repro.dist import DeviceView

        g = load_dataset("tiny")
        pg = partition_graph(g, P_, "greedy")
        sampler = KHopSampler(g, fanouts=[5, 5], batch_size=B)
        schedules = [build_schedule(sampler, pg, worker=w, s0=7,
                                    num_epochs=epochs, n_hot=n_hot)
                     for w in range(P_)]
        dv = DeviceView.build(pg)
    mesh = make_mesh((P_,), ("data",))
    return g, pg, schedules, dv, mesh


def _make_runner(cls, g, schedules, dv, mesh, B, **kw):
    from repro.models import GNNConfig
    from repro.train import AdamW
    cfg = GNNConfig(kind="sage", in_dim=g.feat_dim, hidden_dim=32,
                    num_classes=g.num_classes, num_layers=2)
    return cls(schedules, dv, cfg, AdamW(lr=3e-3), mesh, B, g.labels,
               **kw)


def check_device_runner():
    """Multi-epoch double-buffer runner: one compilation, host-parity
    miss accounting, C_sec swap shrinking epoch-1 pull lanes, and
    rapid == baseline training curves (identical schedule)."""
    from repro.dist import (DeviceRapidGNNRunner, DeviceBaselineRunner,
                            assert_host_parity, collate_device_epoch,
                            epoch_k_max)

    B, epochs = 16, 3
    g, pg, schedules, dv, mesh = _runner_setup(B=B, epochs=epochs)
    runner = _make_runner(DeviceRapidGNNRunner, g, schedules, dv, mesh, B)
    reports = runner.run()
    assert len(reports) == epochs
    assert runner.trace_count == 1, \
        f"expected ONE XLA trace across {epochs} epochs, got " \
        f"{runner.trace_count}"
    losses = np.concatenate([r.losses for r in reports])
    assert not np.isnan(losses).any()
    assert reports[-1].losses[-1] < reports[0].losses[0]

    # per-(epoch, worker) residual-miss lanes == host-sim cache_misses
    assert_host_parity(schedules, pg, B, reports)

    # double-buffer effect: epoch 1 collated against the SWAPPED-in
    # C_sec beats the no-swap counterfactual (stuck on epoch 0's C_s)
    caches0 = [dv.remap_cache(ws.epoch(0).cache_ids) for ws in schedules]
    es1 = [ws.epoch(1) for ws in schedules]
    k_stale = max(runner.k_max, epoch_k_max(es1, caches0, dv))
    stale = collate_device_epoch(es1, caches0, dv, g.labels, B,
                                 runner.m_max, runner.edge_max, k_stale,
                                 runner.num_steps)
    stale_lanes = int(stale["send_mask"].sum())
    assert reports[1].total_miss_lanes < stale_lanes, \
        f"swap did not shrink epoch-1 pull lanes: " \
        f"{reports[1].total_miss_lanes} vs stale {stale_lanes}"

    baseline = _make_runner(DeviceBaselineRunner, g, schedules, dv, mesh, B)
    rep_b = baseline.run()
    assert baseline.trace_count == 1
    # no cache: every remote id rides the lanes, so never fewer
    for r, b in zip(reports, rep_b):
        assert b.total_miss_lanes >= r.total_miss_lanes
    # identical schedule + exact feature paths => identical curves
    np.testing.assert_allclose(
        np.concatenate([r.losses for r in reports]),
        np.concatenate([r.losses for r in rep_b]), rtol=1e-4, atol=1e-5)
    print("device_runner OK")


def check_uneven_workers():
    """Workers with fewer/zero batches get fully masked empty steps and
    still match host-sim accounting (pre-fix: IndexError in
    collate_device_epoch / epoch_edge_maxima)."""
    from repro.dist import DeviceRapidGNNRunner, assert_host_parity

    B, epochs = 16, 2
    g, pg, schedules, dv, mesh = _runner_setup(B=B, epochs=epochs,
                                               uneven=True)
    assert schedules[2].epoch(0).num_batches == 0
    assert schedules[3].epoch(0).num_batches < \
        schedules[0].epoch(0).num_batches
    runner = _make_runner(DeviceRapidGNNRunner, g, schedules, dv, mesh, B)
    reports = runner.run()
    assert runner.trace_count == 1
    for r in reports:
        assert not np.isnan(r.losses).any()
        assert r.miss_lanes[2] == 0         # no batches -> no pulls
    assert_host_parity(schedules, pg, B, reports)
    print("uneven_workers OK")


def check_determinism():
    """Same seed => bit-identical staged pull plans, cache ids, and loss
    curves across two COMPLETELY FRESH device-runner builds (graph,
    schedules, DeviceView, mesh, runner all rebuilt) -- the device half
    of the end-to-end determinism property (host half:
    tests/test_eval_campaign.py)."""
    from repro.dist import DeviceRapidGNNRunner

    B, epochs = 16, 2
    runs = []
    for _ in range(2):
        g, pg, schedules, dv, mesh = _runner_setup(B=B, epochs=epochs)
        runner = _make_runner(DeviceRapidGNNRunner, g, schedules, dv,
                              mesh, B)
        staged0 = runner._stage(0)
        reports = runner.run()
        cids = [ws.epoch(e).cache_ids.copy()
                for ws in schedules for e in range(epochs)]
        runs.append((staged0, reports, cids))
    (sa, ra, ca), (sb, rb, cb) = runs
    for x, y in zip(ca, cb):
        np.testing.assert_array_equal(x, y)
    for key in ("send_ids", "send_pos", "send_mask", "input_nodes",
                "labels", "seed_mask"):
        np.testing.assert_array_equal(np.asarray(sa["batches"][key]),
                                      np.asarray(sb["batches"][key]),
                                      err_msg=key)
    np.testing.assert_array_equal(np.asarray(sa["cids"]),
                                  np.asarray(sb["cids"]))
    np.testing.assert_array_equal(
        np.concatenate([r.losses for r in ra]),
        np.concatenate([r.losses for r in rb]))
    np.testing.assert_array_equal(np.stack([r.miss_lanes for r in ra]),
                                  np.stack([r.miss_lanes for r in rb]))
    print("determinism OK")


def check_checkpoint_resume():
    """train/checkpoint.py round trip THROUGH the device runner: run
    epochs [0, 2), save params+opt state at the boundary, restore into a
    FRESH runner, run [2, 3) -- the stitched loss curve must equal an
    uninterrupted 3-epoch run's exactly (float32 survives the npz round
    trip losslessly; the epoch window shares the one compiled program)."""
    import tempfile

    from repro.dist import DeviceRapidGNNRunner
    from repro.models.gnn import init_params
    from repro.train import (save_checkpoint, load_checkpoint,
                             checkpoint_step)

    B, epochs = 16, 3
    g, pg, schedules, dv, mesh = _runner_setup(B=B, epochs=epochs)
    full = _make_runner(DeviceRapidGNNRunner, g, schedules, dv, mesh, B)
    rep_full = full.run()

    r1 = _make_runner(DeviceRapidGNNRunner, g, schedules, dv, mesh, B)
    rep_head = r1.run(stop_epoch=2)
    assert len(rep_head) == 2
    r2 = _make_runner(DeviceRapidGNNRunner, g, schedules, dv, mesh, B)
    with tempfile.TemporaryDirectory() as td:
        pdir = os.path.join(td, "params")
        odir = os.path.join(td, "opt")
        save_checkpoint(pdir, r1.params, step=2)
        save_checkpoint(odir, r1.opt_state, step=2)
        assert checkpoint_step(pdir) == 2
        like_p = init_params(r2.cfg, jax.random.key(r2.seed))
        params = load_checkpoint(pdir, like_p)
        opt_state = load_checkpoint(odir, r2.opt.init(like_p))
    rep_tail = r2.run(params=params, opt_state=opt_state, start_epoch=2)
    assert len(rep_tail) == 1 and rep_tail[0].epoch == 2
    resumed = np.concatenate([r.losses for r in rep_head + rep_tail])
    uninterrupted = np.concatenate([r.losses for r in rep_full])
    np.testing.assert_array_equal(
        resumed, uninterrupted,
        err_msg="resumed loss curve diverges from uninterrupted run")
    # miss accounting unaffected by the restart
    np.testing.assert_array_equal(
        np.stack([r.miss_lanes for r in rep_head + rep_tail]),
        np.stack([r.miss_lanes for r in rep_full]))
    print("checkpoint_resume OK")


def _assert_epoch_bit_equal(a, b):
    """EpochSchedule bit-equality over every payload + hot-set array."""
    assert a.m_max == b.m_max
    np.testing.assert_array_equal(a.cache_ids, b.cache_ids)
    np.testing.assert_array_equal(a.remote_ids, b.remote_ids)
    np.testing.assert_array_equal(a.remote_freq, b.remote_freq)
    fa, fb = a.flat, b.flat
    for f in ("seeds", "seed_starts", "input_nodes", "input_starts",
              "num_dst"):
        np.testing.assert_array_equal(getattr(fa, f), getattr(fb, f),
                                      err_msg=f)
    assert fa.num_layers == fb.num_layers
    for l in range(fa.num_layers):
        for f in ("edge_src", "edge_dst", "edge_mask", "edge_starts"):
            np.testing.assert_array_equal(getattr(fa, f)[l],
                                          getattr(fb, f)[l],
                                          err_msg=f"{f}[{l}]")


def check_overlapped_staging():
    """Train-overlapped next-epoch builds: a LAZY (device-resident)
    schedule under the DEVICE compiler is rebuilt by the runner's
    background staging thread while the previous epoch trains. The
    staged-ahead epochs must be bit-consistent with a cold eager
    (numpy-batched) build, the loss curve must match the eager runner
    exactly, and the one-compilation invariant must survive the thread
    (staging never traces)."""
    from repro.core import build_schedule
    from repro.dist import DeviceRapidGNNRunner, DeviceView, make_mesh
    from repro.graph import KHopSampler, load_dataset, partition_graph

    P_, B, epochs, n_hot = 4, 16, 3, 64
    g = load_dataset("tiny")
    pg = partition_graph(g, P_, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=B)
    eager = [build_schedule(sampler, pg, worker=w, s0=7,
                            num_epochs=epochs, n_hot=n_hot)
             for w in range(P_)]
    lazy = [build_schedule(sampler, pg, worker=w, s0=7,
                           num_epochs=epochs, n_hot=n_hot,
                           compiler="device", lazy=True)
            for w in range(P_)]
    for ws in lazy:
        assert all(e is None for e in ws.epochs)    # payloads dropped
        assert ws.spill_dir is None                 # and never spilled

    dv = DeviceView.build(pg)
    mesh = make_mesh((P_,), ("data",))
    run_e = _make_runner(DeviceRapidGNNRunner, g, eager, dv, mesh, B)
    rep_e = run_e.run()
    run_l = _make_runner(DeviceRapidGNNRunner, g, lazy, dv, mesh, B)
    rep_l = run_l.run()

    assert run_l.trace_count == 1, \
        f"background staging retriggered tracing: {run_l.trace_count}"
    # staged-ahead device-compiled epochs == cold numpy-batched builds
    for we, wl in zip(eager, lazy):
        for e in range(epochs):
            _assert_epoch_bit_equal(we.epoch(e), wl.epoch(e))
    np.testing.assert_array_equal(
        np.concatenate([r.losses for r in rep_e]),
        np.concatenate([r.losses for r in rep_l]),
        err_msg="lazy-schedule loss curve diverges from eager")
    np.testing.assert_array_equal(
        np.stack([r.miss_lanes for r in rep_e]),
        np.stack([r.miss_lanes for r in rep_l]))

    # overlap accounting: every staged epoch recorded a build wall, the
    # final epoch stages nothing, and the exposed slice never exceeds it
    assert run_l.stage_time_s > 0.0
    assert 0.0 <= run_l.exposed_stage_s <= run_l.stage_time_s + 1e-6
    assert all(r.stage_s > 0.0 for r in rep_l[:-1])
    assert rep_l[-1].stage_s == 0.0 and rep_l[-1].exposed_stage_s == 0.0
    print(f"overlap staging wall {run_l.stage_time_s * 1e3:.1f} ms, "
          f"exposed {run_l.exposed_stage_s * 1e3:.1f} ms")
    print("overlapped_staging OK")


def check_fault_recovery():
    """Device staging fault sites (DESIGN.md §10): every tolerated fault
    recovers to a BIT-equal loss curve, persistent faults surface the
    typed ``StagingError``, and a lost staged cache degrades exactly one
    epoch to uncached without touching any other epoch's accounting."""
    from repro.dist import DeviceRapidGNNRunner
    from repro.dist.runner import StagingError
    from repro.fault import active_plan, plan_from_profile

    B, epochs = 16, 3
    g, pg, schedules, dv, mesh = _runner_setup(B=B, epochs=epochs)
    clean = _make_runner(DeviceRapidGNNRunner, g, schedules, dv, mesh, B)
    rep_clean = clean.run()
    oracle = np.concatenate([r.losses for r in rep_clean])

    # stage-flaky: transient background-staging death -> one supervised
    # eager rebuild, zero degradation, bit-equal curve, ONE compilation
    r = _make_runner(DeviceRapidGNNRunner, g, schedules, dv, mesh, B)
    plan = plan_from_profile("stage-flaky", seed=3)
    with active_plan(plan):
        rep = r.run()
    assert plan.total_fires() >= 1, "stage-flaky plan never fired"
    assert r.stage_retries >= 1
    assert r.trace_count == 1
    assert sum(x.degraded for x in rep) == 0
    np.testing.assert_array_equal(
        np.concatenate([x.losses for x in rep]), oracle,
        err_msg="transient staging fault broke loss bit-equality")

    # stage-dead: staging fails on EVERY attempt -> typed StagingError
    # after the bounded retry budget, never a hang or raw thread error
    r = _make_runner(DeviceRapidGNNRunner, g, schedules, dv, mesh, B)
    try:
        with active_plan(plan_from_profile("stage-dead", seed=3)):
            r.run()
    except StagingError:
        pass
    else:
        raise AssertionError(
            "persistent staging failure must raise StagingError")

    # stage-deadline: staging thread hangs past the deadline -> overrun
    # counted, eager rebuild on the critical path, still bit-equal
    r = _make_runner(DeviceRapidGNNRunner, g, schedules, dv, mesh, B,
                     stage_deadline_s=0.05)
    plan = plan_from_profile("stage-deadline", seed=3)
    with active_plan(plan):
        rep = r.run()
    assert plan.fires("stage", "hang") >= 1
    assert r.deadline_overruns >= 1
    assert r.trace_count == 1
    np.testing.assert_array_equal(
        np.concatenate([x.losses for x in rep]), oracle,
        err_msg="deadline-overrun recovery broke loss bit-equality")

    # cache-loss: epoch 1's staged C_s dropped -> that epoch recollates
    # UNCACHED (graceful degrade, counted in the report); features come
    # from the same table either way so the curve stays bit-equal, and
    # the wider-k recollation may cost at most one extra trace
    r = _make_runner(DeviceRapidGNNRunner, g, schedules, dv, mesh, B)
    plan = plan_from_profile("cache-loss", seed=3)
    with active_plan(plan):
        rep = r.run()
    assert plan.fires("stage_cache", "drop") == 1
    assert r.degraded_epochs == 1
    assert rep[1].degraded == 1 and rep[1].degrade_reason == "cache_lost"
    assert sum(x.degraded for x in rep) == 1
    assert 1 <= r.trace_count <= 2
    # uncached epoch pulls strictly more lanes; others match clean
    assert rep[1].total_miss_lanes > rep_clean[1].total_miss_lanes
    for e in (0, 2):
        np.testing.assert_array_equal(rep[e].miss_lanes,
                                      rep_clean[e].miss_lanes)
    np.testing.assert_array_equal(
        np.concatenate([x.losses for x in rep]), oracle,
        err_msg="uncached degraded epoch broke loss bit-equality")
    print("fault_recovery OK")


def check_crash_resume():
    """Kill-and-resume bit parity: periodic atomic run-state checkpoints
    + an injected crash at an epoch boundary; resuming from LATEST must
    reproduce the uninterrupted curve bit-for-bit. Also drills a crash
    INSIDE the checkpoint commit: LATEST must keep naming the previous
    complete step."""
    import tempfile

    from repro.dist import DeviceRapidGNNRunner
    from repro.fault import InjectedCrash, active_plan, plan_from_profile
    from repro.models.gnn import init_params
    from repro.train import latest_step, load_run_state

    B, epochs = 16, 3
    g, pg, schedules, dv, mesh = _runner_setup(B=B, epochs=epochs)
    full = _make_runner(DeviceRapidGNNRunner, g, schedules, dv, mesh, B)
    rep_full = full.run()
    uninterrupted = np.concatenate([r.losses for r in rep_full])

    with tempfile.TemporaryDirectory() as td:
        r1 = _make_runner(DeviceRapidGNNRunner, g, schedules, dv, mesh, B,
                          checkpoint_dir=td, checkpoint_every=1)
        try:
            with active_plan(plan_from_profile("run-crash", seed=5)):
                r1.run()
        except InjectedCrash:
            pass
        else:
            raise AssertionError("run-crash plan must kill the run")
        step = latest_step(td)
        assert step == 2, f"expected LATEST=2 after epoch-2 crash, {step}"

        r2 = _make_runner(DeviceRapidGNNRunner, g, schedules, dv, mesh, B)
        like_p = init_params(r2.cfg, jax.random.key(r2.seed))
        like = {"params": like_p, "opt": r2.opt.init(like_p)}
        state, step = load_run_state(td, like)
        rep_tail = r2.run(params=state["params"],
                          opt_state=state["opt"], start_epoch=step)
        assert len(rep_tail) == epochs - step
        resumed = np.concatenate([r.losses for r in rep_tail])
        np.testing.assert_array_equal(
            resumed,
            np.concatenate([r.losses for r in rep_full[step:]]),
            err_msg="crash-resumed loss curve diverges bit-wise")

    # crash BETWEEN the arrays commit and the manifest commit of step 2:
    # LATEST stays on step 1, which must restore bit-intact
    with tempfile.TemporaryDirectory() as td:
        r3 = _make_runner(DeviceRapidGNNRunner, g, schedules, dv, mesh, B,
                          checkpoint_dir=td, checkpoint_every=1)
        try:
            with active_plan(plan_from_profile("ckpt-crash", seed=5)):
                r3.run()
        except InjectedCrash:
            pass
        else:
            raise AssertionError("ckpt-crash plan must kill the commit")
        assert latest_step(td) == 1
        like_p = init_params(r3.cfg, jax.random.key(r3.seed))
        like = {"params": like_p, "opt": r3.opt.init(like_p)}
        state, step = load_run_state(td, like)
        assert step == 1
    print("crash_resume OK")


def check_topology_two_tier():
    """Hierarchical 2-host x 4-device topology end to end (needs 8
    emulated devices): the two-tier runner must (a) keep trace_count 1,
    (b) produce loss curves BIT-equal to the flat-mesh runner on the
    identical schedule (the two-tier exchange + tuple-axis pmean are
    the same math on the same values), (c) split every epoch's miss
    lanes so intra + inter == the flat lane counts elementwise with
    both tiers non-degenerate, and (d) pass host parity."""
    from repro.dist import (DeviceRapidGNNRunner, Topology,
                            assert_host_parity)

    P_, B, epochs = 8, 16, 3
    if jax.device_count() < P_:
        # graceful under the default 4-device harness ("all" mode); the
        # dedicated pytest lane runs this check with 8 devices and
        # asserts the OK line, so a skip can never mask a failure there
        print(f"topology_two_tier SKIPPED (needs {P_} devices, "
              f"have {jax.device_count()})")
        return
    g, pg, schedules, dv, mesh = _runner_setup(P_=P_, B=B, epochs=epochs)
    flat = _make_runner(DeviceRapidGNNRunner, g, schedules, dv, mesh, B)
    rep_f = flat.run()
    assert flat.trace_count == 1

    topo = Topology.hierarchical(2, 4)
    hier = _make_runner(DeviceRapidGNNRunner, g, schedules, dv,
                        topo.make_mesh(), B, topology=topo)
    rep_h = hier.run()
    assert hier.trace_count == 1, \
        f"hierarchical runner traced {hier.trace_count}x"

    # bit-equal curves: same schedule, same values, same full-group
    # collectives -- only the wires differ
    np.testing.assert_array_equal(
        np.concatenate([r.losses for r in rep_f]),
        np.concatenate([r.losses for r in rep_h]),
        err_msg="two-tier loss curve diverges from flat mesh")

    intra_total = inter_total = 0
    for rf, rh in zip(rep_f, rep_h):
        np.testing.assert_array_equal(
            rh.intra_lanes + rh.inter_lanes, rf.miss_lanes,
            err_msg=f"epoch {rf.epoch}: tier split does not sum to the "
                    f"flat lane counts")
        np.testing.assert_array_equal(rh.miss_lanes, rf.miss_lanes)
        intra_total += int(rh.intra_lanes.sum())
        inter_total += int(rh.inter_lanes.sum())
    assert intra_total > 0 and inter_total > 0, \
        f"degenerate tier split: intra={intra_total} inter={inter_total}"
    # per-tier wire rows decompose the padded total
    for rh in rep_h:
        assert rh.intra_wire_rows + rh.inter_wire_rows == rh.wire_rows

    assert_host_parity(schedules, pg, B, rep_h)
    print(f"topology intra_lanes={intra_total} inter_lanes={inter_total}")
    print("topology_two_tier OK")


def check_serve_gnn():
    """Serving lane on 4 emulated devices: one service per worker over
    the SAME partitioned graph, each serving the same request streams.
    Per-worker responses must be bit-equal to that worker's own oracle
    through the tier ladder (uncached -> fresh), and a flaky-pull plan
    must recover bit-equal -- worker-keyed Philox streams mean workers
    sample DIFFERENT subgraphs, so cross-worker equality is not
    expected and not asserted."""
    from repro.fault import active_plan, plan_from_profile
    from repro.graph import load_dataset, partition_graph, KHopSampler
    from repro.graph.sampler import rng_from
    from repro.models import GNNConfig, init_params
    from repro.serve.gnn import GNNInferenceService

    assert jax.device_count() == 4
    g = load_dataset("tiny", seed=0)
    pg = partition_graph(g, 4, "greedy")
    sampler = KHopSampler(g, fanouts=[3, 3], batch_size=4)
    cfg = GNNConfig(kind="sage", in_dim=g.feat_dim, hidden_dim=16,
                    num_classes=g.num_classes, num_layers=2)
    params = init_params(cfg, jax.random.key(0))
    rng = rng_from(13, 0xD157)
    streams = [rng.integers(0, g.num_nodes, size=int(k))
               for k in rng.integers(1, 5, size=6)]

    def serve_round(svc, batch):
        pendings = [svc.submit(s) for s in batch]
        served = 0
        while served < len(pendings):
            served += svc.step(timeout=0.1)
        return [p.result(timeout=5.0) for p in pendings]

    for w in range(4):
        svc = GNNInferenceService(pg, sampler, cfg, params, s0=13,
                                  worker=w, n_hot=32,
                                  default_timeout_s=30.0)
        try:
            for r in serve_round(svc, streams[:3]):      # uncached
                np.testing.assert_array_equal(
                    r.logits, svc.oracle(streams[r.rid], r.rid))
            svc.warmer.warm_now()
            plan = plan_from_profile("serve-pull-flaky", seed=w)
            with active_plan(plan):                      # fresh + faults
                for r in serve_round(svc, streams[3:]):
                    np.testing.assert_array_equal(
                        r.logits, svc.oracle(streams[r.rid], r.rid))
            assert svc.trace_count == 1, svc.trace_count
        finally:
            svc.close()
    print("serve_gnn OK")


def check_moe_expert_parallel():
    from repro.dist import make_mesh
    from repro.models.transformer.common import ArchConfig
    from repro.models.transformer.moe import init_moe_params, moe_apply
    cfg = ArchConfig(name="t", d_model=32, moe=True, num_experts=4,
                     top_k=2, moe_d_ff=16, capacity_factor=4.0,
                     dtype="float32")
    params = init_moe_params(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, 32))
    ref = moe_apply(params, x, cfg, mesh=None)
    mesh = make_mesh((2, 2), ("data", "model"))
    with mesh:
        out = moe_apply(params, x, cfg, mesh=mesh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print("moe_expert_parallel OK")


def check_sharded_decode_attention():
    from repro.dist import make_mesh
    from repro.serve.attention import sharded_decode_attention
    from repro.models.transformer.attention import decode_attention
    rng = np.random.default_rng(3)
    B, S, H, kvH, dh = 4, 64, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(B, 1, H, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, kvH, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, kvH, dh)).astype(np.float32))
    ln = jnp.asarray([10, 33, 64, 50], jnp.int32)
    ref = decode_attention(q, k, v, ln)
    mesh = make_mesh((2, 2), ("data", "model"))
    with mesh:
        out = sharded_decode_attention(mesh, q, k, v, ln)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print("sharded_decode_attention OK")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    checks = {"pull": check_pull_features,
              "epoch": check_pipelined_gnn_epoch,
              "runner": check_device_runner,
              "uneven": check_uneven_workers,
              "determinism": check_determinism,
              "checkpoint": check_checkpoint_resume,
              "overlap": check_overlapped_staging,
              "fault": check_fault_recovery,
              "crashresume": check_crash_resume,
              "topology": check_topology_two_tier,
              "serve": check_serve_gnn,
              "moe": check_moe_expert_parallel,
              "decode": check_sharded_decode_attention}
    if which == "all":
        for fn in checks.values():
            fn()
    else:
        checks[which]()
    print("ALL DIST CHECKS OK")
