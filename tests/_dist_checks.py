"""Multi-device checks, run in a subprocess with 4 host devices
(tests/test_distributed.py sets XLA_FLAGS before python starts)."""
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp


def check_pull_features():
    from repro.dist import make_mesh, build_pull_plan, pull_features
    P_, n_per, d, m_max, k_max = 4, 16, 8, 12, 6
    mesh = make_mesh((4,), ("data",))
    rng = np.random.default_rng(0)
    table_global = rng.normal(size=(P_ * n_per, d)).astype(np.float32)
    owner = np.repeat(np.arange(P_), n_per)
    plans, want = [], []
    for w in range(P_):
        ids = rng.choice(P_ * n_per, size=m_max, replace=False)
        pos = np.arange(m_max)
        plans.append(build_pull_plan(ids.astype(np.int32),
                                     pos.astype(np.int32), owner, P_,
                                     k_max))
        exp = np.zeros((m_max, d), np.float32)
        exp[pos] = table_global[ids]
        want.append(exp)
    with mesh:
        out = pull_features(
            mesh, jnp.asarray(table_global.reshape(P_, n_per, d)),
            jnp.asarray(np.stack([p.send_ids for p in plans])),
            jnp.asarray(np.stack([p.send_pos for p in plans])),
            jnp.asarray(np.stack([p.send_mask for p in plans])),
            jnp.asarray((np.arange(P_) * n_per).astype(np.int32)), m_max)
    np.testing.assert_allclose(np.asarray(out), np.stack(want), rtol=1e-6)
    print("pull_features OK")


def check_pipelined_gnn_epoch():
    from repro.graph import load_dataset, partition_graph, KHopSampler
    from repro.core import build_schedule
    from repro.core.schedule import epoch_edge_maxima
    from repro.dist import (make_mesh, DeviceView, epoch_k_max,
                            collate_device_epoch, stack_caches,
                            make_pipelined_epoch)
    from repro.models import GNNConfig, init_params
    from repro.train import AdamW

    P_, n_hot, B = 4, 64, 16
    g = load_dataset("tiny")
    pg = partition_graph(g, P_, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=B)
    schedules = [build_schedule(sampler, pg, worker=w, s0=7,
                                num_epochs=1, n_hot=n_hot)
                 for w in range(P_)]
    dv = DeviceView.build(pg)
    es_list = [ws.epoch(0) for ws in schedules]
    m_max = max(es.m_max for es in es_list)
    edge_max = None
    for es in es_list:
        em = epoch_edge_maxima(es)
        edge_max = em if edge_max is None else [max(a, b) for a, b
                                                in zip(edge_max, em)]
    caches = [dv.remap_cache(es.cache_ids) for es in es_list]
    S = min(es.num_batches for es in es_list)
    k_max = epoch_k_max(es_list, caches, dv, g.labels, B, m_max, edge_max)
    batches = collate_device_epoch(es_list, caches, dv, g.labels, B,
                                   m_max, edge_max, k_max, S)
    cids, cfeats = stack_caches(caches, dv, n_hot)

    mesh = make_mesh((P_,), ("data",))
    cfg = GNNConfig(kind="sage", in_dim=g.feat_dim, hidden_dim=32,
                    num_classes=g.num_classes, num_layers=2)
    params = init_params(cfg, jax.random.key(0))
    opt = AdamW(lr=3e-3)
    epoch_fn = make_pipelined_epoch(cfg, opt, mesh, m_max)
    with mesh:
        _, _, losses, _ = epoch_fn(
            params, opt.init(params), jnp.asarray(dv.table),
            jnp.asarray(dv.offsets), jnp.asarray(cids),
            jnp.asarray(cfeats), jax.tree.map(jnp.asarray, batches))
        losses = np.asarray(losses)
    assert not np.isnan(losses).any()
    assert losses[-1] < losses[0]
    print("pipelined_gnn_epoch OK")


def check_moe_expert_parallel():
    from repro.dist import make_mesh
    from repro.models.transformer.common import ArchConfig
    from repro.models.transformer.moe import init_moe_params, moe_apply
    cfg = ArchConfig(name="t", d_model=32, moe=True, num_experts=4,
                     top_k=2, moe_d_ff=16, capacity_factor=4.0,
                     dtype="float32")
    params = init_moe_params(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, 32))
    ref = moe_apply(params, x, cfg, mesh=None)
    mesh = make_mesh((2, 2), ("data", "model"))
    with mesh:
        out = moe_apply(params, x, cfg, mesh=mesh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print("moe_expert_parallel OK")


def check_sharded_decode_attention():
    from repro.dist import make_mesh
    from repro.serve.attention import sharded_decode_attention
    from repro.models.transformer.attention import decode_attention
    rng = np.random.default_rng(3)
    B, S, H, kvH, dh = 4, 64, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(B, 1, H, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, kvH, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, kvH, dh)).astype(np.float32))
    ln = jnp.asarray([10, 33, 64, 50], jnp.int32)
    ref = decode_attention(q, k, v, ln)
    mesh = make_mesh((2, 2), ("data", "model"))
    with mesh:
        out = sharded_decode_attention(mesh, q, k, v, ln)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print("sharded_decode_attention OK")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    checks = {"pull": check_pull_features,
              "epoch": check_pipelined_gnn_epoch,
              "moe": check_moe_expert_parallel,
              "decode": check_sharded_decode_attention}
    if which == "all":
        for fn in checks.values():
            fn()
    else:
        checks[which]()
    print("ALL DIST CHECKS OK")
