"""Per-architecture smoke tests (assignment deliverable f).

Each assigned arch gets a REDUCED variant of the same family (<=2-3
layers, d_model <= 512, <= 4 experts) running one forward + one train
step on CPU, asserting output shapes and no NaNs. Decode smoke asserts
cache-consistency with the parallel forward where the family supports it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow      # compiles every arch end-to-end

from repro.configs import ARCH_NAMES, get_reduced
from repro.models.transformer import (init_params, forward, encode,
                                      lm_loss, init_decode_state,
                                      serve_step)
from repro.train.optim import AdamW

B, S = 2, 16


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1),
             "loss_mask": jnp.ones((B, S), jnp.float32)}
    if cfg.mrope_sections:
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S))
    if cfg.frontend == "vision":
        batch["embeds"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 1), (B, S, cfg.d_model))
    if cfg.kind == "encdec":
        batch["enc_embeds"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 2), (B, S, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch)
    assert cfg.d_model <= 512 and cfg.num_layers <= 3
    if cfg.moe:
        assert cfg.num_experts <= 4
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    enc_out = (encode(cfg, params, batch["enc_embeds"])
               if cfg.kind == "encdec" else None)
    logits = forward(cfg, params, batch["tokens"],
                     mrope_positions=batch.get("mrope_positions"),
                     embeds=batch.get("embeds"), enc_out=enc_out)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_train_step(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.key(0))
    opt = AdamW(lr=1e-3, max_grad_norm=1.0)
    opt_state = opt.init(params)
    batch = _batch(cfg, jax.random.key(1))

    @jax.jit
    def step(p, o, b):
        (loss, _), grads = jax.value_and_grad(
            lambda pp: lm_loss(cfg, pp, b), has_aux=True)(p)
        p2, o2 = opt.update(grads, o, p)
        return p2, o2, loss

    p2, o2, loss = step(params, opt_state, batch)
    assert bool(jnp.isfinite(loss))
    # params actually changed
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b2: float(jnp.abs(a.astype(jnp.float32)
                                    - b2.astype(jnp.float32)).max()),
        params, p2))
    assert max(delta) > 0.0


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if a != "seamless-m4t-medium"])
def test_reduced_decode_matches_forward(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    # text-only comparison (frontend embeds are a prefill-time input)
    logits = forward(cfg, params, batch["tokens"],
                     mrope_positions=batch.get("mrope_positions"))
    st = init_decode_state(cfg, B, max_len=S)
    outs = []
    for t in range(S):
        mp = (batch["mrope_positions"][:, :, t:t + 1]
              if cfg.mrope_sections else None)
        lg, st = serve_step(cfg, params, st, batch["tokens"][:, t:t + 1],
                            jnp.full((B,), t, jnp.int32),
                            mrope_positions=mp)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - logits)))
    assert err < 5e-2, err


def test_encdec_decode_runs():
    cfg = get_reduced("seamless-m4t-medium")
    params = init_params(cfg, jax.random.key(0))
    st = init_decode_state(cfg, B, max_len=S, src_len=8)
    lg, st2 = serve_step(cfg, params, st,
                         jnp.zeros((B, 1), jnp.int32),
                         jnp.zeros((B,), jnp.int32))
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all())
