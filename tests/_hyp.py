"""Hypothesis compatibility shim for the property tests.

When the real ``hypothesis`` package is installed (requirements-dev.txt)
it is re-exported unchanged. When it is missing -- minimal CI images,
air-gapped runners -- a deterministic fallback provides just the subset
the suite uses (``@given`` + ``@settings`` + ``composite`` with
``st.integers`` / ``st.floats`` / ``st.booleans`` / ``st.sampled_from``
/ ``st.just`` / ``st.lists`` / ``st.tuples``): each property test runs
``max_examples`` times against a fixed-seed RNG stream, so the suite
still collects and exercises the properties everywhere, only with fixed
rather than adversarial examples.

``tests/strategies.py`` layers the repo's domain strategies
(partitioned graphs, uneven worker schedules, cache budgets, assembly
query mixes, pull-request multisets) on top of this shim.
"""
from __future__ import annotations

try:
    from hypothesis import HealthCheck, given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    from hypothesis.strategies import composite  # noqa: F401
    HAVE_HYPOTHESIS = True
    #: pass as ``settings(..., suppress_health_check=ALL_HEALTH_CHECKS)``
    #: for properties whose strategies do real work (schedule builders):
    #: the draw IS the scenario construction, so "too slow" is expected.
    ALL_HEALTH_CHECKS = list(HealthCheck)
except ImportError:
    HAVE_HYPOTHESIS = False
    ALL_HEALTH_CHECKS = ()          # shim ignores the kwarg anyway

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(
                lambda rng: items[int(rng.integers(0, len(items)))])

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def _draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.draw(rng) for _ in range(n)]
            return _Strategy(_draw)

        @staticmethod
        def tuples(*elements):
            return _Strategy(
                lambda rng: tuple(e.draw(rng) for e in elements))

    st = _Strategies()

    def composite(fn):
        """Shim for ``hypothesis.strategies.composite``: the decorated
        function takes ``draw`` first; calling it (with any extra args)
        yields a strategy whose draw threads the shared RNG through."""
        def make(*args, **kwargs):
            return _Strategy(
                lambda rng: fn(lambda s: s.draw(rng), *args, **kwargs))
        make.__name__ = fn.__name__
        return make

    def given(*strategies):
        def deco(fn):
            # NOTE: no functools.wraps -- pytest must see the zero-arg
            # signature (the drawn values are not fixtures).
            def wrapper():
                rng = np.random.default_rng(0)
                for _ in range(getattr(wrapper, "_max_examples", 10)):
                    fn(*[s.draw(rng) for s in strategies])
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._hyp_fallback = True
            return wrapper
        return deco

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            if getattr(fn, "_hyp_fallback", False):
                fn._max_examples = max_examples
            return fn
        return deco
