"""Hypothesis compatibility shim for the property tests.

When the real ``hypothesis`` package is installed (requirements-dev.txt)
it is re-exported unchanged. When it is missing -- minimal CI images,
air-gapped runners -- a deterministic fallback provides just the subset
the suite uses (``@given`` + ``@settings`` with ``st.integers`` /
``st.floats``): each property test runs ``max_examples`` times against a
fixed-seed RNG stream, so the suite still collects and exercises the
properties everywhere, only with fixed rather than adversarial examples.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    st = _Strategies()

    def given(*strategies):
        def deco(fn):
            # NOTE: no functools.wraps -- pytest must see the zero-arg
            # signature (the drawn values are not fixtures).
            def wrapper():
                rng = np.random.default_rng(0)
                for _ in range(getattr(wrapper, "_max_examples", 10)):
                    fn(*[s.draw(rng) for s in strategies])
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._hyp_fallback = True
            return wrapper
        return deco

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            if getattr(fn, "_hyp_fallback", False):
                fn._max_examples = max_examples
            return fn
        return deco
