"""Transformer substrate unit + property tests: attention chunking
equivalence, SSD vs naive recurrence, RG-LRU scan vs sequential, MoE
routing invariants, RoPE properties."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.models.transformer.attention import attention
from repro.models.transformer.common import (apply_rope, apply_mrope,
                                             rms_norm, softcap, ArchConfig)
from repro.models.transformer.ssm import ssd_scan
from repro.models.transformer.rglru import (init_rglru_params, rglru_scan,
                                            _gates)
from repro.models.transformer.moe import init_moe_params, moe_local, capacity


def _naive_attention(q, k, v, causal=True, window=0, cap=0.0):
    B, Sq, H, dh = q.shape
    kvH = k.shape[2]
    G = H // kvH
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q * dh ** -0.5, kk)
    s = softcap(s, cap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    valid = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        valid &= kpos <= qpos
    if window > 0:
        valid &= kpos > qpos - window
    s = jnp.where(valid[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("Sq,q_chunk,kv_chunk", [
    (64, 16, 32), (64, 64, 64), (128, 32, 16)])
@pytest.mark.parametrize("H,kvH", [(4, 2), (8, 1), (4, 4)])
def test_chunked_attention_matches_naive(Sq, q_chunk, kv_chunk, H, kvH):
    rng = np.random.default_rng(Sq + H)
    B, dh = 2, 16
    q = jnp.asarray(rng.normal(size=(B, Sq, H, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Sq, kvH, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Sq, kvH, dh)).astype(np.float32))
    out = attention(q, k, v, causal=True, q_chunk=q_chunk,
                    kv_chunk=kv_chunk)
    ref = _naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [8, 16, 64])
def test_banded_attention_matches_naive(window):
    rng = np.random.default_rng(window)
    B, Sq, H, kvH, dh = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, Sq, H, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Sq, kvH, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Sq, kvH, dh)).astype(np.float32))
    out = attention(q, k, v, causal=True, window=window, q_chunk=16)
    ref = _naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_attention_softcap():
    rng = np.random.default_rng(1)
    B, Sq, H, kvH, dh = 1, 32, 2, 2, 16
    q = jnp.asarray(rng.normal(size=(B, Sq, H, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Sq, kvH, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Sq, kvH, dh)).astype(np.float32))
    out = attention(q, k, v, attn_softcap=5.0, q_chunk=8, kv_chunk=8)
    ref = _naive_attention(q, k, v, cap=5.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_matches_naive_recurrence():
    rng = np.random.default_rng(3)
    b, S, h, p, n = 2, 32, 3, 8, 4
    x = jnp.asarray(rng.normal(size=(b, S, h, p)).astype(np.float32))
    dA = jnp.asarray(
        -np.abs(rng.normal(size=(b, S, h))).astype(np.float32) * 0.5)
    B_ = jnp.asarray(rng.normal(size=(b, S, n)).astype(np.float32))
    C_ = jnp.asarray(rng.normal(size=(b, S, n)).astype(np.float32))
    st_ = np.zeros((b, h, p, n), np.float32)
    ys = []
    for t in range(S):
        da = np.exp(np.asarray(dA[:, t]))
        st_ = st_ * da[..., None, None] + np.einsum(
            "bhp,bn->bhpn", np.asarray(x[:, t]), np.asarray(B_[:, t]))
        ys.append(np.einsum("bhpn,bn->bhp", st_, np.asarray(C_[:, t])))
    y_naive = np.stack(ys, axis=1)
    for chunk in (4, 8, 16, 32):
        y, fin = ssd_scan(x, dA, B_, C_, chunk)
        np.testing.assert_allclose(np.asarray(y), y_naive, rtol=2e-4,
                                   atol=2e-4)
    np.testing.assert_allclose(np.asarray(fin), st_, rtol=2e-4, atol=2e-4)


def test_rglru_associative_scan_matches_sequential():
    cfg = ArchConfig(name="t", d_model=16, lru_width=16, dtype="float32")
    params = init_rglru_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(4)
    u = jnp.asarray(rng.normal(size=(2, 24, 16)).astype(np.float32))
    h, last = rglru_scan(params, u)
    a, b = _gates(params, u)
    hs = np.zeros((2, 16), np.float32)
    for t in range(24):
        hs = np.asarray(a[:, t]) * hs + np.asarray(b[:, t])
        np.testing.assert_allclose(np.asarray(h[:, t]), hs, rtol=2e-4,
                                   atol=2e-4)
    np.testing.assert_allclose(np.asarray(last), hs, rtol=2e-4, atol=2e-4)


def test_moe_partial_sums_equal_full():
    """Expert-parallel invariant: sum of per-shard partial outputs ==
    full local MoE (the psum identity)."""
    cfg = ArchConfig(name="t", d_model=16, moe=True, num_experts=8,
                     top_k=2, moe_d_ff=8, capacity_factor=4.0,
                     dtype="float32")
    params = init_moe_params(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (12, 16))
    full = moe_local(params, x, cfg, 0, 8)
    parts = []
    for off in (0, 4):
        sliced = dict(params)
        sliced["w1"] = params["w1"][off:off + 4]
        sliced["w2"] = params["w2"][off:off + 4]
        sliced["w3"] = params["w3"][off:off + 4]
        parts.append(moe_local(sliced, x, cfg, off, 4,
                               cap=capacity(cfg, 12)))
    np.testing.assert_allclose(np.asarray(parts[0] + parts[1]),
                               np.asarray(full), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_lowest_priority():
    cfg = ArchConfig(name="t", d_model=8, moe=True, num_experts=2,
                     top_k=1, moe_d_ff=4, capacity_factor=0.5,
                     dtype="float32")
    params = init_moe_params(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (8, 8))
    out = moe_local(params, x, cfg, 0, 2)        # tiny capacity
    assert bool(jnp.isfinite(out).all())


def test_rope_preserves_norm_and_relative_phase():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 16)).astype(np.float32))
    pos = jnp.arange(8)[None]
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))
    dots = []
    for p in (0, 5):
        qr = apply_rope(q, jnp.array([[p]]), 1e4)
        kr = apply_rope(k, jnp.array([[p + 3]]), 1e4)
        dots.append(float(jnp.sum(qr * kr)))
    assert abs(dots[0] - dots[1]) < 1e-4


def test_mrope_equals_rope_when_streams_equal():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(2, 8, 2, 16)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(8)[None, None], (3, 2, 8))
    a = apply_mrope(x, pos, 1e4, (2, 3, 3))
    b = apply_rope(x, pos[0], 1e4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.floats(1.0, 100.0))
def test_softcap_bounded(n, cap):
    x = jnp.linspace(-1e4, 1e4, n)
    y = softcap(x, cap)
    assert bool(jnp.all(jnp.abs(y) <= cap + 1e-3))


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 32))
def test_rms_norm_unit_scale(d):
    x = jnp.asarray(np.random.default_rng(d).normal(size=(4, d)) * 100,
                    jnp.float32)
    y = rms_norm(x, jnp.zeros(d))
    rms = jnp.sqrt(jnp.mean(jnp.square(y), -1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)
