"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp
oracle, swept over shapes and dtypes (assignment deliverable c)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.gather_agg.ops import gather_agg
from repro.kernels.cache_lookup.ops import cache_lookup
from repro.kernels.flash_decode.ops import flash_decode, flash_decode_batched
from repro.kernels.flash_decode.ref import finalize, combine


@pytest.mark.parametrize("nd,fanout,m,d", [
    (8, 4, 32, 128), (16, 10, 64, 128), (32, 25, 200, 256),
    (4, 3, 16, 384),
])
@pytest.mark.parametrize("dtype", [np.float32])
def test_gather_agg_sweep(nd, fanout, m, d, dtype):
    rng = np.random.default_rng(nd * fanout)
    h = jnp.asarray(rng.normal(size=(m, d)).astype(dtype))
    src = jnp.asarray(rng.integers(0, m, size=nd * fanout).astype(np.int32))
    mask = jnp.asarray(rng.random(nd * fanout) > 0.25)
    ref = gather_agg(h, src, mask, nd=nd, fanout=fanout, use_kernel=False)
    ker = gather_agg(h, src, mask, nd=nd, fanout=fanout, use_kernel=True,
                     interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_gather_agg_zero_degree_rows():
    """Rows whose every edge is masked must aggregate to exactly 0."""
    m, d, nd, fanout = 16, 128, 4, 3
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    src = jnp.zeros(nd * fanout, jnp.int32)
    mask = np.ones(nd * fanout, bool)
    mask[:fanout] = False                     # dst 0 fully masked
    out = gather_agg(h, src, jnp.asarray(mask), nd=nd, fanout=fanout,
                     use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out)[0], 0.0)


@pytest.mark.parametrize("n_hot,m,d", [
    (256, 128, 128), (1024, 256, 128), (2048, 512, 256),
])
def test_cache_lookup_sweep(n_hot, m, d):
    rng = np.random.default_rng(n_hot)
    ids = np.sort(rng.choice(10 ** 6, size=n_hot,
                             replace=False)).astype(np.int32)
    feats = rng.normal(size=(n_hot, d)).astype(np.float32)
    q = np.concatenate([
        rng.choice(ids, size=m // 2),
        rng.integers(10 ** 6, 2 * 10 ** 6, size=m // 2 - 4),
        np.full(4, -1)]).astype(np.int32)
    rng.shuffle(q)
    base = rng.normal(size=(m, d)).astype(np.float32)
    args = (jnp.asarray(ids), jnp.asarray(feats), jnp.asarray(q),
            jnp.asarray(base))
    ref, hit_r = cache_lookup(*args, use_kernel=False)
    ker, hit_k = cache_lookup(*args, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(hit_r), np.asarray(hit_k))
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker), rtol=1e-6)


@pytest.mark.parametrize("n_hot,m,d", [
    (1000, 130, 129),    # nothing divides the (256, 1024, 128) tiles
    (7, 3, 5),           # everything smaller than one tile
    (0, 17, 128),        # empty cache
    (33, 257, 384),
])
def test_cache_lookup_awkward_shapes(n_hot, m, d):
    """Regression: ``search`` asserted m % tq == 0 / n_hot % tc == 0 and
    ``merge_gather`` asserted d % d_tile == 0 -- an awkward batch size
    crashed the compiled epoch. Internal padding must make any shape
    agree with the oracle."""
    rng = np.random.default_rng(n_hot + m)
    ids = np.sort(rng.choice(10 ** 6, size=n_hot,
                             replace=False)).astype(np.int32)
    pool = ids if n_hot else np.array([5], np.int32)
    q = np.concatenate([
        rng.choice(pool, size=m // 2),
        rng.integers(10 ** 6, 2 * 10 ** 6, size=m - m // 2)]
    ).astype(np.int32)
    feats = rng.normal(size=(n_hot, d)).astype(np.float32)
    base = rng.normal(size=(m, d)).astype(np.float32)
    args = (jnp.asarray(ids), jnp.asarray(feats), jnp.asarray(q),
            jnp.asarray(base))
    ref, hit_r = cache_lookup(*args, use_kernel=False)
    ker, hit_k = cache_lookup(*args, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(hit_r), np.asarray(hit_k))
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker))


def test_cache_lookup_sentinel_query_never_hits_padded_tail():
    """Regression: internal n_hot padding appends INT32_MAX sentinel
    entries; a sentinel-valued query must NOT match them (kernel and
    oracle must agree the sentinel never hits)."""
    n_hot, m, d = 1500, 8, 16          # 1500 % tc != 0 -> padded tail
    rng = np.random.default_rng(1)
    ids = np.sort(rng.choice(10 ** 6, size=n_hot,
                             replace=False)).astype(np.int32)
    feats = rng.normal(size=(n_hot, d)).astype(np.float32)
    q = np.full(m, 2 ** 31 - 1, np.int32)
    q[0] = ids[3]                      # one real hit for contrast
    base = np.zeros((m, d), np.float32)
    args = (jnp.asarray(ids), jnp.asarray(feats), jnp.asarray(q),
            jnp.asarray(base))
    ref, hit_r = cache_lookup(*args, use_kernel=False)
    ker, hit_k = cache_lookup(*args, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(hit_k), np.asarray(hit_r))
    assert bool(hit_k[0]) and not np.asarray(hit_k)[1:].any()
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref))


def test_gather_agg_awkward_feature_dim():
    """Regression: d % d_tile assert -> internal padding."""
    rng = np.random.default_rng(77)
    nd, fanout, m, d = 7, 3, 40, 129
    h = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    src = jnp.asarray(rng.integers(0, m, size=nd * fanout
                                   ).astype(np.int32))
    mask = jnp.asarray(rng.random(nd * fanout) > 0.3)
    ref = gather_agg(h, src, mask, nd=nd, fanout=fanout, use_kernel=False)
    ker = gather_agg(h, src, mask, nd=nd, fanout=fanout, use_kernel=True,
                     interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_cache_lookup_empty_and_full_hit():
    d, m = 128, 256
    rng = np.random.default_rng(5)
    ids = np.arange(0, 4096, 4, dtype=np.int32)      # 1024 entries
    feats = rng.normal(size=(ids.size, d)).astype(np.float32)
    base = np.zeros((m, d), np.float32)
    q_all_hit = jnp.asarray(np.repeat(ids[:m // 4], 4)[:m])
    out, hit = cache_lookup(jnp.asarray(ids), jnp.asarray(feats),
                            q_all_hit, jnp.asarray(base),
                            use_kernel=True, interpret=True)
    assert bool(hit.all())
    q_no_hit = jnp.asarray((ids[:m] + 1).astype(np.int32))
    out, hit = cache_lookup(jnp.asarray(ids), jnp.asarray(feats),
                            q_no_hit, jnp.asarray(base),
                            use_kernel=True, interpret=True)
    assert not bool(hit.any())
    np.testing.assert_allclose(np.asarray(out), base)


@pytest.mark.parametrize("H,kvH,dh,S", [
    (8, 2, 64, 512), (4, 4, 128, 1024), (16, 1, 64, 2048),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(H, kvH, dh, S, dtype):
    rng = np.random.default_rng(H * S)
    q = jnp.asarray(rng.normal(size=(H, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(S, kvH, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(S, kvH, dh)), dtype)
    ln = jnp.asarray(S * 3 // 4, jnp.int32)
    ref = flash_decode(q.astype(jnp.float32), k.astype(jnp.float32),
                       v.astype(jnp.float32), ln, use_kernel=False)
    ker = flash_decode(q.astype(jnp.float32), k.astype(jnp.float32),
                       v.astype(jnp.float32), ln, use_kernel=True,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(finalize(ker[0], ker[2])),
                               np.asarray(finalize(ref[0], ref[2])),
                               rtol=3e-5, atol=3e-5)


def test_flash_decode_shard_combine_invariance():
    """Partial (acc,m,l) combined over sequence shards == full attention;
    this is the correctness basis of the seq-sharded KV cache."""
    rng = np.random.default_rng(9)
    H, kvH, dh, S = 8, 2, 64, 1024
    q = jnp.asarray(rng.normal(size=(H, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(S, kvH, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(S, kvH, dh)).astype(np.float32))
    ln = jnp.asarray(777, jnp.int32)
    full = flash_decode(q, k, v, ln, use_kernel=False)
    want = np.asarray(finalize(full[0], full[2]))
    for shards in (2, 4, 8):
        step = S // shards
        parts = []
        for i in range(shards):
            lnl = jnp.clip(ln - i * step, 0, step)
            parts.append(flash_decode(q, k[i * step:(i + 1) * step],
                                      v[i * step:(i + 1) * step], lnl,
                                      use_kernel=False))
        acc, m, l = combine(parts)
        np.testing.assert_allclose(np.asarray(finalize(acc, l)), want,
                                   rtol=3e-5, atol=3e-5)


def test_flash_decode_softcap_and_window():
    rng = np.random.default_rng(11)
    H, kvH, dh, S = 4, 2, 64, 512
    q = jnp.asarray(rng.normal(size=(H, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(S, kvH, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(S, kvH, dh)).astype(np.float32))
    ln = jnp.asarray(400, jnp.int32)
    st = jnp.asarray(150, jnp.int32)
    ref = flash_decode(q, k, v, ln, st, softcap=30.0, use_kernel=False)
    ker = flash_decode(q, k, v, ln, st, softcap=30.0, use_kernel=True,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(finalize(ker[0], ker[2])),
                               np.asarray(finalize(ref[0], ref[2])),
                               rtol=3e-5, atol=3e-5)
