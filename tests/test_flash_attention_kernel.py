"""Training flash-attention Pallas kernel vs oracle, shape/dtype sweep."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import flash_attention


@pytest.mark.parametrize("B,S,H,kvH,dh", [
    (1, 128, 4, 2, 64), (2, 256, 8, 1, 64), (2, 128, 4, 4, 128),
])
def test_flash_attention_sweep(B, S, H, kvH, dh):
    rng = np.random.default_rng(S + H)
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, kvH, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, kvH, dh)).astype(np.float32))
    ref = flash_attention(q, k, v, use_kernel=False)
    ker = flash_attention(q, k, v, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("kw", [
    dict(causal=True, softcap=30.0),
    dict(causal=True, window=64),
    dict(causal=False),
])
def test_flash_attention_variants(kw):
    rng = np.random.default_rng(7)
    B, S, H, kvH, dh = 2, 256, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, kvH, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, kvH, dh)).astype(np.float32))
    ref = flash_attention(q, k, v, use_kernel=False, **kw)
    ker = flash_attention(q, k, v, use_kernel=True, interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_flash_attention_bf16_inputs():
    rng = np.random.default_rng(9)
    B, S, H, kvH, dh = 1, 128, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, S, kvH, dh)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, S, kvH, dh)), jnp.bfloat16)
    ref = flash_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), use_kernel=False)
    ker = flash_attention(q, k, v, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(ker).astype(np.float32),
                               np.asarray(ref), rtol=2e-2, atol=2e-2)
