"""Host-side (single-device) tests for the multi-epoch device runner's
collation layer: uneven-worker padding, empty-epoch pad metadata, and the
global static bounds the one-compilation property rests on. The on-mesh
runner itself is exercised by tests/test_distributed.py on 4 emulated
devices."""
import numpy as np
import pytest

from _uneven import build_uneven_case
from repro.core import merge_pad_bounds
from repro.core.schedule import epoch_edge_maxima
from repro.dist import collate_device_epoch, empty_caches, epoch_k_max


@pytest.fixture(scope="module")
def uneven():
    """4 partitions; worker 2 keeps NO train nodes, worker 3 half a batch."""
    return build_uneven_case(P_=4, B=16, epochs=2, n_hot=64)


def test_uneven_schedule_shapes(uneven):
    g, pg, schedules, dv = uneven
    assert schedules[2].epoch(0).num_batches == 0
    assert 0 < schedules[3].epoch(0).num_batches < \
        schedules[0].epoch(0).num_batches


def test_epoch_edge_maxima_empty_epoch(uneven):
    """Regression: es.batches[0] indexed unconditionally -> IndexError."""
    g, pg, schedules, dv = uneven
    es = schedules[2].epoch(0)
    assert es.num_batches == 0
    # layer count now rides the FlatEpoch layout, so the empty epoch
    # reports all-zero maxima even without the num_layers hint
    assert epoch_edge_maxima(es) == [0, 0]
    assert epoch_edge_maxima(es, num_layers=2) == [0, 0]
    es0 = schedules[0].epoch(0)
    assert all(e > 0 for e in epoch_edge_maxima(es0))


def test_pad_bounds_survive_empty_epochs(uneven):
    """An all-empty worker must report zero bounds without collapsing the
    layer list, and populated workers keep real bounds."""
    g, pg, schedules, dv = uneven
    m2, em2 = schedules[2].pad_bounds()
    assert m2 == 0 and all(e == 0 for e in em2)
    m0, em0 = schedules[0].pad_bounds()
    assert m0 > 0 and len(em0) == 2 and all(e > 0 for e in em0)


def test_collate_pads_short_workers_with_masked_steps(uneven):
    """Regression: es.batches[i] indexed for all num_steps -> IndexError
    for short/zero-batch workers. Tail steps must be fully masked."""
    g, pg, schedules, dv = uneven
    m_max, edge_max = merge_pad_bounds(schedules)
    es_list = [ws.epoch(0) for ws in schedules]
    caches = [dv.remap_cache(es.cache_ids) for es in es_list]
    S = max(es.num_batches for es in es_list)
    k_max = epoch_k_max(es_list, caches, dv)
    out = collate_device_epoch(es_list, caches, dv, g.labels, 16, m_max,
                               edge_max, k_max, S)
    nb3 = es_list[3].num_batches
    # worker 2: every step empty; worker 3: tail beyond its batches empty
    for w, lo in ((2, 0), (3, nb3)):
        assert (out["input_nodes"][lo:, w] == -1).all()
        assert not out["seed_mask"][lo:, w].any()
        assert not out["send_mask"][lo:, w].any()
        for l in range(len(edge_max)):
            assert not out["edge_mask"][l][lo:, w].any()
    # populated worker keeps real content
    assert (out["input_nodes"][0, 0] >= 0).any()
    assert out["send_mask"][:, 0].sum() > 0


def test_collate_rejects_truncating_num_steps(uneven):
    g, pg, schedules, dv = uneven
    m_max, edge_max = merge_pad_bounds(schedules)
    es_list = [ws.epoch(0) for ws in schedules]
    caches = [dv.remap_cache(es.cache_ids) for es in es_list]
    S = max(es.num_batches for es in es_list)
    with pytest.raises(ValueError, match="more batches"):
        collate_device_epoch(es_list, caches, dv, g.labels, 16, m_max,
                             edge_max, 10_000, S - 1)


def test_empty_caches_route_everything_through_lanes(uneven):
    """Baseline collation key: with empty C_s every remote id is a miss,
    so lane counts equal the per-batch unique remote counts."""
    g, pg, schedules, dv = uneven
    m_max, edge_max = merge_pad_bounds(schedules)
    es_list = [ws.epoch(0) for ws in schedules]
    nocache = empty_caches(4, g.feat_dim)
    k_max = epoch_k_max(es_list, nocache, dv)
    out = collate_device_epoch(es_list, nocache, dv, g.labels, 16, m_max,
                               edge_max, k_max,
                               max(es.num_batches for es in es_list))
    for w, es in enumerate(es_list):
        want = sum(int((pg.owner[b.input_nodes] != w).sum())
                   for b in es.batches)
        got = int(out["send_mask"][:, w].sum())
        assert got == want
