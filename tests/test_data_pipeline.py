"""Data pipeline + hot-token embedding cache tests (paper technique on
the transformer side)."""
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data.pipeline import (zipf_tokens, make_batch,
                                 synthetic_lm_batches,
                                 enumerate_token_accesses)
from repro.graph.sampler import rng_from
from repro.models.transformer.embedding import HotEmbeddingSim


def test_zipf_long_tail():
    rng = np.random.default_rng(0)
    toks = zipf_tokens(rng, 10_000, (100_000,))
    counts = np.bincount(toks, minlength=10_000)
    top = np.sort(counts)[::-1]
    assert top[:100].sum() > 0.35 * counts.sum()    # head-heavy
    assert (counts == 0).sum() > 50                 # long tail untouched


def test_deterministic_batches():
    cfg = get_reduced("granite-3-2b")
    a = list(synthetic_lm_batches(cfg, 2, 16, 3, s0=5))
    b = list(synthetic_lm_batches(cfg, 2, 16, 3, s0=5))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x["tokens"]),
                                      np.asarray(y["tokens"]))
    c = list(synthetic_lm_batches(cfg, 2, 16, 1, s0=6))
    assert not np.array_equal(np.asarray(a[0]["tokens"]),
                              np.asarray(c[0]["tokens"]))


def test_offline_enumeration_matches_runtime():
    """Alg. 1 lines 1-3 on tokens: offline counts == actual accesses."""
    cfg = get_reduced("smollm-360m")
    counts = enumerate_token_accesses(cfg, 2, 32, 4, s0=9)
    runtime = np.zeros(cfg.vocab_size, np.int64)
    for i in range(4):
        toks = zipf_tokens(rng_from(9, 0, i), cfg.vocab_size, (2, 32))
        runtime += np.bincount(toks.reshape(-1),
                               minlength=cfg.vocab_size)
    np.testing.assert_array_equal(counts, runtime)


def test_hot_embedding_cache_invariants():
    counts = np.zeros(1000, np.int64)
    counts[:50] = 1000          # hot head
    counts[50:200] = 3
    sim = HotEmbeddingSim(vocab=1000, d=8, num_workers=4, n_hot=64,
                          counts=counts)
    # caches only hold remote ids
    for w in range(4):
        assert np.all(sim.owner[sim.cache[w]] != w)
    # hot head ids (remote ones) always cached
    hot_remote = [t for t in range(50) if sim.owner[t] != 0]
    assert np.isin(hot_remote, sim.cache[0]).all()
    # cached traffic <= baseline, always
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 1000, size=(4, 64))
    b, c, h = sim.batch_traffic(toks, worker=0)
    assert c <= b
    assert h >= 0


def test_make_batch_shapes_all_families():
    for arch in ("qwen2-vl-72b", "seamless-m4t-medium", "mamba2-1.3b"):
        cfg = get_reduced(arch)
        batch = make_batch(cfg, np.random.default_rng(0), 2, 16)
        assert batch["tokens"].shape == (2, 16)
        if cfg.frontend == "vision":
            assert batch["embeds"].shape == (2, 16, cfg.d_model)
            assert batch["mrope_positions"].shape == (3, 2, 16)
        if cfg.kind == "encdec":
            assert batch["enc_embeds"].shape == (2, 16, cfg.d_model)
