"""Regression guard: every assigned architecture config matches the
assignment sheet EXACTLY (layer counts, dims, heads, vocab, family
features)."""
import pytest

from repro.configs import ARCH_NAMES, INPUT_SHAPES, SUBQUADRATIC, get_arch

# (layers, d_model, heads, kv, d_ff, vocab)
SPEC = {
    "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
    "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
    "smollm-360m": (32, 960, 15, 5, 2560, 49152),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
    "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
    "mamba2-1.3b": (48, 2048, 64, 64, 0, 50280),
    "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
    "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
    "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_assigned_dimensions_exact(arch):
    cfg = get_arch(arch)
    L, d, h, kv, ff, v = SPEC[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_family_features():
    assert get_arch("seamless-m4t-medium").kind == "encdec"
    assert get_arch("qwen1.5-32b").qkv_bias
    assert get_arch("qwen3-moe-30b-a3b").num_experts == 128
    assert get_arch("qwen3-moe-30b-a3b").top_k == 8
    g2 = get_arch("gemma2-2b")
    assert g2.pattern == ("local", "attn") and g2.attn_softcap == 50.0
    m2 = get_arch("mamba2-1.3b")
    assert m2.pattern == ("ssm",) and m2.ssm_state == 128
    a = get_arch("arctic-480b")
    assert a.num_experts == 128 and a.top_k == 2 and a.dense_residual
    vl = get_arch("qwen2-vl-72b")
    assert vl.mrope_sections == (16, 24, 24) and vl.frontend == "vision"
    rg = get_arch("recurrentgemma-9b")
    assert rg.pattern == ("rglru", "rglru", "local")
    assert rg.tail == ("rglru", "rglru")          # 38 = 12*3 + 2


def test_head_dims_consistent():
    for a in ARCH_NAMES:
        cfg = get_arch(a)
        if "ssm" in cfg.pattern:
            assert cfg.d_inner == cfg.ssm_heads * cfg.ssm_head_dim
        else:
            assert cfg.num_heads % cfg.num_kv_heads == 0


def test_input_shape_suite():
    assert INPUT_SHAPES["train_4k"] == (4096, 256, "train")
    assert INPUT_SHAPES["prefill_32k"] == (32768, 32, "prefill")
    assert INPUT_SHAPES["decode_32k"] == (32768, 128, "decode")
    assert INPUT_SHAPES["long_500k"] == (524288, 1, "decode")
    assert SUBQUADRATIC == {"mamba2-1.3b", "recurrentgemma-9b",
                            "gemma2-2b"}


def test_param_counts_plausible():
    """Analytic N within the family's nominal ballpark."""
    expect = {"granite-3-2b": (2.0e9, 4.0e9),
              "qwen1.5-32b": (25e9, 40e9),
              "smollm-360m": (0.25e9, 0.5e9),
              "arctic-480b": (380e9, 560e9),
              "qwen2-vl-72b": (55e9, 85e9),
              "qwen3-moe-30b-a3b": (24e9, 36e9),
              "mamba2-1.3b": (0.9e9, 1.8e9),
              "recurrentgemma-9b": (7e9, 12e9),
              "gemma2-2b": (2.0e9, 3.5e9)}
    for a, (lo, hi) in expect.items():
        n = get_arch(a).param_counts()["total"]
        assert lo < n < hi, (a, n)
    a3 = get_arch("qwen3-moe-30b-a3b").param_counts()
    assert a3["active"] < 0.25 * a3["total"]      # ~3B active of 30B
