"""Device schedule-compiler suite (ISSUE 6 / DESIGN.md §2.2).

``sample_epoch_batched_device`` must be BIT-identical to the numpy
``sample_epoch_batched`` compiler over arbitrary drawn graphs (zero-
degree nodes, empty/tiny train sets), on BOTH lookup paths (dense table
and searchsorted) and through both fallbacks (int64 key spaces, empty
epochs). The seg_sort kernel must match ``jax.lax.sort`` including
stability; device hot-set selection must reproduce ``select_hot_set``;
the background ``SpillWriter`` must round-trip bit-exact and surface
writer-thread failures; lazy schedules must rebuild bit-equal epochs.
"""
import dataclasses
import os
import tempfile

import numpy as np
import pytest

from _hyp import ALL_HEALTH_CHECKS, given, settings
from strategies import build_sampler_graph, sampler_epoch_cases
from repro.graph import load_dataset, partition_graph, KHopSampler
import repro.graph.device_sampler as dsm
from repro.graph.device_sampler import (device_remote_freq,
                                        device_select_hot_set,
                                        sample_epoch_batched_device)
from repro.core import build_schedule
from repro.core.schedule import (SpillWriter, _build_epoch,
                                 load_epoch_npz, select_hot_set,
                                 spill_path)


def assert_flat_bit_equal(ref, got):
    """Every FlatEpoch array AND dtype identical -- the §2.2 contract."""
    assert (ref.epoch, ref.worker) == (got.epoch, got.worker)
    assert ref.num_batches == got.num_batches
    assert ref.num_layers == got.num_layers
    for f in ("seeds", "seed_starts", "input_nodes", "input_starts",
              "num_dst"):
        a, b = getattr(ref, f), getattr(got, f)
        np.testing.assert_array_equal(a, b, err_msg=f)
        assert a.dtype == b.dtype, f
    for l in range(ref.num_layers):
        for f in ("edge_src", "edge_dst", "edge_mask", "edge_starts"):
            a, b = getattr(ref, f)[l], getattr(got, f)[l]
            np.testing.assert_array_equal(a, b, err_msg=f"{f}[{l}]")
            assert a.dtype == b.dtype, f"{f}[{l}]"


# ---- device compiler vs numpy compiler (the tentpole contract) -----------

@settings(max_examples=10, deadline=None,
          suppress_health_check=ALL_HEALTH_CHECKS)
@given(sampler_epoch_cases())
def test_device_compiler_bit_equal_to_batched(case):
    """For ANY drawn (graph, train, fanouts, B): the device compiler's
    FlatEpoch is bit-equal to the numpy compiler's -- including
    zero-degree nodes, empty train sets and batch_size > |train|."""
    g, train, fanouts, B, s0, w, e = case
    sampler = KHopSampler(g, fanouts=list(fanouts), batch_size=B)
    ref = sampler.sample_epoch_batched(s0, w, e, train)
    got = sample_epoch_batched_device(sampler, s0, w, e, train)
    assert_flat_bit_equal(ref, got)


def test_device_compiler_searchsorted_path(monkeypatch):
    """Key spaces past the dense-table budget switch to searchsorted
    membership/inverse lookups -- still bit-equal."""
    g = build_sampler_graph(5, n=60, n_zero=10)
    train = np.arange(60, dtype=np.int64)
    s = KHopSampler(g, fanouts=[4, 3], batch_size=9)
    ref = s.sample_epoch_batched(13, 1, 2, train)
    monkeypatch.setattr(dsm, "DEVICE_TABLE_MAX_SLOTS", 0)
    got = sample_epoch_batched_device(s, 13, 1, 2, train)
    assert_flat_bit_equal(ref, got)


def test_device_compiler_int64_key_fallback(monkeypatch):
    """Key spaces past the int32 bound take the numpy wide-key path
    (device sorts are int32-only) -- equal to the per-batch oracle."""
    import repro.graph.sampler as sampler_mod
    from test_schedule_compiler import assert_batches_bit_equal

    g = build_sampler_graph(3, n=50, n_zero=8)
    train = np.arange(50, dtype=np.int64)
    s = KHopSampler(g, fanouts=[3, 2], batch_size=7)
    monkeypatch.setattr(dsm, "KEY_INT32_MAX_SLOTS", 0)
    monkeypatch.setattr(sampler_mod, "KEY_INT32_MAX_SLOTS", 0)
    got = sample_epoch_batched_device(s, 11, 0, 1, train)
    monkeypatch.undo()
    assert_batches_bit_equal(s.sample_epoch(11, 0, 1, train),
                             got.to_batches())


def test_device_compiler_empty_epoch():
    g = build_sampler_graph(1, n=20)
    s = KHopSampler(g, fanouts=[3], batch_size=4)
    got = sample_epoch_batched_device(s, 5, 0, 0,
                                      np.zeros(0, np.int64))
    assert got.num_batches == 0


# ---- build_schedule end to end: all three compilers ----------------------

def test_build_schedule_device_compiler_identical():
    """On a real partitioned graph the device compiler produces the
    SAME schedule as batched/loop: payload, remote ids/freqs, hot set,
    pad bounds."""
    from test_schedule_compiler import _assert_epochs_equal

    g = load_dataset("tiny")
    pg = partition_graph(g, 4, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=16)
    kw = dict(s0=42, num_epochs=2, n_hot=64)
    for w in (0, 2):
        wb = build_schedule(sampler, pg, worker=w, compiler="batched",
                            **kw)
        wd = build_schedule(sampler, pg, worker=w, compiler="device",
                            **kw)
        for e in range(2):
            a, b = wb.epoch(e), wd.epoch(e)
            _assert_epochs_equal(a, b)
            for f in ("remote_ids", "remote_freq", "cache_ids"):
                assert getattr(a, f).dtype == getattr(b, f).dtype, f
        assert wb.pad_bounds() == wd.pad_bounds()
    with pytest.raises(ValueError):
        build_schedule(sampler, pg, worker=0, compiler="bogus", **kw)


# ---- device remote-frequency + hot-set ordering --------------------------

def test_device_remote_freq_matches_unique():
    rng = np.random.default_rng(4)
    remote = rng.integers(0, 97, size=500).astype(np.int64)
    ids, freq = device_remote_freq(remote, span=100)
    ri, rf = np.unique(remote, return_counts=True)
    np.testing.assert_array_equal(ids, ri)
    np.testing.assert_array_equal(freq, rf)
    assert ids.dtype == np.int64 and freq.dtype == np.int64
    # empty and wide-span fallbacks
    for r, span in ((np.zeros(0, np.int64), 10),
                    (remote, 2 ** 40)):
        ids, freq = device_remote_freq(r, span=span)
        ri, rf = (np.unique(r, return_counts=True) if r.size
                  else (np.zeros(0, np.int64), np.zeros(0, np.int64)))
        np.testing.assert_array_equal(ids, ri)
        np.testing.assert_array_equal(freq, rf)


def test_device_hot_set_matches_host():
    """(freq desc, id asc) prefix incl. ties straddling the boundary."""
    ids = np.array([10, 11, 12, 13, 14], np.int64)
    freq = np.array([3, 1, 2, 1, 1], np.int64)
    for n_hot in (0, 3, 4, 99):
        np.testing.assert_array_equal(
            device_select_hot_set(ids, freq, n_hot),
            select_hot_set(ids, freq, n_hot))
    rng = np.random.default_rng(9)
    ids = np.unique(rng.integers(0, 5000, size=700)).astype(np.int64)
    freq = rng.integers(1, 6, size=ids.shape[0]).astype(np.int64)
    np.testing.assert_array_equal(device_select_hot_set(ids, freq, 64),
                                  select_hot_set(ids, freq, 64))


# ---- seg_sort kernel parity (interpret mode; TPU lane in CI) -------------

def test_radix_sort_matches_ref():
    from repro.kernels.seg_sort import seg_sort
    from repro.kernels.seg_sort.ref import seg_sort_ref

    rng = np.random.default_rng(7)
    keys = rng.integers(0, 1 << 20, size=1024).astype(np.int32)
    keys[1000:] = 2 ** 31 - 1       # sentinel pad tail
    payload = np.arange(1024, dtype=np.int32)
    rk, rp = seg_sort_ref(keys, payload)
    gk, gp = seg_sort(keys, payload, num_bits=21, backend="radix",
                      interpret=True)
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(gp), np.asarray(rp))


def test_radix_sort_stability_under_duplicates():
    from repro.kernels.seg_sort import seg_sort

    rng = np.random.default_rng(8)
    keys = rng.integers(0, 7, size=256).astype(np.int32)
    payload = np.arange(256, dtype=np.int32)
    gk, gp = seg_sort(keys, payload, num_bits=3, backend="radix",
                      interpret=True)
    order = np.argsort(keys, kind="stable")
    np.testing.assert_array_equal(np.asarray(gk), keys[order])
    np.testing.assert_array_equal(np.asarray(gp), payload[order])


def test_seg_sort_backend_resolution():
    import jax
    from repro.kernels.seg_sort import resolve_backend
    from repro.kernels.seg_sort.seg_sort import MAX_VMEM_N

    with pytest.raises(ValueError):
        resolve_backend("bogus")
    assert resolve_backend("ref") == "ref"
    # radix honours the VMEM residency bound
    assert resolve_backend("radix", MAX_VMEM_N) == "radix"
    assert resolve_backend("radix", MAX_VMEM_N + 1) == "ref"
    want = "radix" if jax.default_backend() == "tpu" else "ref"
    assert resolve_backend("auto", 128) == want


def test_seg_sort_keys_only_and_empty():
    from repro.kernels.seg_sort import seg_sort

    keys = np.array([5, 3, 5, 1], np.int32)
    gk, gp = seg_sort(keys, num_bits=3, backend="radix", interpret=True)
    np.testing.assert_array_equal(np.asarray(gk), [1, 3, 5, 5])
    assert gp is None
    ek, ep = seg_sort(np.zeros(0, np.int32), backend="radix",
                      interpret=True)
    assert np.asarray(ek).size == 0 and ep is None


# ---- SpillWriter: background npz writes ----------------------------------

def _tiny_epoch():
    g = load_dataset("tiny")
    pg = partition_graph(g, 2, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=32)
    local = pg.local_nodes[0]
    tm = pg.graph.train_mask
    train = local[tm[local]] if tm is not None else local
    return _build_epoch(sampler, pg, 0, 7, 0, train, 64)


def test_spill_writer_round_trip():
    """An epoch written by the background writer reloads bit-equal --
    the spill regression the off-critical-path move must not break."""
    from test_schedule_compiler import _assert_epochs_equal

    es = _tiny_epoch()
    with tempfile.TemporaryDirectory() as td:
        path = spill_path(td, 0, 0)
        w = SpillWriter()
        try:
            w.submit(path, es)
            w.flush()
            back = load_epoch_npz(path)
        finally:
            w.close()
    _assert_epochs_equal(es, back)
    for f in ("seed_starts", "input_starts"):
        np.testing.assert_array_equal(getattr(back.flat, f),
                                      getattr(es.flat, f))


def test_spill_writer_raises_on_failed_write():
    """Writer-thread failures surface on the submitting thread at the
    next flush/close, never silently drop an epoch."""
    es = _tiny_epoch()
    w = SpillWriter()
    try:
        w.submit(os.path.join(os.sep, "nonexistent-dir!", "x.npz"), es)
        with pytest.raises(RuntimeError, match="spill write failed"):
            w.flush()
    finally:
        try:
            w.close()
        except RuntimeError:
            pass


# ---- lazy (device-resident) schedules ------------------------------------

def test_lazy_schedule_rebuilds_bit_equal():
    """lazy=True drops payloads AND skips spill; epoch(e) re-runs the
    compiler on demand and must reproduce the eager build exactly."""
    from test_schedule_compiler import _assert_epochs_equal

    g = load_dataset("tiny")
    pg = partition_graph(g, 4, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=16)
    kw = dict(worker=1, s0=3, num_epochs=2, n_hot=64)
    eager = build_schedule(sampler, pg, **kw)
    lazy = build_schedule(sampler, pg, lazy=True, **kw)
    assert all(e is None for e in lazy.epochs)
    assert lazy.spill_dir is None and lazy.builder is not None
    for e in range(2):
        _assert_epochs_equal(eager.epoch(e), lazy.epoch(e))
    assert eager.pad_bounds() == lazy.pad_bounds()
    # lazy overrides a spill request: device-resident means no disk
    with tempfile.TemporaryDirectory() as td:
        lz = build_schedule(sampler, pg, spill_dir=td, lazy=True, **kw)
        assert lz.spill_dir is None and os.listdir(td) == []


# ---- campaign plumbing ---------------------------------------------------

def test_cellspec_schedule_backend_field():
    from repro.eval.spec import CellSpec

    c = CellSpec(backend="device", system="rapidgnn", dataset="tiny",
                 batch_size=16, workers=4, n_hot=64, epochs=1,
                 schedule_backend="device")
    assert CellSpec.from_dict(c.to_dict()) == c
    assert c.effective_compiler == "device"
    # the backend toggle is NOT part of the differential pairing key:
    # schedules are bit-identical either way (this suite pins it)
    assert c.scenario_key() == dataclasses.replace(
        c, schedule_backend="numpy").scenario_key()
    assert dataclasses.replace(
        c, schedule_backend="numpy").effective_compiler == "batched"
    with pytest.raises(ValueError):
        CellSpec(backend="host", system="rapidgnn", dataset="tiny",
                 batch_size=16, workers=4, n_hot=64, epochs=1,
                 schedule_backend="bogus")
