"""Online GNN serving tests (ISSUE 10 / DESIGN.md §11): admission /
shed / deadline unit behaviour, per-tier bit-equality against the clean
single-request oracle, the stale-snapshot contract, the concurrent
sync_pull metrics identity, and the chaos property -- any request
stream under any serve fault profile yields responses that are
bit-equal to the oracle OR flagged stale with snapshot-consistent
features OR typed errors; never silent corruption."""
import threading

import numpy as np
import pytest
from _hyp import given, settings, st

import jax

from repro.core.metrics import EpochMetrics
from repro.fault import active_plan, plan_from_profile
from repro.graph import KHopSampler, load_dataset, partition_graph
from repro.graph.sampler import rng_from
from repro.models import GNNConfig, init_params
from repro.serve.gnn import (GNNInferenceService, Overloaded, ServeClosed,
                             ServePullError, TIER_FRESH, TIER_STALE,
                             TIER_UNCACHED, WarmerError, serve_pad_bounds)

S0 = 7
P_ = 4
_CACHE = {}


def _world():
    """Memoized module world (plain function, not a fixture: the
    hypothesis shim's ``@given`` wrapper takes no pytest args)."""
    if "world" not in _CACHE:
        g = load_dataset("tiny", seed=0)
        pg = partition_graph(g, P_, "greedy")
        sampler = KHopSampler(g, fanouts=[3, 3], batch_size=4)
        cfg = GNNConfig(kind="sage", in_dim=g.feat_dim, hidden_dim=16,
                        num_classes=g.num_classes, num_layers=2)
        params = init_params(cfg, jax.random.key(0))
        _CACHE["world"] = (g, pg, sampler, cfg, params)
    return _CACHE["world"]


def _program():
    """One compile for the whole module (ServeProgram is shareable
    across services with identical static shapes)."""
    if "program" not in _CACHE:
        g, pg, sampler, cfg, params = _world()
        _CACHE["program"] = GNNInferenceService(
            pg, sampler, cfg, params, s0=S0).program
    return _CACHE["program"]


@pytest.fixture(scope="module")
def world():
    return _world()


@pytest.fixture(scope="module")
def program(world):
    return _program()


def make_service(world, program, **kw):
    g, pg, sampler, cfg, params = world
    kw.setdefault("n_hot", 32)
    kw.setdefault("high_water", 64)
    kw.setdefault("default_timeout_s", 30.0)
    return GNNInferenceService(pg, sampler, cfg, params, s0=S0,
                               program=program, **kw)


def drain(svc, pendings):
    """Step synchronously (no threads) until every pending resolves;
    -> list of (pending, response-or-typed-error)."""
    need = len(pendings)
    served = 0
    while served < need:
        got = svc.step(timeout=0.1)
        assert got > 0, "dispatcher starved with requests outstanding"
        served += got
    out = []
    for p in pendings:
        try:
            out.append((p, p.result(timeout=5.0)))
        except (Overloaded, ServeClosed, ServePullError,
                WarmerError) as exc:
            out.append((p, exc))
    return out


def streams_for(seed, g, n, max_seeds=4):
    rng = rng_from(seed, 0x7E57)
    return [rng.integers(0, g.num_nodes, size=int(k))
            for k in rng.integers(1, max_seeds + 1, size=n)]


# ---- tier ladder: uncached -> fresh -> stale, all bit-equal ---------------

def test_uncached_then_fresh_bit_equal_one_trace(world, program):
    g = world[0]
    svc = make_service(world, program)
    try:
        streams = streams_for(1, g, 6)
        first = [svc.submit(s) for s in streams[:3]]
        for p, resp in drain(svc, first):
            assert resp.tier == TIER_UNCACHED and not resp.stale
            np.testing.assert_array_equal(
                resp.logits, svc.oracle(streams[resp.rid], resp.rid))
        # serving observed the remote traffic; one warm cycle publishes
        # the hot snapshot and the next round serves the fresh tier
        assert svc.warmer.warm_now()
        second = [svc.submit(s) for s in streams[3:]]
        for p, resp in drain(svc, second):
            assert resp.tier == TIER_FRESH and not resp.stale
            np.testing.assert_array_equal(
                resp.logits, svc.oracle(streams[resp.rid], resp.rid))
        h = svc.health()
        assert h["served_uncached"] == 3 and h["served_fresh"] == 3
        assert h["trace_count"] == 1   # oracle + both tiers, ONE trace
    finally:
        svc.close()


def test_stale_tier_serves_last_good_snapshot(world, program):
    g = world[0]
    svc = make_service(world, program)
    try:
        streams = streams_for(2, g, 4)
        for _p, resp in drain(svc, [svc.submit(s) for s in streams[:2]]):
            np.testing.assert_array_equal(
                resp.logits, svc.oracle(streams[resp.rid], resp.rid))
        assert svc.warmer.warm_now()        # generation 1: healthy
        # serve-warm-stale kills warm generation 2 past any retry
        # budget: the warmer degrades, the last-good snapshot stays
        with active_plan(plan_from_profile("serve-warm-stale", seed=0)):
            with pytest.raises(WarmerError):
                svc.warmer.warm_now()
            for _p, resp in drain(svc,
                                  [svc.submit(s) for s in streams[2:]]):
                assert resp.tier == TIER_STALE and resp.stale
                assert resp.cache_generation == 1
                # staleness contract: the snapshot served from is
                # bit-equal to the immutable authoritative table
                c = resp.served_cache
                np.testing.assert_array_equal(c.feats, g.features[c.ids])
                np.testing.assert_array_equal(
                    resp.logits, svc.oracle(streams[resp.rid], resp.rid))
        # the warmer self-heals once the fault clears
        assert svc.warmer.warm_now()
        assert svc.warmer.snapshot()[1]
        assert svc.health()["served_stale"] == 2
    finally:
        svc.close()


# ---- admission: shed, deadlines, close ------------------------------------

def test_overload_sheds_typed_past_high_water(world, program):
    g = world[0]
    svc = make_service(world, program, high_water=2)
    try:
        streams = streams_for(3, g, 5)
        admitted = [svc.submit(s) for s in streams[:2]]
        for s in streams[2:]:
            with pytest.raises(Overloaded):
                svc.submit(s)
        assert svc.queue.shed == 3
        # shedding burns rids but never re-orders admitted requests
        assert [p.rid for p in admitted] == [0, 1]
        results = drain(svc, admitted)
        for _p, resp in results:
            np.testing.assert_array_equal(
                resp.logits, svc.oracle(streams[resp.rid], resp.rid))
        # queue drained -> admission reopens below high water
        svc.submit(streams[0])
    finally:
        svc.close()


def test_expired_deadline_counted_but_still_correct(world, program):
    g = world[0]
    svc = make_service(world, program)
    try:
        seeds = streams_for(4, g, 1)[0]
        pending = svc.submit(seeds, timeout_s=0.0)   # already expired
        (_p, resp), = drain(svc, [pending])
        assert resp.deadline_missed
        np.testing.assert_array_equal(resp.logits,
                                      svc.oracle(seeds, resp.rid))
        assert svc.health()["deadline_miss"] == 1
    finally:
        svc.close()


def test_close_fails_backlog_typed_and_rejects_submits(world, program):
    g = world[0]
    svc = make_service(world, program)
    pending = svc.submit(streams_for(5, g, 1)[0])
    svc.close()
    with pytest.raises(ServeClosed):
        pending.result(timeout=1.0)
    with pytest.raises(ServeClosed):
        svc.submit(np.array([0]))
    svc.close()   # idempotent


def test_dead_pull_fails_one_request_not_the_batch(world, program):
    """serve-pull-dead pins rid 1's residual pull dead past any retry:
    that request fails typed, its batchmates are still bit-equal."""
    g = world[0]
    svc = make_service(world, program)
    try:
        streams = streams_for(6, g, 3)
        with active_plan(plan_from_profile("serve-pull-dead", seed=0)):
            results = drain(svc, [svc.submit(s) for s in streams])
        for p, r in results:
            if p.rid == 1:
                assert isinstance(r, ServePullError)
            else:
                np.testing.assert_array_equal(
                    r.logits, svc.oracle(streams[p.rid], p.rid))
        assert svc.health()["errors"] == 1
    finally:
        svc.close()


# ---- static shapes --------------------------------------------------------

def test_serve_pad_bounds_worst_case():
    # B=4 seeds, fanouts [2, 3] output->input: last hop emits 4*3=12
    # edges over a frontier of at worst 4*(1+3)=16; first hop 16*2=32
    m_max, edge_max = serve_pad_bounds([2, 3], 4)
    assert edge_max == [32, 12]
    assert m_max == 4 * (1 + 3) * (1 + 2)
    # a single-seed request can never outgrow the bounds
    m1, e1 = serve_pad_bounds([2, 3], 1)
    assert m1 <= m_max and all(a <= b for a, b in zip(e1, edge_max))


# ---- concurrent sync_pull metrics identity (the fetch.py lock fix) --------

def test_sync_pull_metrics_identity_under_8_threads(world):
    """8 threads hammering ONE store with ONE shared EpochMetrics: the
    accumulated counters must satisfy the exact differential identity
    ``remote_bytes == rpc_count * row_bytes`` and the per-call count --
    unsynchronized ``+=`` loses increments under this load."""
    from repro.core.fetch import ShardedFeatureStore

    g, pg = world[0], world[1]
    store = ShardedFeatureStore(pg, worker=0)
    m = EpochMetrics(epoch=0)
    reps, n_threads = 60, 8
    rng = rng_from(11, 0x5D)
    id_sets = [rng.integers(0, g.num_nodes, size=32)
               for _ in range(n_threads)]
    n_remote = [int((pg.owner[ids] != 0).sum()) for ids in id_sets]
    errs = []

    def hammer(ids):
        try:
            for _ in range(reps):
                store.sync_pull(ids, m)
        except BaseException as exc:           # pragma: no cover
            errs.append(exc)

    threads = [threading.Thread(target=hammer, args=(id_sets[t],))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    row = g.feat_dim * g.features.itemsize
    assert m.sync_pull_calls == n_threads * reps
    assert m.rpc_count == reps * sum(n_remote)
    assert m.remote_bytes == m.rpc_count * row


# ---- the serving chaos property -------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.sampled_from([None, "serve-pull-flaky",
                        "serve-queue-shed", "serve-warm-stale"]))
def test_any_stream_any_profile_bit_equal_or_stale(seed, profile):
    """Any request stream x any serve fault profile: every non-shed
    response is bit-equal to the clean single-request oracle, or
    ``stale=True`` with features bit-equal to the snapshot it was
    served from. Typed sheds/pull failures are allowed; silent
    corruption is not."""
    world, program = _world(), _program()
    g = world[0]
    svc = make_service(world, program)
    try:
        streams = streams_for(seed, g, 6)
        # seed traffic + generation 1 so the stale profile has a
        # last-good snapshot to degrade to
        for _p, r in drain(svc, [svc.submit(s) for s in streams[:2]]):
            np.testing.assert_array_equal(
                r.logits, svc.oracle(streams[r.rid], r.rid))
        svc.warmer.warm_now()
        plan = (plan_from_profile(profile, seed=seed & 0xFFFF)
                if profile else None)
        with active_plan(plan):
            try:
                svc.warmer.warm_now()
            except WarmerError:
                pass                           # degrade -> stale tier
            pendings = []
            for s in streams[2:]:
                try:
                    pendings.append(svc.submit(s))
                except Overloaded:
                    pass                       # typed shed is allowed
            for p, r in drain(svc, pendings):
                if isinstance(r, BaseException):
                    assert isinstance(r, ServePullError)
                    continue
                np.testing.assert_array_equal(
                    r.logits, svc.oracle(streams[r.rid], r.rid))
                if r.stale:
                    c = r.served_cache
                    np.testing.assert_array_equal(c.feats,
                                                  g.features[c.ids])
        assert svc.health()["trace_count"] == 1
    finally:
        svc.close()
