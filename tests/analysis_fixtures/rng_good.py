"""Good fixture: randomness arrives via the sanctioned keyed streams."""
from repro.graph.sampler import rng_from


def keyed_draw(s0, worker, epoch, n):
    rng = rng_from(s0, worker, epoch)
    return rng.integers(0, 100, size=n)


def passed_generator(rng, n):
    # receiving a Generator is always fine; only minting one is gated
    return rng.normal(size=n)
