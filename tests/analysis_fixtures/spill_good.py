"""Good fixture: flat in-memory arrays with a JSON sidecar."""
import json

import numpy as np


def pack(arrs):
    return {k: np.asarray(v) for k, v in arrs.items()}


def manifest(path, meta):
    with open(path, "w") as f:
        json.dump(meta, f)
