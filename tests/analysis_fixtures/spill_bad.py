"""Bad fixture: SPILL-SAFETY violations (pinned line numbers)."""
import pickle

import numpy as np


def save(path, arr, obj):
    np.save(path, arr)                           # L8: np IO outside spill
    with open(path + ".pkl", "wb") as f:
        pickle.dump(obj, f)                      # L10: pickled objects


def load(path):
    return np.load(path, allow_pickle=True)      # L14: np IO + pickle (x2)
