"""Bad fixture: RNG-CONTRACT violations (pinned by test_analysis.py)."""
import random
import time

import numpy as np


def unkeyed(n):
    rng = np.random.default_rng(0)                    # L9: unkeyed stream
    return rng


def global_stream(n):
    np.random.seed(0)                                 # L14: global seed
    return np.random.rand(n)                          # L15: global draw


def stdlib(n):
    random.seed(7)                                    # L19: stdlib seed
    return [random.random() for _ in range(n)]        # L20: stdlib draw


def wall_clock():
    return np.random.default_rng(time.time_ns())      # L24: time-seeded (x2)
