"""Good fixture: shape-static casts; host work stays outside trace."""
import time
from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("block",))
def tiled(x, block):
    nb = int(x.shape[0] // block)     # shape + static param: fine
    bits = int(block - 1).bit_length()
    return x[: nb * block], bits


def host_loop(xs):
    t0 = time.perf_counter()          # not trace-reachable: fine
    out = [float(x) for x in xs]
    print(len(out))
    return out, time.perf_counter() - t0


@jax.jit
def body(x):
    m = x.shape[0]
    k = int(m * 2)                    # static dataflow through m
    return jnp.zeros((k,)) + x.sum()
