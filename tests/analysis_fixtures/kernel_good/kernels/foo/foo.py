"""Good kernel family: the Pallas implementation."""
import jax.experimental.pallas as pl


def foo(x, interpret=False):
    return pl.pallas_call(_body, out_shape=x, interpret=interpret)(x)


def _body(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2
