"""Good kernel family: the pure reference oracle."""


def foo_ref(x):
    return x * 2
