"""Good kernel family: public wrapper with interpret-mode backend."""
from repro.kernels.foo import foo as _impl_foo  # fixture: parse-only


def foo(x, interpret=False):
    return _impl_foo(x, interpret=interpret)
