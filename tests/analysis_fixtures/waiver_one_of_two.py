"""Two identical violations; exactly one carries a valid waiver."""
import numpy as np


def a():
    return np.random.default_rng(0)  # repro: allow(RNG-CONTRACT) -- fixture: deliberate suppression


def b():
    return np.random.default_rng(0)                   # L10: NOT waived
