"""Bad fixture: a Pallas kernel living outside kernels/."""
import jax.experimental.pallas as pl


def rogue(x):
    return pl.pallas_call(_body, out_shape=x)(x)      # L6: stray pallas


def _body(x_ref, o_ref):
    o_ref[...] = x_ref[...]
