"""Bad kernel family: no ref.py, no foo.py, no CPU backend path."""
import jax.experimental.pallas as pl


def foo_op(x):
    return pl.pallas_call(_body, out_shape=x)(x)


def _body(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2
