"""Good fixture: owned, joined, exception-propagating worker."""
import threading


class Worker:
    def __init__(self):
        self._err = None
        self._err_lock = threading.Lock()
        self._result = None
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        try:
            self._result = 42
        except BaseException as exc:
            with self._err_lock:
                self._err = exc

    def join(self, timeout=None):
        self._t.join(timeout)
        with self._err_lock:
            err, self._err = self._err, None
        if err is not None:
            raise RuntimeError("worker failed") from err

    def result(self):
        return self._result


def run_owned():
    t = threading.Thread(target=print, daemon=True)
    t.start()
    t.join()
