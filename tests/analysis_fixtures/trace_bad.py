"""Bad fixture: TRACE-PURITY violations inside jit-reachable code."""
import time

import jax


@jax.jit
def step(x):
    t0 = time.perf_counter()          # L9: time.* at trace time
    v = x.sum().item()                # L10: host sync
    n = int(x[0])                     # L11: concretizes a tracer
    print("step", n)                  # L12: host IO at trace time
    return v + t0


def helper(x):
    return float(x.mean())            # L17: reached transitively


@jax.jit
def outer(x):
    return helper(x)
