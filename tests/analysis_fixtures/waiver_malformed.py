"""Waivers that are themselves findings (and waive nothing)."""
import numpy as np


def a():
    return np.random.default_rng(0)  # repro: allow(RNG-CONTRACT)


def b():
    # repro: allow(RNG-CONTRACT) this text lacks the dash separator
    return np.random.default_rng(0)
