"""Bad fixture: THREAD-DISCIPLINE violations (pinned line numbers)."""
import threading


class Leaky:
    def __init__(self):
        self._result = None
        self._t = threading.Thread(target=self._run, daemon=True)  # L8: x3
        self._t.start()

    def _run(self):
        self._result = 42             # written by thread, read below

    def result(self):
        return self._result


def fire_and_forget():
    threading.Thread(target=print, daemon=True).start()            # L19


def local_daemon():
    t = threading.Thread(target=print, daemon=True)                # L23
    t.start()
