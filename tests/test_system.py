"""End-to-end behaviour tests for the paper's system (deliverable c).

Covers: full RapidGNN training convergence + parity with the baseline
(paper Fig. 9 / Prop 3.1), prefetch pipeline liveness, checkpointing
round-trip, partitioner balance, dataset statistics, optimizer.
"""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow      # end-to-end training loops

from repro.graph import (load_dataset, partition_graph, KHopSampler,
                         random_partition, greedy_partition)
from repro.core import (build_schedule, ShardedFeatureStore,
                        RapidGNNRunner, BaselineRunner, NetworkModel)
from repro.models import (GNNConfig, init_params, make_train_step,
                          batch_to_device)
from repro.train import (AdamW, SGD, cosine_schedule, save_checkpoint,
                         load_checkpoint, checkpoint_step, global_norm)


def _train_system(system, epochs=4, s0=7):
    g = load_dataset("tiny")
    pg = partition_graph(g, 2, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=32)
    ws = build_schedule(sampler, pg, worker=0, s0=s0, num_epochs=epochs,
                        n_hot=128)
    cfg = GNNConfig(kind="sage", in_dim=g.feat_dim, hidden_dim=32,
                    num_classes=g.num_classes, num_layers=2)
    params = init_params(cfg, jax.random.key(0))
    opt = AdamW(lr=3e-3)
    box = {"p": params, "o": opt.init(params), "loss": [], "acc": []}
    step = make_train_step(cfg, opt)

    def train_fn(feats, cb):
        box["p"], box["o"], aux = step(box["p"], box["o"],
                                       batch_to_device(cb, feats))
        box["loss"].append(float(aux["loss"]))
        box["acc"].append(float(aux["acc"]))
        return box["loss"][-1]

    store = ShardedFeatureStore(pg, worker=0,
                                net=NetworkModel(enabled=False))
    runner = (RapidGNNRunner(ws, store, batch_size=32, Q=4,
                             train_fn=train_fn)
              if system == "rapidgnn"
              else BaselineRunner(ws, store, batch_size=32,
                                  train_fn=train_fn))
    metrics = runner.run()
    return box, metrics


def test_rapidgnn_training_converges():
    box, m = _train_system("rapidgnn")
    assert box["loss"][-1] < box["loss"][0] * 0.5
    assert box["acc"][-1] > 0.8
    assert not any(np.isnan(box["loss"]))


def test_convergence_parity_with_baseline():
    """Prop 3.1 / Fig 9: identical schedule => identical training curves."""
    r, _ = _train_system("rapidgnn")
    b, _ = _train_system("baseline")
    np.testing.assert_allclose(r["loss"], b["loss"], rtol=1e-5, atol=1e-6)


def test_prefetcher_serves_all_batches_in_order():
    _, m = _train_system("rapidgnn", epochs=2)
    for em in m.epochs:
        assert em.default_path == 0
        assert em.prefetch_hits > 0


def test_partitioners():
    g = load_dataset("tiny")
    for fn in (random_partition, greedy_partition):
        pg = fn(g, 4)
        sizes = pg.part_sizes
        assert sizes.sum() == g.num_nodes
        assert sizes.max() - sizes.min() <= max(4, g.num_nodes // 50)
    # edge-cut partitioner must beat random on a clustered graph
    r = random_partition(g, 4).edge_cut_fraction()
    ge = greedy_partition(g, 4).edge_cut_fraction()
    assert ge < r


def test_dataset_statistics():
    g = load_dataset("tiny")
    g.validate()
    deg = g.in_degree()
    assert deg.mean() > 4
    # heavy tail: max degree much larger than mean
    assert deg.max() > 5 * deg.mean()
    assert g.features.shape == (g.num_nodes, 32)
    assert g.labels.max() < 8


def test_checkpoint_roundtrip(tmp_path):
    cfg = GNNConfig(kind="sage", in_dim=8, hidden_dim=16, num_classes=4,
                    num_layers=2)
    params = init_params(cfg, jax.random.key(1))
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, params, step=123)
    assert checkpoint_step(path) == 123
    like = jax.tree.map(lambda a: np.zeros(a.shape, a.dtype), params)
    loaded = load_checkpoint(path, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_optimizers_reduce_quadratic():
    def loss(p):
        return jnp.sum((p["w"] - 3.0) ** 2)

    for opt in (AdamW(lr=0.1), SGD(lr=0.05)):
        p = {"w": jnp.zeros(4)}
        s = opt.init(p)
        for _ in range(100):
            g = jax.grad(loss)(p)
            p, s = opt.update(g, s, p)
        assert float(loss(p)) < 0.3


def test_cosine_schedule_and_global_norm():
    f = cosine_schedule(1.0, warmup=10, total=100)
    assert float(f(0)) == 0.0
    assert abs(float(f(10)) - 1.0) < 1e-6
    assert float(f(100)) < 1e-6
    assert abs(float(global_norm({"a": jnp.ones(4), "b": jnp.ones(4)}))
               - np.sqrt(8)) < 1e-6


def test_spill_to_disk_schedule(tmp_path):
    """SSD-streaming mode: schedules spilled per epoch as flat npz
    blocks (no pickled object graph), reloaded on use."""
    g = load_dataset("tiny")
    pg = partition_graph(g, 2, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=32)
    ws = build_schedule(sampler, pg, worker=0, s0=7, num_epochs=2,
                        n_hot=64, spill_dir=str(tmp_path))
    assert all(e is None for e in ws.epochs)
    es = ws.epoch(1)
    assert es.num_batches > 0
    assert os.path.exists(tmp_path / "w0_e1.npz")
