"""Shared uneven-train-partition scenario builder.

The DEFAULT arguments reproduce the historical fixed case -- worker 2
trains on NOTHING (zero batches per epoch), worker 3 on half a batch --
used by the host-side collation tests (tests/test_device_runner.py) and
the on-mesh subprocess checks (tests/_dist_checks.py) so they cover the
identical scenario. ``zero_workers`` / ``partial_workers`` parameterize
it for the property suite (tests/strategies.py draws them).
"""
import dataclasses


def build_uneven_case(P_=4, B=16, epochs=2, n_hot=64, s0=7,
                      zero_workers=(2,), partial_workers=None):
    """-> (graph, partitioned_graph, worker schedules, DeviceView).

    ``zero_workers``: partitions whose train nodes are all masked off.
    ``partial_workers``: {worker: keep_count} -- keep only the first
    ``keep_count`` train nodes of that partition (default: worker 3
    keeps half a batch)."""
    from repro.graph import load_dataset, partition_graph, KHopSampler
    from repro.core import build_schedule
    from repro.dist import DeviceView

    if partial_workers is None:
        partial_workers = {3: B // 2}
    g = load_dataset("tiny")
    # load_dataset caches: replace the graph before editing train_mask so
    # other tests sharing the cached instance stay unaffected
    g = dataclasses.replace(g, train_mask=g.train_mask.copy())
    pg = partition_graph(g, P_, "greedy")
    tm = g.train_mask.copy()
    for w in zero_workers:
        tm[pg.local_nodes[w]] = False
    for w, keep_n in partial_workers.items():
        if w in zero_workers:
            continue
        lw = pg.local_nodes[w]
        keep = lw[tm[lw]][:keep_n]
        tm[lw] = False
        tm[keep] = True
    g.train_mask = tm
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=B)
    schedules = [build_schedule(sampler, pg, worker=w, s0=s0,
                                num_epochs=epochs, n_hot=n_hot)
                 for w in range(P_)]
    return g, pg, schedules, DeviceView.build(pg)
