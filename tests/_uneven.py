"""Shared uneven-train-partition scenario: worker 2 trains on NOTHING
(zero batches per epoch), worker 3 on half a batch. Used by both the
host-side collation tests (tests/test_device_runner.py) and the on-mesh
subprocess checks (tests/_dist_checks.py) so they cover the identical
case."""
import dataclasses


def build_uneven_case(P_=4, B=16, epochs=2, n_hot=64, s0=7):
    """-> (graph, partitioned_graph, worker schedules, DeviceView)."""
    from repro.graph import load_dataset, partition_graph, KHopSampler
    from repro.core import build_schedule
    from repro.dist import DeviceView

    g = load_dataset("tiny")
    # load_dataset caches: replace the graph before editing train_mask so
    # other tests sharing the cached instance stay unaffected
    g = dataclasses.replace(g, train_mask=g.train_mask.copy())
    pg = partition_graph(g, P_, "greedy")
    tm = g.train_mask.copy()
    tm[pg.local_nodes[2]] = False
    l3 = pg.local_nodes[3]
    keep = l3[tm[l3]][: B // 2]
    tm[l3] = False
    tm[keep] = True
    g.train_mask = tm
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=B)
    schedules = [build_schedule(sampler, pg, worker=w, s0=s0,
                                num_epochs=epochs, n_hot=n_hot)
                 for w in range(P_)]
    return g, pg, schedules, DeviceView.build(pg)
