"""Campaign subsystem tests (repro.eval, DESIGN.md §7).

Fast lane: a tiny host-only campaign exercises the full pipeline --
schema validity of BENCH_paper.json, headline-ratio floors measured on
this repo's own tiny grid, differential verification with teeth (an
injected counter perturbation MUST fail), the generalized host-vs-device
parity check on a synthesized pair, and end-to-end determinism as a
property over seeds. The cross-backend campaign with REAL device cells
runs in the slow lane (subprocess, 4 emulated devices).
"""
import copy
import json

import numpy as np
import pytest

from _hyp import ALL_HEALTH_CHECKS, given, settings, st
from repro.eval import (CellResult, CellSpec, build_fault_report,
                        check_backend_pair, all_pass, failures,
                        fault_grid, tiny_host_grid,
                        validate_fault_report, validate_report,
                        verify_cells, verify_fault_pairs)
from repro.eval.campaign import run_campaign
from repro.eval.cells import run_host_cell


@pytest.fixture(scope="module")
def tiny_report(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("bench") / "BENCH_paper.json")
    report = run_campaign(tiny_host_grid(epochs=2),
                          include_device=False, out_path=out)
    with open(out) as f:
        return report, json.load(f)


# ---------------------------------------------------------------------------
# schema + headline ratios
# ---------------------------------------------------------------------------

def test_report_schema_valid(tiny_report):
    report, loaded = tiny_report
    assert validate_report(report) == []
    assert validate_report(loaded) == []        # survives JSON round trip
    assert loaded["schema"] == "rapidgnn.bench_paper/v2"
    assert loaded["num_cells"] == 2
    # v2: every cell carries the fault/degradation counters (zero when
    # the campaign runs clean)
    for cell in loaded["cells"]:
        assert cell["fault_events"] == 0
        assert cell["degraded_epochs"] == 0


def test_all_differential_checks_pass(tiny_report):
    report, _ = tiny_report
    assert report["all_checks_pass"], [
        c for c in report["differential"] if c["status"] == "FAIL"]
    # the tiny host pair exercises at least the internal + system layers
    ran = {c["check"] for c in report["differential"]}
    assert {"bytes_identity", "miss_matrix_sum", "fetch_not_more",
            "loss_agreement"} <= ran


def test_fetch_reduction_above_repo_floor(tiny_report):
    """Counter-deterministic: the tiny grid measures ~2.27x fewer remote
    fetches for rapid vs dgl-metis; 1.3 is a safe regression floor."""
    _, loaded = tiny_report
    pair = loaded["pairs"][0]
    assert pair["baseline_system"] == "dgl-metis"
    assert pair["fetch_reduction_x"] >= 1.3
    assert pair["bytes_reduction_x"] > 0


def test_timing_ratios_sane(tiny_report):
    """Time-derived ratios are noisy on shared CI -- only sanity-bound
    them (the deterministic signal lives in the fetch counters)."""
    _, loaded = tiny_report
    pair = loaded["pairs"][0]
    assert pair["throughput_speedup"] > 0.2
    for k in ("cpu_ratio", "gpu_ratio", "total_ratio"):
        assert pair["energy"][k] > 0


def test_epoch_metrics_round_trip(tiny_report):
    """The per-epoch drill-down records (RunMetrics.to_dict epochs for
    host cells) survive the JSON round trip through EpochMetrics.
    from_dict and stay consistent with the cell's miss matrix."""
    from repro.core.metrics import EpochMetrics, RunMetrics

    _, loaded = tiny_report
    for cell in loaded["cells"]:
        assert cell["spec"]["backend"] == "host"
        ems = [EpochMetrics.from_dict(d)
               for d in cell["epoch_metrics"]]
        assert len(ems) == cell["spec"]["epochs"]
        for e, em in enumerate(ems):
            assert em.to_dict() == cell["epoch_metrics"][e]
            # worker 0's per-epoch misses == miss_matrix column 0
            assert em.cache_misses == cell["miss_matrix"][e][0]
        rm = RunMetrics.from_dict({"epochs": cell["epoch_metrics"]})
        assert rm.totals()["cache_misses"] == sum(
            e.cache_misses for e in ems)


def test_schema_validator_catches_damage(tiny_report):
    report, _ = tiny_report
    bad = copy.deepcopy(report)
    del bad["pairs"]
    assert validate_report(bad)
    bad2 = copy.deepcopy(report)
    del bad2["cells"][0]["miss_matrix"]
    assert any("miss_matrix" in p for p in validate_report(bad2))


# ---------------------------------------------------------------------------
# differential verification has teeth
# ---------------------------------------------------------------------------

def _cells_from(report):
    return [CellResult.from_dict(d)
            for d in copy.deepcopy(report["cells"])]


def test_unperturbed_cells_verify(tiny_report):
    report, _ = tiny_report
    assert all_pass(verify_cells(_cells_from(report)))


def test_injected_rpc_miscount_fails(tiny_report):
    report, _ = tiny_report
    cells = _cells_from(report)
    cells[0].rpc_count += 1
    bad = failures(verify_cells(cells))
    assert bad, "perturbed rpc_count slipped through"
    assert any(c.check == "bytes_identity" for c in bad)


def test_injected_miss_matrix_miscount_fails(tiny_report):
    report, _ = tiny_report
    cells = _cells_from(report)
    cells[1].miss_matrix[0][0] += 1
    bad = failures(verify_cells(cells))
    assert any(c.check == "miss_matrix_sum" for c in bad)


def test_injected_loss_drift_fails(tiny_report):
    """The cache-is-lossless contract: a drifted loss value in the rapid
    cell must break loss_agreement with the baseline."""
    report, _ = tiny_report
    cells = _cells_from(report)
    rapid = next(c for c in cells if c.system == "rapidgnn")
    rapid.losses[3] += 0.1
    bad = failures(verify_cells(cells))
    assert any(c.check == "loss_agreement" for c in bad)


def test_backend_pair_parity_on_synthesized_device_cell(tiny_report):
    """The generalized assert_host_parity: a device cell whose lane
    matrix EQUALS the host miss matrix passes all cross-backend checks;
    one perturbed lane count fails miss_parity (and only a lane-level
    perturbation -- the scalar counters still agree)."""
    report, _ = tiny_report
    host = next(c for c in _cells_from(report)
                if c.system == "rapidgnn")
    dspec = dict(host.spec, backend="device")
    dev = CellResult.from_dict(dict(
        host.to_dict(), spec=dspec, payload_bytes=host.remote_bytes,
        trace_count=1))
    assert all(c.status == "PASS" for c in check_backend_pair(host, dev))
    dev.miss_matrix[0][0] += 1
    bad = [c for c in check_backend_pair(host, dev)
           if c.status == "FAIL"]
    assert [c.check for c in bad] == ["miss_parity"]


# ---------------------------------------------------------------------------
# end-to-end determinism (property over seeds): host-sim path.
# The device-path twin runs on 4 emulated devices in tests/_dist_checks
# (slow lane).
# ---------------------------------------------------------------------------

def _det_spec(seed):
    return CellSpec(backend="host", system="rapidgnn", dataset="tiny",
                    batch_size=16, workers=2, n_hot=64, epochs=2,
                    seed=seed, fanouts=(5, 5), partition="greedy",
                    all_workers=False, net_enabled=False)


@settings(max_examples=2, deadline=None,
          suppress_health_check=ALL_HEALTH_CHECKS)
@given(st.integers(0, 2 ** 31 - 1))
def test_host_end_to_end_determinism(seed):
    """Same seed => bit-identical loss curves, miss matrices and cache
    ids across two FRESH runner instances, and bit-identical staged pull
    plans across two fresh schedule builds + collations."""
    a = run_host_cell(_det_spec(seed))
    b = run_host_cell(_det_spec(seed))
    assert a.losses == b.losses                 # float-exact
    assert a.miss_matrix == b.miss_matrix
    assert a.rpc_count == b.rpc_count

    from repro.graph import load_dataset, partition_graph, KHopSampler
    from repro.core import build_schedule, merge_pad_bounds
    from repro.dist import (DeviceView, collate_device_epoch,
                            epoch_k_max)

    g = load_dataset("tiny")
    pg = partition_graph(g, 2, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=16)
    staged = []
    for _ in range(2):
        schedules = [build_schedule(sampler, pg, worker=w, s0=seed,
                                    num_epochs=2, n_hot=64)
                     for w in range(2)]
        m_max, edge_max = merge_pad_bounds(schedules)
        dv = DeviceView.build(pg)
        es_list = [ws.epoch(0) for ws in schedules]
        caches = [dv.remap_cache(es.cache_ids) for es in es_list]
        k_max = epoch_k_max(es_list, caches, dv)
        S = max(es.num_batches for es in es_list)
        staged.append((
            [es.cache_ids.copy() for es in es_list],
            collate_device_epoch(es_list, caches, dv, g.labels, 16,
                                 m_max, edge_max, k_max, S)))
    (cids_a, plan_a), (cids_b, plan_b) = staged
    for ca, cb in zip(cids_a, cids_b):
        np.testing.assert_array_equal(ca, cb)
    for k in ("send_ids", "send_pos", "send_mask", "input_nodes"):
        np.testing.assert_array_equal(plan_a[k], plan_b[k], err_msg=k)


# ---------------------------------------------------------------------------
# fault campaign (host-only fast lane; the full grid incl. device cells
# runs via `python -m repro.eval.campaign --fault` in CI)
# ---------------------------------------------------------------------------

def _fault_spec(profile):
    return CellSpec(backend="host", system="rapidgnn", dataset="tiny",
                    batch_size=16, workers=2, n_hot=64, epochs=2,
                    seed=42, fanouts=(5, 5), partition="greedy",
                    all_workers=False, net_enabled=False,
                    fault_profile=profile,
                    fault_seed=0 if profile == "none" else 7)


@pytest.fixture(scope="module")
def fault_cells():
    return [run_host_cell(_fault_spec(p))
            for p in ("none", "csec-loss", "pull-flaky")]


def test_fault_grid_well_formed():
    spec = fault_grid()
    profiles = {c.fault_profile for c in spec.cells}
    assert "none" in profiles and "cache-loss" in profiles
    # faulted cells are their own scenario: they never silently pair
    # with clean cells in the standard differential layers
    keys = [c.scenario_key() for c in spec.cells]
    assert len(set(keys)) == len(keys) - 1      # host+device "none" pair
    with pytest.raises(ValueError):
        _fault_spec("no-such-profile")


def test_fault_cells_fire_and_recover_bit_exact(fault_cells):
    clean, csec, pull = fault_cells
    assert clean.fault_events == 0 and clean.degraded_epochs == 0
    # every injection fired ...
    assert csec.fault_events > 0 and pull.fault_events > 0
    # ... forced the intended recovery path ...
    assert csec.csec_degraded >= 1 and csec.degraded_epochs >= 1
    assert pull.pull_retries >= 1 and pull.degraded_epochs == 0
    # ... and recovery is LOSSLESS: bit-equal loss curves vs clean
    for faulted in (csec, pull):
        assert faulted.losses == clean.losses


def test_verify_fault_pairs_has_teeth(fault_cells):
    checks = verify_fault_pairs(fault_cells)
    assert {c.check for c in checks} >= {"fault_fired",
                                         "fault_loss_parity"}
    assert all_pass(checks), failures(checks)
    # a diverged recovered curve must be caught
    bad = [CellResult.from_dict(copy.deepcopy(c.to_dict()))
           for c in fault_cells]
    bad[1].losses[0] += 0.25
    got = failures(verify_fault_pairs(bad))
    assert any(c.check == "fault_loss_parity" for c in got)
    # a plan that never fired must be caught too
    quiet = [CellResult.from_dict(copy.deepcopy(c.to_dict()))
             for c in fault_cells]
    quiet[2].fault_events = 0
    got = failures(verify_fault_pairs(quiet))
    assert any(c.check == "fault_fired" for c in got)


def test_fault_report_schema_round_trip(fault_cells, tmp_path):
    from repro.eval import write_report

    checks = verify_cells(fault_cells) + verify_fault_pairs(fault_cells)
    report = build_fault_report("fault", fault_cells, checks)
    assert validate_fault_report(report) == []
    assert report["all_checks_pass"], failures(checks)
    out = str(tmp_path / "BENCH_fault.json")
    write_report(report, out)
    with open(out) as f:
        loaded = json.load(f)
    assert validate_fault_report(loaded) == []
    # the acceptance criterion: >= 1 degraded-epoch cell in the artifact
    assert any(r["degraded_epochs"] > 0 for r in loaded["fault_summary"])
    # validator teeth: a campaign where nothing degrades is invalid
    for r in loaded["fault_summary"]:
        r["degraded_epochs"] = 0
    assert any("degraded" in p for p in validate_fault_report(loaded))


# ---------------------------------------------------------------------------
# the real cross-backend campaign (subprocess; slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fast_campaign_cross_backend_differential(tmp_path):
    """The acceptance path: the --fast grid's host AND device cells,
    every differential layer passing -- including miss_parity /
    payload_bytes / vector_pull_bytes against the REAL device runners."""
    from repro.eval.spec import fast_grid

    out = str(tmp_path / "BENCH_paper.json")
    report = run_campaign(fast_grid(), out_path=out)
    assert validate_report(report) == []
    assert report["all_checks_pass"], [
        c for c in report["differential"] if c["status"] == "FAIL"]
    parity = [c for c in report["differential"]
              if c["check"] == "miss_parity"]
    assert len(parity) == 2                     # rapid + baseline pairs
    assert all(c["status"] == "PASS" for c in parity)
    backends = {c["spec"]["backend"] for c in report["cells"]}
    assert backends == {"host", "device"}
