"""RapidGNN core invariants: determinism, Prop 3.1, cache bounds,
accounting identities (unit + property tests)."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.graph import load_dataset, partition_graph, KHopSampler
from repro.graph.sampler import derive_seed, rng_from
from repro.core import (build_schedule, ShardedFeatureStore,
                        RapidGNNRunner, BaselineRunner, NetworkModel,
                        FeatureCache, collate, global_pad_bounds,
                        assemble_features, EpochMetrics)
from repro.core.cache import EMPTY
from repro.core.runtime import occurrence_remote_ids


@pytest.fixture(scope="module")
def setup():
    g = load_dataset("tiny")
    pg = partition_graph(g, 2, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=32)
    ws = build_schedule(sampler, pg, worker=0, s0=7, num_epochs=2,
                        n_hot=128)
    return g, pg, sampler, ws


# ---- seeding / Prop 3.1 --------------------------------------------------

def test_seed_derivation_deterministic_and_distinct():
    s = derive_seed(42, 1, 2, 3)
    assert s == derive_seed(42, 1, 2, 3)
    seen = {derive_seed(42, w, e, i) for w in range(4) for e in range(4)
            for i in range(4)}
    assert len(seen) == 64          # no collisions across (w, e, i)


def test_sampler_determinism(setup):
    g, pg, sampler, ws = setup
    b1 = sampler.sample_batch(7, 0, 0, 0, ws.epoch(0).batches[0].seeds)
    b2 = sampler.sample_batch(7, 0, 0, 0, ws.epoch(0).batches[0].seeds)
    assert np.array_equal(b1.input_nodes, b2.input_nodes)
    for l in range(2):
        assert np.array_equal(b1.blocks[l].edge_src, b2.blocks[l].edge_src)


def test_sampler_uniform_marginal():
    """Prop 3.1(a): selection frequency of each adjacency slot is uniform
    (distinct neighbors weighted by their edge multiplicity -- the graph
    is a multigraph)."""
    g = load_dataset("tiny")
    v = int(np.argmax(g.in_degree()))
    nbrs = g.neighbors(v)
    uniq, mult = np.unique(nbrs, return_counts=True)
    sampler = KHopSampler(g, fanouts=[8], batch_size=1)
    counts = {int(u): 0 for u in uniq}
    trials = 400
    for i in range(trials):
        b = sampler.sample_batch(0, 0, 0, i, np.array([v]))
        picked = b.input_nodes[b.blocks[0].edge_src]
        for u in picked[b.blocks[0].edge_mask]:
            counts[int(u)] += 1
    freq = np.array([counts[int(u)] for u in uniq], np.float64)
    exp = freq.sum() * mult / mult.sum()
    assert np.all(np.abs(freq - exp) < 5 * np.sqrt(exp + 1) + 5)


def test_batches_differ_across_epochs_and_indices(setup):
    g, pg, sampler, ws = setup
    e0, e1 = ws.epoch(0), ws.epoch(1)
    assert not np.array_equal(e0.batches[0].seeds, e1.batches[0].seeds)
    assert not np.array_equal(e0.batches[0].input_nodes,
                              e0.batches[1].input_nodes)


# ---- schedule / cache invariants -----------------------------------------

def test_schedule_covers_all_train_nodes_once_per_epoch(setup):
    g, pg, sampler, ws = setup
    local = pg.local_nodes[0]
    train = local[g.train_mask[local]]
    for e in range(2):
        seeds = np.concatenate([b.seeds for b in ws.epoch(e).batches])
        assert np.array_equal(np.sort(seeds), np.sort(train))


def test_cache_ids_sorted_remote_only(setup):
    g, pg, sampler, ws = setup
    es = ws.epoch(0)
    assert np.all(np.diff(es.cache_ids) > 0)
    assert np.all(pg.owner[es.cache_ids] != 0)
    # top-n_hot by frequency: min cached freq >= max uncached freq is NOT
    # required (ties), but cached mass must be maximal for its size
    cached_mask = np.isin(es.remote_ids, es.cache_ids)
    if (~cached_mask).any() and cached_mask.any():
        assert es.remote_freq[cached_mask].min() >= \
            es.remote_freq[~cached_mask].max() - 0  # ties allowed


def test_memory_bound(setup):
    """Paper §3: Mem_device <= 2 n_hot d + Q m_max d."""
    g, pg, sampler, ws = setup
    store = ShardedFeatureStore(pg, worker=0, net=NetworkModel(
        enabled=False))
    runner = RapidGNNRunner(ws, store, batch_size=32, Q=4)
    runner.run()
    m_max, _ = global_pad_bounds(ws)
    bound = (2 * ws.n_hot * g.feat_dim) * 4
    assert runner.device_cache_bytes <= bound + 2 * ws.n_hot * 8 + 64


def test_feature_cache_lookup_correct():
    rng = np.random.default_rng(0)
    ids = np.sort(rng.choice(1000, 50, replace=False)).astype(np.int64)
    feats = rng.normal(size=(50, 8)).astype(np.float32)
    fc = FeatureCache(ids, feats)
    q = np.array([ids[3], 999999, ids[10], -5])
    pos, hit = fc.lookup(q)
    assert list(hit) == [True, False, True, False]
    assert np.allclose(feats[3], fc.feats[pos[0]])


def test_empty_cache_lookup_is_all_miss():
    """Regression: lookup/gather on a 0-entry cache raised IndexError
    (ids[pos_c] evaluated on an empty table) -- must short-circuit to an
    all-miss result, including for the EMPTY singleton."""
    q = np.array([5, 0, 999], np.int64)
    for fc in (EMPTY, FeatureCache(np.zeros(0, np.int64),
                                   np.zeros((0, 4), np.float32))):
        pos, hit = fc.lookup(q)
        assert pos.shape == q.shape and hit.shape == q.shape
        assert not hit.any()
        out = np.ones((3, fc.feats.shape[1]), np.float32)
        h = fc.gather(q, out)
        assert not h.any()
        np.testing.assert_allclose(out, 1.0)    # untouched
    # scalar query path
    _, hit = EMPTY.lookup(np.int64(7))
    assert not bool(hit)


def test_assemble_features_with_empty_cache(setup):
    """An installed-but-empty cache (e.g. a worker with no remote
    accesses) must behave exactly like cache=None."""
    g, pg, sampler, ws = setup
    store = ShardedFeatureStore(pg, worker=0,
                                net=NetworkModel(enabled=False))
    m_max, edge_max = global_pad_bounds(ws)
    b = ws.epoch(0).batches[0]
    cb = collate(b, g.labels, 32, m_max, edge_max)
    empty = FeatureCache(np.zeros(0, np.int64),
                         np.zeros((0, g.feat_dim), np.float32))
    feats = assemble_features(cb, store, empty, EpochMetrics(),
                              critical_path=False)
    np.testing.assert_allclose(feats[:b.num_input_nodes],
                               g.features[b.input_nodes])


# ---- accounting identities ------------------------------------------------


def test_baseline_dedupe_false_charges_per_occurrence(setup):
    """dedupe=False models the redundant-RPC regime: per-occurrence
    charging can never report FEWER remote bytes/RPCs than the deduped
    (per-batch-unique) default."""
    g, pg, sampler, ws = setup
    net = NetworkModel(enabled=False)
    dd = BaselineRunner(ws, ShardedFeatureStore(pg, 0, net),
                        batch_size=32, dedupe=True).run().totals()
    occ = BaselineRunner(ws, ShardedFeatureStore(pg, 0, net),
                         batch_size=32, dedupe=False).run().totals()
    assert occ["remote_bytes"] >= dd["remote_bytes"]
    assert occ["rpc_count"] >= dd["rpc_count"]
    # tiny graph has repeated neighbors within batches, so strictly more
    assert occ["remote_bytes"] > dd["remote_bytes"]
    # per-occurrence multiset covers every unique remote id per batch
    for e in range(len(ws.epochs)):
        for b in ws.epoch(e).batches[:2]:
            uniq = b.input_nodes[pg.owner[b.input_nodes] != 0]
            occ_ids = occurrence_remote_ids(b, pg.owner, 0)
            assert np.isin(uniq, occ_ids).all()

def test_rpc_equals_miss_set(setup):
    """Paper invariant: per-epoch RPC count == sum of miss-set sizes."""
    g, pg, sampler, ws = setup
    store = ShardedFeatureStore(pg, worker=0,
                                net=NetworkModel(enabled=False))
    runner = RapidGNNRunner(ws, store, batch_size=32, Q=2)
    m = runner.run()
    for em in m.epochs:
        assert em.rpc_count == em.cache_misses
        assert em.remote_bytes == em.rpc_count * g.feat_dim * 4


def test_all_local_sync_pull_charges_no_phantom_rpc(setup):
    """Regression: a fully-LOCAL SyncPull batch used to charge one
    phantom RPC (``n_rpc = max(len(owners), 1)``) and its modelled
    latency even though no partition was touched; it must charge zero
    RPCs, zero bytes and zero modelled network time."""
    g, pg, sampler, ws = setup
    store = ShardedFeatureStore(pg, worker=0, net=NetworkModel(
        enabled=True))                       # enabled: latency WOULD show
    local = pg.local_nodes[0][:8]
    for critical_path in (False, True):
        em = EpochMetrics()
        out = store.sync_pull(local, em, critical_path=critical_path)
        np.testing.assert_allclose(out, g.features[local])
        assert em.rpc_count == 0
        assert em.remote_bytes == 0
        assert em.modeled_net_time_s == 0.0
        assert em.sync_net_time_s == 0.0
        assert em.sync_pull_calls == 1       # the call itself is counted
    # a batch with remote ids still charges per-partition RPCs
    remote = pg.local_nodes[1][:4]
    em = EpochMetrics()
    store.sync_pull(remote, em)
    assert em.rpc_count == 4                 # |M_i|, not partitions
    assert em.modeled_net_time_s > 0.0


def test_baseline_fetches_all_remote(setup):
    g, pg, sampler, ws = setup
    store = ShardedFeatureStore(pg, worker=0,
                                net=NetworkModel(enabled=False))
    m = BaselineRunner(ws, store, batch_size=32).run()
    for e, em in enumerate(m.epochs):
        want = sum(int((pg.owner[b.input_nodes] != 0).sum())
                   for b in ws.epoch(e).batches)
        assert em.rpc_count == want


def test_rapidgnn_never_fetches_more_than_baseline(setup):
    g, pg, sampler, ws = setup
    net = NetworkModel(enabled=False)
    r = RapidGNNRunner(ws, ShardedFeatureStore(pg, 0, net),
                       batch_size=32).run().totals()
    b = BaselineRunner(ws, ShardedFeatureStore(pg, 0, net),
                       batch_size=32).run().totals()
    assert r["rpc_count"] < b["rpc_count"]


def test_assembled_features_match_ground_truth(setup):
    """End-to-end data-path correctness: every valid slot holds the true
    global feature row, regardless of cache/miss path taken."""
    g, pg, sampler, ws = setup
    store = ShardedFeatureStore(pg, worker=0,
                                net=NetworkModel(enabled=False))
    es = ws.epoch(0)
    m_max, edge_max = global_pad_bounds(ws)
    met = EpochMetrics()
    cache_feats = store.vector_pull(es.cache_ids, met)
    cache = FeatureCache(es.cache_ids, cache_feats)
    for b in es.batches[:3]:
        cb = collate(b, g.labels, 32, m_max, edge_max)
        feats = assemble_features(cb, store, cache, met,
                                  critical_path=False)
        want = g.features[b.input_nodes]
        np.testing.assert_allclose(feats[:b.num_input_nodes], want)
        np.testing.assert_allclose(feats[b.num_input_nodes:], 0.0)


# ---- property-based -------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 7), st.integers(0, 7))
def test_seed_streams_reproducible(s0, w, e):
    a = rng_from(s0, w, e, 0).integers(0, 1 << 30, 8)
    b = rng_from(s0, w, e, 0).integers(0, 1 << 30, 8)
    assert np.array_equal(a, b)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 500), st.integers(1, 64))
def test_cache_lookup_property(n, m):
    """searchsorted-based cache lookup: hits iff id in cache."""
    rng = np.random.default_rng(n * 1000 + m)
    ids = np.sort(rng.choice(10000, size=min(n, 100),
                             replace=False)).astype(np.int64)
    fc = FeatureCache(ids, rng.normal(size=(ids.size, 4)).astype(
        np.float32))
    q = rng.integers(0, 10000, size=m)
    _, hit = fc.lookup(q)
    assert np.array_equal(hit, np.isin(q, ids))
