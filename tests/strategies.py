"""Shared hypothesis strategies for the property suites, layered on the
``tests/_hyp.py`` shim (they run under real hypothesis when installed
and under the deterministic fallback otherwise).

Domain strategies:

  * ``cache_budgets``        -- n_hot values incl. the 0 / 1 boundaries
  * ``uneven_worker_cases``  -- partitioned tiny graph + per-worker
                                schedules with drawn zero/partial-train
                                workers (generalizes the fixed scenario
                                in tests/_uneven.py)
  * ``assemble_cases``       -- (table, base, cache, query, pulled)
                                tuples for the fused-assembly parity
                                suite, over drawn query mixes + shapes
  * ``pull_request_sets``    -- grouped pull requests with duplicates
                                and padding ids for the lane packer
  * ``plan_round_trips``     -- (P, n_per, d, m, seed) shapes for the
                                pull-plan owner/slot round trip
  * ``sampler_epoch_cases``  -- (graph, train, fanouts, B, s0, w, e)
                                for the schedule-compiler parity suite:
                                drawn graphs WITH zero-degree nodes,
                                empty / tiny / full train sets,
                                batch_size > |train|

plus ``build_assemble_case`` / ``build_sampler_graph`` as plain
deterministic builders the non-property regression tests anchor on.
"""
from __future__ import annotations

import numpy as np

from _hyp import st, composite
from _uneven import build_uneven_case

CACHE_PAD32 = np.int32(2 ** 31 - 1)

ASSEMBLE_KINDS = ("mixed", "all_hit", "all_miss", "all_local", "padded")


# ---------------------------------------------------------------------------
# scalar strategies
# ---------------------------------------------------------------------------

@composite
def cache_budgets(draw, hi=256):
    """Cache sizes with the degenerate boundaries (0: cache disabled /
    empty C_s; 1: single hot row) drawn often."""
    if draw(st.booleans()):
        return draw(st.sampled_from([0, 1, hi]))
    return draw(st.integers(2, hi))


@composite
def seeds(draw):
    return draw(st.integers(0, 2 ** 31 - 1))


# ---------------------------------------------------------------------------
# schedules over partitioned graphs
# ---------------------------------------------------------------------------

@composite
def uneven_worker_cases(draw, epochs=2):
    """-> (graph, pg, schedules, DeviceView): a 4-way partitioned tiny
    graph whose per-worker train sets are drawn -- possibly empty,
    possibly a fraction of a batch -- exercising every padding path of
    the epoch collation (zero-batch workers, short workers, ragged
    final batches)."""
    B = draw(st.integers(8, 24))
    n_hot = draw(cache_budgets(hi=128))
    s0 = draw(st.integers(0, 999))
    zero = draw(st.sampled_from([(), (2,), (0, 2)]))
    partial = {}
    if draw(st.booleans()):
        partial = {3: max(1, B // draw(st.integers(2, 4)))}
    return build_uneven_case(P_=4, B=B, epochs=epochs, n_hot=n_hot,
                             s0=s0, zero_workers=zero,
                             partial_workers=partial)


# ---------------------------------------------------------------------------
# fused-assembly cases
# ---------------------------------------------------------------------------

def build_assemble_case(kind, rng, P_=4, n_per=32, d=96, n_hot=24, m=48,
                        worker=1):
    """Build (table, base, cache_ids, cache_feats, query, pulled) for one
    named query mix (deterministic given ``rng``). Requires
    ``n_hot + m <= (P_ - 1) * n_per`` so the miss pool never underflows."""
    import jax.numpy as jnp

    base = worker * n_per
    table = rng.normal(size=(n_per, d)).astype(np.float32)
    local_pool = np.arange(base, base + n_per)
    remote_pool = np.setdiff1d(np.arange(P_ * n_per), local_pool)
    cids = np.sort(rng.choice(remote_pool, size=n_hot,
                              replace=False)).astype(np.int32)
    cfeats = rng.normal(size=(n_hot, d)).astype(np.float32)
    miss_pool = np.setdiff1d(remote_pool, cids)
    if kind == "mixed":
        q = np.concatenate([rng.choice(local_pool, size=m // 4),
                            rng.choice(cids, size=m // 4),
                            rng.choice(miss_pool, size=m // 4,
                                       replace=False),
                            np.full(m - 3 * (m // 4), -1)])
    elif kind == "all_hit":
        q = rng.choice(cids, size=m)
    elif kind == "all_miss":
        q = rng.choice(miss_pool, size=m, replace=False)
    elif kind == "all_local":
        q = rng.choice(local_pool, size=m)
    elif kind == "padded":
        q = np.concatenate([np.full(m // 2, -1),
                            np.full(m - m // 2, CACHE_PAD32)])
    else:
        raise ValueError(kind)
    q = q.astype(np.int32)
    rng.shuffle(q)
    pulled = np.where((q >= 0) & (q < CACHE_PAD32), 1.0, 0.0)[:, None] \
        * rng.normal(size=(m, d))
    return (jnp.asarray(table), jnp.int32(base), jnp.asarray(cids),
            jnp.asarray(cfeats), jnp.asarray(q),
            jnp.asarray(pulled.astype(np.float32)))


@composite
def assemble_cases(draw):
    """Drawn query mix AND drawn shapes (deliberately unrelated to any
    kernel tile size, so internal padding is always exercised)."""
    kind = draw(st.sampled_from(ASSEMBLE_KINDS))
    n_per = draw(st.integers(16, 48))
    d = draw(st.integers(3, 160))
    n_hot = draw(st.integers(1, n_per))          # miss pool >= 2*n_per
    m = draw(st.integers(8, 2 * n_per))
    rng = np.random.default_rng(draw(seeds()))
    return build_assemble_case(kind, rng, P_=4, n_per=n_per, d=d,
                               n_hot=n_hot, m=m)


# ---------------------------------------------------------------------------
# sampler epochs (schedule-compiler parity, DESIGN.md §2.1)
# ---------------------------------------------------------------------------

def build_sampler_graph(seed, n=40, n_zero=6, avg_deg=3):
    """Small random in-CSR graph whose first ``n_zero`` nodes have NO
    in-edges (the zero-degree masked-self-loop path the sampler must
    pad), deterministic given ``seed``."""
    from repro.graph.graph import Graph

    rng = np.random.default_rng(seed)
    ne = n * avg_deg
    dst = rng.integers(n_zero, n, size=ne).astype(np.int64)
    src = rng.integers(0, n, size=ne).astype(np.int64)
    return Graph.from_edges(
        src, dst, n, features=np.zeros((n, 4), np.float32),
        labels=rng.integers(0, 4, size=n).astype(np.int32),
        num_classes=4)


@composite
def sampler_epoch_cases(draw):
    """-> (graph, train_nodes, fanouts, batch_size, s0, worker, epoch)
    covering the compiler's boundary inputs: zero-degree nodes in and
    around the frontier, EMPTY train sets, train sets smaller than one
    batch (batch_size > |train|), and 1-3 layer fanout stacks."""
    n = draw(st.integers(12, 60))
    n_zero = draw(st.integers(0, n // 4))
    g = build_sampler_graph(draw(seeds()), n=n, n_zero=n_zero,
                            avg_deg=draw(st.integers(1, 6)))
    kind = draw(st.sampled_from(["empty", "tiny", "all", "subset"]))
    rng = np.random.default_rng(draw(seeds()))
    if kind == "empty":
        train = np.zeros(0, np.int64)
    elif kind == "tiny":        # with B up to 16: batch_size > |train|
        train = rng.choice(n, size=draw(st.integers(1, 3)),
                           replace=False)
    elif kind == "all":
        train = np.arange(n, dtype=np.int64)
    else:
        train = rng.choice(n, size=draw(st.integers(1, n)),
                           replace=False)
    fanouts = draw(st.sampled_from([(3,), (3, 2), (4, 3, 2)]))
    return (g, np.sort(train).astype(np.int64), fanouts,
            draw(st.integers(1, 16)), draw(st.integers(0, 999)),
            draw(st.integers(0, 3)), draw(st.integers(0, 3)))


# ---------------------------------------------------------------------------
# pull plans / lane packing
# ---------------------------------------------------------------------------

@composite
def plan_round_trips(draw):
    """(P, n_per, d, m, seed): m distinct global ids spread over P
    owners, positions 0..m-1 -- the owner/slot round-trip shape."""
    P_ = draw(st.integers(2, 6))
    n_per = draw(st.integers(4, 40))
    d = draw(st.integers(1, 16))
    m = draw(st.integers(1, min(P_ * n_per, 48)))
    return P_, n_per, d, m, draw(seeds())


@composite
def two_tier_cases(draw):
    """Grouped pull requests on a drawn hierarchical topology:
    -> (per_group [(ids, pos)...], owner_of, topo, requester, k_flat,
    k_intra, k_inter). ``requester`` is the flat worker issuing every
    request; the ``mode`` draw forces all-same-host and all-cross-host
    request sets often, so each tier's EMPTY degenerate path (intra
    carrying everything / inter carrying everything) is exercised, not
    just the mixed case. k bounds are true maxima plus drawn slack."""
    from repro.dist import Topology

    hosts = draw(st.integers(1, 3))
    dph = draw(st.integers(1, 3))
    topo = Topology.hierarchical(hosts, dph)
    P_ = topo.num_workers
    n_per = draw(st.integers(4, 16))
    G = draw(st.integers(1, 5))
    requester = draw(st.integers(0, P_ - 1))
    owner_of = np.repeat(np.arange(P_), n_per)
    mode = draw(st.sampled_from(["mixed", "same_only", "cross_only"]))
    if hosts == 1 and mode == "cross_only":
        mode = "same_only"              # one host: everything is local
    all_ids = np.arange(P_ * n_per)
    same_pool = all_ids[np.asarray(
        topo.same_host(owner_of, requester))]
    cross_pool = np.setdiff1d(all_ids, same_pool)
    pool = {"mixed": all_ids, "same_only": same_pool,
            "cross_only": cross_pool}[mode]
    rng = np.random.default_rng(draw(seeds()))
    per_group = []
    k_flat = k_intra = k_inter = 1
    for _ in range(G):
        n = int(rng.integers(0, 24))
        gi = np.where(rng.random(n) < 0.15, -1,
                      rng.choice(pool, size=n) if pool.size
                      else np.full(n, -1))
        gp = rng.integers(0, 64, size=n)
        if n > 4:                                     # inject exact dupes
            gi[:2] = gi[2:4]
            gp[:2] = gp[2:4]
        valid = gi >= 0
        if valid.any():
            uniq = np.unique(np.stack([gi[valid], gp[valid]]), axis=1)
            own = owner_of[uniq[0]]
            k_flat = max(k_flat, int(np.bincount(
                own, minlength=P_).max()))
            same = np.asarray(topo.same_host(own, requester))
            if same.any():
                k_intra = max(k_intra, int(np.bincount(
                    topo.local_of(own[same]),
                    minlength=topo.devices_per_host).max()))
            if (~same).any():
                k_inter = max(k_inter, int(np.bincount(
                    own[~same], minlength=P_).max()))
        per_group.append((gi, gp))
    slack = int(rng.integers(0, 3))
    return (per_group, owner_of, topo, requester, k_flat + slack,
            k_intra + slack, k_inter + slack)


@composite
def pull_request_sets(draw):
    """Grouped pull requests with exact duplicates and -1 padding rows:
    -> (per_group [(ids, pos)...], owner_of, P, k_max). ``k_max`` is
    sized to the true per-(group, owner) maximum so packing never
    overflows but often runs exactly full."""
    P_ = draw(st.integers(1, 5))
    n_per = draw(st.integers(4, 24))
    G = draw(st.integers(1, 6))
    owner_of = np.repeat(np.arange(P_), n_per)
    rng = np.random.default_rng(draw(seeds()))
    per_group = []
    k_need = 1
    for _ in range(G):
        n = int(rng.integers(0, 30))
        gi = rng.integers(-1, P_ * n_per, size=n)     # -1: padding rows
        gp = rng.integers(0, 64, size=n)
        if n > 4:                                     # inject exact dupes
            gi[:2] = gi[2:4]
            gp[:2] = gp[2:4]
        valid = gi >= 0
        if valid.any():
            uniq = np.unique(np.stack([gi[valid], gp[valid]]), axis=1)
            counts = np.bincount(owner_of[uniq[0]], minlength=P_)
            k_need = max(k_need, int(counts.max()))
        per_group.append((gi, gp))
    k_max = k_need + int(rng.integers(0, 3))
    return per_group, owner_of, P_, k_max
