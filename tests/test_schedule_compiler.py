"""Schedule-compiler suite (ISSUE 5 / DESIGN.md §2.1): the vectorized
``sample_epoch_batched`` must be BIT-identical to the per-batch
``sample_epoch`` oracle, FlatEpoch must round-trip through its
SampledBatch views and the npz spill, and hot-set selection must break
frequency ties deterministically (Prop 3.1)."""
import dataclasses
import tempfile

import numpy as np
import pytest

from _hyp import ALL_HEALTH_CHECKS, given, settings
from strategies import build_sampler_graph, sampler_epoch_cases
from repro.graph import load_dataset, partition_graph, KHopSampler
from repro.graph.sampler import FlatEpoch
from repro.core import build_schedule
from repro.core.schedule import select_hot_set


def assert_batches_bit_equal(ref, got):
    assert len(ref) == len(got)
    for br, bn in zip(ref, got):
        assert (br.epoch, br.index, br.worker) == \
            (bn.epoch, bn.index, bn.worker)
        np.testing.assert_array_equal(br.seeds, bn.seeds)
        np.testing.assert_array_equal(br.input_nodes, bn.input_nodes)
        assert br.input_nodes.dtype == bn.input_nodes.dtype
        assert len(br.blocks) == len(bn.blocks)
        for x, y in zip(br.blocks, bn.blocks):
            assert (x.num_src, x.num_dst) == (y.num_src, y.num_dst)
            for f in ("edge_src", "edge_dst", "edge_mask"):
                a, b = getattr(x, f), getattr(y, f)
                np.testing.assert_array_equal(a, b)
                assert a.dtype == b.dtype


# ---- batched vs per-batch oracle (the tentpole contract) -----------------

@settings(max_examples=25, deadline=None,
          suppress_health_check=ALL_HEALTH_CHECKS)
@given(sampler_epoch_cases())
def test_batched_sampler_bit_equal_to_loop(case):
    """For ANY drawn (graph, train, fanouts, B): every seed, input-node
    and edge array of every batch is bit-equal between the whole-epoch
    compiler and the per-batch oracle -- including zero-degree nodes,
    empty train sets and batch_size > |train|."""
    g, train, fanouts, B, s0, w, e = case
    sampler = KHopSampler(g, fanouts=list(fanouts), batch_size=B)
    loop = sampler.sample_epoch(s0, w, e, train)
    flat = sampler.sample_epoch_batched(s0, w, e, train)
    assert flat.num_batches == len(loop)
    assert flat.num_layers == len(fanouts)
    assert_batches_bit_equal(loop, flat.to_batches())


def test_batched_sampler_int64_key_fallback(monkeypatch):
    """Key spaces past the int32 bound take the wide-key path; it must
    stay bit-equal to the oracle too."""
    import repro.graph.sampler as sampler_mod

    g = build_sampler_graph(3, n=50, n_zero=8)
    train = np.arange(50, dtype=np.int64)
    s = KHopSampler(g, fanouts=[3, 2], batch_size=7)
    monkeypatch.setattr(sampler_mod, "KEY_INT32_MAX_SLOTS", 0)
    flat = s.sample_epoch_batched(11, 0, 1, train)
    monkeypatch.undo()
    assert_batches_bit_equal(s.sample_epoch(11, 0, 1, train),
                             flat.to_batches())


# ---- FlatEpoch <-> SampledBatch round trip -------------------------------

@settings(max_examples=15, deadline=None,
          suppress_health_check=ALL_HEALTH_CHECKS)
@given(sampler_epoch_cases())
def test_flat_epoch_round_trip(case):
    """from_batches(to_batches(flat)) reproduces every flat array,
    offset vector and dtype."""
    g, train, fanouts, B, s0, w, e = case
    sampler = KHopSampler(g, fanouts=list(fanouts), batch_size=B)
    flat = sampler.sample_epoch_batched(s0, w, e, train)
    back = FlatEpoch.from_batches(flat.to_batches(), epoch=e, worker=w,
                                  num_layers=len(fanouts))
    for f in ("seeds", "seed_starts", "input_nodes", "input_starts",
              "num_dst"):
        np.testing.assert_array_equal(getattr(back, f), getattr(flat, f))
    for l in range(flat.num_layers):
        for f in ("edge_src", "edge_dst", "edge_mask", "edge_starts"):
            a, b = getattr(back, f)[l], getattr(flat, f)[l]
            np.testing.assert_array_equal(a, b)
            assert a.dtype == b.dtype


# ---- build_schedule: loop oracle, npz spill ------------------------------

def _assert_epochs_equal(a, b):
    assert a.epoch == b.epoch and a.m_max == b.m_max
    for f in ("remote_ids", "remote_freq", "cache_ids"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    assert_batches_bit_equal(a.batches, b.batches)


def test_build_schedule_compilers_identical():
    """End to end on a real partitioned graph: the batched compiler and
    the loop oracle produce identical schedules (payload + hot-set
    metadata + pad bounds)."""
    g = load_dataset("tiny")
    pg = partition_graph(g, 4, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=16)
    kw = dict(s0=42, num_epochs=2, n_hot=64)
    for w in range(4):
        wb = build_schedule(sampler, pg, worker=w, compiler="batched",
                            **kw)
        wl = build_schedule(sampler, pg, worker=w, compiler="loop", **kw)
        for e in range(2):
            _assert_epochs_equal(wb.epoch(e), wl.epoch(e))
        assert wb.pad_bounds() == wl.pad_bounds()
    with pytest.raises(ValueError):
        build_schedule(sampler, pg, worker=0, compiler="bogus", **kw)


def test_npz_spill_round_trip_equals_in_memory():
    """Spilled epochs reload bit-equal to the in-memory schedule: flat
    payload, hot-set metadata, pad bounds."""
    g = load_dataset("tiny")
    pg = partition_graph(g, 2, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=32)
    kw = dict(worker=0, s0=7, num_epochs=2, n_hot=64)
    mem = build_schedule(sampler, pg, **kw)
    with tempfile.TemporaryDirectory() as td:
        sp = build_schedule(sampler, pg, spill_dir=td, **kw)
        assert all(x is None for x in sp.epochs)
        for e in range(2):
            a, b = mem.epoch(e), sp.epoch(e)
            _assert_epochs_equal(a, b)
            for f in ("seed_starts", "input_starts"):
                np.testing.assert_array_equal(getattr(a.flat, f),
                                              getattr(b.flat, f))
        assert mem.pad_bounds() == sp.pad_bounds()


# ---- deterministic hot-set selection (satellite: Prop 3.1) ---------------

def test_hot_set_tie_break_boundary():
    """Frequency ties straddling the n_hot boundary resolve by (freq
    desc, id asc) -- never by partition internals."""
    ids = np.array([10, 11, 12, 13, 14], np.int64)
    freq = np.array([3, 1, 2, 1, 1], np.int64)
    # boundary cuts through the freq-1 tie class {11, 13, 14}: the
    # lowest id must win the last slot
    np.testing.assert_array_equal(select_hot_set(ids, freq, 3),
                                  [10, 11, 12])
    np.testing.assert_array_equal(select_hot_set(ids, freq, 4),
                                  [10, 11, 12, 13])
    # all tied: lowest ids win
    np.testing.assert_array_equal(
        select_hot_set(ids, np.ones(5, np.int64), 2), [10, 11])
    # degenerate sizes
    np.testing.assert_array_equal(select_hot_set(ids, freq, 99), ids)
    assert select_hot_set(np.zeros(0, np.int64),
                          np.zeros(0, np.int64), 4).size == 0
    assert select_hot_set(ids, freq, 0).size == 0


def test_hot_set_deterministic_on_real_schedule():
    """The built cache is exactly the (freq desc, id asc) prefix of the
    epoch's remote set -- reconstructable from remote_ids/remote_freq
    alone, so no numpy partition detail can leak in."""
    g = load_dataset("tiny")
    pg = partition_graph(g, 4, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=16)
    ws = build_schedule(sampler, pg, worker=1, s0=3, num_epochs=1,
                        n_hot=40)
    es = ws.epoch(0)
    assert 0 < es.cache_ids.shape[0] <= 40
    order = np.lexsort((es.remote_ids, -es.remote_freq))
    want = np.sort(es.remote_ids[order[:es.cache_ids.shape[0]]])
    np.testing.assert_array_equal(es.cache_ids, want)


# ---- zero-batch synthetic workers through the device collation -----------

def test_collate_skips_zero_layer_empty_worker():
    """Regression: a synthetic ``EpochSchedule(batches=[])`` carries a
    0-layer FlatEpoch (no layer count to infer); the slab-fill loop
    must skip it like the old rec loop did, leaving its steps fully
    masked."""
    from repro.core.schedule import EpochSchedule
    from repro.dist.gnn_step import (collate_device_epoch,
                                     collate_device_epoch_loop,
                                     empty_caches)
    from repro.dist import DeviceView

    g = load_dataset("tiny")
    pg = partition_graph(g, 2, "greedy")
    dv = DeviceView.build(pg)
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=16)
    ws = build_schedule(sampler, pg, worker=0, s0=1, num_epochs=1,
                        n_hot=0)
    es_list = [ws.epoch(0), EpochSchedule(epoch=0, batches=[])]
    caches = empty_caches(2, g.feat_dim)
    from repro.core.schedule import epoch_edge_maxima
    edge_max = epoch_edge_maxima(es_list[0])
    args = (es_list, caches, dv, g.labels, 16, es_list[0].m_max,
            edge_max, 64, es_list[0].num_batches)
    vec = collate_device_epoch(*args)
    loop = collate_device_epoch_loop(*args)
    for k in ("input_nodes", "labels", "seed_mask", "send_ids",
              "send_pos", "send_mask"):
        np.testing.assert_array_equal(vec[k], loop[k])
    for k in ("edge_src", "edge_dst", "edge_mask"):
        for l in range(len(edge_max)):
            np.testing.assert_array_equal(vec[k][l], loop[k][l])
    assert not vec["seed_mask"][:, 1].any()     # empty worker all-masked


# ---- campaign plumbing ---------------------------------------------------

def test_cellspec_schedule_compiler_field():
    from repro.eval.spec import CellSpec

    c = CellSpec(backend="host", system="rapidgnn", dataset="tiny",
                 batch_size=16, workers=4, n_hot=64, epochs=1,
                 schedule_compiler="loop")
    assert CellSpec.from_dict(c.to_dict()) == c
    # the compiler toggle is NOT part of the differential pairing key:
    # schedules are bit-identical either way
    assert c.scenario_key() == dataclasses.replace(
        c, schedule_compiler="batched").scenario_key()
    with pytest.raises(ValueError):
        CellSpec(backend="host", system="rapidgnn", dataset="tiny",
                 batch_size=16, workers=4, n_hot=64, epochs=1,
                 schedule_compiler="bogus")
