"""Single-device tests for the pull-plan wire format (no subprocess, no
mesh): build_pull_plan's packing is pure numpy, so its id->(owner, slot)
round trip, dedupe, and overflow contract are checked by simulating the
exchange host-side (DESIGN.md §6.2). The round trip is a PROPERTY over
drawn shapes (tests/strategies.py)."""
import numpy as np
import pytest

from _hyp import ALL_HEALTH_CHECKS, given, settings
from strategies import plan_round_trips, two_tier_cases
from repro.dist import (build_pull_plan, pack_pull_lanes,
                        pack_pull_lanes_two_tier)
from repro.dist.gnn_step import DeviceView
from repro.graph import load_dataset, partition_graph


def _simulate_exchange(plan, table, offsets, m_max, d):
    """Host-side replay of pull_shard's two all_to_all legs."""
    out = np.zeros((m_max, d), np.float32)
    for p in range(plan.send_ids.shape[0]):
        lanes = plan.send_mask[p]
        slots = plan.send_ids[p][lanes] - offsets[p]
        out[plan.send_pos[p][lanes]] = table[p][slots]
    return out


@settings(max_examples=25, deadline=None,
          suppress_health_check=ALL_HEALTH_CHECKS)
@given(plan_round_trips())
def test_round_trip_owner_slot(case):
    """For ANY (P, n_per, d, m): every id lands in its owner's lane and
    the replayed exchange reproduces a direct gather."""
    P_, n_per, d, m, seed = case
    rng = np.random.default_rng(seed)
    table = rng.normal(size=(P_, n_per, d)).astype(np.float32)
    owner = np.repeat(np.arange(P_), n_per)
    offsets = np.arange(P_) * n_per
    ids = rng.choice(P_ * n_per, size=m, replace=False).astype(np.int32)
    pos = np.arange(m, dtype=np.int32)
    plan = build_pull_plan(ids, pos, owner, P_, k_max=m)
    # every id landed in its owner's lane...
    for p in range(P_):
        lane_ids = plan.send_ids[p][plan.send_mask[p]]
        assert np.all(owner[lane_ids] == p)
    assert int(plan.counts.sum()) == m
    # ...and the replayed exchange reproduces a direct gather
    out = _simulate_exchange(plan, table, offsets, m, d)
    np.testing.assert_allclose(out[pos],
                               table.reshape(-1, d)[ids], rtol=0)


def test_padding_ids_dropped():
    owner = np.repeat(np.arange(2), 8)
    ids = np.array([3, -1, 12, -1], np.int32)
    pos = np.array([0, 1, 2, 3], np.int32)
    plan = build_pull_plan(ids, pos, owner, 2, k_max=4)
    assert plan.counts.tolist() == [1, 1]
    assert int(plan.send_mask.sum()) == 2


def test_dedupe_repeated_id_pos_pairs():
    """Exact (id, pos) duplicates collapse to one lane slot; the same id
    at distinct positions keeps one slot per position (each output row
    must receive its feature)."""
    owner = np.zeros(16, np.int64)
    ids = np.array([5, 5, 5, 9], np.int32)
    pos = np.array([2, 2, 7, 0], np.int32)
    plan = build_pull_plan(ids, pos, owner, 1, k_max=4)
    assert int(plan.counts[0]) == 3          # (5,2) deduped, (5,7) kept
    got = sorted(zip(plan.send_ids[0][plan.send_mask[0]].tolist(),
                     plan.send_pos[0][plan.send_mask[0]].tolist()))
    assert got == [(5, 2), (5, 7), (9, 0)]


def test_overflow_raises_not_truncates():
    owner = np.zeros(64, np.int64)
    ids = np.arange(10, dtype=np.int32)
    pos = np.arange(10, dtype=np.int32)
    with pytest.raises(ValueError, match="k_max"):
        build_pull_plan(ids, pos, owner, 1, k_max=4)
    # boundary: exactly k_max fits
    plan = build_pull_plan(ids, pos, owner, 1, k_max=10)
    assert int(plan.counts[0]) == 10


def test_pack_pull_lanes_big_base_stays_on_fast_path(monkeypatch):
    """Regression: ``span_i`` computed from ``ids.max() + 1`` pushed big
    device-id bases (large P*n_per puts every id near 2**31) onto the
    slow lexsort fallback even when the epoch's actual id RANGE was
    tiny. The rebased key must (a) keep this boundary case on the
    single-sort path and (b) pack identically to both the lexsort
    fallback and per-group ``build_pull_plan``."""
    from repro.dist import feature_a2a
    from repro.dist.feature_a2a import _fast_key_fits, pack_pull_lanes

    num_groups, P_ = 1536, 256            # 6 steps x 256 workers
    base = 2 ** 31 - 2 ** 13              # ids stay int32-safe
    rng = np.random.default_rng(0)
    n = 400
    ids = (base + rng.integers(0, 4096, size=n)).astype(np.int64)
    pos = rng.integers(0, 8192, size=n).astype(np.int64)
    group = rng.integers(0, num_groups, size=n).astype(np.int64)
    owner = rng.integers(0, P_, size=n).astype(np.int64)
    k_max = 8

    # the historical absolute-max span overflows the int64 key budget...
    assert not _fast_key_fits(num_groups, P_, int(ids.max()) + 1,
                              int(pos.max()) + 1)
    # ...the rebased span does not: the fast path stays available
    assert _fast_key_fits(num_groups, P_,
                          int(ids.max()) - int(ids.min()) + 1,
                          int(pos.max()) - int(pos.min()) + 1)

    args = (ids, pos, group, owner, num_groups, P_, k_max)
    fast = pack_pull_lanes(*args)
    monkeypatch.setattr(feature_a2a, "_fast_key_fits",
                        lambda *a: False)       # force lexsort fallback
    slow = pack_pull_lanes(*args)
    for a, b in zip(fast, slow):
        np.testing.assert_array_equal(a, b)
    monkeypatch.undo()

    # lane contents: every (group, owner) lane holds exactly its
    # requests, ascending by (id, pos) -- the build_pull_plan contract
    sids, spos, smask, counts = fast
    assert int(counts.sum()) == n
    for gid in np.unique(group):
        sel = group == gid
        for p in np.unique(owner[sel]):
            lane = smask[gid, p]
            want = sel & (owner == p)
            order = np.lexsort((pos[want], ids[want]))
            np.testing.assert_array_equal(
                sids[gid, p][lane],
                ids[want][order].astype(np.int32))
            np.testing.assert_array_equal(
                spos[gid, p][lane],
                pos[want][order].astype(np.int32))


def test_negative_owner_raises_not_crashes():
    """Regression: an out-of-range owner (e.g. a corrupted owner map
    handing an id to worker -1) used to crash inside ``np.bincount``
    with an opaque numpy error; build_pull_plan must validate owners
    explicitly with the same message ``pack_pull_lanes`` uses."""
    owner = np.array([0, 0, -1, 1], np.int64)     # id 2 owned by "-1"
    ids = np.array([2], np.int32)
    pos = np.array([0], np.int32)
    with pytest.raises(ValueError, match="owner id out of range"):
        build_pull_plan(ids, pos, owner, 2, k_max=4)
    with pytest.raises(ValueError, match="owner id out of range"):
        build_pull_plan(np.array([3], np.int32), pos,
                        np.array([0, 0, 0, 7], np.int64), 2, k_max=4)
    # negative IDS are padding and still fine (dropped before validation)
    plan = build_pull_plan(np.array([-1], np.int32), pos, owner, 2,
                           k_max=4)
    assert int(plan.counts.sum()) == 0


def test_request_bytes_accounts_id_leg():
    """Satellite bugfix: the (P, k_max) int32 id matrix the request leg
    ships was never accounted; request_bytes covers it."""
    owner = np.repeat(np.arange(2), 8)
    ids = np.array([3, 12], np.int32)
    pos = np.array([0, 1], np.int32)
    plan = build_pull_plan(ids, pos, owner, 2, k_max=4)
    assert plan.request_bytes() == 2 * 4 * 4      # P * k_max * itemsize
    assert plan.request_bytes() == plan.send_ids.size * 4


@settings(max_examples=25, deadline=None,
          suppress_health_check=ALL_HEALTH_CHECKS)
@given(two_tier_cases())
def test_two_tier_union_bit_equal_to_flat(case):
    """Two-tier parity property (DESIGN.md §6.7): for ANY drawn
    topology, requester and request mix -- including the all-same-host
    and all-cross-host degenerate draws where one tier is empty -- the
    union of the intra and inter lane sets is bit-equal to the flat
    ``pack_pull_lanes`` packing: every (group, owner) bucket lands in
    exactly one tier, with identical ascending (id, pos) lanes."""
    per_group, owner_of, topo, requester, k_flat, k_i, k_x = case
    G = len(per_group)
    P_, D = topo.num_workers, topo.devices_per_host
    ids = np.concatenate([gi for gi, _ in per_group]) \
        if per_group else np.zeros(0, np.int64)
    pos = np.concatenate([gp for _, gp in per_group]) \
        if per_group else np.zeros(0, np.int64)
    group = np.concatenate([np.full(len(gi), g)
                            for g, (gi, _) in enumerate(per_group)])
    owner = owner_of[np.maximum(ids, 0)]          # -1 ids: dropped anyway
    req = np.full(ids.shape, requester)

    flat = pack_pull_lanes(ids, pos, group, owner, G, P_, k_flat)
    intra, inter = pack_pull_lanes_two_tier(
        ids, pos, group, owner, req, G, topo, k_i, k_x)

    f_ids, f_pos, f_mask, f_cnt = flat
    i_ids, i_pos, i_mask, i_cnt = intra
    x_ids, x_pos, x_mask, x_cnt = inter
    assert i_ids.shape == (G, D, k_i)
    assert x_ids.shape == (G, P_, k_x)
    # the tiers partition the flat lane total exactly
    assert int(i_cnt.sum()) + int(x_cnt.sum()) == int(f_cnt.sum())
    host_r = topo.host_of(requester)
    for g in range(G):
        for o in range(P_):
            lane = f_mask[g, o]
            if topo.host_of(o) == host_r:
                tid = i_ids[g, topo.local_of(o)][i_mask[g,
                                                        topo.local_of(o)]]
                tpo = i_pos[g, topo.local_of(o)][i_mask[g,
                                                        topo.local_of(o)]]
                # same-host owners never appear on the DCN tier
                assert int(x_cnt[g, o]) == 0
            else:
                tid = x_ids[g, o][x_mask[g, o]]
                tpo = x_pos[g, o][x_mask[g, o]]
            np.testing.assert_array_equal(tid, f_ids[g, o][lane])
            np.testing.assert_array_equal(tpo, f_pos[g, o][lane])


def test_device_view_round_trip():
    """DeviceView relabeling: g2d is a bijection onto per-partition slot
    ranges and the sharded table holds the right rows."""
    g = load_dataset("tiny")
    pg = partition_graph(g, 4, "greedy")
    dv = DeviceView.build(pg)
    assert dv.table.shape == (4, dv.n_per, g.feat_dim)
    for p, loc in enumerate(pg.local_nodes):
        dev = dv.g2d[loc]
        assert np.all(dev // dv.n_per == p)           # ownership by range
        np.testing.assert_array_equal(
            dv.table[p, dev - p * dv.n_per], g.features[loc])
    # remapped caches stay sorted unique (binary-search contract) and
    # feature-aligned (slot order tracks sorted global order per part)
    es_cache = pg.local_nodes[1][:16]                 # sorted global ids
    dc = dv.remap_cache(es_cache)
    assert np.all(np.diff(dc.ids) > 0)
    np.testing.assert_array_equal(dc.feats, g.features[es_cache])
