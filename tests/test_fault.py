"""Fault-injection plane (DESIGN.md §10): plan determinism, probe
semantics, per-site fire + bit-exact recovery on the host runtime,
spill heal, checkpoint atomicity, and the seeded chaos property
(every random plan either recovers bit-exactly or raises typed)."""
import dataclasses
import os

import numpy as np
import pytest
from _hyp import ALL_HEALTH_CHECKS, given, settings, st

from repro.fault import (FaultPlan, FaultRule, FatalFault, InjectedCrash,
                         InjectedFault, TransientFault, active_plan,
                         current, fault_point, plan_from_profile,
                         random_plan, retry_call)
from repro.fault.plan import PROFILES


# ---- plan determinism / rule gating --------------------------------------

def test_decide_is_order_independent():
    """The Bernoulli draw is a pure function of (site, kind, rule,
    attempt, ctx) -- two plans with the same seed agree on every context
    no matter which order the contexts are probed in."""
    rules = (FaultRule("pull", "error", p=0.5, max_attempt=3),)
    ctxs = [(a, e, w, i) for a in range(2) for e in range(3)
            for w in range(2) for i in range(3)]
    fwd = FaultPlan(11, rules)
    rev = FaultPlan(11, rules)
    got_f = [fwd.decide("pull", *c) is not None for c in ctxs]
    got_r = [rev.decide("pull", *c) is not None for c in reversed(ctxs)]
    assert got_f == got_r[::-1]
    assert fwd.snapshot() == rev.snapshot()
    # and it is not degenerate: a p=0.5 rule both fires and skips
    assert 0 < sum(got_f) < len(got_f)
    # a different seed gives a different schedule
    other = FaultPlan(12, rules)
    got_o = [other.decide("pull", *c) is not None for c in ctxs]
    assert got_o != got_f


def test_rule_context_gating():
    plan = FaultPlan(0, (FaultRule("prefetch", "error", epochs=(1,),
                                   workers=(0,), indices=(2,),
                                   max_attempt=1),))
    hit = dict(attempt=0, epoch=1, worker=0, index=2)
    assert plan.decide("prefetch", **hit) is not None
    assert plan.decide("pull", **hit) is None              # wrong site
    assert plan.decide("prefetch", 0, 0, 0, 2) is None     # wrong epoch
    assert plan.decide("prefetch", 0, 1, 1, 2) is None     # wrong worker
    assert plan.decide("prefetch", 0, 1, 0, 0) is None     # wrong index
    assert plan.decide("prefetch", 1, 1, 0, 2) is not None # attempt <= max
    assert plan.decide("prefetch", 2, 1, 0, 2) is None     # retry cleared
    assert plan.fires("prefetch", "error") == 2
    assert plan.total_fires() == 2
    assert plan.snapshot() == {"prefetch:error": 2}


def test_rule_validation():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultRule("nope", "error")
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultRule("pull", "nope")
    with pytest.raises(ValueError, match="outside"):
        FaultRule("pull", "error", p=1.5)
    with pytest.raises(ValueError, match="unknown fault profile"):
        plan_from_profile("nope")
    for name in PROFILES:
        plan_from_profile(name, seed=3)     # every profile constructs


def test_random_plan_is_seed_stable():
    a, b = random_plan(7, 2), random_plan(7, 2)
    assert a.rules == b.rules
    assert random_plan(7, 3).rules != a.rules or \
        random_plan(8, 2).rules != a.rules


# ---- fault_point / retry_call semantics ----------------------------------

def test_fault_point_without_plan_is_noop():
    assert current() is None
    assert fault_point("pull", epoch=0) is None


def test_fault_point_kinds():
    def plan_for(kind, **kw):
        return FaultPlan(0, (FaultRule("pull", kind, **kw),))

    with active_plan(plan_for("error")):
        with pytest.raises(TransientFault):
            fault_point("pull")
    with active_plan(plan_for("fatal")):
        with pytest.raises(FatalFault):
            fault_point("pull")
    with active_plan(plan_for("crash")):
        with pytest.raises(InjectedCrash):
            fault_point("pull")
    with active_plan(plan_for("hang", delay_s=0.01)):
        assert fault_point("pull") == "hang"
    # file kind without a path operand degrades to an advisory return
    with active_plan(plan_for("drop")):
        assert fault_point("pull") == "drop"
    assert current() is None                # context manager restored


def test_fault_point_file_damage(tmp_path):
    payload = bytes(range(200)) * 10
    for kind in ("corrupt", "truncate", "drop"):
        p = tmp_path / f"{kind}.bin"
        p.write_bytes(payload)
        plan = FaultPlan(0, (FaultRule("spill_write", kind),))
        with active_plan(plan):
            assert fault_point("spill_write", path=str(p)) == kind
        if kind == "drop":
            assert not p.exists()
        elif kind == "truncate":
            assert p.stat().st_size == len(payload) // 2
        else:
            got = p.read_bytes()
            assert len(got) == len(payload) and got != payload
            assert sum(a != b for a, b in zip(got, payload)) == 1


def test_retry_call_clears_transient_and_bounds_attempts():
    calls = []

    def flaky(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise TransientFault("boom")
        return "done"

    retried = []
    out = retry_call(flaky, retries=3, base_delay_s=0.0,
                     on_retry=retried.append)
    assert out == "done" and calls == [0, 1, 2] and retried == [0, 1]

    with pytest.raises(TransientFault):
        retry_call(lambda a: (_ for _ in ()).throw(TransientFault("x")),
                   retries=2, base_delay_s=0.0)
    with pytest.raises(FatalFault):       # non-retryable passes through
        retry_call(lambda a: (_ for _ in ()).throw(FatalFault("x")),
                   retries=2, base_delay_s=0.0)


# ---- spill integrity + heal ----------------------------------------------

@pytest.fixture()
def spilled(tmp_path):
    from repro.core import build_schedule
    from repro.graph import KHopSampler, load_dataset, partition_graph

    g = load_dataset("tiny")
    pg = partition_graph(g, 2, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=32)
    ws = build_schedule(sampler, pg, worker=0, s0=7, num_epochs=2,
                        n_hot=64, spill_dir=str(tmp_path))
    return ws


def _damage(path, how):
    if how == "drop":
        os.remove(path)
    elif how == "truncate":
        os.truncate(path, os.path.getsize(path) // 2)
    else:
        with open(path, "r+b") as f:
            f.seek(os.path.getsize(path) // 2)
            b = f.read(1)
            f.seek(-1, os.SEEK_CUR)
            f.write(bytes([b[0] ^ 0xFF]))


@pytest.mark.parametrize("how", ["corrupt", "truncate", "drop"])
def test_spill_damage_heals_bit_identically(spilled, tmp_path, how):
    from repro.core.schedule import SpillCorruptError, load_epoch_npz, \
        spill_path

    ws = spilled
    ref = ws.epoch(1)                       # clean read first
    path = spill_path(str(tmp_path), 0, 1)
    _damage(path, how)
    if how != "drop":                       # direct load surfaces typed
        with pytest.raises(SpillCorruptError) as ei:
            load_epoch_npz(path)
        assert ei.value.path == path
    healed = ws.epoch(1)                    # heal: rebuild + re-spill
    assert ws.spill_rebuilds == 1
    np.testing.assert_array_equal(healed.cache_ids, ref.cache_ids)
    np.testing.assert_array_equal(healed.flat.seeds, ref.flat.seeds)
    np.testing.assert_array_equal(healed.flat.input_nodes,
                                  ref.flat.input_nodes)
    again = load_epoch_npz(path)            # re-spilled file loads clean
    np.testing.assert_array_equal(again.flat.seeds, ref.flat.seeds)
    assert ws.spill_rebuilds == 1


def test_spill_stale_crc_detected(spilled, tmp_path):
    """An array whose companion crc was not updated (torn in-place
    rewrite) must fail integrity, not load silently."""
    from repro.core.schedule import SpillCorruptError, load_epoch_npz, \
        spill_path

    path = spill_path(str(tmp_path), 0, 0)
    spilled.epoch(0)
    with np.load(path) as z:
        arrs = {k: z[k] for k in z.files}
    arrs["seeds"] = arrs["seeds"].copy()
    arrs["seeds"][0] += 1                   # payload edited, crc stale
    np.savez(path, **arrs)
    with pytest.raises(SpillCorruptError, match="seeds"):
        load_epoch_npz(path)


def test_spill_corruption_without_builder_raises(spilled, tmp_path):
    from repro.core.schedule import SpillCorruptError, spill_path

    ws = dataclasses.replace(spilled, builder=None)
    _damage(spill_path(str(tmp_path), 0, 0), "truncate")
    with pytest.raises(SpillCorruptError):
        ws.epoch(0)


# ---- checkpoint atomicity ------------------------------------------------

def _tree():
    return {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.linspace(-1, 1, 4).astype(np.float32)}


def test_checkpoint_round_trip_and_typed_corruption(tmp_path):
    from repro.train import (CheckpointCorruptError, checkpoint_step,
                             load_checkpoint, save_checkpoint)

    d = str(tmp_path / "ck")
    tree = _tree()
    save_checkpoint(d, tree, step=5)
    assert checkpoint_step(d) == 5
    like = {k: np.zeros_like(v) for k, v in tree.items()}
    out = load_checkpoint(d, like, expect_step=5)
    for k in tree:
        np.testing.assert_array_equal(out[k], tree[k])

    with pytest.raises(CheckpointCorruptError, match="step mismatch"):
        load_checkpoint(d, like, expect_step=6)
    with pytest.raises(CheckpointCorruptError, match="leaf set"):
        load_checkpoint(d, {**like, "extra": np.zeros(2)})
    with pytest.raises(CheckpointCorruptError, match="shape mismatch"):
        load_checkpoint(d, {"w": np.zeros((2, 2), np.float32),
                            "b": like["b"]})
    os.truncate(os.path.join(d, "arrays.npz"),
                os.path.getsize(os.path.join(d, "arrays.npz")) // 2)
    with pytest.raises(CheckpointCorruptError, match="torn"):
        load_checkpoint(d, like)
    with pytest.raises(CheckpointCorruptError, match="manifest"):
        load_checkpoint(str(tmp_path / "nowhere"), like)


def test_run_state_latest_pointer_and_crash_atomicity(tmp_path):
    from repro.train import (latest_step, load_run_state, save_run_state)

    root = str(tmp_path)
    assert latest_step(root) is None
    tree = _tree()
    save_run_state(root, tree, step=1)
    save_run_state(root, {k: v + 1 for k, v in tree.items()}, step=2)
    assert latest_step(root) == 2
    like = {k: np.zeros_like(v) for k, v in tree.items()}
    out, step = load_run_state(root, like)
    assert step == 2
    np.testing.assert_array_equal(out["w"], tree["w"] + 1)

    # crash INSIDE the step-3 commit (between arrays and manifest):
    # LATEST must keep naming step 2, which must restore bit-intact
    with pytest.raises(InjectedCrash):
        with active_plan(FaultPlan(
                0, (FaultRule("checkpoint", "crash", epochs=(3,)),))):
            save_run_state(root, tree, step=3)
    assert latest_step(root) == 2
    out, step = load_run_state(root, like)
    assert step == 2
    np.testing.assert_array_equal(out["w"], tree["w"] + 1)


def test_chaos_checkpoint_drill_passes():
    from repro.fault.chaos import _checkpoint_drill

    msgs = []
    assert _checkpoint_drill(msgs.append)
    assert msgs == []


# ---- prefetch supervision surfaces typed + names the thread --------------

def test_prefetch_hang_stall_and_join_name():
    from repro.core import NetworkModel, ShardedFeatureStore, \
        build_schedule
    from repro.core.cache import DoubleBufferCache
    from repro.core.metrics import EpochMetrics
    from repro.core.prefetch import PrefetchStall, Prefetcher
    from repro.core.schedule import epoch_edge_maxima
    from repro.graph import KHopSampler, load_dataset, partition_graph

    g = load_dataset("tiny")
    pg = partition_graph(g, 2, "greedy")
    sampler = KHopSampler(g, fanouts=[5, 5], batch_size=32)
    ws = build_schedule(sampler, pg, worker=0, s0=7, num_epochs=1,
                        n_hot=64)
    es = ws.epoch(0)
    store = ShardedFeatureStore(pg, worker=0,
                                net=NetworkModel(enabled=False))
    plan = FaultPlan(0, (FaultRule("prefetch", "hang", indices=(0,),
                                   delay_s=0.6),))
    pf = Prefetcher(es, store, DoubleBufferCache(store.d),
                    g.labels, 32, es.m_max, epoch_edge_maxima(es),
                    Q=2, metrics=EpochMetrics(epoch=0))
    with active_plan(plan):
        pf.start()
        try:
            with pytest.raises(PrefetchStall, match="prefetch-w0-e0"):
                pf.get(timeout=0.05)        # producer asleep -> stall
            with pytest.raises(TimeoutError, match="prefetch-w0-e0"):
                pf.join(timeout=0.05)       # bounded join names it too
        finally:
            pf.close(timeout=5.0)           # thread wakes and exits


# ---- host runtime: per-profile fire + bit-exact recovery -----------------

_CH = None
_ORACLE = None


def _chaos():
    """Module-lazy shared scenario (jit compile once); fresh spill dir
    per run, so file damage never leaks across tests."""
    global _CH, _ORACLE
    if _CH is None:
        from repro.fault.chaos import _Chaos
        _CH = _Chaos()
        _ORACLE = _CH.run(None)
    return _CH, _ORACLE


@pytest.mark.parametrize("profile", ["pull-flaky", "prefetch-flaky",
                                     "csec-loss", "spill-rot",
                                     "spill-trunc", "spill-gone"])
def test_host_profile_fires_and_recovers_bit_exact(profile):
    ch, oracle = _chaos()
    plan = plan_from_profile(profile, seed=4)
    losses = ch.run(plan)
    assert plan.total_fires() >= 1, f"{profile} never fired"
    np.testing.assert_array_equal(
        losses, oracle,
        err_msg=f"{profile} recovery broke loss bit-equality")


def test_host_prefetch_stall_falls_back_bit_exact():
    """A producer hang past the stall deadline: the trainer rebuilds the
    batch on the critical path -- counted, and still bit-equal."""
    ch, oracle = _chaos()
    plan = plan_from_profile("prefetch-hang", seed=4)
    losses = ch.run(plan, stall_timeout_s=0.05)
    assert plan.fires("prefetch", "hang") >= 1
    np.testing.assert_array_equal(losses, oracle)


def test_host_persistent_faults_surface_typed():
    from repro.core.prefetch import PrefetchWorkerError

    ch, _ = _chaos()
    # pull-dead exhausts sync_pull's retry budget INSIDE the prefetch
    # thread, whose own supervision wraps it typed; the injected fault
    # rides the cause chain
    with pytest.raises(PrefetchWorkerError) as ei:
        ch.run(plan_from_profile("pull-dead", seed=4))
    cause = ei.value.__cause__
    while cause is not None and not isinstance(cause, InjectedFault):
        cause = cause.__cause__
    assert isinstance(cause, InjectedFault)
    with pytest.raises(PrefetchWorkerError):
        ch.run(plan_from_profile("prefetch-fatal", seed=4))


# ---- chaos property: any seeded plan is bit-equal-or-typed ---------------

@settings(max_examples=4, deadline=None,
          suppress_health_check=ALL_HEALTH_CHECKS)
@given(st.integers(min_value=0, max_value=2**16),
       st.integers(min_value=0, max_value=7))
def test_random_plans_recover_bit_exact_or_raise_typed(seed, i):
    from repro.fault.chaos import _allowed_errors

    ch, oracle = _chaos()
    plan = random_plan(seed, i)
    try:
        losses = ch.run(plan)
    except _allowed_errors():
        return                              # typed surface is a pass
    assert losses.shape == oracle.shape
    np.testing.assert_array_equal(
        losses, oracle,
        err_msg=f"plan {plan.name} (seed={seed}) silently diverged")
